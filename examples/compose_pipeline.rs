//! Pipeline-spec composition demo: build pipelines that exist in **no**
//! registry — from a spec string and from the typed builder — round-trip
//! them, and show that the stream header carries the canonical spec so the
//! artifact is fully self-describing.
//!
//! Run: `cargo run --release --example compose_pipeline`

use sz3::data::Field;
use sz3::pipeline::spec::{EncSpec, PipelineBuilder};
use sz3::pipeline::{build, canonical, decompress_any, CompressConf, ErrorBound};
use sz3::util::rng::Pcg32;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dims = [48usize, 32, 32];
    let mut rng = Pcg32::seeded(11);
    let field =
        Field::f32("demo", &dims, sz3::util::prop::smooth_field(&mut rng, &dims))?;
    let eb = 1e-3;
    let conf = CompressConf::new(ErrorBound::Abs(eb));

    // 1. a spec string: the SZ3-LR stage stack but with the from-scratch
    //    lzhuf lossless backend — a composition no registry name offers
    let spec = "block(lorenzo+regression)/linear/huffman/lzhuf";
    let c = build(spec)?;
    let stream = c.compress(&field, &conf)?;
    let header = sz3::pipeline::peek_header(&stream)?;
    assert_eq!(header.pipeline, canonical(spec)?, "header is the canonical spec");
    let out = decompress_any(&stream)?;
    assert_eq!(out.shape.dims(), field.shape.dims());
    println!(
        "1. '{spec}'\n   header='{}' ratio {:.2}",
        header.pipeline,
        field.nbytes() as f64 / stream.len() as f64
    );

    // 2. the typed builder: linearized 2nd-order Lorenzo with arithmetic
    //    coding and no lossless stage
    let spec = PipelineBuilder::lorenzo(2)
        .preprocess(sz3::pipeline::spec::PreSpec::Linearize)
        .radius(512)
        .encoder(EncSpec::Arithmetic)
        .lossless("bypass")
        .finish()?;
    let c = spec.build()?;
    let stream2 = c.compress(&field, &conf)?;
    let out = decompress_any(&stream2)?;
    assert_eq!(out.shape.dims(), field.shape.dims());
    println!(
        "2. builder -> '{}' ratio {:.2}",
        spec.canonical(),
        field.nbytes() as f64 / stream2.len() as f64
    );

    // both compositions honor the bound like any registry pipeline
    for (label, restored) in
        [("spec", decompress_any(&stream)?), ("builder", decompress_any(&stream2)?)]
    {
        let worst = field
            .values
            .to_f64_vec()
            .iter()
            .zip(restored.values.to_f64_vec())
            .map(|(o, d)| (o - d).abs())
            .fold(0.0f64, f64::max);
        assert!(worst <= eb * (1.0 + 1e-12), "{label}: {worst} > {eb}");
        println!("   {label}: worst |err| {worst:.3e} <= {eb:.0e}");
    }

    println!("\ncomposed pipelines are self-describing — no registry required.");
    Ok(())
}
