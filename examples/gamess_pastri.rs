//! GAMESS scenario (paper §4): compress ERI-like streams with the three
//! PaSTRI variants and print the Table 1 rows (ratio + compression speed)
//! plus the Fig. 3 unpredictable-rate characterization.
//!
//! Run: `cargo run --release --example gamess_pastri`

use std::time::Instant;
use sz3::datagen::gamess;
use sz3::pipeline::{decompress_any, CompressConf, Compressor, ErrorBound, PastriCompressor};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let eb = 1e-10; // the domain scientists' requirement (Table 1)
    let n = 1 << 21; // ~16 MB per field (f64)
    println!("GAMESS ERI-like data, absolute error bound {eb:.0e}, {n} doubles/field\n");
    println!(
        "{:<8} {:<18} {:>8} {:>14} {:>12}",
        "field", "compressor", "ratio", "comp MB/s", "unpred %"
    );
    for field in gamess::gamess_dataset(n, 42) {
        let variants: Vec<PastriCompressor> = vec![
            PastriCompressor::sz(),
            PastriCompressor::sz_with_zstd(),
            PastriCompressor::sz3(),
        ];
        for c in variants {
            let conf = CompressConf::with_radius(ErrorBound::Abs(eb), 64);
            let t0 = Instant::now();
            let (stream, [data_idx, _, _]) = c.compress_instrumented(&field, &conf)?;
            let dt = t0.elapsed();
            let ratio = field.nbytes() as f64 / stream.len() as f64;
            let mbs = field.nbytes() as f64 / 1e6 / dt.as_secs_f64();
            let unpred =
                100.0 * data_idx.iter().filter(|&&i| i == 0).count() as f64 / data_idx.len() as f64;
            println!(
                "{:<8} {:<18} {:>8.2} {:>14.1} {:>11.1}%",
                field.name,
                c.name(),
                ratio,
                mbs,
                unpred
            );
            // verify the bound end to end
            let out = decompress_any(&stream)?;
            for (o, d) in field.values.to_f64_vec().iter().zip(out.values.to_f64_vec()) {
                assert!((o - d).abs() <= eb * (1.0 + 1e-9), "bound violated");
            }
        }
        println!();
    }
    println!("(expect the Table 1 ordering: sz3-pastri > sz-pastri-zstd > sz-pastri in ratio,\n reversed in speed — the unpred-aware quantizer + lossless stage trade speed for ratio)");
    Ok(())
}
