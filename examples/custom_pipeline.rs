//! Composability demo (the paper's core claim): build a *new* compressor
//! from modules without touching the framework —
//!   1. a pointwise-relative-bound codec by composing the log-transform
//!      preprocessor with a standard pipeline (paper [20]);
//!   2. a feature-preserving codec via the element-wise quantizer (cpSZ
//!      [21]) with tight bounds in a region of interest;
//!   3. a brand-new user-defined predictor plugged into the statically
//!      composed `StaticSzCompressor` (Appendix A.6 template polymorphism).
//!
//! Run: `cargo run --release --example custom_pipeline`

use sz3::data::{Field, NdCursor, Scalar, Shape};
use sz3::encoder::HuffmanEncoder;
use sz3::lossless::ZstdLossless;
use sz3::pipeline::point::StaticSzCompressor;
use sz3::pipeline::{CompressConf, Compressor, ErrorBound};
use sz3::predictor::Predictor;
use sz3::preprocessor::{LogTransform, Preprocessor};
use sz3::quantizer::{BoundsMap, ElementwiseQuantizer, LinearQuantizer};
use sz3::util::rng::Pcg32;

/// A user-defined predictor: average of the two straddling neighbors along
/// the last axis (a "smoothing" predictor none of the built-ins provide).
struct NeighborMean;

impl<T: Scalar> Predictor<T> for NeighborMean {
    fn name(&self) -> &'static str {
        "neighbor-mean"
    }
    fn predict(&self, c: &NdCursor<T>) -> f64 {
        let nd = c.ndim();
        let mut off = vec![0isize; nd];
        off[nd - 1] = -1;
        let a = c.neighbor_f64(&off);
        off[nd - 1] = -2;
        let b = c.neighbor_f64(&off);
        1.5 * a - 0.5 * b // linear extrapolation from the last two points
    }
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut rng = Pcg32::seeded(3);

    // ---------- 1. pointwise-relative bound via log transform ----------
    let n = 65536;
    let vals: Vec<f64> = (0..n)
        .map(|i| {
            let t = i as f64 / 500.0;
            (t.sin() + 1.2) * 10f64.powf(3.0 * (t * 0.1).cos()) // 6 decades
        })
        .collect();
    let mut field = Field::f64("wide-range", &[n], vals.clone())?;
    let rel = 1e-3;
    let mut conf = CompressConf::new(ErrorBound::PwRel(rel));
    let log = LogTransform::default();
    let state = log.process(&mut field, &mut conf)?;
    let inner = sz3::pipeline::build("lorenzo-1d").unwrap();
    let stream = inner.compress(&field, &conf)?;
    let mut restored = sz3::pipeline::decompress_any(&stream)?;
    log.postprocess(&mut restored, &state)?;
    let worst_rel = vals
        .iter()
        .zip(restored.values.to_f64_vec())
        .map(|(o, d)| (d / o - 1.0).abs())
        .fold(0.0f64, f64::max);
    println!(
        "1. log-transform + lorenzo-1d: pointwise relative bound {rel:.0e}, worst {:.3e}, ratio {:.2}",
        worst_rel,
        (n * 8) as f64 / stream.len() as f64
    );
    assert!(worst_rel <= rel * (1.0 + 1e-9));

    // ---------- 2. feature-preserving element-wise bounds ----------
    let m = 32768;
    let data: Vec<f64> = (0..m).map(|i| (i as f64 * 0.01).sin() * 10.0).collect();
    // region of interest (a "critical feature") gets a 1000x tighter bound
    let roi = 8000..9000;
    let map = BoundsMap {
        segments: vec![(8000, 1e-2), (1000, 1e-5), (m - 9000, 1e-2)],
    };
    let q = ElementwiseQuantizer::<f64>::new(map, 32768);
    let mut buf = data.clone();
    let shape = Shape::new(&[m])?;
    let mut compressor = StaticSzCompressor::new(
        sz3::predictor::LorenzoPredictor::new(1),
        q,
        HuffmanEncoder::new(),
        ZstdLossless::default(),
    );
    let stream2 = compressor.compress(&mut buf, &shape)?;
    let out = compressor.decompress(&stream2, &shape)?;
    let mut worst_roi = 0.0f64;
    let mut worst_rest = 0.0f64;
    for (i, (o, d)) in data.iter().zip(&out).enumerate() {
        let e = (o - d).abs();
        if roi.contains(&i) {
            worst_roi = worst_roi.max(e);
        } else {
            worst_rest = worst_rest.max(e);
        }
    }
    println!(
        "2. element-wise quantizer: ROI err {worst_roi:.2e} (<=1e-5), elsewhere {worst_rest:.2e} (<=1e-2), ratio {:.2}",
        (m * 8) as f64 / stream2.len() as f64
    );
    assert!(worst_roi <= 1e-5 * (1.0 + 1e-9) && worst_rest <= 1e-2 * (1.0 + 1e-9));

    // ---------- 3. user-defined predictor in a static composition ----------
    let k = 1 << 16;
    let series: Vec<f32> = (0..k)
        .map(|i| {
            let t = i as f32 * 2e-4;
            t * 100.0 + (t * 30.0).sin() * 3.0 + rng.normal() as f32 * 0.01
        })
        .collect();
    let shape = Shape::new(&[k])?;
    let mut custom = StaticSzCompressor::new(
        NeighborMean,
        LinearQuantizer::<f32>::new(1e-3),
        HuffmanEncoder::new(),
        ZstdLossless::default(),
    );
    let mut buf = series.clone();
    let stream3 = custom.compress(&mut buf, &shape)?;
    let out = custom.decompress(&stream3, &shape)?;
    let worst = series
        .iter()
        .zip(&out)
        .map(|(o, d)| (o - d).abs())
        .fold(0.0f32, f32::max);
    println!(
        "3. custom '{}' predictor: abs bound 1e-3, worst {worst:.3e}, ratio {:.2}",
        Predictor::<f32>::name(&NeighborMean),
        (k * 4) as f64 / stream3.len() as f64
    );
    assert!(worst as f64 <= 1e-3 * (1.0 + 1e-9));

    println!("\nall three custom compositions respect their bounds — modules compose.");
    Ok(())
}
