//! Indexed-seek region extraction from a chunked container.
//!
//! A simulated multi-field snapshot is compressed into one `SZ3C` v2
//! artifact on disk; a `ContainerReader` over a seekable file source then
//! serves a small region of interest, decoding only the chunks that
//! overlap it — the artifact is never fully loaded, every fetched chunk is
//! CRC-checked, and a second query hits the warm-chunk LRU cache. The
//! result is verified bit-identical to slicing a full decompression.
//!
//! Run: `cargo run --release --example reader_roi`

use sz3::config::JobConfig;
use sz3::container;
use sz3::coordinator::{slice_rows, Coordinator};
use sz3::data::Field;
use sz3::pipeline::ErrorBound;
use sz3::reader::ContainerReader;
use sz3::util::prop;
use sz3::util::rng::Pcg32;

fn main() {
    // -- a 2-field snapshot, sharded into 8-row chunks ---------------------
    let (nz, ny, nx) = (64usize, 32, 32);
    let mut rng = Pcg32::seeded(7);
    let fields: Vec<Field> = ["density", "velocity_x"]
        .iter()
        .map(|name| {
            let dims = [nz, ny, nx];
            Field::f32(*name, &dims, prop::smooth_field(&mut rng, &dims)).unwrap()
        })
        .collect();
    let cfg = JobConfig {
        pipeline: "sz3-lr".into(),
        bound: ErrorBound::Abs(1e-3),
        workers: 4,
        chunk_elems: ny * nx * 8,
        queue_depth: 4,
        ..Default::default()
    };
    let coord = Coordinator::from_config(&cfg).unwrap();
    let (artifact, report) = coord.run_to_container(fields).unwrap();
    println!("compressed: {report}");

    let path = std::env::temp_dir().join(format!("sz3_example_roi_{}.sz3c", std::process::id()));
    std::fs::write(&path, &artifact).unwrap();
    println!("artifact: {} bytes at {}", artifact.len(), path.display());

    // -- open for random access: only the index is read --------------------
    let reader = ContainerReader::open_path(&path)
        .unwrap()
        .with_workers(4)
        .with_cache_bytes(64 << 20);
    println!(
        "opened v{} container: fields {:?}, {} chunks, {} bytes fetched so far",
        reader.version(),
        reader.field_names(),
        reader.index().entries.len(),
        reader.stats().bytes_fetched
    );

    // -- region of interest: rows 20..29 of one field ----------------------
    let roi = 20..29;
    let region = reader.read_region("density", roi.clone()).unwrap();
    let s = reader.stats();
    println!(
        "ROI density[{}..{}]: {:?}, decoded {} of {} chunks, fetched {} of {} bytes ({} crc-checked)",
        roi.start,
        roi.end,
        region.shape.dims(),
        s.chunks_decoded,
        reader.field_chunks("density").unwrap(),
        s.bytes_fetched,
        artifact.len(),
        s.crc_verified
    );
    assert!(
        s.bytes_fetched < artifact.len() as u64 / 2,
        "ROI read should fetch a fraction of the artifact"
    );

    // -- verify: bit-identical to slicing the full decompression -----------
    let full = container::decompress_container(&artifact, 4).unwrap();
    let dense = full.iter().find(|f| f.name == "density").unwrap();
    let expect = slice_rows(dense, (roi.start, roi.end)).unwrap();
    assert_eq!(region.values, expect.values, "ROI must match the sliced full decode");
    println!("verified: ROI bit-identical to sliced full decompression");

    // -- the serve-path steady state: warm cache ----------------------------
    let before = reader.stats();
    reader.read_region("density", roi).unwrap();
    let after = reader.stats();
    println!(
        "warm re-read: +{} decodes, +{} cache hits",
        after.chunks_decoded - before.chunks_decoded,
        after.cache_hits - before.cache_hits
    );
    assert_eq!(after.chunks_decoded, before.chunks_decoded, "warm read decodes nothing");

    let _ = std::fs::remove_file(&path);
}
