//! Golden-fixture generator: materialize the deterministic format-
//! compatibility corpus (`sz3::container::fixtures::golden_set`) under
//! `rust/tests/fixtures/`, one `.sz3c` artifact per container version
//! plus the expected decoded bytes of every `(snapshot, field)`.
//!
//! Run after any intentional format change, review the diff, and commit
//! the result — the compat suite (`cargo test --test compat`) then locks
//! decoding of the committed artifacts bit-for-bit. Re-running on an
//! unchanged tree must be a no-op (the corpus is fully seeded).
//!
//! ```text
//! cargo run --release --example gen_fixtures
//! ```

use std::path::PathBuf;

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures");
    std::fs::create_dir_all(&dir).expect("create fixtures dir");
    let set = sz3::container::fixtures::golden_set().expect("build corpus");
    for fx in &set {
        let path = dir.join(fx.artifact_file());
        let existed = path.exists()
            && std::fs::read(&path).map(|old| old == fx.artifact).unwrap_or(false);
        std::fs::write(&path, &fx.artifact).expect("write artifact");
        println!(
            "{} ({} bytes){}",
            path.display(),
            fx.artifact.len(),
            if existed { " [unchanged]" } else { "" }
        );
        for (snapshot, field, bytes) in &fx.expected {
            let path = dir.join(fx.expected_file(*snapshot, field));
            std::fs::write(&path, bytes).expect("write expected decode");
            println!("{} ({} bytes)", path.display(), bytes.len());
        }
    }
    println!("{} fixtures written to {}", set.len(), dir.display());
}
