//! Chunked container + per-chunk adaptive pipeline selection (the paper's
//! best-fit composition claim at chunk granularity): build a field whose
//! regions have very different character, stream it through the
//! coordinator with adaptive selection, inspect the `SZ3C` chunk index to
//! see each region pick its own pipeline, decompress in parallel through
//! the common `decompress_any` entry point, and verify the error bound on
//! every element.
//!
//! Run: `cargo run --release --example container_adaptive`

use sz3::config::JobConfig;
use sz3::container;
use sz3::coordinator::Coordinator;
use sz3::data::Field;
use sz3::pipeline::{decompress_any, ErrorBound};
use sz3::util::rng::Pcg32;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // A "campaign snapshot" with three regimes stacked along the slow axis:
    // smooth flow, a steep-but-linear gradient, and detector-like noise.
    let (nz, ny, nx) = (48usize, 32, 32);
    let mut rng = Pcg32::seeded(7);
    let mut vals = Vec::with_capacity(nz * ny * nx);
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let v = if z < nz / 3 {
                    // smooth: low-frequency waves
                    (0.6 * (z as f64 * 0.21).sin()
                        + 0.5 * (y as f64 * 0.13).cos()
                        + 0.4 * (x as f64 * 0.09).sin()) as f32
                } else if z < 2 * nz / 3 {
                    // linear ramp + small noise (regression territory)
                    (0.8 * z as f64 - 0.5 * y as f64 + 0.25 * x as f64
                        + rng.normal() * 0.02) as f32
                } else {
                    // unpredictable: white noise over a wide range
                    rng.uniform(-400.0, 400.0) as f32
                };
                vals.push(v);
            }
        }
    }
    let field = Field::f32("campaign", &[nz, ny, nx], vals)?;

    let eb = 0.2;
    let cfg = JobConfig {
        pipeline: "sz3-lr".into(),
        bound: ErrorBound::Abs(eb),
        workers: 4,
        chunk_elems: ny * nx * 8, // 8 rows per chunk -> 6 chunks
        queue_depth: 4,
        adaptive: true,
        ..Default::default()
    };
    let coord = Coordinator::from_config(&cfg)?;
    let (artifact, report) = coord.run_to_container(vec![field.clone()])?;
    println!("compress : {report}");
    println!(
        "artifact : {} bytes (ratio {:.2} incl. index)",
        artifact.len(),
        field.nbytes() as f64 / artifact.len() as f64
    );

    // The chunk index is the paper's selection decision, made durable.
    let (index, _) = container::read_index(&artifact)?;
    println!("\nchunk index (per-chunk best-fit selection):");
    for e in &index.entries {
        println!(
            "  rows {:>2}..{:<2} -> {:<16} ({} bytes)",
            e.rows.0, e.rows.1, e.pipeline, e.len
        );
    }
    let mix = index.per_pipeline();
    println!("pipeline mix: {mix:?}");
    assert!(mix.len() >= 2, "regimes should select different pipelines");

    // One entry point for both single streams and containers.
    let restored = decompress_any(&artifact)?;
    assert_eq!(restored.shape.dims(), field.shape.dims());
    let worst = field
        .values
        .to_f64_vec()
        .iter()
        .zip(restored.values.to_f64_vec())
        .map(|(o, d)| (o - d).abs())
        .fold(0.0f64, f64::max);
    println!("\nbound check: max|err| {worst:.3e} <= {eb:.1e}");
    assert!(worst <= eb * (1.0 + 1e-12));
    println!("OK — every chunk within the bound through its own pipeline.");
    Ok(())
}
