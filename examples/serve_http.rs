//! End-to-end HTTP serving walkthrough: compress a two-field snapshot
//! into an `SZ3C` artifact, publish it with the in-process server
//! (`sz3 serve-http`'s engine), then act as a remote client — list the
//! catalog, read metadata, pull a region of interest, fetch a raw
//! compressed chunk and decode it locally, and finally check `/statsz`
//! to see the shared byte-budgeted cache doing its job.
//!
//! Run: `cargo run --release --example serve_http`

use sz3::config::{JobConfig, Json};
use sz3::coordinator::Coordinator;
use sz3::data::Field;
use sz3::pipeline::{self, ErrorBound};
use sz3::server::{self, ArtifactStore, HttpClient, StoreOptions};
use sz3::util::prop;
use sz3::util::rng::Pcg32;

fn main() {
    // -- produce an artifact the way `sz3 compress --container` would ------
    let dims = [48usize, 32, 32];
    let mut rng = Pcg32::seeded(99);
    let fields = vec![
        Field::f32("density", &dims, prop::smooth_field(&mut rng, &dims)).unwrap(),
        Field::f32("energy", &dims, prop::smooth_field(&mut rng, &dims)).unwrap(),
    ];
    let cfg = JobConfig {
        pipeline: "sz3-lr".into(),
        bound: ErrorBound::Abs(1e-3),
        workers: 4,
        chunk_elems: 32 * 32 * 6, // 6 rows per chunk -> 8 chunks per field
        queue_depth: 4,
        ..Default::default()
    };
    let coord = Coordinator::from_config(&cfg).unwrap();
    let (artifact, report) = coord.run_to_container(fields).unwrap();
    println!("compressed: {report}");

    let dir = std::env::temp_dir().join(format!("sz3_example_http_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("snapshot.sz3c"), &artifact).unwrap();

    // -- publish: artifacts open once, CRC-verified, behind one cache ------
    let store = ArtifactStore::open_dir(
        &dir,
        &StoreOptions { cache_bytes: 32 << 20, workers: 4, verify: true },
    )
    .unwrap();
    let handle = server::serve(store, "127.0.0.1:0", 2).unwrap();
    let addr = handle.addr();
    println!("serving on http://{addr}");

    {
        let mut client = HttpClient::connect(addr).unwrap();

        // -- list the catalog ----------------------------------------------
        let resp = client.get("/v1/artifacts").unwrap();
        println!("GET /v1/artifacts -> {} {}", resp.status, resp.text().unwrap());

        // -- metadata: dims, dtype, chunk map ------------------------------
        let resp = client.get("/v1/artifacts/snapshot").unwrap();
        let meta = Json::parse(resp.text().unwrap()).unwrap();
        let f0 = &meta.get("fields").unwrap().as_arr().unwrap()[0];
        let f0_dims: Vec<usize> = f0
            .get("dims")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        println!(
            "GET /v1/artifacts/snapshot -> field '{}' dims {:?} in {} chunks",
            f0.get("name").unwrap().as_str().unwrap(),
            f0_dims,
            f0.get("chunks").unwrap().as_usize().unwrap()
        );

        // -- region of interest: rows 10..22 of one field ------------------
        let resp = client
            .get("/v1/artifacts/snapshot/fields/density?rows=10..22")
            .unwrap();
        println!(
            "GET .../fields/density?rows=10..22 -> {} bytes, dims [{}], dtype {}",
            resp.body.len(),
            resp.header("x-sz3-dims").unwrap(),
            resp.header("x-sz3-dtype").unwrap()
        );
        assert_eq!(resp.body.len(), 12 * 32 * 32 * 4);

        // a second, overlapping read comes from the warm cache
        let resp2 = client
            .get("/v1/artifacts/snapshot/fields/density?rows=12..18")
            .unwrap();
        assert_eq!(resp2.status, 200);

        // -- raw chunk passthrough: decode client-side ---------------------
        let resp = client.get("/v1/artifacts/snapshot/raw?chunk=0").unwrap();
        let chunk = pipeline::decompress_any(&resp.body).unwrap();
        println!(
            "GET .../raw?chunk=0 -> {} compressed bytes via {}, decoded locally to {:?}",
            resp.body.len(),
            resp.header("x-sz3-pipeline").unwrap(),
            chunk.shape.dims()
        );

        // -- observability --------------------------------------------------
        let resp = client.get("/statsz").unwrap();
        let stats = Json::parse(resp.text().unwrap()).unwrap();
        let snap = stats.get("artifacts").unwrap().get("snapshot").unwrap();
        println!(
            "GET /statsz -> decoded {} chunks, {} cache hits, cache holds {} bytes",
            snap.get("chunks_decoded").unwrap().as_usize().unwrap(),
            snap.get("cache_hits").unwrap().as_usize().unwrap(),
            stats.get("cache").unwrap().get("bytes").unwrap().as_usize().unwrap()
        );
        assert!(
            snap.get("cache_hits").unwrap().as_usize().unwrap() >= 1,
            "overlapping reads must hit the warm cache"
        );
    }

    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
    println!("done: server drained and shut down cleanly");
}
