//! Quickstart: compress a field with a registry pipeline, decompress, and
//! verify the error bound.
//!
//! Run: `cargo run --release --example quickstart`

use sz3::data::Field;
use sz3::metrics;
use sz3::pipeline::{build, decompress_any, CompressConf, ErrorBound};
use sz3::util::rng::Pcg32;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // A smooth 3-D field (stand-in for one simulation snapshot variable).
    let dims = [64usize, 64, 64];
    let mut rng = Pcg32::seeded(7);
    let values = sz3::util::prop::smooth_field(&mut rng, &dims);
    let field = Field::f32("demo", &dims, values)?;

    // Pick a pipeline from the registry and an error bound.
    let pipeline = build("sz3-interp").expect("registered pipeline");
    let conf = CompressConf::new(ErrorBound::Rel(1e-4));

    let stream = pipeline.compress(&field, &conf)?;
    let restored = decompress_any(&stream)?;

    let m = metrics::evaluate(&field, &restored, stream.len());
    println!("pipeline      : {}", pipeline.name());
    println!("original      : {} bytes {:?}", field.nbytes(), field.shape.dims());
    println!("compressed    : {} bytes", stream.len());
    println!("metrics       : {m}");

    // The headline guarantee: every point within the absolute bound.
    let abs = ErrorBound::Rel(1e-4).to_abs(&field)?;
    let worst = field
        .values
        .to_f64_vec()
        .iter()
        .zip(restored.values.to_f64_vec())
        .map(|(o, d)| (o - d).abs())
        .fold(0.0f64, f64::max);
    assert!(worst <= abs * (1.0 + 1e-12));
    println!("bound check   : max|err| {worst:.3e} <= {abs:.3e}  OK");
    Ok(())
}
