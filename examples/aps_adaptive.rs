//! APS scenario (paper §5): compress ptychography-like stacks with the
//! adaptive SZ3-APS pipeline vs the fixed baselines, across the error-bound
//! switch point. Near-lossless integer counts decompress exactly (the
//! paper's infinite-PSNR case).
//!
//! Run: `cargo run --release --example aps_adaptive`

use sz3::datagen::aps::{diffraction_stack, Sample};
use sz3::metrics;
use sz3::pipeline::{self, decompress_any, CompressConf, ErrorBound};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    for sample in [Sample::ChipPillar, Sample::FlatChip] {
        let field = diffraction_stack(sample, 96, 48, 48, 42);
        println!(
            "== {} ({:?}, {:.1} MB) ==",
            field.name,
            field.shape.dims(),
            field.nbytes() as f64 / 1e6
        );
        println!(
            "{:<16} {:>8} {:>10} {:>10} {:>8}",
            "pipeline", "abs eb", "ratio", "psnr", "mode"
        );
        for eb in [0.1, 0.4, 2.0, 8.0] {
            for name in ["sz3-aps", "sz3-lr", "lorenzo-1d"] {
                let c = pipeline::build(name).unwrap();
                let conf = CompressConf::new(ErrorBound::Abs(eb));
                let stream = c.compress(&field, &conf)?;
                let out = decompress_any(&stream)?;
                let m = metrics::evaluate(&field, &out, stream.len());
                let mode = if name == "sz3-aps" {
                    if eb < 0.5 {
                        "1d-time"
                    } else {
                        "3d-block"
                    }
                } else {
                    "-"
                };
                println!(
                    "{:<16} {:>8.1} {:>10.2} {:>10.2} {:>8}",
                    name, eb, m.ratio, m.psnr, mode
                );
            }
        }
        println!();
    }
    println!("(expect: sz3-aps tracks the best baseline in each regime — the §5.3 claim;\n at eb<0.5 PSNR=inf because integer counts recover exactly)");
    Ok(())
}
