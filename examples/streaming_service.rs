//! END-TO-END DRIVER: stream a realistic multi-field scientific workload
//! through the full stack — datagen → coordinator (sharding, bounded-queue
//! backpressure, worker pool) → SZ3-LR with PJRT-backed block analysis when
//! `artifacts/` is present → decompress → verify the error bound on every
//! element — and report the headline metrics (ratio, PSNR, throughput).
//!
//! This is the run recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `make artifacts && cargo run --release --example streaming_service`

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;
use sz3::config::JobConfig;
use sz3::coordinator::{reassemble, CompressedChunk, Coordinator};
use sz3::metrics;
use sz3::pipeline::{self, ErrorBound};
use sz3::runtime::{PjrtAnalyzer, PjrtEngine, PjrtService};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let rel_eb = 1e-3;
    let cfg = JobConfig {
        pipeline: "sz3-lr".into(),
        bound: ErrorBound::Rel(rel_eb),
        ..Default::default()
    };
    let mut coord = Coordinator::from_config(&cfg)?;

    // PJRT-backed analysis when artifacts exist (the three-layer path).
    let dir = PjrtEngine::default_dir();
    let mut backend = "native";
    if PjrtEngine::available(&dir) {
        let service = PjrtService::start(&dir)?;
        println!(
            "analysis backend: PJRT ({}) with artifacts for dims {:?}",
            service.platform, service.dims
        );
        backend = "pjrt";
        coord.make_compressor = Arc::new(move || {
            Box::new(
                pipeline::BlockCompressor::sz3_lr()
                    .with_analyzer(Arc::new(PjrtAnalyzer::new(service.clone()))),
            )
        });
    } else {
        println!("analysis backend: native (run `make artifacts` for the PJRT path)");
    }

    // Workload: the full Table 3 survey (8 applications, reduced dims).
    let datasets = sz3::datagen::survey(42);
    let total_bytes: usize = datasets.iter().map(|d| d.nbytes()).sum();
    println!(
        "workload: {} datasets, {} fields, {:.1} MB uncompressed; pipeline={} rel_eb={rel_eb} workers={} queue={}",
        datasets.len(),
        datasets.iter().map(|d| d.fields.len()).sum::<usize>(),
        total_bytes as f64 / 1e6,
        cfg.pipeline,
        cfg.workers,
        cfg.queue_depth,
    );

    let mut grand_in = 0u64;
    let mut grand_out = 0u64;
    let t0 = Instant::now();
    let mut all: Vec<(String, Vec<CompressedChunk>, Vec<sz3::data::Field>)> = Vec::new();
    for ds in datasets {
        let originals = ds.fields.clone();
        let mut chunks: HashMap<String, Vec<CompressedChunk>> = HashMap::new();
        let report = coord.run(ds.fields, |c| {
            chunks.entry(c.field.clone()).or_default().push(c);
        })?;
        println!("  {:<12} {report}", ds.name);
        grand_in += report.bytes_in;
        grand_out += report.bytes_out;
        for f in &originals {
            let field_chunks = chunks.remove(&f.name).expect("chunks for field");
            all.push((ds.name.to_string(), field_chunks, vec![f.clone()]));
        }
    }
    let compress_wall = t0.elapsed();

    // Decompress + verify every element of every field.
    let t1 = Instant::now();
    let mut worst_rel = 0.0f64;
    let mut psnr_min = f64::INFINITY;
    let mut violations = 0usize;
    for (ds_name, chunks, fields) in &all {
        let field = &fields[0];
        let restored = reassemble(chunks)?;
        let stream_len: usize = chunks.iter().map(|c| c.stream.len()).sum();
        let m = metrics::evaluate(field, &restored, stream_len);
        psnr_min = psnr_min.min(m.psnr);
        let (lo, hi) = field.value_range();
        let abs = rel_eb * (hi - lo).max(f64::MIN_POSITIVE);
        let worst = field
            .values
            .to_f64_vec()
            .iter()
            .zip(restored.values.to_f64_vec())
            .map(|(o, d)| (o - d).abs())
            .fold(0.0f64, f64::max);
        if worst > abs * (1.0 + 1e-12) {
            violations += 1;
            eprintln!("BOUND VIOLATION {ds_name}/{}: {worst} > {abs}", field.name);
        }
        worst_rel = worst_rel.max(worst / abs);
    }
    let decompress_wall = t1.elapsed();

    println!("\n=== headline metrics ===");
    println!("analysis backend      : {backend}");
    println!("total                 : {:.1} MB -> {:.1} MB", grand_in as f64 / 1e6, grand_out as f64 / 1e6);
    println!("overall ratio         : {:.2}", grand_in as f64 / grand_out as f64);
    println!("compress throughput   : {:.1} MB/s (wall, incl. generation-side streaming)", grand_in as f64 / 1e6 / compress_wall.as_secs_f64());
    println!("decompress throughput : {:.1} MB/s", grand_in as f64 / 1e6 / decompress_wall.as_secs_f64());
    println!("min field PSNR        : {psnr_min:.1} dB");
    println!("worst err / bound     : {worst_rel:.4} (must be <= 1)");
    println!("bound violations      : {violations}");
    assert_eq!(violations, 0, "error bound must hold everywhere");
    println!("OK — all layers composed; every element within the requested bound.");
    Ok(())
}
