"""Kernel-vs-reference correctness: the CORE numeric signal of the L1 layer.

Pallas kernels (interpret mode) must match the pure-jnp oracle in ref.py,
which in turn must match plain numpy least squares. Hypothesis sweeps
shapes, magnitudes and degenerate inputs.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.analysis import analyze_blocks, TILE
from compile.kernels.quantize import quantize_blocks

RNG = np.random.default_rng(1234)


def random_blocks(batch, shape, scale=1.0, rng=RNG):
    return (rng.standard_normal((batch,) + shape) * scale).astype(np.float32)


# ---------------------------------------------------------------- ref.py ---


class TestReferenceOracle:
    def test_fit_exact_on_planes(self):
        # f(i,j,k) = 2i - 1.5j + 0.25k + 7 must be recovered exactly
        i, j, k = np.meshgrid(np.arange(4), np.arange(5), np.arange(6), indexing="ij")
        plane = (2.0 * i - 1.5 * j + 0.25 * k + 7.0).astype(np.float32)
        coeffs = np.asarray(ref.regression_fit(jnp.asarray(plane[None])))
        np.testing.assert_allclose(coeffs[0], [2.0, -1.5, 0.25, 7.0], atol=1e-4)

    def test_fit_matches_numpy_lstsq(self):
        blocks = random_blocks(8, (6, 6, 6))
        coeffs = np.asarray(ref.regression_fit(jnp.asarray(blocks)))
        # design matrix for one block
        i, j, k = np.meshgrid(np.arange(6), np.arange(6), np.arange(6), indexing="ij")
        A = np.stack([i.ravel(), j.ravel(), k.ravel(), np.ones(216)], axis=1)
        for b in range(8):
            expect, *_ = np.linalg.lstsq(A, blocks[b].ravel(), rcond=None)
            np.testing.assert_allclose(coeffs[b], expect, rtol=2e-3, atol=2e-3)

    def test_lorenzo_zero_on_multilinear(self):
        i, j = np.meshgrid(np.arange(5), np.arange(5), indexing="ij")
        lin = (3.0 * i + 4.0 * j).astype(np.float32)
        pred = np.asarray(ref.lorenzo_pred(jnp.asarray(lin[None])))
        # interior points exact; boundary sees zero padding
        np.testing.assert_allclose(pred[0, 1:, 1:], lin[1:, 1:], atol=1e-4)

    def test_quantize_respects_bound(self):
        blocks = random_blocks(4, (6, 6, 6), scale=10.0)
        coeffs = ref.regression_fit(jnp.asarray(blocks))
        pred = ref.regression_predict(coeffs, (6, 6, 6))
        eb = 0.05
        idx, rec = ref.quantize(jnp.asarray(blocks), pred, eb, 512)
        err = np.abs(np.asarray(rec) - blocks)
        assert err.max() <= eb * (1 + 1e-6)
        # unpredictable entries must be exact
        unpred = np.asarray(idx) == 0
        np.testing.assert_array_equal(np.asarray(rec)[unpred], blocks[unpred])

    @given(
        nd=st.integers(1, 3),
        scale=st.floats(1e-3, 1e3),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=25, deadline=None)
    def test_fit_optimality_property(self, nd, scale, seed):
        # least squares: perturbing any coefficient cannot reduce SSE
        rng = np.random.default_rng(seed)
        shape = {1: (16,), 2: (6, 5), 3: (4, 5, 3)}[nd]
        blocks = random_blocks(2, shape, scale=scale, rng=rng).astype(np.float64)
        coeffs = np.asarray(ref.regression_fit(jnp.asarray(blocks)))
        pred = np.asarray(ref.regression_predict(jnp.asarray(coeffs), shape))
        base = ((blocks - pred) ** 2).sum(axis=tuple(range(1, nd + 1)))
        for d in range(nd + 1):
            for delta in (-1e-3 * scale, 1e-3 * scale):
                c2 = coeffs.copy()
                c2[:, d] += delta
                p2 = np.asarray(ref.regression_predict(jnp.asarray(c2), shape))
                sse2 = ((blocks - p2) ** 2).sum(axis=tuple(range(1, nd + 1)))
                assert (sse2 >= base - 1e-6 * scale * scale).all()


# ------------------------------------------------------- pallas kernels ---


class TestAnalysisKernel:
    @pytest.mark.parametrize("shape", [(128,), (12, 12), (6, 6, 6), (4, 4, 4, 4)])
    def test_matches_ref(self, shape):
        blocks = jnp.asarray(random_blocks(TILE * 2, shape, scale=5.0))
        coeffs, lor, reg = analyze_blocks(blocks)
        ec, el, er = ref.analyze(blocks)
        np.testing.assert_allclose(np.asarray(coeffs), np.asarray(ec), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(lor), np.asarray(el), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(reg), np.asarray(er), rtol=1e-5, atol=1e-5)

    def test_zero_blocks(self):
        blocks = jnp.zeros((TILE, 6, 6, 6), jnp.float32)
        coeffs, lor, reg = analyze_blocks(blocks)
        assert np.allclose(np.asarray(coeffs), 0)
        assert np.allclose(np.asarray(lor), 0)
        assert np.allclose(np.asarray(reg), 0)

    @given(
        scale_exp=st.floats(-4, 4),
        seed=st.integers(0, 2**31 - 1),
        nd=st.integers(1, 3),
    )
    @settings(max_examples=20, deadline=None)
    def test_hypothesis_sweep(self, scale_exp, seed, nd):
        rng = np.random.default_rng(seed)
        shape = {1: (128,), 2: (12, 12), 3: (6, 6, 6)}[nd]
        blocks = jnp.asarray(
            random_blocks(TILE, shape, scale=10.0**scale_exp, rng=rng)
        )
        coeffs, lor, reg = analyze_blocks(blocks)
        ec, el, er = ref.analyze(blocks)
        scale = float(jnp.abs(blocks).max()) + 1e-30
        np.testing.assert_allclose(
            np.asarray(coeffs), np.asarray(ec), rtol=1e-4, atol=1e-5 * scale
        )
        np.testing.assert_allclose(
            np.asarray(lor), np.asarray(el), rtol=1e-4, atol=1e-5 * scale
        )
        np.testing.assert_allclose(
            np.asarray(reg), np.asarray(er), rtol=1e-4, atol=1e-5 * scale
        )


class TestQuantizeKernel:
    @pytest.mark.parametrize("shape", [(12, 12), (6, 6, 6)])
    def test_matches_ref(self, shape):
        blocks = jnp.asarray(random_blocks(TILE, shape, scale=3.0))
        coeffs = ref.regression_fit(blocks)
        eb = jnp.asarray([0.01], jnp.float32)
        idx, rec = quantize_blocks(blocks, coeffs, eb, radius=512)
        pred = ref.regression_predict(coeffs, shape)
        eidx, erec = ref.quantize(blocks, pred, 0.01, 512)
        # f32 summation order differs between the kernel's plane evaluation
        # and ref's; indices may flip on exact bin boundaries (~0 of 36k) and
        # recovered values agree to f32 accuracy.
        idx_np, eidx_np = np.asarray(idx), np.asarray(eidx)
        assert (idx_np != eidx_np).mean() < 1e-3
        np.testing.assert_allclose(np.asarray(rec), np.asarray(erec), rtol=1e-4, atol=1e-6)

    @given(
        eb_exp=st.floats(-5, -1),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=15, deadline=None)
    def test_bound_always_holds(self, eb_exp, seed):
        rng = np.random.default_rng(seed)
        blocks = jnp.asarray(random_blocks(TILE, (6, 6, 6), scale=1.0, rng=rng))
        coeffs = ref.regression_fit(blocks)
        eb = float(10.0**eb_exp)
        idx, rec = quantize_blocks(blocks, coeffs, jnp.asarray([eb], jnp.float32), radius=512)
        err = np.abs(np.asarray(rec) - np.asarray(blocks))
        assert err.max() <= eb * (1 + 1e-5)
        unpred = np.asarray(idx) == 0
        np.testing.assert_array_equal(np.asarray(rec)[unpred], np.asarray(blocks)[unpred])
