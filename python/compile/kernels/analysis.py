"""L1 Pallas kernels: blockwise regression fit + predictor-error estimation.

The compute hot-spot of the SZ3-LR pipeline (paper §6.2): for a batch of
equally-shaped blocks, fit the regression hyperplane, evaluate its mean
|residual|, and estimate the order-1 Lorenzo error. One fused kernel
produces all three outputs so the block tile is loaded into VMEM once.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid runs over tiles of
`TILE` blocks; each program holds a (TILE, *block_shape) tile in VMEM
(~TILE·216·4 B for 3-D) and reduces it on the VPU. `interpret=True` is
mandatory here — the CPU PJRT plugin cannot execute Mosaic custom calls —
so these kernels lower to plain HLO that both jax and the rust runtime can
run; the BlockSpec structure is what would carry over to real TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Blocks per kernel program (VMEM tile).
TILE = 256


def _analysis_kernel(x_ref, coeff_ref, lor_ref, reg_ref, *, block_shape):
    """Fused fit + error estimation for one VMEM tile of blocks."""
    x = x_ref[...]  # (TILE, *block_shape)
    nd = len(block_shape)
    tile = x.shape[0]
    n = 1
    for s in block_shape:
        n *= s
    flat = x.reshape(tile, -1)
    mean = flat.mean(axis=1)

    # --- regression fit (diagonalized normal equations) ---
    slopes = []
    for d in range(nd):
        sd = block_shape[d]
        coord = jnp.arange(sd, dtype=x.dtype) - (sd - 1) / 2.0
        shape = [1] * (nd + 1)
        shape[1 + d] = sd
        num = (x * coord.reshape(shape)).reshape(tile, -1).sum(axis=1)
        denom = n * (sd * sd - 1) / 12.0
        slopes.append(num / denom)
    intercept = mean
    for d in range(nd):
        intercept = intercept - slopes[d] * (block_shape[d] - 1) / 2.0
    coeff_ref[...] = jnp.stack(slopes + [intercept], axis=1)

    # --- regression residual ---
    pred = intercept.reshape((tile,) + (1,) * nd)
    for d in range(nd):
        sd = block_shape[d]
        coord = jnp.arange(sd, dtype=x.dtype)
        shape = [1] * (nd + 1)
        shape[1 + d] = sd
        pred = pred + slopes[d].reshape((tile,) + (1,) * nd) * coord.reshape(shape)
    reg_ref[...] = jnp.abs(x - pred).reshape(tile, -1).mean(axis=1)

    # --- Lorenzo error (inclusion-exclusion over backward shifts) ---
    lpred = jnp.zeros_like(x)
    for subset in range(1, 1 << nd):
        shifted = x
        for d in range(nd):
            if subset >> d & 1:
                pad = [(0, 0)] * x.ndim
                pad[1 + d] = (1, 0)
                shifted = jnp.pad(shifted, pad)[
                    tuple(
                        slice(0, x.shape[a]) if a == 1 + d else slice(None)
                        for a in range(x.ndim)
                    )
                ]
        sign = 1.0 if bin(subset).count("1") % 2 == 1 else -1.0
        lpred = lpred + sign * shifted
    lor_ref[...] = jnp.abs(x - lpred).reshape(tile, -1).mean(axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def analyze_blocks(blocks: jnp.ndarray, *, interpret: bool = True):
    """Batched block analysis via Pallas.

    blocks: (B, *block_shape) with B a multiple of TILE.
    Returns (coeffs (B, nd+1), lorenzo_err (B,), regression_err (B,)).
    """
    b = blocks.shape[0]
    block_shape = blocks.shape[1:]
    nd = len(block_shape)
    assert b % TILE == 0, f"batch {b} must be a multiple of {TILE}"
    grid = (b // TILE,)
    tile_block = (TILE,) + tuple(block_shape)
    zero_tail = (0,) * nd
    kernel = functools.partial(_analysis_kernel, block_shape=tuple(block_shape))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(tile_block, lambda i: (i,) + zero_tail)],
        out_specs=[
            pl.BlockSpec((TILE, nd + 1), lambda i: (i, 0)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nd + 1), blocks.dtype),
            jax.ShapeDtypeStruct((b,), blocks.dtype),
            jax.ShapeDtypeStruct((b,), blocks.dtype),
        ],
        interpret=interpret,
    )(blocks)
