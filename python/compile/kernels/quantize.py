"""L1 Pallas kernel: linear-scaling quantization of regression-predicted
blocks.

Regression prediction depends only on the fitted plane — never on
decompressed neighbors — so quantization of regression-selected blocks is
embarrassingly parallel (unlike the Lorenzo path, which stays sequential in
rust). This kernel evaluates the plane and quantizes the whole tile in one
pass: the batched counterpart of `LinearQuantizer::quantize` +
`RegressionFit::predict`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 256


def _quantize_kernel(x_ref, coeff_ref, eb_ref, idx_ref, rec_ref, *, block_shape, radius):
    x = x_ref[...]
    coeffs = coeff_ref[...]
    eb = eb_ref[0]
    nd = len(block_shape)
    tile = x.shape[0]
    pred = coeffs[:, nd].reshape((tile,) + (1,) * nd)
    for d in range(nd):
        sd = block_shape[d]
        coord = jnp.arange(sd, dtype=x.dtype)
        shape = [1] * (nd + 1)
        shape[1 + d] = sd
        pred = pred + coeffs[:, d].reshape((tile,) + (1,) * nd) * coord.reshape(shape)
    diff = x - pred
    q = jnp.round(diff / (2.0 * eb))
    rec = pred + q * 2.0 * eb
    ok = (jnp.abs(q) < radius) & (jnp.abs(rec - x) <= eb)
    idx_ref[...] = jnp.where(ok, q.astype(jnp.int32) + radius, 0).astype(jnp.int32)
    rec_ref[...] = jnp.where(ok, rec, x)


@functools.partial(jax.jit, static_argnames=("radius", "interpret"))
def quantize_blocks(
    blocks: jnp.ndarray,
    coeffs: jnp.ndarray,
    eb: jnp.ndarray,
    *,
    radius: int = 32768,
    interpret: bool = True,
):
    """Quantize a batch of regression-predicted blocks.

    blocks: (B, *shape); coeffs: (B, nd+1); eb: (1,) scalar array.
    Returns (indices int32 (B, *shape), recovered (B, *shape)).
    """
    b = blocks.shape[0]
    block_shape = blocks.shape[1:]
    nd = len(block_shape)
    assert b % TILE == 0, f"batch {b} must be a multiple of {TILE}"
    grid = (b // TILE,)
    tile_block = (TILE,) + tuple(block_shape)
    zero_tail = (0,) * nd
    kernel = functools.partial(
        _quantize_kernel, block_shape=tuple(block_shape), radius=radius
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(tile_block, lambda i: (i,) + zero_tail),
            pl.BlockSpec((TILE, nd + 1), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec(tile_block, lambda i: (i,) + zero_tail),
            pl.BlockSpec(tile_block, lambda i: (i,) + zero_tail),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(blocks.shape, jnp.int32),
            jax.ShapeDtypeStruct(blocks.shape, blocks.dtype),
        ],
        interpret=interpret,
    )(blocks, coeffs, eb)
