"""Pure-jnp reference oracle for the L1 Pallas kernels.

This is the single source of truth for the block-analysis math shared by
three implementations:
  * this module (pure jnp)                      — swept with hypothesis
  * the Pallas kernels in this package          — tested against this module
  * ``rust/src/pipeline/analysis.rs`` (native)  — same closed forms in f64

The math (paper SZ2 [8] / SZ3 §6.2):
  * regression fit: least-squares hyperplane over a regular block grid —
    after centering each coordinate the normal equations diagonalize, so
    every slope is an independent weighted sum;
  * lorenzo error: mean |x - order-1 Lorenzo prediction| with zero padding
    at block boundaries;
  * quantize: SZ linear-scaling quantization of residuals against a
    predicted block.
"""

from __future__ import annotations

import jax.numpy as jnp


def regression_fit(blocks: jnp.ndarray) -> jnp.ndarray:
    """Fit ``f(i) = sum_d b_d i_d + c`` per block.

    blocks: (B, s0, ..., sd) -> coeffs (B, d+1), slopes then intercept.
    """
    nd = blocks.ndim - 1
    b = blocks.shape[0]
    n = 1
    for s in blocks.shape[1:]:
        n *= s
    mean = blocks.reshape(b, -1).mean(axis=1)
    slopes = []
    for d in range(nd):
        sd = blocks.shape[1 + d]
        coord = jnp.arange(sd, dtype=blocks.dtype) - (sd - 1) / 2.0
        shape = [1] * (nd + 1)
        shape[1 + d] = sd
        centered = coord.reshape(shape)
        num = (blocks * centered).reshape(b, -1).sum(axis=1)
        denom = n * (sd * sd - 1) / 12.0
        slopes.append(num / denom)
    intercept = mean
    for d in range(nd):
        sd = blocks.shape[1 + d]
        intercept = intercept - slopes[d] * (sd - 1) / 2.0
    return jnp.stack(slopes + [intercept], axis=1)


def regression_predict(coeffs: jnp.ndarray, block_shape: tuple) -> jnp.ndarray:
    """Evaluate fitted planes on the block grid: (B, d+1) -> (B, *shape)."""
    nd = len(block_shape)
    b = coeffs.shape[0]
    pred = coeffs[:, nd].reshape((b,) + (1,) * nd)
    for d in range(nd):
        sd = block_shape[d]
        coord = jnp.arange(sd, dtype=coeffs.dtype)
        shape = [1] * (nd + 1)
        shape[1 + d] = sd
        pred = pred + coeffs[:, d].reshape((b,) + (1,) * nd) * coord.reshape(shape)
    return pred


def regression_err(blocks: jnp.ndarray) -> jnp.ndarray:
    """Mean |residual| of the per-block regression fit: (B,)."""
    coeffs = regression_fit(blocks)
    pred = regression_predict(coeffs, blocks.shape[1:])
    b = blocks.shape[0]
    return jnp.abs(blocks - pred).reshape(b, -1).mean(axis=1)


def _shift_back(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    """x[..., i-1, ...] with zero at i = 0 (per-block zero padding)."""
    pad = [(0, 0)] * x.ndim
    pad[axis] = (1, 0)
    padded = jnp.pad(x, pad)
    sl = [slice(None)] * x.ndim
    sl[axis] = slice(0, x.shape[axis])
    return padded[tuple(sl)]


def lorenzo_pred(blocks: jnp.ndarray) -> jnp.ndarray:
    """Order-1 Lorenzo prediction per point (inclusion-exclusion over
    backward neighbors), zero padding outside the block."""
    nd = blocks.ndim - 1
    pred = jnp.zeros_like(blocks)
    for subset in range(1, 1 << nd):
        shifted = blocks
        for d in range(nd):
            if subset >> d & 1:
                shifted = _shift_back(shifted, 1 + d)
        sign = 1.0 if bin(subset).count("1") % 2 == 1 else -1.0
        pred = pred + sign * shifted
    return pred


def lorenzo_err(blocks: jnp.ndarray) -> jnp.ndarray:
    """Mean |x - Lorenzo prediction| per block: (B,)."""
    b = blocks.shape[0]
    return jnp.abs(blocks - lorenzo_pred(blocks)).reshape(b, -1).mean(axis=1)


def analyze(blocks: jnp.ndarray):
    """Full block analysis: (coeffs, lorenzo_err, regression_err)."""
    coeffs = regression_fit(blocks)
    pred = regression_predict(coeffs, blocks.shape[1:])
    b = blocks.shape[0]
    reg = jnp.abs(blocks - pred).reshape(b, -1).mean(axis=1)
    lor = lorenzo_err(blocks)
    return coeffs, lor, reg


def quantize(blocks: jnp.ndarray, pred: jnp.ndarray, eb, radius: int):
    """SZ linear-scaling quantization of a predicted block batch.

    Returns (indices, recovered): index 0 marks unpredictable (caller
    stores those exactly), q + radius otherwise; recovered is the value
    the decompressor reconstructs.
    """
    diff = blocks - pred
    q = jnp.round(diff / (2.0 * eb))
    rec = pred + q * 2.0 * eb
    ok = (jnp.abs(q) < radius) & (jnp.abs(rec - blocks) <= eb)
    indices = jnp.where(ok, q.astype(jnp.int32) + radius, 0).astype(jnp.int32)
    recovered = jnp.where(ok, rec, blocks)
    return indices, recovered


def stats(x: jnp.ndarray) -> jnp.ndarray:
    """Field statistics for PSNR/range metrics: [min, max, sum, sumsq]."""
    return jnp.stack([x.min(), x.max(), x.sum(), (x * x).sum()])
