"""L2: the JAX compute graphs AOT-lowered for the rust runtime.

Each exported function composes the L1 Pallas kernels into the graph that
the rust coordinator executes via PJRT:

* ``analysis_fn``  — batched block analysis (fit + both error estimates)
  for the SZ3-LR composite predictor selection; one variant per
  dimensionality with the SZ2 block sides (128 / 12² / 6³ / 4⁴).
* ``quantize_fn``  — batched regression-block quantization.
* ``stats_fn``     — field statistics (min/max/sum/sumsq) for metrics.

Shapes are static (PJRT executables are shape-specialized): the runtime
pads the last batch with zero blocks, whose analysis results are discarded.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.analysis import analyze_blocks
from .kernels.quantize import quantize_blocks
from .kernels import ref

# Batch of blocks per executable invocation (runtime pads to this).
BATCH = 4096
# SZ2 block sides per dimensionality (must match rust block_side()).
BLOCK_SHAPES = {
    1: (128,),
    2: (12, 12),
    3: (6, 6, 6),
    4: (4, 4, 4, 4),
}
# Elements per stats invocation.
STATS_N = 1 << 16


def analysis_fn(blocks: jnp.ndarray):
    """(BATCH, *block_shape) -> (coeffs, lorenzo_err, regression_err)."""
    return analyze_blocks(blocks, interpret=True)


def quantize_fn(blocks: jnp.ndarray, coeffs: jnp.ndarray, eb: jnp.ndarray):
    """(BATCH, *shape), (BATCH, nd+1), (1,) -> (indices, recovered)."""
    return quantize_blocks(blocks, coeffs, eb, interpret=True)


def stats_fn(x: jnp.ndarray):
    """(STATS_N,) -> (4,) = [min, max, sum, sumsq]."""
    return (ref.stats(x),)
