"""AOT compile path: lower the L2 graphs to HLO **text** artifacts.

HLO text — not ``.serialize()`` protos — is the interchange format: jax
>= 0.5 emits HloModuleProto with 64-bit instruction ids which the
xla_extension 0.5.1 used by the published ``xla`` crate rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Python runs ONLY here, at build time (`make artifacts`). The rust binary
is self-contained once ``artifacts/`` exists.

Usage: python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_analysis(nd: int) -> str:
    shape = (model.BATCH,) + model.BLOCK_SHAPES[nd]
    spec = jax.ShapeDtypeStruct(shape, jnp.float32)
    return to_hlo_text(jax.jit(model.analysis_fn).lower(spec))


def lower_quantize(nd: int) -> str:
    shape = (model.BATCH,) + model.BLOCK_SHAPES[nd]
    bspec = jax.ShapeDtypeStruct(shape, jnp.float32)
    cspec = jax.ShapeDtypeStruct((model.BATCH, nd + 1), jnp.float32)
    espec = jax.ShapeDtypeStruct((1,), jnp.float32)
    return to_hlo_text(jax.jit(model.quantize_fn).lower(bspec, cspec, espec))


def lower_stats() -> str:
    spec = jax.ShapeDtypeStruct((model.STATS_N,), jnp.float32)
    return to_hlo_text(jax.jit(model.stats_fn).lower(spec))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file target (ignored)")
    args = ap.parse_args()
    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    manifest = {
        "batch": model.BATCH,
        "stats_n": model.STATS_N,
        "block_shapes": {str(k): list(v) for k, v in model.BLOCK_SHAPES.items()},
        "artifacts": {},
    }
    for nd in (1, 2, 3, 4):
        name = f"analysis_{nd}d.hlo.txt"
        text = lower_analysis(nd)
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        manifest["artifacts"][f"analysis_{nd}d"] = name
        print(f"wrote {name} ({len(text)} chars)")
    for nd in (2, 3):
        name = f"quantize_{nd}d.hlo.txt"
        text = lower_quantize(nd)
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        manifest["artifacts"][f"quantize_{nd}d"] = name
        print(f"wrote {name} ({len(text)} chars)")
    name = "stats.hlo.txt"
    text = lower_stats()
    with open(os.path.join(out_dir, name), "w") as f:
        f.write(text)
    manifest["artifacts"]["stats"] = name
    print(f"wrote {name} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json to {out_dir}")


if __name__ == "__main__":
    main()
