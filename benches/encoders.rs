//! Ablation bench (DESIGN.md §Perf): encoder and lossless stage choices on
//! a fixed quantization-index workload — the design-choice study behind the
//! module instances of Fig. 1. Reports size and speed per instance.
//!
//! Output: `enc,<stage>,<instance>,<bytes>,<mbs>`

use sz3::bench_harness::Bench;
use sz3::byteio::{ByteReader, ByteWriter};
use sz3::encoder::{self, Encoder};
use sz3::lossless::{self, Lossless};
use sz3::util::rng::Pcg32;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let bench = if quick { Bench::quick() } else { Bench::default() };
    let n = if quick { 1 << 18 } else { 1 << 21 };
    // quantization-index-like stream: two-sided geometric around the center
    let mut rng = Pcg32::seeded(42);
    let radius = 32768u32;
    let symbols: Vec<u32> = (0..n)
        .map(|_| {
            let d = (rng.normal() * 4.0).round() as i64;
            (radius as i64 + d).max(0) as u32
        })
        .collect();
    let raw_bytes = n * 4;
    println!("# encoder/lossless ablation over {n} indices (quick={quick})");
    println!("enc,stage,instance,bytes,mbs");
    for name in ["huffman", "fixed_huffman", "arithmetic", "raw"] {
        let e = encoder::by_name(name, radius).unwrap();
        let mut w = ByteWriter::new();
        e.encode(&symbols, &mut w).unwrap();
        let encoded = w.finish();
        let (_, mbs) = bench.throughput(&format!("enc|{name}"), raw_bytes, || {
            let mut w = ByteWriter::new();
            e.encode(&symbols, &mut w).unwrap();
            w.finish()
        });
        // verify decode correctness while we're here
        let mut r = ByteReader::new(&encoded);
        assert_eq!(e.decode(&mut r, n).unwrap(), symbols);
        println!("enc,encoder,{name},{},{mbs:.1}", encoded.len());
    }
    // lossless stage over the huffman output (the realistic input)
    let e = encoder::by_name("huffman", radius).unwrap();
    let mut w = ByteWriter::new();
    e.encode(&symbols, &mut w).unwrap();
    let payload = w.finish();
    for name in ["zstd", "gzip", "lzhuf", "rle", "bypass"] {
        let l = lossless::by_name(name).unwrap();
        let packed = l.compress(&payload).unwrap();
        assert_eq!(l.decompress(&packed).unwrap(), payload);
        let (_, mbs) = bench.throughput(&format!("ll|{name}"), payload.len(), || {
            l.compress(&payload).unwrap()
        });
        println!("enc,lossless,{name},{},{mbs:.1}", packed.len());
    }
}
