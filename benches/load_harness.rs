//! Production write-path load harness: N concurrent clients drive a
//! mixed ROI / raw-chunk / ingest / delete workload against one writable
//! server while two writer threads continuously replace one artifact and
//! publish/delete another. The PR's acceptance bar lives here: zero 5xx
//! responses and zero wrong reads (every ROI body bit-identical to a
//! published snapshot) under sustained concurrent ingest, with exact
//! client-observed p50/p99/throughput recorded to `BENCH_PR8.json`.
//!
//! Output: `load,<case>,<p50_us>,<p99_us>,<rps>,<mbs>`

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};
use sz3::bench_harness::PerfSummary;
use sz3::server::{self, HttpClient, Registry, ServeOptions, StoreOptions};

const DIMS: (usize, usize) = (64, 256);

const PARAMS: &str = "{\"dims\":[64,256],\"fields\":[\"rho\"],\
     \"pipeline\":\"sz3-lr\",\"bound\":{\"mode\":\"abs\",\"value\":0.001},\
     \"chunk_elems\":512}";

/// Frame an ingest body: `[u32le json_len][json params][le f32 data]`.
fn ingest_body(base: f32) -> Vec<u8> {
    let mut body = (PARAMS.len() as u32).to_le_bytes().to_vec();
    body.extend_from_slice(PARAMS.as_bytes());
    for i in 0..DIMS.0 * DIMS.1 {
        body.extend_from_slice(&(base + (i as f32) * 1e-3).to_le_bytes());
    }
    body
}

/// Exact percentile over raw latency samples (µs).
fn percentile_us(samples: &mut [u64], q: f64) -> u64 {
    samples.sort_unstable();
    if samples.is_empty() {
        return 0;
    }
    let idx = ((samples.len() - 1) as f64 * q).round() as usize;
    samples[idx]
}

/// PUT with bounded retry on 429 back-pressure. Returns (status, retries).
fn put_with_retry(c: &mut HttpClient, target: &str, body: &[u8]) -> (u16, u64) {
    let mut retries = 0u64;
    loop {
        let resp = c.put(target, body).unwrap();
        if resp.status == 429 {
            retries += 1;
            assert!(retries < 1000, "ingest slots never freed");
            std::thread::sleep(Duration::from_millis(5));
            continue;
        }
        return (resp.status, retries);
    }
}

struct ReaderOutcome {
    samples: Vec<u64>,
    bytes: u64,
    reads: u64,
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let readers = 8usize;
    let hot_replaces = if quick { 6u64 } else { 20 };
    let flap_cycles = if quick { 4u64 } else { 12 };
    println!("# load_harness bench (quick={quick}, {readers} reader clients)");

    let dir = std::env::temp_dir()
        .join(format!("sz3_bench_load_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let reg = Arc::new(
        Registry::open_dir(
            &dir,
            &StoreOptions { cache_bytes: 128 << 20, workers: 2, verify: true },
        )
        .unwrap()
        .with_max_inflight_ingests(2),
    );
    let opts = ServeOptions {
        threads: 8,
        max_body: 64 << 20,
        max_conns: 128,
        read_timeout: Duration::from_secs(10),
        ..Default::default()
    };
    let handle =
        server::serve_registry(Arc::clone(&reg), "127.0.0.1:0", opts).unwrap();
    let addr = handle.addr();

    // seed the three artifacts and capture bit-exact oracles (the
    // compressor is deterministic: re-publishing an input reproduces
    // these bytes exactly)
    let body_a = ingest_body(0.0);
    let body_b = ingest_body(7.5);
    let body_static = ingest_body(100.0);
    let body_flap = ingest_body(-3.0);
    let hot_roi = "/v1/artifacts/hot/fields/rho?rows=0..64";
    let static_roi = "/v1/artifacts/static/fields/rho?rows=8..24";
    let flap_roi = "/v1/artifacts/flap/fields/rho?rows=0..16";
    let mut c = HttpClient::connect(addr).unwrap();
    assert_eq!(c.put("/v1/artifacts/hot", &body_a).unwrap().status, 201);
    let oracle_a = Arc::new(c.get(hot_roi).unwrap().body);
    assert_eq!(c.put("/v1/artifacts/hot", &body_b).unwrap().status, 200);
    let oracle_b = Arc::new(c.get(hot_roi).unwrap().body);
    assert_ne!(*oracle_a, *oracle_b);
    assert_eq!(c.put("/v1/artifacts/static", &body_static).unwrap().status, 201);
    let oracle_static = Arc::new(c.get(static_roi).unwrap().body);
    assert_eq!(c.put("/v1/artifacts/flap", &body_flap).unwrap().status, 201);
    let oracle_flap = Arc::new(c.get(flap_roi).unwrap().body);
    let raw_oracles: Arc<Vec<Vec<u8>>> = Arc::new(
        (0..4)
            .map(|i| {
                let resp =
                    c.get(&format!("/v1/artifacts/static/raw?chunk={i}")).unwrap();
                assert_eq!(resp.status, 200);
                resp.body
            })
            .collect(),
    );

    let stop = Arc::new(AtomicBool::new(false));
    let err_5xx = Arc::new(AtomicU64::new(0));
    let mismatches = Arc::new(AtomicU64::new(0));
    let retries_total = Arc::new(AtomicU64::new(0));

    // writer 1: continuous replace of "hot", alternating the two payloads
    let hot_writer = {
        let (retries_total, body_a, body_b) =
            (Arc::clone(&retries_total), body_a.clone(), body_b.clone());
        std::thread::spawn(move || {
            let mut c = HttpClient::connect(addr).unwrap();
            for i in 0..hot_replaces {
                let body = if i % 2 == 0 { &body_a } else { &body_b };
                let (status, retries) =
                    put_with_retry(&mut c, "/v1/artifacts/hot", body);
                assert_eq!(status, 200, "replace #{i}");
                retries_total.fetch_add(retries, Ordering::Relaxed);
            }
        })
    };

    // writer 2: publish/delete flap on "flap"
    let flap_writer = {
        let (retries_total, body_flap) =
            (Arc::clone(&retries_total), body_flap.clone());
        std::thread::spawn(move || {
            let mut c = HttpClient::connect(addr).unwrap();
            for i in 0..flap_cycles {
                assert_eq!(c.delete("/v1/artifacts/flap").unwrap().status, 200, "#{i}");
                let (status, retries) =
                    put_with_retry(&mut c, "/v1/artifacts/flap", &body_flap);
                assert_eq!(status, 201, "re-create #{i}");
                retries_total.fetch_add(retries, Ordering::Relaxed);
            }
        })
    };

    // N reader clients, four traffic mixes
    let mut reader_handles = Vec::new();
    for i in 0..readers {
        let stop = Arc::clone(&stop);
        let err_5xx = Arc::clone(&err_5xx);
        let mismatches = Arc::clone(&mismatches);
        let (a, b, st, fl, raw) = (
            Arc::clone(&oracle_a),
            Arc::clone(&oracle_b),
            Arc::clone(&oracle_static),
            Arc::clone(&oracle_flap),
            Arc::clone(&raw_oracles),
        );
        reader_handles.push(std::thread::spawn(move || {
            let mut c = HttpClient::connect(addr).unwrap();
            let mut out =
                ReaderOutcome { samples: Vec::new(), bytes: 0, reads: 0 };
            let mut k = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let (target, kind) = match i % 4 {
                    0 => (hot_roi.to_string(), 0),
                    1 => (static_roi.to_string(), 1),
                    2 => (
                        format!("/v1/artifacts/static/raw?chunk={}", k % raw.len()),
                        2,
                    ),
                    _ => (flap_roi.to_string(), 3),
                };
                let t0 = Instant::now();
                let resp = c.get(&target).unwrap();
                out.samples.push(t0.elapsed().as_micros() as u64);
                out.bytes += resp.body.len() as u64;
                out.reads += 1;
                k += 1;
                if resp.status >= 500 {
                    err_5xx.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                let ok = match kind {
                    0 => resp.status == 200 && (resp.body == *a || resp.body == *b),
                    1 => resp.status == 200 && resp.body == *st,
                    2 => {
                        resp.status == 200
                            && resp.body == raw[(k - 1) % raw.len()]
                    }
                    _ => match resp.status {
                        200 => resp.body == *fl,
                        404 => true,
                        _ => false,
                    },
                };
                if !ok {
                    mismatches.fetch_add(1, Ordering::Relaxed);
                }
            }
            out
        }));
    }

    let wall = Instant::now();
    hot_writer.join().unwrap();
    flap_writer.join().unwrap();
    // let readers overlap the whole write window plus a settle beat
    std::thread::sleep(Duration::from_millis(if quick { 50 } else { 200 }));
    stop.store(true, Ordering::Relaxed);
    let wall = wall.elapsed().as_secs_f64().max(1e-9);

    let mut samples = Vec::new();
    let (mut bytes, mut reads) = (0u64, 0u64);
    for h in reader_handles {
        let out = h.join().unwrap();
        samples.extend(out.samples);
        bytes += out.bytes;
        reads += out.reads;
    }
    let p50 = percentile_us(&mut samples, 0.50);
    let p99 = percentile_us(&mut samples, 0.99);
    let rps = reads as f64 / wall;
    let mbs = bytes as f64 / 1e6 / wall;
    let e5 = err_5xx.load(Ordering::Relaxed);
    let wrong = mismatches.load(Ordering::Relaxed);
    let retried = retries_total.load(Ordering::Relaxed);
    println!("load,mixed,{p50},{p99},{rps:.0},{mbs:.1}");
    println!(
        "# {reads} reads, {e5} 5xx, {wrong} wrong, {retried} 429-retries, \
         generation {}",
        reg.generation()
    );

    // the acceptance bar: nothing failed, nothing was ever wrong
    assert!(reads > 0, "readers must overlap the write window");
    assert_eq!(e5, 0, "zero 5xx under concurrent ingest");
    assert_eq!(wrong, 0, "zero wrong reads under replace/delete churn");
    assert_eq!(
        reg.generation(),
        // seeds: hot x2 + static + flap, then the two writer loops
        4 + hot_replaces + 2 * flap_cycles,
        "every mutation bumped the epoch exactly once"
    );

    let mut summary = PerfSummary::new();
    summary.record("load_reader_clients", readers as f64);
    summary.record("load_p50_us", p50 as f64);
    summary.record("load_p99_us", p99 as f64);
    summary.record("load_rps", rps);
    summary.record("load_mbs", mbs);
    summary.record("load_reads", reads as f64);
    summary.record("load_replaces", hot_replaces as f64);
    summary.record("load_flap_cycles", flap_cycles as f64);
    summary.record("load_429_retries", retried as f64);
    summary.record("load_5xx", e5 as f64);
    summary.record("load_wrong_reads", wrong as f64);

    drop(c); // close the seed connection so shutdown doesn't wait it out
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);

    summary.write_json("BENCH_PR8.json").unwrap();
    println!("# perf summary written to BENCH_PR8.json");
    println!("{}", summary.to_json());
}
