//! Series delta bench: pack a smoothly-evolving time series into one v3
//! container with and without snapshot delta mode, assert the acceptance
//! criteria (delta beats direct on total bytes; every snapshot's
//! `read_region_at` is bit-identical to the independent standalone
//! decode), and measure snapshot-ROI latency cold vs cache-warm. Emits
//! the machine-readable `BENCH_PR4.json` perf summary.
//!
//! Output: `series,<case>,<value>`

use sz3::bench_harness::{Bench, PerfSummary};
use sz3::config::JobConfig;
use sz3::container::fixtures::{reference_decode, smooth_series};
use sz3::coordinator::{Coordinator, Snapshot};
use sz3::data::Field;
use sz3::pipeline::ErrorBound;
use sz3::reader::ContainerReader;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let bench = if quick { Bench::quick() } else { Bench::default() };
    let nz = if quick { 48 } else { 128 };
    let (ny, nx) = (48usize, 48);
    let steps = 4usize;
    println!("# series delta bench (quick={quick})");

    // a smoothly-evolving series: fixed seed, slow drift so consecutive
    // snapshots stay correlated (the shared deterministic builder)
    let dims = [nz, ny, nx];
    let snapshot_fields: Vec<Field> = smooth_series(4042, &dims, steps, 0.02, "rho")
        .into_iter()
        .map(|mut s| s.fields.remove(0))
        .collect();
    let raw_bytes: usize = snapshot_fields.iter().map(Field::nbytes).sum();

    let eb = 1e-3;
    let cfg = JobConfig {
        pipeline: "sz3-lr".into(),
        bound: ErrorBound::Abs(eb),
        workers: 4,
        chunk_elems: ny * nx * 8, // 8 rows per chunk
        queue_depth: 4,
        ..Default::default()
    };
    let coord = Coordinator::from_config(&cfg).unwrap();
    let series = |fields: &[Field]| -> Vec<Snapshot> {
        fields
            .iter()
            .enumerate()
            .map(|(t, f)| Snapshot::new(format!("t{t}"), vec![f.clone()]))
            .collect()
    };

    let mut summary = PerfSummary::new();

    // pack: direct vs delta
    let t0 = std::time::Instant::now();
    let (direct, _) =
        coord.run_series_to_container(series(&snapshot_fields), false).unwrap();
    let direct_secs = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let (delta, rep) =
        coord.run_series_to_container(series(&snapshot_fields), true).unwrap();
    let delta_secs = t0.elapsed().as_secs_f64();
    println!("series,direct_bytes,{}", direct.len());
    println!("series,delta_bytes,{}", delta.len());
    println!("series,delta_chunks,{}", rep.delta_chunks);
    println!("# {rep}");
    summary.record("series_direct_ratio", raw_bytes as f64 / direct.len() as f64);
    summary.record("series_delta_ratio", raw_bytes as f64 / delta.len() as f64);
    summary.record("series_delta_savings", rep.delta_savings());
    summary.record("series_pack_direct_mbs", raw_bytes as f64 / 1e6 / direct_secs.max(1e-9));
    summary.record("series_pack_delta_mbs", raw_bytes as f64 / 1e6 / delta_secs.max(1e-9));

    // ACCEPTANCE: a smoothly-evolving 3+ snapshot series must pack
    // smaller with delta mode than direct
    assert!(rep.delta_chunks > 0, "smooth series must select delta chunks");
    assert!(
        delta.len() < direct.len(),
        "delta container ({} bytes) must beat direct ({} bytes)",
        delta.len(),
        direct.len()
    );

    // ACCEPTANCE: every snapshot read back from either container is
    // bit-identical to the standalone decode of that snapshot.
    // (a) direct container vs standalone compress/decompress;
    // (b) delta container vs the independent reference decoder
    //     (pipeline-level chain resolution, no ContainerReader).
    let direct_reader = ContainerReader::from_slice(&direct).unwrap().with_workers(4);
    let delta_reader = ContainerReader::from_slice(&delta).unwrap().with_workers(4);
    let reference = reference_decode(&delta).unwrap();
    for (t, field) in snapshot_fields.iter().enumerate() {
        let (standalone, _) = coord.run_to_container(vec![field.clone()]).unwrap();
        let lone = sz3::container::decompress_container(&standalone, 4)
            .unwrap()
            .remove(0);
        let from_direct = direct_reader.read_field_at(t, "rho").unwrap();
        assert_eq!(
            from_direct.values.to_le_bytes(),
            lone.values.to_le_bytes(),
            "direct snapshot {t} != standalone decode"
        );
        let from_delta = delta_reader.read_field_at(t, "rho").unwrap();
        let (_, _, oracle) = reference
            .iter()
            .find(|(s, f, _)| *s == t && f == "rho")
            .expect("reference holds every snapshot");
        assert_eq!(
            &from_delta.values.to_le_bytes(),
            oracle,
            "delta snapshot {t} != independent reference decode"
        );
        // and the reconstruction respects the error bound end to end
        // (1% slack: baseline+residual adds one f32 rounding, ~½ulp)
        for (o, d) in field
            .values
            .to_f64_vec()
            .iter()
            .zip(from_delta.values.to_f64_vec())
        {
            assert!((o - d).abs() <= eb * 1.01, "bound at snapshot {t}");
        }
    }
    println!("# acceptance checks passed");

    // ROI latency on the last snapshot (longest delta chain): cold
    // reader per iteration vs a byte-budget-cache-warm reader
    let last = steps - 1;
    let roi = 2 * 8..3 * 8; // exactly one chunk
    let roi_bytes = (roi.end - roi.start) * ny * nx * 4;
    let (s, cold_mbs) = bench.throughput("read_region_at(cold, delta chain)", roi_bytes, || {
        let r = ContainerReader::from_slice(&delta).unwrap();
        r.read_region_at(last, "rho", roi.clone()).unwrap()
    });
    println!("series,roi_cold_ms,{:.3}", s.mean.as_secs_f64() * 1e3);
    summary.record("series_roi_cold_mbs", cold_mbs);
    summary.record("series_roi_cold_ms", s.mean.as_secs_f64() * 1e3);

    let warm_reader = ContainerReader::from_slice(&delta)
        .unwrap()
        .with_cache_bytes(64 << 20);
    warm_reader.read_region_at(last, "rho", roi.clone()).unwrap();
    let (s, warm_mbs) = bench.throughput("read_region_at(warm cache)", roi_bytes, || {
        warm_reader.read_region_at(last, "rho", roi.clone()).unwrap()
    });
    println!("series,roi_warm_ms,{:.3}", s.mean.as_secs_f64() * 1e3);
    summary.record("series_roi_warm_mbs", warm_mbs);
    summary.record("series_roi_warm_ms", s.mean.as_secs_f64() * 1e3);
    let rs = warm_reader.stats();
    println!(
        "# warm reader: {} decodes, {} cache hits, {} delta resolutions",
        rs.chunks_decoded, rs.cache_hits, rs.delta_applied
    );

    // snapshot-0 ROI for comparison (no chain to resolve)
    let (_, first_mbs) = bench.throughput("read_region_at(cold, snapshot 0)", roi_bytes, || {
        let r = ContainerReader::from_slice(&delta).unwrap();
        r.read_region_at(0, "rho", roi.clone()).unwrap()
    });
    summary.record("series_roi_s0_mbs", first_mbs);

    summary.write_json("BENCH_PR4.json").unwrap();
    println!("# perf summary written to BENCH_PR4.json");
    println!("{}", summary.to_json());
}
