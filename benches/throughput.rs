//! Fig. 8 regeneration: compression/decompression throughput (MB/s) of
//! every pipeline on the eight survey datasets at relative error bound
//! 1e-3. Expect the paper's ordering: Truncation ≫ LR/LR-s > Interp, with
//! Truncation several × the next best.
//!
//! Output lines: `tp,<dataset>,<pipeline>,<comp MB/s>,<decomp MB/s>,<ratio>`

use sz3::bench_harness::Bench;
use sz3::pipeline::{self, CompressConf, ErrorBound};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let bench = if quick { Bench::quick() } else { Bench::default() };
    let pipelines = ["sz3-truncation", "sz3-lr", "sz3-lr-s", "sz3-interp"];
    println!("# Fig. 8: throughput at rel eb 1e-3 (quick={quick})");
    println!("tp,dataset,pipeline,compress_mbs,decompress_mbs,ratio");
    for ds in sz3::datagen::survey(42) {
        // one representative field per dataset keeps runtime sane
        let field = &ds.fields[0];
        let bytes = field.nbytes();
        for name in pipelines {
            let c = pipeline::build(name).unwrap();
            let conf = CompressConf::new(ErrorBound::Rel(1e-3));
            let stream = match c.compress(field, &conf) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("# {name} on {}: {e}", ds.name);
                    continue;
                }
            };
            let ratio = bytes as f64 / stream.len() as f64;
            let (_, comp_mbs) =
                bench.throughput(&format!("{}|{name}|comp", ds.name), bytes, || {
                    c.compress(field, &conf).unwrap()
                });
            let (_, dec_mbs) =
                bench.throughput(&format!("{}|{name}|dec", ds.name), bytes, || {
                    c.decompress(&stream).unwrap()
                });
            println!("tp,{},{name},{comp_mbs:.1},{dec_mbs:.1},{ratio:.2}", ds.name);
        }
    }
}
