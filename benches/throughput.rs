//! Fig. 8 regeneration plus the PR 9 fast-family acceptance gate.
//!
//! Part 1 — Fig. 8: compression/decompression throughput (MB/s) of every
//! pipeline on the eight survey datasets at relative error bound 1e-3.
//! Expect the paper's ordering: Truncation ≫ LR/LR-s > Interp, with
//! Truncation several × the next best, and `szx` above Truncation.
//!
//! Part 2 — constant-heavy corpus: a piecewise-flat field (the SZx sweet
//! spot: instrument backgrounds, masked regions, quiesced checkpoints)
//! where the constblock family must beat the fastest prediction-based
//! family by ≥ 5× compress throughput. Asserted, so the CI smoke run
//! fails on regression.
//!
//! Part 3 — kernel microbenches: dispatched vs always-scalar variants of
//! the shared SIMD kernels, so the perf summary records what the runtime
//! dispatch is actually buying on this host.
//!
//! Output lines: `tp,<dataset>,<pipeline>,<comp MB/s>,<decomp MB/s>,<ratio>`
//! and `szx,<metric>,<value>`; machine-readable summary in `BENCH_PR9.json`.

use sz3::bench_harness::{Bench, PerfSummary};
use sz3::data::Field;
use sz3::pipeline::{self, CompressConf, ErrorBound};
use sz3::util::rng::Pcg32;
use sz3::util::simd;

/// Piecewise-constant f32 volume: long plateaus at random levels with an
/// occasional short noisy stretch (~2% of elements), the shape SZx's
/// constant-block scan is built for.
fn constant_heavy_field(nelems: usize, seed: u64) -> Field {
    let mut rng = Pcg32::seeded(seed);
    let mut vals = Vec::with_capacity(nelems);
    while vals.len() < nelems {
        let run = (500 + rng.below(4000)).min(nelems - vals.len());
        if rng.below(50) == 0 {
            for _ in 0..run {
                vals.push((rng.below(1 << 20) as f32 / 1e4) - 50.0);
            }
        } else {
            let level = (rng.below(1 << 20) as f32 / 1e4) - 50.0;
            vals.resize(vals.len() + run, level);
        }
    }
    Field::f32("plateau", &[nelems], vals).unwrap()
}

/// Min-of-iterations compress throughput in MB/s (least noise-polluted
/// estimate, same convention as the obs overhead bench).
fn comp_mbs(bench: &Bench, label: &str, field: &Field, name: &str) -> f64 {
    let c = pipeline::build(name).unwrap();
    let conf = CompressConf::new(ErrorBound::Abs(1e-3));
    let s = bench.run(label, || {
        c.compress(field, &conf).unwrap();
    });
    field.nbytes() as f64 / 1e6 / s.min.as_secs_f64().max(1e-9)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let bench = if quick { Bench::quick() } else { Bench::default() };
    let mut summary = PerfSummary::new();

    // ---------------------------------------------------- Fig. 8 sweep
    let pipelines =
        ["sz3-truncation", "sz3-lr", "sz3-lr-s", "sz3-interp", "szx"];
    println!("# Fig. 8: throughput at rel eb 1e-3 (quick={quick})");
    println!("tp,dataset,pipeline,compress_mbs,decompress_mbs,ratio");
    for ds in sz3::datagen::survey(42) {
        // one representative field per dataset keeps runtime sane
        let field = &ds.fields[0];
        let bytes = field.nbytes();
        for name in pipelines {
            let c = pipeline::build(name).unwrap();
            let conf = CompressConf::new(ErrorBound::Rel(1e-3));
            let stream = match c.compress(field, &conf) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("# {name} on {}: {e}", ds.name);
                    continue;
                }
            };
            let ratio = bytes as f64 / stream.len() as f64;
            let (_, comp_mbs) =
                bench.throughput(&format!("{}|{name}|comp", ds.name), bytes, || {
                    c.compress(field, &conf).unwrap()
                });
            let (_, dec_mbs) =
                bench.throughput(&format!("{}|{name}|dec", ds.name), bytes, || {
                    c.decompress(&stream).unwrap()
                });
            println!("tp,{},{name},{comp_mbs:.1},{dec_mbs:.1},{ratio:.2}", ds.name);
        }
    }

    // ------------------------------- constant-heavy acceptance corpus
    let nelems = if quick { 1 << 20 } else { 1 << 22 };
    let field = constant_heavy_field(nelems, 0x5a3c);
    let mb = field.nbytes() as f64 / 1e6;
    println!("# constant-heavy corpus: {mb:.0} MB piecewise-flat f32");

    // fastest existing (prediction/truncation) family on this corpus
    let mut best_existing = 0.0f64;
    let mut best_name = "";
    for name in ["sz3-truncation", "sz3-lr-s"] {
        let mbs = comp_mbs(&bench, &format!("const|{name}"), &field, name);
        println!("szx,existing_{name}_comp_mbs,{mbs:.1}");
        summary.record(&format!("existing_{name}_comp_mbs"), mbs);
        if mbs > best_existing {
            best_existing = mbs;
            best_name = name;
        }
    }

    // the constblock family: registry alias (derived keep, zstd tail) and
    // the pinned-keep/bypass configuration a throughput-first deployment
    // would run
    let szx_alias = comp_mbs(&bench, "const|szx", &field, "szx");
    let szx_tuned = comp_mbs(
        &bench,
        "const|szx-tuned",
        &field,
        "constblock(256)/truncation@k2/raw/bypass",
    );
    let szx_best = szx_alias.max(szx_tuned);
    println!("szx,szx_alias_comp_mbs,{szx_alias:.1}");
    println!("szx,szx_tuned_comp_mbs,{szx_tuned:.1}");
    summary.record("szx_alias_comp_mbs", szx_alias);
    summary.record("szx_tuned_comp_mbs", szx_tuned);
    summary.record("existing_best_comp_mbs", best_existing);

    // round-trip sanity + ratio on the acceptance corpus (the fast path
    // must still honor the bound it advertises)
    let c = pipeline::build("szx").unwrap();
    let conf = CompressConf::new(ErrorBound::Abs(1e-3));
    let stream = c.compress(&field, &conf).unwrap();
    let restored = c.decompress(&stream).unwrap();
    let worst = field
        .values
        .to_f64_vec()
        .iter()
        .zip(restored.values.to_f64_vec())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(worst <= 1e-3 + 1e-9, "szx bound violated: {worst:.3e}");
    let ratio = field.nbytes() as f64 / stream.len() as f64;
    println!("szx,const_corpus_ratio,{ratio:.1}");
    summary.record("const_corpus_ratio", ratio);

    let speedup = szx_best / best_existing.max(1e-9);
    println!("szx,speedup_vs_{best_name},{speedup:.2}");
    summary.record("speedup_vs_existing", speedup);

    // ACCEPTANCE: the SZx-style family is ≥5× the fastest existing family
    // on its target corpus
    assert!(
        speedup >= 5.0,
        "szx {szx_best:.0} MB/s is only {speedup:.2}x {best_name} \
         ({best_existing:.0} MB/s); acceptance bar is 5x"
    );

    // -------------------------------------------- kernel microbenches
    println!("# kernel dispatch: {}", simd::dispatch_label());
    summary.record(
        "avx2_active",
        if simd::avx2_active() { 1.0 } else { 0.0 },
    );

    let n = 1 << 16;
    let mut rng = Pcg32::seeded(0x6b31);
    let vals: Vec<f64> =
        (0..n).map(|_| rng.below(1 << 20) as f64 / 1e4).collect();
    let preds: Vec<f64> = vals.iter().map(|v| v + 0.01).collect();
    let bytes = n * 8;

    fn kernel(
        bench: &Bench,
        summary: &mut PerfSummary,
        name: &str,
        bytes: usize,
        disp: impl FnMut(),
        scal: impl FnMut(),
    ) {
        let d = bench.run(&format!("{name}|dispatched"), disp);
        let s = bench.run(&format!("{name}|scalar"), scal);
        let d_mbs = bytes as f64 / 1e6 / d.min.as_secs_f64().max(1e-9);
        let s_mbs = bytes as f64 / 1e6 / s.min.as_secs_f64().max(1e-9);
        println!("szx,kernel_{name}_dispatched_mbs,{d_mbs:.0}");
        println!("szx,kernel_{name}_scalar_mbs,{s_mbs:.0}");
        summary.record(&format!("kernel_{name}_dispatched_mbs"), d_mbs);
        summary.record(&format!("kernel_{name}_scalar_mbs"), s_mbs);
        summary.record(&format!("kernel_{name}_speedup"), d_mbs / s_mbs.max(1e-9));
    }

    let mut row_d = vals.clone();
    let mut codes_d = vec![0u32; n];
    let mut row_s = vals.clone();
    let mut codes_s = vec![0u32; n];
    kernel(
        &bench,
        &mut summary,
        "linear_quantize_f64",
        bytes,
        || {
            row_d.copy_from_slice(&vals);
            simd::linear_quantize_f64(&mut row_d, &preds, 1e-3, 512, &mut codes_d);
        },
        || {
            row_s.copy_from_slice(&vals);
            simd::linear_quantize_f64_scalar(&mut row_s, &preds, 1e-3, 512, &mut codes_s);
        },
    );
    kernel(
        &bench,
        &mut summary,
        "minmax_f64",
        bytes,
        || {
            std::hint::black_box(simd::minmax_f64(&vals));
        },
        || {
            std::hint::black_box(simd::minmax_f64_scalar(&vals));
        },
    );
    let raw: Vec<u8> = (0..bytes).map(|i| (i * 31 % 251) as u8).collect();
    kernel(
        &bench,
        &mut summary,
        "crc32",
        bytes,
        || {
            std::hint::black_box(simd::crc32_update(0, &raw));
        },
        || {
            std::hint::black_box(simd::crc32_update_scalar(0, &raw));
        },
    );

    summary.write_json("BENCH_PR9.json").unwrap();
    println!("# wrote BENCH_PR9.json");
}
