//! Observability overhead bench: prove the always-on metrics registry and
//! an armed span tracer cost less than 3% of end-to-end compression
//! throughput, and that the lock-free primitives stay in nanosecond
//! territory. Emits the machine-readable `BENCH_PR7.json` perf summary
//! and asserts the acceptance bar (the smoke run fails CI on regression).
//!
//! Output: `obs,<case>,<value>`

use sz3::bench_harness::{Bench, PerfSummary};
use sz3::config::JobConfig;
use sz3::coordinator::Coordinator;
use sz3::data::Field;
use sz3::obs;
use sz3::pipeline::ErrorBound;
use sz3::util::{prop, rng::Pcg32};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let bench = if quick { Bench::quick() } else { Bench::default() };
    let nz = if quick { 32 } else { 96 };
    println!("# obs overhead bench (quick={quick})");

    let mut summary = PerfSummary::new();

    // -- primitive costs: one relaxed atomic add / bucketed observe -----
    let c = obs::Counter::new();
    let s = bench.run("counter.add x1024", || {
        for i in 0..1024u64 {
            c.add(i & 1);
        }
    });
    let counter_ns = s.min.as_nanos() as f64 / 1024.0;
    println!("obs,counter_ns_per_op,{counter_ns:.2}");
    summary.record("counter_ns_per_op", counter_ns);

    let h = obs::Histogram::new();
    let s = bench.run("histogram.observe_us x1024", || {
        for i in 0..1024u64 {
            h.observe_us(i & 4095);
        }
    });
    let hist_ns = s.min.as_nanos() as f64 / 1024.0;
    println!("obs,histogram_ns_per_op,{hist_ns:.2}");
    summary.record("histogram_ns_per_op", hist_ns);

    // -- end to end: always-on metrics (the baseline — instrumentation is
    // compiled in) vs the same run with the span tracer armed -----------
    let dims = [nz, 48usize, 48];
    let mut rng = Pcg32::seeded(4207);
    let field =
        Field::f32("rho", &dims, prop::smooth_field(&mut rng, &dims)).unwrap();
    let raw_bytes = field.nbytes();
    let cfg = JobConfig {
        pipeline: "sz3-lr".into(),
        bound: ErrorBound::Abs(1e-3),
        workers: 2,
        chunk_elems: 48 * 48 * 4,
        queue_depth: 2,
        ..Default::default()
    };
    let coord = Coordinator::from_config(&cfg).unwrap();

    obs::trace::disable();
    let base = bench.run("run_to_container (tracer off)", || {
        coord.run_to_container(vec![field.clone()]).unwrap()
    });
    obs::trace::enable(1 << 16);
    let traced = bench.run("run_to_container (tracer armed)", || {
        coord.run_to_container(vec![field.clone()]).unwrap()
    });
    let trace_json = obs::trace::dump_json().expect("armed tracer dumps");
    obs::trace::disable();
    assert!(
        trace_json.contains("\"traceEvents\"") && trace_json.contains("\"ph\":\"X\""),
        "trace dump must be Chrome trace_event JSON"
    );

    // min-of-iterations comparison: the fastest run of each mode is the
    // least noise-polluted estimate of its true cost
    let base_s = base.min.as_secs_f64().max(1e-9);
    let traced_s = traced.min.as_secs_f64().max(1e-9);
    let compress_mbs = raw_bytes as f64 / 1e6 / base_s;
    let traced_mbs = raw_bytes as f64 / 1e6 / traced_s;
    let overhead_pct = ((traced_s - base_s) / base_s * 100.0).max(0.0);
    println!("obs,compress_mbs,{compress_mbs:.1}");
    println!("obs,compress_traced_mbs,{traced_mbs:.1}");
    println!("obs,overhead_pct,{overhead_pct:.2}");
    println!("# {base}");
    println!("# {traced}");
    summary.record("compress_mbs", compress_mbs);
    summary.record("compress_traced_mbs", traced_mbs);
    summary.record("overhead_pct", overhead_pct);

    // ACCEPTANCE: observability costs < 3% of end-to-end throughput even
    // with the tracer armed, and the hot-path primitive stays nanoscale
    assert!(
        overhead_pct < 3.0,
        "observability overhead {overhead_pct:.2}% >= 3% \
         (base {base_s:.6}s, traced {traced_s:.6}s)"
    );
    assert!(counter_ns < 200.0, "counter add {counter_ns:.1} ns/op is not hot-path safe");

    summary.write_json("BENCH_PR7.json").unwrap();
    println!("# wrote BENCH_PR7.json");
}
