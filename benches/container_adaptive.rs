//! Container + adaptive-selection bench: the coordinator's native `SZ3C`
//! artifact path (pack, parallel decompress) with a fixed pipeline vs
//! per-chunk best-fit selection, on a heterogeneous multi-regime workload
//! where no single pipeline fits every chunk. Expect the adaptive run to
//! match or beat the best fixed pipeline's ratio while keeping container
//! decompression parallel across the worker pool.
//!
//! Output: `cont,<mode>,<ratio>,<compress_mbs>,<decompress_mbs>,<mix>`

use sz3::bench_harness::container_roundtrip;
use sz3::config::JobConfig;
use sz3::coordinator::Coordinator;
use sz3::data::Field;
use sz3::pipeline::ErrorBound;
use sz3::util::rng::Pcg32;

fn workload(seed: u64, nz: usize) -> Vec<Field> {
    let (ny, nx) = (48usize, 48);
    let mut rng = Pcg32::seeded(seed);
    let mut vals = Vec::with_capacity(nz * ny * nx);
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let v = if (z / 3) % 3 == 0 {
                    (0.6 * (z as f64 * 0.11).sin() + 0.5 * (y as f64 * 0.07).cos()
                        + 0.4 * (x as f64 * 0.05).sin()) as f32
                } else if (z / 3) % 3 == 1 {
                    (0.5 * z as f64 - 0.3 * y as f64 + 0.2 * x as f64
                        + rng.normal() * 0.02) as f32
                } else {
                    rng.uniform(-300.0, 300.0) as f32
                };
                vals.push(v);
            }
        }
    }
    vec![Field::f32("hetero", &[nz, ny, nx], vals).unwrap()]
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let nz = if quick { 48 } else { 144 };
    println!("# container + adaptive selection bench (quick={quick})");
    println!("cont,mode,ratio,compress_mbs,decompress_mbs,mix");
    for (mode, pipeline, adaptive) in [
        ("fixed-lr", "sz3-lr", false),
        ("fixed-interp", "sz3-interp", false),
        ("fixed-truncation", "sz3-truncation", false),
        ("adaptive", "sz3-lr", true),
    ] {
        let cfg = JobConfig {
            pipeline: pipeline.into(),
            bound: ErrorBound::Abs(0.2),
            workers: 4,
            chunk_elems: 48 * 48 * 3, // one regime stripe per chunk
            queue_depth: 4,
            adaptive,
            ..Default::default()
        };
        let coord = Coordinator::from_config(&cfg).unwrap();
        let run = container_roundtrip(&coord, workload(42, nz)).unwrap();
        let mix: Vec<String> =
            run.per_pipeline.iter().map(|(p, n)| format!("{p}x{n}")).collect();
        println!(
            "cont,{mode},{:.2},{:.1},{:.1},{}",
            run.ratio(),
            run.report.throughput_mbs(),
            run.decompress_mbs(),
            mix.join("|")
        );
    }
}
