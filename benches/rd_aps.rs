//! Fig. 6 regeneration: rate-distortion on the APS ptychography-like
//! stacks — the adaptive SZ3-APS against the fixed baselines (3-D blockwise
//! "SZ2.1-3D", linearized 1-D, and the non-adaptive pipelines). Expect:
//! the 3-D compressor wins at high error bounds; past the eb=0.5 knee the
//! time-transposed 1-D path jumps to lossless (infinite PSNR, printed as
//! `inf`); SZ3-APS tracks the envelope.
//!
//! Output: `rd,fig6,<sample>,<pipeline>,<abs_eb>,<bitrate>,<psnr>,<ratio>`

use sz3::datagen::aps::{diffraction_stack, Sample};
use sz3::metrics;
use sz3::pipeline::{self, CompressConf, ErrorBound};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (t, h, w) = if quick { (48, 32, 32) } else { (128, 48, 48) };
    let bounds: &[f64] = if quick {
        &[4.0, 0.4]
    } else {
        &[16.0, 8.0, 4.0, 2.0, 1.0, 0.6, 0.4, 0.2, 0.1]
    };
    println!("# Fig. 6: APS rate-distortion (quick={quick}, stack {t}x{h}x{w})");
    println!("rd,figure,dataset,pipeline,abs_eb,bitrate,psnr,ratio");
    for sample in [Sample::ChipPillar, Sample::FlatChip] {
        let field = diffraction_stack(sample, t, h, w, 42);
        for name in ["sz3-aps", "sz3-lr", "lorenzo-1d"] {
            let c = pipeline::build(name).unwrap();
            for &eb in bounds {
                let conf = CompressConf::new(ErrorBound::Abs(eb));
                let stream = match c.compress(&field, &conf) {
                    Ok(s) => s,
                    Err(e) => {
                        eprintln!("# {name} at {eb}: {e}");
                        continue;
                    }
                };
                let len = stream.len();
                let out = pipeline::decompress_any(&stream).expect("decode");
                let m = metrics::evaluate(&field, &out, len);
                println!(
                    "rd,fig6,{},{name},{eb},{:.4},{:.2},{:.2}",
                    field.name, m.bit_rate, m.psnr, m.ratio
                );
            }
        }
    }
}
