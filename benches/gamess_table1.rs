//! Table 1 regeneration: compression ratio and speed of the three PaSTRI
//! variants on the GAMESS fields at abs eb 1e-10. Expect: ratios ordered
//! sz3-pastri > sz-pastri-zstd > sz-pastri (paper: 10.8 / 9.3 / 8.5 on
//! ff|ff), speeds reversed (the lossless stage + bitplane coding cost).
//!
//! Output: `t1,<field>,<pipeline>,<ratio>,<compress_mbs>,<decompress_mbs>`

use sz3::bench_harness::Bench;
use sz3::datagen::gamess;
use sz3::pipeline::{decompress_any, CompressConf, Compressor, ErrorBound, PastriCompressor};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let bench = if quick { Bench::quick() } else { Bench::default() };
    let n = if quick { 1 << 19 } else { 1 << 21 };
    let eb = 1e-10;
    println!("# Table 1: GAMESS at abs eb {eb:.0e}, {n} doubles/field (quick={quick})");
    println!("t1,field,pipeline,ratio,compress_mbs,decompress_mbs");
    for field in gamess::gamess_dataset(n, 42) {
        let variants: Vec<PastriCompressor> = vec![
            PastriCompressor::sz(),
            PastriCompressor::sz_with_zstd(),
            PastriCompressor::sz3(),
        ];
        for c in &variants {
            let conf = CompressConf::with_radius(ErrorBound::Abs(eb), 64);
            let stream = c.compress(&field, &conf).expect("compress");
            let ratio = field.nbytes() as f64 / stream.len() as f64;
            let (_, comp) = bench.throughput(
                &format!("{}|{}", field.name, c.name()),
                field.nbytes(),
                || c.compress(&field, &conf).unwrap(),
            );
            let (_, dec) = bench.throughput(
                &format!("{}|{}|dec", field.name, c.name()),
                field.nbytes(),
                || decompress_any(&stream).unwrap(),
            );
            println!("t1,{},{},{ratio:.2},{comp:.1},{dec:.1}", field.name, c.name());
        }
    }
}
