//! L1/L2 perf bench: batched block analysis — native Rust vs the
//! AOT-compiled PJRT executable (when `artifacts/` exists). This is the
//! compute hot-spot the three-layer architecture accelerates; §Perf in
//! EXPERIMENTS.md records the before/after.
//!
//! Output: `an,<dims>,<backend>,<blocks_per_s>,<mbs>`

use sz3::bench_harness::Bench;
use sz3::pipeline::analysis::{BlockAnalyzer, NativeAnalyzer};
use sz3::runtime::{PjrtEngine, PjrtService};
use sz3::util::rng::Pcg32;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let bench = if quick { Bench::quick() } else { Bench::default() };
    let nb = if quick { 2048 } else { 8192 };
    let mut rng = Pcg32::seeded(42);
    println!("# block-analysis backend bench, {nb} blocks/call (quick={quick})");
    println!("an,dims,backend,blocks_per_s,mbs");
    let service = {
        let dir = PjrtEngine::default_dir();
        if PjrtEngine::available(&dir) {
            Some(PjrtService::start(&dir).expect("pjrt service"))
        } else {
            eprintln!("# no artifacts; PJRT rows skipped (run `make artifacts`)");
            None
        }
    };
    for dims in [vec![128usize], vec![12usize, 12], vec![6usize, 6, 6]] {
        let block_len: usize = dims.iter().product();
        let blocks: Vec<f64> = (0..nb * block_len).map(|_| rng.uniform(-10.0, 10.0)).collect();
        let bytes = blocks.len() * 8;
        let label = dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x");

        let native = NativeAnalyzer;
        let (s, mbs) = bench.throughput(&format!("native|{label}"), bytes, || {
            native.analyze_batch(&blocks, &dims).unwrap()
        });
        println!("an,{label},native,{:.0},{mbs:.1}", nb as f64 / s.mean.as_secs_f64());

        if let Some(svc) = &service {
            let (s, mbs) = bench.throughput(&format!("pjrt|{label}"), bytes, || {
                svc.analyze(&blocks, &dims).unwrap()
            });
            println!("an,{label},pjrt,{:.0},{mbs:.1}", nb as f64 / s.mean.as_secs_f64());
        }
    }
}
