//! Rate-distortion + measured-selection bench behind `docs/SELECTION.md`:
//!
//! 1. **RD curves per family** — every `DEFAULT_CANDIDATES` pipeline
//!    compresses the same mixed three-stratum corpus (smooth / noise /
//!    flat) at several absolute bounds, printing one grep-able point per
//!    `(family, eb)` so the curves can be plotted straight off the log.
//! 2. **Measured selection vs the per-chunk oracle** — the measured
//!    selector (`JobConfig{measured, optimize: "ratio"}`) packs the
//!    corpus once; the oracle total is the sum over chunks of the
//!    smallest payload any fixed candidate produced for that chunk.
//!    Acceptance bar: selection lands within 2% of the oracle (the
//!    stratified sample must generalize to the full chunk).
//!
//! Output lines:
//!   `rd,<family>,<eb>,<payload_bytes>,<ratio>`
//!   `sel,<mode>,<payload_bytes>,<ratio>,<mix>`
//! plus a machine-readable summary in `BENCH_PR10.json`.

use sz3::bench_harness::{Bench, PerfSummary};
use sz3::config::JobConfig;
use sz3::container::{read_index, AdaptiveChunkSelector};
use sz3::coordinator::Coordinator;
use sz3::data::Field;
use sz3::pipeline::ErrorBound;
use sz3::util::rng::Pcg32;
use std::collections::HashMap;

/// Three chunk-aligned strata so no single family fits every chunk:
/// low-frequency smooth structure, full-range white noise, one constant.
fn mixed_corpus(nz: usize) -> Field {
    let (ny, nx) = (24usize, 24);
    let mut rng = Pcg32::seeded(4242);
    let mut vals = Vec::with_capacity(nz * ny * nx);
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                vals.push(if z < nz / 3 {
                    0.6 * ((z as f32) * 0.21).sin()
                        + 0.5 * ((y as f32) * 0.14).cos()
                        + 0.3 * ((x as f32) * 0.09).sin()
                } else if z < 2 * nz / 3 {
                    rng.uniform(-500.0, 500.0) as f32
                } else {
                    3.25
                });
            }
        }
    }
    Field::f32("mixed", &[nz, ny, nx], vals).unwrap()
}

fn base_cfg(eb: f64) -> JobConfig {
    JobConfig {
        bound: ErrorBound::Abs(eb),
        workers: 4,
        chunk_elems: 24 * 24 * 8, // 8 rows per chunk: chunks stay in-stratum
        queue_depth: 4,
        ..Default::default()
    }
}

/// Compressed payload bytes per chunk index (container framing excluded,
/// so fixed and adaptive runs compare codec output, not index overhead).
fn chunk_payloads(artifact: &[u8]) -> Vec<(usize, usize)> {
    let (index, _) = read_index(artifact).unwrap();
    index.entries.iter().map(|e| (e.chunk_index, e.len)).collect()
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let bench = if quick { Bench::quick() } else { Bench::default() };
    let mut summary = PerfSummary::new();

    let nz = if quick { 48 } else { 96 };
    let field = mixed_corpus(nz);
    let raw_bytes = field.values.to_le_bytes().len();
    println!("# rd_selection bench (quick={quick}, {raw_bytes} raw bytes)");

    // ---- part 1: RD curve per family --------------------------------
    println!("rd,family,eb,payload_bytes,ratio");
    let bounds = [0.01f64, 0.1, 0.5];
    // per-chunk minimum payload over all candidates at the selection eb,
    // collected while the fixed runs happen anyway
    let sel_eb = 0.25f64;
    let mut oracle: HashMap<usize, usize> = HashMap::new();
    for family in AdaptiveChunkSelector::DEFAULT_CANDIDATES {
        for eb in bounds {
            let cfg =
                JobConfig { pipeline: family.to_string(), ..base_cfg(eb) };
            let coord = Coordinator::from_config(&cfg).unwrap();
            let (artifact, _) =
                coord.run_to_container(vec![field.clone()]).unwrap();
            let payload: usize =
                chunk_payloads(&artifact).iter().map(|(_, n)| n).sum();
            println!(
                "rd,{family},{eb},{payload},{:.2}",
                raw_bytes as f64 / payload as f64
            );
            if eb == bounds[1] {
                summary.record(
                    &format!("ratio_{family}"),
                    raw_bytes as f64 / payload as f64,
                );
            }
        }
        let cfg = JobConfig { pipeline: family.to_string(), ..base_cfg(sel_eb) };
        let coord = Coordinator::from_config(&cfg).unwrap();
        let (artifact, _) = coord.run_to_container(vec![field.clone()]).unwrap();
        for (ci, n) in chunk_payloads(&artifact) {
            let slot = oracle.entry(ci).or_insert(usize::MAX);
            *slot = (*slot).min(n);
        }
    }

    // ---- part 2: measured selection vs per-chunk oracle -------------
    let cfg = JobConfig {
        pipeline: "sz3-lr".into(),
        measured: true,
        optimize: "ratio".into(),
        ..base_cfg(sel_eb)
    };
    let coord = Coordinator::from_config(&cfg).unwrap();
    let mut artifact = Vec::new();
    let s = bench.run("measured_pack", || {
        let (a, _) = coord.run_to_container(vec![field.clone()]).unwrap();
        artifact = a;
    });
    let measured_mbs =
        raw_bytes as f64 / s.min.as_secs_f64() / (1024.0 * 1024.0);

    let selection: usize = chunk_payloads(&artifact).iter().map(|(_, n)| n).sum();
    let oracle_total: usize = oracle.values().sum();
    let (index, _) = read_index(&artifact).unwrap();
    let mix: Vec<String> =
        index.per_pipeline().iter().map(|(p, n)| format!("{p}x{n}")).collect();
    println!(
        "sel,measured,{selection},{:.2},{}",
        raw_bytes as f64 / selection as f64,
        mix.join("|")
    );
    println!(
        "sel,oracle,{oracle_total},{:.2},per-chunk-min",
        raw_bytes as f64 / oracle_total as f64
    );

    let overhead_pct =
        100.0 * (selection as f64 - oracle_total as f64) / oracle_total as f64;
    println!("# measured selection vs oracle: {overhead_pct:+.2}%");
    assert!(
        selection as f64 <= oracle_total as f64 * 1.02,
        "measured selection ({selection} B) must land within 2% of the \
         per-chunk oracle ({oracle_total} B); got {overhead_pct:+.2}%"
    );
    assert!(
        index.per_pipeline().len() >= 2,
        "mixed corpus must produce a heterogeneous pipeline mix"
    );

    summary.record("measured_payload_bytes", selection as f64);
    summary.record("oracle_payload_bytes", oracle_total as f64);
    summary.record("selection_vs_oracle_pct", overhead_pct);
    summary.record("measured_ratio", raw_bytes as f64 / selection as f64);
    summary.record("measured_pack_mbs", measured_mbs);
    summary.write_json("BENCH_PR10.json").unwrap();
    println!("# wrote BENCH_PR10.json");
}
