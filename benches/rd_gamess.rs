//! Fig. 4 regeneration: rate-distortion of SZ-Pastri, SZ-Pastri+zstd and
//! SZ3-Pastri on the three GAMESS ERI-like fields. Expect SZ3-Pastri to
//! dominate at ~all bit rates (bitplane unpredictables + lossless stage).
//!
//! Output: `rd,fig4,<field>,<pipeline>,<abs_eb>,<bitrate>,<psnr>,<ratio>`

use sz3::datagen::gamess;
use sz3::metrics;
use sz3::pipeline::{decompress_any, CompressConf, Compressor, ErrorBound, PastriCompressor};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let n = if quick { 1 << 18 } else { 1 << 20 };
    let bounds: &[f64] = if quick {
        &[1e-8, 1e-10]
    } else {
        &[1e-6, 1e-7, 1e-8, 1e-9, 1e-10, 1e-11, 1e-12]
    };
    println!("# Fig. 4: GAMESS rate-distortion (quick={quick})");
    println!("rd,figure,dataset,pipeline,abs_eb,bitrate,psnr,ratio");
    for field in gamess::gamess_dataset(n, 42) {
        let variants: Vec<PastriCompressor> = vec![
            PastriCompressor::sz(),
            PastriCompressor::sz_with_zstd(),
            PastriCompressor::sz3(),
        ];
        for c in &variants {
            for &eb in bounds {
                let conf = CompressConf::with_radius(ErrorBound::Abs(eb), 64);
                let stream = match c.compress(&field, &conf) {
                    Ok(s) => s,
                    Err(e) => {
                        eprintln!("# {} at {eb}: {e}", c.name());
                        continue;
                    }
                };
                let len = stream.len();
                let out = decompress_any(&stream).expect("decode");
                let m = metrics::evaluate(&field, &out, len);
                println!(
                    "rd,fig4,{},{},{eb:.1e},{:.4},{:.2},{:.2}",
                    field.name,
                    c.name(),
                    m.bit_rate,
                    m.psnr,
                    m.ratio
                );
            }
        }
    }
}
