//! HTTP serving bench: ROI request latency and throughput over loopback
//! through `sz3::server`, cold (first touch of each chunk, cache empty)
//! vs warm (every chunk resident in the shared byte-budgeted cache).
//! Exact client-observed percentiles — p50/p99 are computed from the raw
//! per-request sample vector, not the server's bucketed histogram — and
//! the machine-readable `BENCH_PR3.json` perf summary for the CI trend
//! line. The PR's acceptance bar lives here: warm p50 must come in below
//! cold p50.
//!
//! Output: `serve,<case>,<p50_us>,<p99_us>,<rps>,<mbs>`

use sz3::bench_harness::PerfSummary;
use sz3::config::JobConfig;
use sz3::coordinator::Coordinator;
use sz3::data::Field;
use sz3::pipeline::ErrorBound;
use sz3::server::{self, ArtifactStore, HttpClient, StoreOptions};
use sz3::util::prop;
use sz3::util::rng::Pcg32;
use std::time::Instant;

/// Exact percentile over raw latency samples (µs).
fn percentile_us(samples: &mut [u64], q: f64) -> u64 {
    samples.sort_unstable();
    if samples.is_empty() {
        return 0;
    }
    let idx = ((samples.len() - 1) as f64 * q).round() as usize;
    samples[idx]
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let nz = if quick { 96 } else { 256 };
    let (ny, nx) = (64usize, 64);
    let rows_per_chunk = 8;
    let warm_passes = if quick { 3 } else { 10 };
    println!("# serve_http bench (quick={quick})");

    // one artifact: nz x 64 x 64, 8 rows per chunk
    let mut rng = Pcg32::seeded(7042);
    let dims = [nz, ny, nx];
    let field = Field::f32("snapshot", &dims, prop::smooth_field(&mut rng, &dims)).unwrap();
    let cfg = JobConfig {
        pipeline: "sz3-lr".into(),
        bound: ErrorBound::Abs(1e-3),
        workers: 4,
        chunk_elems: ny * nx * rows_per_chunk,
        queue_depth: 4,
        ..Default::default()
    };
    let coord = Coordinator::from_config(&cfg).unwrap();
    let (artifact, report) = coord.run_to_container(vec![field]).unwrap();
    let n_chunks = report.chunks;
    println!("# artifact: {} bytes, {} chunks (ratio {:.2})", artifact.len(), n_chunks, report.ratio());

    let dir = std::env::temp_dir().join(format!("sz3_bench_http_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("snapshot.sz3c"), &artifact).unwrap();

    // cache big enough to hold the full decoded artifact: the warm pass
    // measures the serve path, not eviction churn
    let store = ArtifactStore::open_dir(
        &dir,
        &StoreOptions { cache_bytes: 256 << 20, workers: 2, verify: false },
    )
    .unwrap();
    let handle = server::serve(store, "127.0.0.1:0", 4).unwrap();
    let addr = handle.addr();
    let mut summary = PerfSummary::new();

    // one ROI target per chunk, each spanning exactly one chunk
    let targets: Vec<String> = (0..n_chunks)
        .map(|c| {
            format!(
                "/v1/artifacts/snapshot/fields/snapshot?rows={}..{}",
                c * rows_per_chunk,
                (c + 1) * rows_per_chunk
            )
        })
        .collect();
    let roi_bytes = rows_per_chunk * ny * nx * 4;

    {
        let mut client = HttpClient::connect(addr).unwrap();

        // -- cold: first touch of every chunk decodes it ------------------
        let mut cold = Vec::with_capacity(targets.len());
        for t in &targets {
            let t0 = Instant::now();
            let resp = client.get(t).unwrap();
            cold.push(t0.elapsed().as_micros() as u64);
            assert_eq!(resp.status, 200);
            assert_eq!(resp.body.len(), roi_bytes);
        }
        let cold_p50 = percentile_us(&mut cold, 0.50);
        let cold_p99 = percentile_us(&mut cold, 0.99);
        println!("serve,cold,{cold_p50},{cold_p99},-,-");
        summary.record("serve_cold_p50_us", cold_p50 as f64);
        summary.record("serve_cold_p99_us", cold_p99 as f64);

        // -- warm: every chunk resident, repeated passes ------------------
        let mut warm = Vec::with_capacity(targets.len() * warm_passes);
        let wall = Instant::now();
        for _ in 0..warm_passes {
            for t in &targets {
                let t0 = Instant::now();
                let resp = client.get(t).unwrap();
                warm.push(t0.elapsed().as_micros() as u64);
                assert_eq!(resp.status, 200);
            }
        }
        let wall = wall.elapsed().as_secs_f64().max(1e-9);
        let n_warm = warm.len();
        let warm_p50 = percentile_us(&mut warm, 0.50);
        let warm_p99 = percentile_us(&mut warm, 0.99);
        let rps = n_warm as f64 / wall;
        let mbs = (n_warm * roi_bytes) as f64 / 1e6 / wall;
        println!("serve,warm,{warm_p50},{warm_p99},{rps:.0},{mbs:.1}");
        summary.record("serve_warm_p50_us", warm_p50 as f64);
        summary.record("serve_warm_p99_us", warm_p99 as f64);
        summary.record("serve_warm_rps", rps);
        summary.record("serve_warm_mbs", mbs);

        // the acceptance bar: the cache must make repeat queries cheaper
        assert!(
            warm_p50 < cold_p50,
            "warm p50 {warm_p50}µs must beat cold p50 {cold_p50}µs"
        );

        // server-side view for the log: decodes happened once, hits after
        let resp = client.get("/statsz").unwrap();
        println!("# statsz: {}", resp.text().unwrap());
    } // drop the client connection before shutting the server down
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);

    summary.write_json("BENCH_PR3.json").unwrap();
    println!("# perf summary written to BENCH_PR3.json");
    println!("{}", summary.to_json());
}
