//! Fig. 7 regeneration: rate-distortion of SZ3-LR, SZ3-Interp and
//! SZ3-Truncation across the eight survey datasets. Expect: Truncation
//! worst everywhere; Interp ahead of LR at low bit rates (esp. Miranda);
//! LR competitive at high-accuracy settings (Scale, Hurricane).
//!
//! Output: `rd,fig7,<dataset>,<pipeline>,<rel_eb>,<bitrate>,<psnr>,<ratio>`

use sz3::bench_harness::{print_rd_series, rd_sweep};
use sz3::pipeline;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let bounds: Vec<f64> = if quick {
        vec![1e-2, 1e-3, 1e-4]
    } else {
        vec![5e-2, 1e-2, 5e-3, 1e-3, 5e-4, 1e-4, 5e-5, 1e-5]
    };
    println!("# Fig. 7: rate-distortion on the survey datasets (quick={quick})");
    println!("rd,figure,dataset,pipeline,rel_eb,bitrate,psnr,ratio");
    for ds in sz3::datagen::survey(42) {
        for name in ["sz3-lr", "sz3-interp", "sz3-truncation"] {
            let c = pipeline::build(name).unwrap();
            let pts = rd_sweep(c.as_ref(), &ds.fields[0], &bounds, 32768);
            print_rd_series("fig7", ds.name, name, &pts);
        }
    }
}
