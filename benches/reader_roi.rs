//! Indexed-seek ROI bench: region extraction through the random-access
//! container reader vs whole-container decompression, over in-memory and
//! file-backed sources, cold and cache-warm. Also emits the machine-
//! readable `BENCH_PR2.json` perf summary (compress / decompress /
//! ROI-read throughput) for the CI trend line.
//!
//! Output: `roi,<case>,<mbs>,<chunks_decoded>,<bytes_fetched>`

use sz3::bench_harness::{Bench, PerfSummary};
use sz3::config::JobConfig;
use sz3::container;
use sz3::coordinator::Coordinator;
use sz3::data::Field;
use sz3::pipeline::ErrorBound;
use sz3::reader::{ContainerReader, FileSource, PrefetchSource};
use sz3::util::prop;
use sz3::util::rng::Pcg32;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let bench = if quick { Bench::quick() } else { Bench::default() };
    let nz = if quick { 96 } else { 384 };
    let (ny, nx) = (64usize, 64);
    println!("# reader ROI bench (quick={quick})");

    let mut rng = Pcg32::seeded(1042);
    let dims = [nz, ny, nx];
    let field = Field::f32("snapshot", &dims, prop::smooth_field(&mut rng, &dims)).unwrap();
    let raw_bytes = field.nbytes();

    let cfg = JobConfig {
        pipeline: "sz3-lr".into(),
        bound: ErrorBound::Abs(1e-3),
        workers: 4,
        chunk_elems: ny * nx * 8, // 8 rows per chunk
        queue_depth: 4,
        ..Default::default()
    };
    let coord = Coordinator::from_config(&cfg).unwrap();
    let mut summary = PerfSummary::new();

    // compress throughput (coordinator -> v2 container)
    let t0 = std::time::Instant::now();
    let (artifact, report) = coord.run_to_container(vec![field]).unwrap();
    let compress_mbs = raw_bytes as f64 / 1e6 / t0.elapsed().as_secs_f64().max(1e-9);
    let chunks = report.chunks;
    println!("# {} chunks, artifact {} bytes (ratio {:.2})", chunks, artifact.len(), report.ratio());
    summary.record("compress_mbs", compress_mbs);
    summary.record("ratio", report.ratio());

    // full parallel decompression (batch path, through the reader)
    let (_, full_mbs) = bench.throughput("decompress_container(full)", raw_bytes, || {
        container::decompress_container(&artifact, cfg.workers).unwrap()
    });
    summary.record("decompress_mbs", full_mbs);
    println!("roi,full,{full_mbs:.1},{chunks},{}", artifact.len());

    // ROI covering one chunk: cold reader per iteration (slice source)
    let roi = 2 * 8..3 * 8; // exactly chunk 2
    let roi_bytes = (roi.end - roi.start) * ny * nx * 4;
    let (_, cold_mbs) = bench.throughput("read_region(cold, slice)", roi_bytes, || {
        let r = ContainerReader::from_slice(&artifact).unwrap();
        r.read_region("snapshot", roi.clone()).unwrap()
    });
    {
        let r = ContainerReader::from_slice(&artifact).unwrap();
        r.read_region("snapshot", roi.clone()).unwrap();
        let s = r.stats();
        println!("roi,cold_slice,{cold_mbs:.1},{},{}", s.chunks_decoded, s.bytes_fetched);
        summary.record("roi_cold_mbs", cold_mbs);
    }

    // ROI with a warm LRU cache: the serve-path steady state
    let warm_reader = ContainerReader::from_slice(&artifact)
        .unwrap()
        .with_cache_bytes(64 << 20);
    warm_reader.read_region("snapshot", roi.clone()).unwrap();
    let (_, warm_mbs) = bench.throughput("read_region(warm cache)", roi_bytes, || {
        warm_reader.read_region("snapshot", roi.clone()).unwrap()
    });
    let s = warm_reader.stats();
    println!("roi,warm_cache,{warm_mbs:.1},{},{}", s.chunks_decoded, s.bytes_fetched);
    summary.record("roi_warm_mbs", warm_mbs);

    // ROI through a prefetching file source: the on-disk serving shape
    let path = std::env::temp_dir().join(format!("sz3_reader_roi_{}.sz3c", std::process::id()));
    std::fs::write(&path, &artifact).unwrap();
    let (_, file_mbs) = bench.throughput("read_region(cold, file)", roi_bytes, || {
        let src = PrefetchSource::new(
            Box::new(FileSource::open(&path).unwrap()),
            1 << 20,
        );
        let r = ContainerReader::new(Box::new(src)).unwrap();
        r.read_region("snapshot", roi.clone()).unwrap()
    });
    println!("roi,cold_file,{file_mbs:.1},1,-");
    summary.record("roi_file_mbs", file_mbs);
    let _ = std::fs::remove_file(&path);

    summary.write_json("BENCH_PR2.json").unwrap();
    println!("# perf summary written to BENCH_PR2.json");
    println!("{}", summary.to_json());
}
