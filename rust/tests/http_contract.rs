//! HTTP status-code contract, table-driven over a real loopback socket:
//! the `?snapshot=` parameter (valid / out-of-range → 404 / malformed →
//! 400) layered on the existing 400/404/416 matrix, against a store
//! holding both a v3 delta series and a plain single-snapshot artifact;
//! plus the write-path lifecycle matrix (400/413/429/408, replace and
//! delete semantics) against a writable registry with tight limits.

use sz3::config::{JobConfig, Json};
use sz3::container::fixtures::smooth_series;
use sz3::coordinator::Coordinator;
use sz3::pipeline::ErrorBound;
use sz3::reader::ContainerReader;
use sz3::server::{
    self, ArtifactStore, HttpClient, Registry, ServeOptions, StoreOptions,
};

/// Build the two artifacts: "series" (3 snapshots, delta on) and "plain"
/// (one snapshot), both one field "rho" of 12×12×12, 4 chunks/snapshot.
fn build_artifacts() -> (Vec<u8>, Vec<u8>) {
    let cfg = JobConfig {
        pipeline: "sz3-lr".into(),
        bound: ErrorBound::Abs(1e-3),
        workers: 2,
        chunk_elems: 3 * 144,
        queue_depth: 2,
        ..Default::default()
    };
    let coord = Coordinator::from_config(&cfg).unwrap();
    let snaps = smooth_series(828, &[12, 12, 12], 3, 0.01, "rho");
    let plain_field = snaps[0].fields[0].clone();
    let (series, _) = coord.run_series_to_container(snaps, true).unwrap();
    let (plain, _) = coord.run_to_container(vec![plain_field]).unwrap();
    (series, plain)
}

#[test]
fn snapshot_and_error_matrix_over_loopback() {
    let (series, plain) = build_artifacts();
    let dir =
        std::env::temp_dir().join(format!("sz3_http_contract_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("series.sz3c"), &series).unwrap();
    std::fs::write(dir.join("plain.sz3c"), &plain).unwrap();

    let store = ArtifactStore::open_dir(
        &dir,
        &StoreOptions { cache_bytes: 8 << 20, workers: 2, verify: true },
    )
    .unwrap();
    let handle = server::serve(store, "127.0.0.1:0", 2).unwrap();
    let addr = handle.addr();
    {
        let mut client = HttpClient::connect(addr).unwrap();

        // table-driven status contract
        let cases: &[(&str, u16)] = &[
            // catalog + metadata
            ("/v1/artifacts", 200),
            ("/v1/artifacts/series", 200),
            ("/v1/artifacts/plain", 200),
            ("/v1/artifacts/none", 404),
            ("/v2/artifacts", 404),
            // snapshot parameter: valid
            ("/v1/artifacts/series/fields/rho?snapshot=0", 200),
            ("/v1/artifacts/series/fields/rho?snapshot=1", 200),
            ("/v1/artifacts/series/fields/rho?snapshot=2&rows=2..7", 200),
            ("/v1/artifacts/plain/fields/rho?snapshot=0", 200),
            // snapshot parameter: out of range → 404
            ("/v1/artifacts/series/fields/rho?snapshot=3", 404),
            ("/v1/artifacts/series/fields/rho?snapshot=99", 404),
            ("/v1/artifacts/plain/fields/rho?snapshot=1", 404),
            // snapshot parameter: malformed → 400
            ("/v1/artifacts/series/fields/rho?snapshot=abc", 400),
            ("/v1/artifacts/series/fields/rho?snapshot=-1", 400),
            ("/v1/artifacts/series/fields/rho?snapshot=1.5", 400),
            ("/v1/artifacts/series/fields/rho?snapshot=", 400),
            // the existing rows/format matrix still holds with snapshots
            ("/v1/artifacts/series/fields/rho?rows=9..99&snapshot=1", 416),
            ("/v1/artifacts/series/fields/rho?rows=5..5", 416),
            ("/v1/artifacts/series/fields/rho?rows=9..7", 416),
            ("/v1/artifacts/series/fields/rho?rows=oops", 400),
            ("/v1/artifacts/series/fields/rho?format=xml", 400),
            ("/v1/artifacts/series/fields/nope", 404),
            // raw chunk passthrough
            ("/v1/artifacts/series/raw?chunk=0", 200),
            ("/v1/artifacts/series/raw?chunk=999", 404),
            ("/v1/artifacts/series/raw?chunk=zap", 400),
            ("/v1/artifacts/series/raw", 400),
            // liveness
            ("/healthz", 200),
            ("/statsz", 200),
        ];
        for (target, expect) in cases {
            let resp = client.get(target).unwrap();
            assert_eq!(resp.status, *expect, "GET {target}");
        }

        // 416 keeps its Content-Range header on a snapshot request
        let resp = client
            .get("/v1/artifacts/series/fields/rho?rows=9..99&snapshot=1")
            .unwrap();
        assert_eq!(resp.header("content-range"), Some("rows */12"));

        // snapshot ROIs serve the exact read_region_at bytes, and each
        // snapshot's bytes differ (the series actually evolves)
        let local = ContainerReader::from_slice(&series).unwrap();
        let mut bodies = Vec::new();
        for snap in 0..3 {
            let resp = client
                .get(&format!("/v1/artifacts/series/fields/rho?rows=2..7&snapshot={snap}"))
                .unwrap();
            assert_eq!(resp.status, 200);
            assert_eq!(resp.header("x-sz3-snapshot"), Some(format!("{snap}")).as_deref());
            let oracle = local.read_region_at(snap, "rho", 2..7).unwrap();
            assert_eq!(resp.body, oracle.values.to_le_bytes(), "snapshot {snap}");
            bodies.push(resp.body);
        }
        assert_ne!(bodies[0], bodies[2], "snapshots must hold distinct data");

        // metadata advertises the snapshot axis
        let resp = client.get("/v1/artifacts/series").unwrap();
        let j = Json::parse(resp.text().unwrap()).unwrap();
        let snaps = j.get("snapshots").unwrap().as_arr().unwrap();
        assert_eq!(snaps.len(), 3);
        assert_eq!(snaps[2].get("tag").unwrap().as_str(), Some("t2"));

        // statsz reports delta resolutions after series reads
        let resp = client.get("/statsz").unwrap();
        let j = Json::parse(resp.text().unwrap()).unwrap();
        let s = j.get("artifacts").unwrap().get("series").unwrap();
        assert!(s.get("delta_applied").unwrap().as_usize().is_some());

        // conditional GET on raw chunks: ETag = chunk CRC-32 (quoted hex),
        // matching If-None-Match → 304 with an empty body, stale → 200
        let resp = client.get("/v1/artifacts/series/raw?chunk=0").unwrap();
        assert_eq!(resp.status, 200);
        let etag = resp.header("etag").expect("v3 chunks carry ETags").to_string();
        let crc = sz3::container::read_index_meta(&series)
            .unwrap()
            .index
            .entries[0]
            .crc32
            .unwrap();
        assert_eq!(etag, format!("\"{crc:08x}\""));
        let resp = client
            .get_with_headers(
                "/v1/artifacts/series/raw?chunk=0",
                &[("If-None-Match", etag.as_str())],
            )
            .unwrap();
        assert_eq!(resp.status, 304, "matching validator must short-circuit");
        assert!(resp.body.is_empty());
        assert_eq!(resp.header("etag"), Some(etag.as_str()));
        let resp = client
            .get_with_headers(
                "/v1/artifacts/series/raw?chunk=0",
                &[("If-None-Match", "\"00000000\"")],
            )
            .unwrap();
        assert_eq!(resp.status, 200, "stale validator gets the payload");

        // the chunk map's pipeline field is the canonical per-chunk spec
        let resp = client.get("/v1/artifacts/plain").unwrap();
        let j = Json::parse(resp.text().unwrap()).unwrap();
        let map = j.get("fields").unwrap().as_arr().unwrap()[0]
            .get("chunk_map")
            .unwrap()
            .as_arr()
            .unwrap();
        let canon = sz3::pipeline::canonical("sz3-lr").unwrap();
        assert_eq!(map[0].get("pipeline").unwrap().as_str(), Some(canon.as_str()));

        // json ROI responses negotiate gzip over the real socket: the
        // encoded body is smaller, decodes to the identity body, and raw
        // format responses never carry an encoding
        let target = "/v1/artifacts/plain/fields/rho?rows=0..6&format=json";
        let plain_resp = client.get(target).unwrap();
        assert_eq!(plain_resp.status, 200);
        assert_eq!(plain_resp.header("vary"), Some("Accept-Encoding"));
        assert_eq!(plain_resp.header("content-encoding"), None);
        let resp = client
            .get_with_headers(target, &[("Accept-Encoding", "gzip, br")])
            .unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("content-encoding"), Some("gzip"));
        assert!(resp.body.len() < plain_resp.body.len() / 2);
        use std::io::Read as _;
        let mut dec = flate2::read::GzDecoder::new(resp.body.as_slice());
        let mut decoded = Vec::new();
        dec.read_to_end(&mut decoded).unwrap();
        assert_eq!(decoded, plain_resp.body);
        let resp = client
            .get_with_headers(
                "/v1/artifacts/plain/fields/rho?rows=0..6",
                &[("Accept-Encoding", "gzip")],
            )
            .unwrap();
        assert_eq!(resp.header("content-encoding"), None, "raw stays identity");
    }
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn request_ids_byte_ranges_and_metrics_over_loopback() {
    let (_, plain) = build_artifacts();
    let dir = std::env::temp_dir()
        .join(format!("sz3_http_contract_obs_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("plain.sz3c"), &plain).unwrap();

    let store = ArtifactStore::open_dir(
        &dir,
        &StoreOptions { cache_bytes: 8 << 20, workers: 2, verify: true },
    )
    .unwrap();
    let handle = server::serve(store, "127.0.0.1:0", 2).unwrap();
    let addr = handle.addr();
    {
        let mut client = HttpClient::connect(addr).unwrap();

        // every response carries a generated X-Request-Id, and two
        // requests never share one
        let a = client.get("/healthz").unwrap();
        let b = client.get("/healthz").unwrap();
        let id_a = a.header("x-request-id").expect("generated id").to_string();
        let id_b = b.header("x-request-id").expect("generated id").to_string();
        assert!(id_a.starts_with("sz3-"), "generated id shape: {id_a}");
        assert_ne!(id_a, id_b, "ids must be unique per request");

        // a well-formed client-supplied id is echoed verbatim
        let resp = client
            .get_with_headers("/healthz", &[("X-Request-Id", "trace-Abc_1.23")])
            .unwrap();
        assert_eq!(resp.header("x-request-id"), Some("trace-Abc_1.23"));

        // a malformed one (unsafe chars) is replaced, not reflected
        let resp = client
            .get_with_headers("/healthz", &[("X-Request-Id", "bad id\"zap")])
            .unwrap();
        let got = resp.header("x-request-id").expect("replacement id");
        assert!(got.starts_with("sz3-"), "malformed id must be regenerated: {got}");

        // error responses carry the id too
        let resp = client
            .get_with_headers("/v1/artifacts/none", &[("X-Request-Id", "err-1")])
            .unwrap();
        assert_eq!(resp.status, 404);
        assert_eq!(resp.header("x-request-id"), Some("err-1"));

        // single byte ranges on raw chunk passthrough
        let full = client.get("/v1/artifacts/plain/raw?chunk=0").unwrap();
        assert_eq!(full.status, 200);
        assert_eq!(full.header("accept-ranges"), Some("bytes"));
        let total = full.body.len();
        let resp = client
            .get_with_headers(
                "/v1/artifacts/plain/raw?chunk=0",
                &[("Range", "bytes=0-9")],
            )
            .unwrap();
        assert_eq!(resp.status, 206);
        assert_eq!(resp.body, full.body[..10]);
        assert_eq!(
            resp.header("content-range"),
            Some(format!("bytes 0-9/{total}").as_str())
        );
        let resp = client
            .get_with_headers(
                "/v1/artifacts/plain/raw?chunk=0",
                &[("Range", "bytes=-4")],
            )
            .unwrap();
        assert_eq!(resp.status, 206, "suffix range");
        assert_eq!(resp.body, full.body[total - 4..]);
        let resp = client
            .get_with_headers(
                "/v1/artifacts/plain/raw?chunk=0",
                &[("Range", format!("bytes={total}-").as_str())],
            )
            .unwrap();
        assert_eq!(resp.status, 416, "first byte past the end");
        assert_eq!(
            resp.header("content-range"),
            Some(format!("bytes */{total}").as_str())
        );
        let resp = client
            .get_with_headers(
                "/v1/artifacts/plain/raw?chunk=0",
                &[("Range", "bytes=0-3,5-9")],
            )
            .unwrap();
        assert_eq!(resp.status, 200, "multi-range is ignored, full body served");
        assert_eq!(resp.body, full.body);

        // /metricsz serves Prometheus text exposition over the wire
        let resp = client.get("/metricsz").unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(
            resp.header("content-type"),
            Some("text/plain; version=0.0.4; charset=utf-8")
        );
        let text = resp.text().unwrap();
        assert!(text.contains("# TYPE sz3_http_requests_total counter"));
        assert!(text.contains("# TYPE sz3_cache_hits_total counter"));
        let families = text.lines().filter(|l| l.starts_with("# TYPE ")).count();
        assert!(families >= 15, "expected >= 15 families, got {families}");
        // this very connection's requests are visible in the counters
        let raw_count = text
            .lines()
            .find(|l| l.starts_with("sz3_http_requests_total{endpoint=\"raw\""))
            .and_then(|l| l.rsplit_once(' '))
            .and_then(|(_, v)| v.parse::<f64>().ok())
            .unwrap_or(0.0);
        assert!(raw_count >= 5.0, "raw requests recorded: {raw_count}");
    }
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Frame an ingest body: `[u32le json_len][json params][le f32 data]`.
fn ingest_body(params: &str, values: &[f32]) -> Vec<u8> {
    let mut body = (params.len() as u32).to_le_bytes().to_vec();
    body.extend_from_slice(params.as_bytes());
    for v in values {
        body.extend_from_slice(&v.to_le_bytes());
    }
    body
}

#[test]
fn write_lifecycle_contract_over_loopback() {
    use std::sync::Arc;
    use std::time::Duration;

    let dir = std::env::temp_dir()
        .join(format!("sz3_http_contract_write_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let reg = Arc::new(
        Registry::open_dir(
            &dir,
            &StoreOptions { cache_bytes: 4 << 20, workers: 2, verify: true },
        )
        .unwrap()
        .with_max_inflight_ingests(1),
    );
    let opts = ServeOptions {
        threads: 2,
        max_body: 64 << 10, // 64 KiB: easy to overflow from a test
        max_conns: 16,
        read_timeout: Duration::from_secs(1),
        ..Default::default()
    };
    let handle =
        server::serve_registry(Arc::clone(&reg), "127.0.0.1:0", opts).unwrap();
    let addr = handle.addr();
    {
        let params = "{\"dims\":[8,64],\"fields\":[\"rho\"],\
             \"pipeline\":\"sz3-lr\",\"bound\":{\"mode\":\"abs\",\"value\":0.001},\
             \"chunk_elems\":256}";
        let values: Vec<f32> = (0..512).map(|i| (i as f32) * 0.01).collect();
        let good = ingest_body(params, &values);
        let mut c = HttpClient::connect(addr).unwrap();

        // bad JSON params → 400, and the failure publishes nothing
        let resp = c.put("/v1/artifacts/w", &ingest_body("{oops", &values)).unwrap();
        assert_eq!(resp.status, 400);
        assert_eq!(c.get("/v1/artifacts/w").unwrap().status, 404);

        // data shorter than the framing requires → 400
        let resp = c.put("/v1/artifacts/w", &ingest_body(params, &values[..99])).unwrap();
        assert_eq!(resp.status, 400);

        // a declared body over the cap → 413 before any body byte is read
        // (the server closes that connection, so reconnect afterwards)
        let big = vec![0u8; (64 << 10) + 1];
        let resp = c.put("/v1/artifacts/w", &big).unwrap();
        assert_eq!(resp.status, 413);
        let mut c = HttpClient::connect(addr).unwrap();

        // create → 201; duplicate id → replace → 200
        assert_eq!(c.put("/v1/artifacts/w", &good).unwrap().status, 201);
        let resp = c.put("/v1/artifacts/w", &good).unwrap();
        assert_eq!(resp.status, 200, "duplicate id replaces");
        let j = Json::parse(resp.text().unwrap()).unwrap();
        assert_eq!(j.get("replaced").unwrap().as_bool(), Some(true));

        // delete-then-GET → 404 everywhere, second delete → 404
        assert_eq!(c.delete("/v1/artifacts/w").unwrap().status, 200);
        assert_eq!(c.get("/v1/artifacts/w").unwrap().status, 404);
        assert_eq!(c.get("/v1/artifacts/w/fields/rho").unwrap().status, 404);
        assert_eq!(c.delete("/v1/artifacts/w").unwrap().status, 404);

        // all ingest slots busy → 429 with a Retry-After hint
        let permit = reg.try_begin_ingest().unwrap();
        let resp = c.put("/v1/artifacts/w", &good).unwrap();
        assert_eq!(resp.status, 429);
        assert_eq!(resp.header("retry-after"), Some("1"));
        drop(permit);
        assert_eq!(c.put("/v1/artifacts/w", &good).unwrap().status, 201);

        // a peer that stalls mid-request (complete request line, then
        // silence) gets 408 once the read timeout fires
        use std::io::{Read as _, Write as _};
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.write_all(b"GET /healthz HTTP/1.1\r\nHost: sz").unwrap();
        s.flush().unwrap();
        let mut raw = Vec::new();
        s.read_to_end(&mut raw).unwrap();
        let head = String::from_utf8_lossy(&raw);
        assert!(
            head.starts_with("HTTP/1.1 408 "),
            "stalled request must answer 408: {head:?}"
        );

        // writable /healthz advertises the write path
        let resp = c.get("/healthz").unwrap();
        let j = Json::parse(resp.text().unwrap()).unwrap();
        assert_eq!(j.get("writable").unwrap().as_bool(), Some(true));
        assert!(j.get("generation").unwrap().as_usize().unwrap() >= 1);
    }
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
