//! Production write-path guarantees over a real loopback socket: the
//! epoch-pointer registry swap must never stall or corrupt concurrent
//! readers, deletes must evict their cache scope with exact accounting,
//! and a client killed mid-upload must leave no trace (no debris on
//! disk, no epoch bump, nothing a rescan could pick up).

use std::io::Write as _;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;
use sz3::config::Json;
use sz3::server::{self, HttpClient, Registry, ServeOptions, StoreOptions};

/// Frame an ingest body: `[u32le json_len][json params][le f32 data]`.
fn ingest_body(params: &str, values: &[f32]) -> Vec<u8> {
    let mut body = (params.len() as u32).to_le_bytes().to_vec();
    body.extend_from_slice(params.as_bytes());
    for v in values {
        body.extend_from_slice(&v.to_le_bytes());
    }
    body
}

const HOT_PARAMS: &str = "{\"dims\":[64,256],\"fields\":[\"rho\"],\
     \"pipeline\":\"sz3-lr\",\"bound\":{\"mode\":\"abs\",\"value\":0.001},\
     \"chunk_elems\":512}";

fn hot_values(base: f32) -> Vec<f32> {
    (0..64 * 256).map(|i| base + (i as f32) * 1e-3).collect()
}

fn temp_serve(tag: &str) -> (std::path::PathBuf, Arc<Registry>, server::ServerHandle) {
    let dir = std::env::temp_dir()
        .join(format!("sz3_write_path_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let reg = Arc::new(
        Registry::open_dir(
            &dir,
            &StoreOptions { cache_bytes: 16 << 20, workers: 2, verify: true },
        )
        .unwrap(),
    );
    let opts = ServeOptions {
        threads: 4,
        max_body: 16 << 20,
        max_conns: 64,
        read_timeout: Duration::from_secs(5),
        ..Default::default()
    };
    let handle =
        server::serve_registry(Arc::clone(&reg), "127.0.0.1:0", opts).unwrap();
    (dir, reg, handle)
}

/// The acceptance bar for the registry swap: concurrent ROI reads during
/// a continuous replace loop always complete (no stall) and every body
/// is bit-identical to exactly one published snapshot — never a blend.
#[test]
fn replace_race_serves_bit_exact_snapshots() {
    let (dir, reg, handle) = temp_serve("replace_race");
    let addr = handle.addr();
    let roi = "/v1/artifacts/hot/fields/rho?rows=0..64";

    // establish the two oracle bodies (compression is deterministic, so
    // re-publishing the same input always serves these exact bytes)
    let mut c = HttpClient::connect(addr).unwrap();
    let body_a = ingest_body(HOT_PARAMS, &hot_values(0.0));
    let body_b = ingest_body(HOT_PARAMS, &hot_values(7.5));
    assert_eq!(c.put("/v1/artifacts/hot", &body_a).unwrap().status, 201);
    let oracle_a = c.get(roi).unwrap();
    assert_eq!(oracle_a.status, 200);
    assert_eq!(c.put("/v1/artifacts/hot", &body_b).unwrap().status, 200);
    let oracle_b = c.get(roi).unwrap();
    assert_eq!(oracle_b.status, 200);
    assert_ne!(oracle_a.body, oracle_b.body, "the two epochs must differ");
    let gen_before = reg.generation();

    let stop = Arc::new(AtomicBool::new(false));
    let mut readers = Vec::new();
    for _ in 0..4 {
        let stop = Arc::clone(&stop);
        let (a, b) = (oracle_a.body.clone(), oracle_b.body.clone());
        readers.push(std::thread::spawn(move || {
            let mut client = HttpClient::connect(addr).unwrap();
            let mut reads = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let resp = client.get(roi).unwrap();
                assert_eq!(resp.status, 200, "reads never fail mid-replace");
                assert!(
                    resp.body == a || resp.body == b,
                    "response must be bit-exactly one snapshot, not a blend"
                );
                reads += 1;
            }
            reads
        }));
    }

    const REPLACES: u64 = 12;
    for i in 0..REPLACES {
        let body = if i % 2 == 0 { &body_a } else { &body_b };
        let resp = c.put("/v1/artifacts/hot", body).unwrap();
        assert_eq!(resp.status, 200, "replace #{i}");
    }
    stop.store(true, Ordering::Relaxed);
    let total: u64 = readers.into_iter().map(|r| r.join().unwrap()).sum();
    assert!(total > 0, "readers must have observed the replace window");
    assert_eq!(
        reg.generation(),
        gen_before + REPLACES,
        "every replace bumps the epoch exactly once"
    );

    drop(c); // close the keep-alive connection so shutdown is immediate
    handle.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Delete evicts the artifact's private cache scope with exact
/// accounting, and a publish/delete flap never yields a wrong read:
/// every response is the full oracle body or a clean 404.
#[test]
fn delete_race_and_exact_cache_eviction() {
    let (dir, reg, handle) = temp_serve("delete_race");
    let addr = handle.addr();
    let roi = "/v1/artifacts/flap/fields/rho?rows=0..64";
    let cache = Arc::clone(reg.snapshot().cache());
    let (len0, bytes0) = (cache.len(), cache.bytes());

    let mut c = HttpClient::connect(addr).unwrap();
    let body = ingest_body(HOT_PARAMS, &hot_values(3.25));
    assert_eq!(c.put("/v1/artifacts/flap", &body).unwrap().status, 201);
    let oracle = c.get(roi).unwrap();
    assert_eq!(oracle.status, 200);
    assert!(cache.bytes() > bytes0, "the ROI read populated the cache");

    // exact accounting: eviction returns the cache to its prior state
    assert_eq!(c.delete("/v1/artifacts/flap").unwrap().status, 200);
    assert_eq!(cache.len(), len0, "delete evicts every key of its scope");
    assert_eq!(cache.bytes(), bytes0, "and reclaims every byte");
    assert_eq!(c.get(roi).unwrap().status, 404);

    let stop = Arc::new(AtomicBool::new(false));
    let mut readers = Vec::new();
    for _ in 0..3 {
        let stop = Arc::clone(&stop);
        let oracle = oracle.body.clone();
        readers.push(std::thread::spawn(move || {
            let mut client = HttpClient::connect(addr).unwrap();
            let (mut hits, mut misses) = (0u64, 0u64);
            while !stop.load(Ordering::Relaxed) {
                let resp = client.get(roi).unwrap();
                match resp.status {
                    200 => {
                        assert_eq!(resp.body, oracle, "no partial publishes");
                        hits += 1;
                    }
                    404 => misses += 1,
                    other => panic!("unexpected status {other} during flap"),
                }
            }
            (hits, misses)
        }));
    }
    for i in 0..8 {
        assert_eq!(c.put("/v1/artifacts/flap", &body).unwrap().status, 201, "#{i}");
        assert_eq!(c.delete("/v1/artifacts/flap").unwrap().status, 200, "#{i}");
    }
    stop.store(true, Ordering::Relaxed);
    let mut total = 0u64;
    for r in readers {
        let (hits, misses) = r.join().unwrap();
        total += hits + misses;
    }
    assert!(total > 0, "readers must have observed the flap window");

    // with no reads in flight, accounting is exact again: one more
    // publish/read/delete cycle reclaims precisely what it added
    // (readers that outlived a delete may have re-cached a retired
    // scope above, so compare against the post-race baseline)
    let (len1, bytes1) = (cache.len(), cache.bytes());
    assert_eq!(c.put("/v1/artifacts/flap", &body).unwrap().status, 201);
    assert_eq!(c.get(roi).unwrap().status, 200);
    assert!(cache.bytes() > bytes1);
    assert_eq!(c.delete("/v1/artifacts/flap").unwrap().status, 200);
    assert_eq!(cache.len(), len1, "delete evicts exactly its own scope");
    assert_eq!(cache.bytes(), bytes1);

    drop(c); // close the keep-alive connection so shutdown is immediate
    handle.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// A client killed mid-upload must leave nothing behind: no staged file
/// on disk, no epoch bump, and nothing for a rescan to pick up.
#[test]
fn crash_mid_ingest_leaves_no_trace() {
    let (dir, reg, handle) = temp_serve("crash");
    let addr = handle.addr();

    let mut c = HttpClient::connect(addr).unwrap();
    let body = ingest_body(HOT_PARAMS, &hot_values(0.5));
    assert_eq!(c.put("/v1/artifacts/keep", &body).unwrap().status, 201);
    let gen0 = reg.generation();

    // send the headers and a sliver of the body, then vanish
    let mut s = TcpStream::connect(addr).unwrap();
    let head = format!(
        "PUT /v1/artifacts/ghost HTTP/1.1\r\nHost: sz3\r\n\
         Content-Length: {}\r\n\r\n",
        body.len()
    );
    s.write_all(head.as_bytes()).unwrap();
    s.write_all(&body[..16]).unwrap();
    s.flush().unwrap();
    drop(s);
    std::thread::sleep(Duration::from_millis(200));

    assert_eq!(reg.generation(), gen0, "aborted upload must not bump the epoch");
    assert!(reg.snapshot().get("ghost").is_none());
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        assert_eq!(
            path.extension().and_then(|e| e.to_str()),
            Some("sz3c"),
            "no staged debris may survive: {path:?}"
        );
    }

    // a foreign partial in staged style and a corrupt .sz3c are both
    // invisible to rescan — it only ever publishes verified containers
    std::fs::write(dir.join(".part.ingest-9-9"), b"partial").unwrap();
    std::fs::write(dir.join("junk.sz3c"), b"not a container").unwrap();
    let resp = c.post("/v1/admin/rescan", &[]).unwrap();
    assert_eq!(resp.status, 200);
    let j = Json::parse(resp.text().unwrap()).unwrap();
    assert_eq!(j.get("added").unwrap().as_usize(), Some(0), "nothing added");
    assert_eq!(j.get("kept").unwrap().as_usize(), Some(1), "keep survives");
    let list = c.get("/v1/artifacts").unwrap();
    let j = Json::parse(list.text().unwrap()).unwrap();
    let ids: Vec<&str> = j
        .get("artifacts")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|a| a.get("id").unwrap().as_str().unwrap())
        .collect();
    assert_eq!(ids, ["keep"], "partials and junk never serve");

    drop(c); // close the keep-alive connection so shutdown is immediate
    handle.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}
