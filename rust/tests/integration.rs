//! Cross-module integration tests: full pipelines over realistic datagen
//! workloads, stream format stability, coordinator + runtime composition,
//! and the paper's qualitative claims as executable assertions.

use sz3::coordinator::{reassemble, CompressedChunk, Coordinator};
use sz3::config::JobConfig;
use sz3::data::{Field, FieldValues};
use sz3::metrics;
use sz3::pipeline::{
    self, decompress_any, peek_header, CompressConf, Compressor, ErrorBound,
};
use sz3::util::rng::Pcg32;
use std::collections::HashMap;

fn check_bound(field: &Field, restored: &Field, abs: f64, label: &str) {
    for (i, (o, d)) in field
        .values
        .to_f64_vec()
        .iter()
        .zip(restored.values.to_f64_vec())
        .enumerate()
    {
        assert!(
            (o - d).abs() <= abs * (1.0 + 1e-12),
            "{label}: |{o} - {d}| > {abs} at {i}"
        );
    }
}

#[test]
fn every_registry_pipeline_roundtrips_every_survey_dataset() {
    // The composability x generality matrix: all registered pipelines on
    // all eight survey applications (first field each, truncated rows to
    // keep runtime sane).
    let names = [
        "sz3-lr",
        "sz3-lr-s",
        "sz3-interp",
        "sz3-truncation",
        "szx",
        "lorenzo-1d",
        "fpzip-like",
    ];
    for ds in sz3::datagen::survey(7) {
        let field = {
            // take a slice of the first field to bound runtime
            let f = &ds.fields[0];
            let dims = f.shape.dims();
            let keep = dims[0].min(12);
            let row: usize = dims[1..].iter().product::<usize>().max(1);
            let mut nd = dims.to_vec();
            nd[0] = keep;
            match &f.values {
                FieldValues::F32(v) => {
                    Field::f32(f.name.clone(), &nd, v[..keep * row].to_vec()).unwrap()
                }
                FieldValues::F64(v) => {
                    Field::f64(f.name.clone(), &nd, v[..keep * row].to_vec()).unwrap()
                }
                FieldValues::I32(v) => Field::new(
                    f.name.clone(),
                    &nd,
                    FieldValues::I32(v[..keep * row].to_vec()),
                )
                .unwrap(),
            }
        };
        let abs = ErrorBound::Rel(1e-3).to_abs(&field).unwrap();
        for name in names {
            let c = pipeline::build(name).unwrap();
            let conf = CompressConf::new(ErrorBound::Abs(abs));
            let stream = c.compress(&field, &conf).unwrap();
            // header carries the right identity for dispatch: the alias's
            // canonical spec
            let h = peek_header(&stream).unwrap();
            assert_eq!(h.pipeline, pipeline::canonical(name).unwrap());
            // preprocessors may reshape (e.g. linearize), but never resize
            assert_eq!(h.len(), field.len());
            let out = decompress_any(&stream).unwrap();
            assert_eq!(out.shape.dims(), field.shape.dims(), "{name} shape restore");
            check_bound(&field, &out, abs, &format!("{name}/{}", ds.name));
        }
    }
}

#[test]
fn paper_claim_interp_beats_lr_on_smooth_low_bitrate() {
    // Fig. 7 Miranda: at low bitrate (high eb) interpolation wins clearly.
    let ds = sz3::datagen::fields::miranda(42);
    let field = &ds.fields[0];
    let conf = CompressConf::new(ErrorBound::Rel(1e-2));
    let ratio = |name: &str| {
        let c = pipeline::build(name).unwrap();
        let s = c.compress(field, &conf).unwrap();
        field.nbytes() as f64 / s.len() as f64
    };
    let interp = ratio("sz3-interp");
    let lr = ratio("sz3-lr");
    assert!(
        interp > lr,
        "interp {interp:.2} should beat lr {lr:.2} on smooth data at low bitrate"
    );
}

#[test]
fn paper_claim_truncation_fastest_lowest_quality() {
    let ds = sz3::datagen::fields::nyx(42);
    let field = &ds.fields[0];
    let conf = CompressConf::new(ErrorBound::Rel(1e-3));
    let mut ratios = HashMap::new();
    for name in ["sz3-truncation", "sz3-lr", "sz3-interp"] {
        let c = pipeline::build(name).unwrap();
        let stream = c.compress(field, &conf).unwrap();
        let out = decompress_any(&stream).unwrap();
        let m = metrics::evaluate(field, &out, stream.len());
        ratios.insert(name, m.ratio);
    }
    assert!(
        ratios["sz3-truncation"] < ratios["sz3-lr"]
            && ratios["sz3-truncation"] < ratios["sz3-interp"],
        "truncation should have the worst ratio: {ratios:?}"
    );
}

#[test]
fn coordinator_streams_gamess_through_pastri() {
    // Cross-module: datagen -> coordinator -> pastri pipeline -> reassembly.
    let cfg = JobConfig {
        pipeline: "sz3-pastri".into(),
        bound: ErrorBound::Abs(1e-8),
        radius: 64,
        workers: 2,
        chunk_elems: 1 << 16,
        queue_depth: 2,
        ..Default::default()
    };
    let coord = Coordinator::from_config(&cfg).unwrap();
    let fields = sz3::datagen::gamess::gamess_dataset(1 << 17, 3);
    let originals = fields.clone();
    let mut by_field: HashMap<String, Vec<CompressedChunk>> = HashMap::new();
    let report = coord
        .run(fields, |c| by_field.entry(c.field.clone()).or_default().push(c))
        .unwrap();
    assert_eq!(report.fields, 3);
    assert!(report.ratio() > 1.0);
    for f in &originals {
        let rec = reassemble(&by_field[&f.name]).unwrap();
        check_bound(f, &rec, 1e-8, &f.name);
    }
}

#[test]
fn stream_is_self_describing_across_pipelines() {
    // decompress_any must route purely on the stream, with no side channel.
    let mut rng = Pcg32::seeded(5);
    let dims = [16usize, 16, 16];
    let f = Field::f32("x", &dims, sz3::util::prop::smooth_field(&mut rng, &dims)).unwrap();
    let conf = CompressConf::new(ErrorBound::Abs(1e-2));
    let mut streams = Vec::new();
    for name in ["sz3-lr", "sz3-interp", "sz3-truncation", "fpzip-like"] {
        streams.push(pipeline::build(name).unwrap().compress(&f, &conf).unwrap());
    }
    // shuffle decode order
    for s in streams.iter().rev() {
        let out = decompress_any(s).unwrap();
        check_bound(&f, &out, 1e-2, "self-describing");
    }
}

#[test]
fn corrupt_streams_error_not_panic() {
    let mut rng = Pcg32::seeded(6);
    let dims = [32usize, 32];
    let f = Field::f32("x", &dims, sz3::util::prop::smooth_field(&mut rng, &dims)).unwrap();
    let conf = CompressConf::new(ErrorBound::Abs(1e-3));
    let stream = pipeline::build("sz3-lr").unwrap().compress(&f, &conf).unwrap();
    // truncations at many offsets must produce Err, never panic
    for cut in [5usize, 20, stream.len() / 2, stream.len() - 3] {
        let r = std::panic::catch_unwind(|| decompress_any(&stream[..cut]));
        match r {
            Ok(Err(_)) => {}
            Ok(Ok(_)) => panic!("truncated stream decoded 'successfully'"),
            Err(_) => panic!("decode panicked on truncated stream (cut={cut})"),
        }
    }
    // single-byte corruption in the body: Err or bound-violating output are
    // both detectable; panics are not acceptable
    let mut bad = stream.clone();
    let idx = bad.len() - 10;
    bad[idx] ^= 0xff;
    let r = std::panic::catch_unwind(|| decompress_any(&bad));
    assert!(r.is_ok(), "decode panicked on corrupt body");
}

#[test]
fn aps_adaptive_tracks_best_baseline() {
    // §5.3: the adaptive pipeline should be within a whisker of the best
    // fixed pipeline on BOTH sides of the switch point.
    use sz3::datagen::aps::{diffraction_stack, Sample};
    let field = diffraction_stack(Sample::ChipPillar, 48, 24, 24, 9);
    for eb in [0.2, 4.0] {
        let conf = CompressConf::new(ErrorBound::Abs(eb));
        let size = |name: &str| {
            pipeline::build(name).unwrap().compress(&field, &conf).unwrap().len()
        };
        let aps = size("sz3-aps");
        let best_fixed = size("sz3-lr").min(size("lorenzo-1d"));
        assert!(
            (aps as f64) <= best_fixed as f64 * 1.10,
            "eb={eb}: adaptive {aps} should track best fixed {best_fixed}"
        );
    }
}

/// Acceptance: a heterogeneous field compressed via
/// `Coordinator::run_to_container` with adaptive selection roundtrips
/// bit-shape-exact through `decompress_any`, different chunks select
/// different pipelines, and every element respects the error bound.
#[test]
fn adaptive_container_mixes_pipelines_and_respects_bound() {
    let (nz, ny, nx) = (32usize, 24, 24);
    let mut rng = Pcg32::seeded(77);
    let mut vals = Vec::with_capacity(nz * ny * nx);
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                if z < nz / 2 {
                    // smooth half: low-frequency structure, tiny residuals
                    vals.push(
                        (0.5 * ((z as f32) * 0.20).sin()
                            + 0.5 * ((y as f32) * 0.15).cos()
                            + 0.3 * ((x as f32) * 0.10).sin()) as f32,
                    );
                } else {
                    // unpredictable half: white noise across the full range
                    vals.push(rng.uniform(-500.0, 500.0) as f32);
                }
            }
        }
    }
    let field = Field::f32("hetero", &[nz, ny, nx], vals).unwrap();
    let eb = 0.25;
    let cfg = JobConfig {
        pipeline: "sz3-lr".into(),
        bound: ErrorBound::Abs(eb),
        workers: 4,
        chunk_elems: ny * nx * 8, // 8 rows per chunk -> 4 chunks, pure halves
        queue_depth: 2,
        adaptive: true,
        ..Default::default()
    };
    let coord = Coordinator::from_config(&cfg).unwrap();
    let (artifact, report) = coord.run_to_container(vec![field.clone()]).unwrap();
    assert_eq!(report.chunks, 4);
    assert!(sz3::container::is_container(&artifact));

    // the chunk index must record a heterogeneous pipeline mix, as
    // canonical specs
    let trunc = pipeline::canonical("sz3-truncation").unwrap();
    let (index, _) = sz3::container::read_index(&artifact).unwrap();
    assert_eq!(index.entries.len(), 4);
    let mix = index.per_pipeline();
    assert!(
        mix.len() >= 2,
        "heterogeneous field should select ≥2 pipelines, got {mix:?}"
    );
    assert!(
        mix.iter().any(|(p, _)| *p == trunc),
        "noise chunks should pick truncation: {mix:?}"
    );
    for e in &index.entries {
        if e.rows.1 <= nz / 2 {
            assert_ne!(
                e.pipeline, trunc,
                "smooth rows {:?} must use a predictor",
                e.rows
            );
        }
    }

    // single-field containers decode through the common entry point
    let out = decompress_any(&artifact).unwrap();
    assert_eq!(out.shape.dims(), field.shape.dims(), "bit-shape-exact dims");
    assert!(matches!(out.values, FieldValues::F32(_)), "dtype preserved");
    check_bound(&field, &out, eb, "adaptive-container");
}

/// Acceptance (measured rate-distortion selection): on a corpus with
/// smooth, turbulent, and flat strata — chunk-aligned so every chunk is
/// homogeneous — measured selection must (a) respect the bound end to
/// end, (b) record per-chunk winners as canonical specs in the index,
/// and (c) produce a container no larger than *any* single fixed
/// candidate pipeline run over the same corpus at the same bound. No
/// fixed family is good everywhere, which is the whole pitch.
#[test]
fn measured_selection_beats_every_fixed_pipeline_on_mixed_corpus() {
    let (nz, ny, nx) = (48usize, 24, 24);
    let mut rng = Pcg32::seeded(4242);
    let mut vals = Vec::with_capacity(nz * ny * nx);
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                vals.push(if z < 16 {
                    // smooth stratum: low-frequency structure
                    0.6 * ((z as f32) * 0.21).sin()
                        + 0.5 * ((y as f32) * 0.14).cos()
                        + 0.3 * ((x as f32) * 0.09).sin()
                } else if z < 32 {
                    // turbulent stratum: full-range white noise
                    rng.uniform(-500.0, 500.0) as f32
                } else {
                    // flat stratum: one constant
                    3.25
                });
            }
        }
    }
    let field = Field::f32("mixed", &[nz, ny, nx], vals).unwrap();
    let eb = 0.25;
    let base = JobConfig {
        bound: ErrorBound::Abs(eb),
        workers: 4,
        chunk_elems: ny * nx * 8, // 8 rows per chunk -> 6 homogeneous chunks
        queue_depth: 2,
        ..Default::default()
    };

    let measured_cfg = JobConfig {
        pipeline: "sz3-lr".into(),
        measured: true,
        optimize: "ratio".into(),
        ..base.clone()
    };
    let coord = Coordinator::from_config(&measured_cfg).unwrap();
    let (artifact, report) = coord.run_to_container(vec![field.clone()]).unwrap();
    assert_eq!(report.chunks, 6);

    // (a) the bound holds over the full reassembled field
    let out = decompress_any(&artifact).unwrap();
    check_bound(&field, &out, eb, "measured-mixed");

    // (b) winners are recorded per chunk, as canonical specs, and the
    // mix is heterogeneous — one family cannot have won every stratum
    let (index, _) = sz3::container::read_index(&artifact).unwrap();
    assert_eq!(index.entries.len(), 6);
    for e in &index.entries {
        assert_eq!(
            pipeline::canonical(&e.pipeline).unwrap(),
            e.pipeline,
            "chunk {} pipeline must be a canonical spec",
            e.chunk_index
        );
    }
    let mix = index.per_pipeline();
    assert!(
        mix.len() >= 2,
        "mixed corpus should produce a pipeline mix, got {mix:?}"
    );

    // (c) no fixed single-family run does better on the whole corpus
    for name in sz3::container::AdaptiveChunkSelector::DEFAULT_CANDIDATES {
        let fixed_cfg =
            JobConfig { pipeline: name.to_string(), ..base.clone() };
        let fixed = Coordinator::from_config(&fixed_cfg).unwrap();
        let (fixed_artifact, _) =
            fixed.run_to_container(vec![field.clone()]).unwrap();
        assert!(
            artifact.len() <= fixed_artifact.len(),
            "measured selection ({} bytes) must not lose to fixed '{name}' \
             ({} bytes)",
            artifact.len(),
            fixed_artifact.len()
        );
    }
}

/// Acceptance (pipeline-spec API): a composed pipeline that corresponds to
/// **no** registry alias compresses via the spec, records its canonical
/// spec in the stream header and the container chunk index, and
/// decompresses bit-identically through `decompress_any` with no alias
/// lookup — while all registry aliases keep resolving.
#[test]
fn composed_spec_pipeline_end_to_end() {
    let spec = "linearize/lorenzo/linear@r512/arithmetic/rle";
    let canon = pipeline::canonical(spec).unwrap();
    assert!(
        sz3::pipeline::spec::ALIASES.iter().all(|(_, c)| *c != canon),
        "test needs a composition outside the alias table"
    );
    let mut rng = Pcg32::seeded(0x5bec);
    let dims = [20usize, 12, 12];
    let field =
        Field::f32("hx", &dims, sz3::util::prop::smooth_field(&mut rng, &dims)).unwrap();
    let eb = 1e-3;
    let conf = CompressConf::new(ErrorBound::Abs(eb));

    // single-stream path: header carries the canonical spec, roundtrip is
    // self-describing
    let c = pipeline::build(spec).unwrap();
    assert_eq!(c.name(), canon);
    let stream = c.compress(&field, &conf).unwrap();
    assert_eq!(peek_header(&stream).unwrap().pipeline, canon);
    let out = decompress_any(&stream).unwrap();
    assert_eq!(out.shape.dims(), field.shape.dims());
    check_bound(&field, &out, eb, "spec-stream");
    // bit-identical re-decode through a freshly built stack
    let again = pipeline::build(&canon).unwrap().decompress(&stream).unwrap();
    assert_eq!(again.values, out.values);

    // container path: the chunk index records the canonical spec per chunk
    // and the container decodes through the common entry point
    let cfg = JobConfig {
        pipeline: spec.into(),
        bound: ErrorBound::Abs(eb),
        workers: 2,
        chunk_elems: 12 * 12 * 5, // 4 chunks
        queue_depth: 2,
        ..Default::default()
    };
    let coord = Coordinator::from_config(&cfg).unwrap();
    let (artifact, report) = coord.run_to_container(vec![field.clone()]).unwrap();
    assert_eq!(report.chunks, 4);
    let (index, _) = sz3::container::read_index(&artifact).unwrap();
    assert!(index.entries.iter().all(|e| e.pipeline == canon), "{index:?}");
    let out = decompress_any(&artifact).unwrap();
    assert_eq!(out.shape.dims(), field.shape.dims());
    check_bound(&field, &out, eb, "spec-container");
}

#[test]
fn coordinator_edge_cases_roundtrip() {
    // (a) field smaller than one chunk, workers > chunks
    let mut rng = Pcg32::seeded(81);
    let small_dims = [4usize, 8, 8];
    let small = Field::f32("small", &small_dims, sz3::util::prop::smooth_field(&mut rng, &small_dims)).unwrap();
    let cfg = JobConfig {
        pipeline: "sz3-lr".into(),
        bound: ErrorBound::Abs(1e-3),
        workers: 8,
        chunk_elems: 1 << 20,
        queue_depth: 2,
        ..Default::default()
    };
    let coord = Coordinator::from_config(&cfg).unwrap();
    let (artifact, report) = coord.run_to_container(vec![small.clone()]).unwrap();
    assert_eq!(report.chunks, 1, "field smaller than one chunk stays whole");
    let out = decompress_any(&artifact).unwrap();
    assert_eq!(out.shape.dims(), small.shape.dims());
    check_bound(&small, &out, 1e-3, "small-field");

    // (b) non-divisible row split: 10 rows at 3 rows/chunk -> 3+3+3+1
    let odd_dims = [10usize, 12, 12];
    let odd = Field::f32("odd", &odd_dims, sz3::util::prop::smooth_field(&mut rng, &odd_dims)).unwrap();
    let cfg = JobConfig { chunk_elems: 3 * 144, workers: 2, bound: ErrorBound::Abs(1e-3), ..cfg };
    let coord = Coordinator::from_config(&cfg).unwrap();
    let mut rows = Vec::new();
    let (artifact, report) = {
        let mut chunks = Vec::new();
        let report = coord.run(vec![odd.clone()], |c| chunks.push(c)).unwrap();
        for c in &chunks {
            rows.push(c.rows);
        }
        (sz3::container::pack(&chunks).unwrap(), report)
    };
    assert_eq!(report.chunks, 4);
    assert_eq!(rows, vec![(0, 3), (3, 6), (6, 9), (9, 10)]);
    let out = decompress_any(&artifact).unwrap();
    assert_eq!(out.shape.dims(), odd.shape.dims());
    check_bound(&odd, &out, 1e-3, "odd-split");

    // (c) multi-field containers refuse the single-field entry point but
    // decode through the container API
    let two = vec![small.clone(), odd.clone()];
    let (artifact, _) = coord.run_to_container(two).unwrap();
    assert!(decompress_any(&artifact).is_err());
    let fields = sz3::container::decompress_container(&artifact, 4).unwrap();
    assert_eq!(fields.len(), 2);

    // (d) degenerate shapes are rejected at the public boundary (the shard
    // planner used to index dims[0] unchecked)
    assert!(Field::f32("empty", &[], vec![]).is_err());
    assert!(Field::f32("zero", &[0], vec![]).is_err());
    assert!(sz3::coordinator::plan_chunks(&small, 0).is_ok(), "tiny budget clamps to 1 row");

    // (e) truncated containers error, never panic
    for cut in [3usize, 9, artifact.len() / 2] {
        let r = std::panic::catch_unwind(|| decompress_any(&artifact[..cut]));
        assert!(matches!(r, Ok(Err(_))), "cut={cut} must error cleanly");
    }
}

/// Acceptance: extracting a 1-chunk ROI from a many-chunk container
/// decodes only the overlapping chunks (asserted via the reader's decode
/// counters) and returns bit-identical data to slicing the full
/// `decompress_container` output; v2 containers round-trip with CRC
/// verification on, and v1 artifacts remain decodable.
#[test]
fn reader_roi_decodes_only_overlapping_chunks() {
    use sz3::coordinator::slice_rows;
    use sz3::reader::ContainerReader;

    // 40 rows of 20x20, 4 rows/chunk -> 10 chunks
    let dims = [40usize, 20, 20];
    let mut rng = Pcg32::seeded(314);
    let field =
        Field::f32("vol", &dims, sz3::util::prop::smooth_field(&mut rng, &dims)).unwrap();
    let cfg = JobConfig {
        pipeline: "sz3-lr".into(),
        bound: ErrorBound::Abs(1e-3),
        workers: 4,
        chunk_elems: 20 * 20 * 4,
        queue_depth: 2,
        ..Default::default()
    };
    let coord = Coordinator::from_config(&cfg).unwrap();
    let (artifact, report) = coord.run_to_container(vec![field.clone()]).unwrap();
    assert_eq!(report.chunks, 10);

    // current version with a CRC per chunk, verified end to end
    let meta = sz3::container::read_index_meta(&artifact).unwrap();
    assert_eq!(meta.version, sz3::container::CURRENT_VERSION);
    assert!(meta.index.entries.iter().all(|e| e.crc32.is_some()));

    let full = sz3::container::decompress_container(&artifact, 4).unwrap().remove(0);
    check_bound(&field, &full, 1e-3, "v2-roundtrip");

    // 1-chunk ROI: exactly rows 12..16 = chunk 3
    let reader = ContainerReader::from_slice(&artifact).unwrap().with_workers(4);
    let region = reader.read_region("vol", 12..16).unwrap();
    let stats = reader.stats();
    assert_eq!(stats.chunks_decoded, 1, "1-chunk ROI must decode exactly 1 of 10");
    assert_eq!(stats.crc_verified, 1, "every fetch is CRC-checked on v2");
    assert_eq!(
        region.values,
        slice_rows(&full, (12, 16)).unwrap().values,
        "ROI must be bit-identical to slicing the full decode"
    );

    // boundary-spanning ROI: rows 14..22 overlaps chunks 3, 4, 5
    let reader = ContainerReader::from_slice(&artifact).unwrap().with_workers(4);
    let region = reader.read_region("vol", 14..22).unwrap();
    assert_eq!(reader.stats().chunks_decoded, 3);
    assert_eq!(region.values, slice_rows(&full, (14, 22)).unwrap().values);

    // v1 artifacts (no checksum) remain decodable through the same path
    let mut chunks = Vec::new();
    coord.run(vec![field.clone()], |c| chunks.push(c)).unwrap();
    let v1 = sz3::container::pack_v1(&chunks).unwrap();
    let old = decompress_any(&v1).unwrap();
    check_bound(&field, &old, 1e-3, "v1-roundtrip");
    let reader = ContainerReader::from_slice(&v1).unwrap();
    assert_eq!(reader.version(), sz3::container::VERSION_V1);
    let region = reader.read_region("vol", 12..16).unwrap();
    assert_eq!(region.values, slice_rows(&full, (12, 16)).unwrap().values);
    assert_eq!(reader.stats().crc_verified, 0);
}

#[test]
fn extract_cli_shape_file_backed_roi_with_cache() {
    // The `sz3 extract` shape end to end: container on disk, file-backed
    // reader, repeated ROI queries hitting the warm-chunk cache.
    use sz3::reader::{ContainerReader, FileSource, PrefetchSource};

    let dims = [32usize, 16, 16];
    let mut rng = Pcg32::seeded(99);
    let field =
        Field::f32("t", &dims, sz3::util::prop::smooth_field(&mut rng, &dims)).unwrap();
    let cfg = JobConfig {
        pipeline: "sz3-interp".into(),
        bound: ErrorBound::Abs(1e-3),
        workers: 2,
        chunk_elems: 16 * 16 * 4, // 8 chunks
        queue_depth: 2,
        ..Default::default()
    };
    let coord = Coordinator::from_config(&cfg).unwrap();
    let (artifact, _) = coord.run_to_container(vec![field]).unwrap();
    let path = std::env::temp_dir()
        .join(format!("sz3_it_extract_{}.sz3c", std::process::id()));
    std::fs::write(&path, &artifact).unwrap();

    let src = PrefetchSource::new(Box::new(FileSource::open(&path).unwrap()), 1 << 16);
    let reader = ContainerReader::new(Box::new(src))
        .unwrap()
        .with_workers(2)
        .with_cache_bytes(4 << 20);
    let a = reader.read_region("t", 10..14).unwrap();
    let cold = reader.stats();
    assert_eq!(cold.chunks_decoded, 2, "rows 10..14 span chunks 8..12 and 12..16");
    assert!(
        cold.bytes_fetched < artifact.len() as u64,
        "ROI must not fetch the whole artifact"
    );
    let b = reader.read_region("t", 10..14).unwrap();
    let warm = reader.stats();
    assert_eq!(a.values, b.values);
    assert_eq!(warm.chunks_decoded, cold.chunks_decoded, "warm read re-decodes nothing");
    assert!(warm.cache_hits > cold.cache_hits);

    let _ = std::fs::remove_file(&path);
}

#[test]
fn pwrel_bound_via_log_transform_pipeline() {
    use sz3::preprocessor::{LogTransform, Preprocessor};
    let mut rng = Pcg32::seeded(8);
    let n = 4096;
    let vals: Vec<f64> =
        (0..n).map(|_| 10f64.powf(rng.uniform(-6.0, 6.0)) * if rng.below(2) == 0 { -1.0 } else { 1.0 }).collect();
    let mut field = Field::f64("w", &[n], vals.clone()).unwrap();
    let rel = 1e-2;
    let mut conf = CompressConf::new(ErrorBound::PwRel(rel));
    let t = LogTransform::default();
    let state = t.process(&mut field, &mut conf).unwrap();
    let c = pipeline::build("lorenzo-1d").unwrap();
    let stream = c.compress(&field, &conf).unwrap();
    let mut out = decompress_any(&stream).unwrap();
    t.postprocess(&mut out, &state).unwrap();
    for (o, d) in vals.iter().zip(out.values.to_f64_vec()) {
        if *o != 0.0 {
            assert!((d / o - 1.0).abs() <= rel * (1.0 + 1e-9));
        }
    }
}

#[test]
fn concurrent_overlapping_roi_reads_through_one_shared_reader() {
    // The serve-path concurrency contract: N threads hammering one shared
    // reader with overlapping ROIs must all see bit-identical results,
    // and the counters must stay exactly consistent (every chunk touch is
    // either a cache hit or a decode, never both, never neither).
    use std::sync::Arc;
    use sz3::reader::ContainerReader;

    let dims = [32usize, 16, 16];
    let mut rng = Pcg32::seeded(314);
    let field =
        Field::f32("t", &dims, sz3::util::prop::smooth_field(&mut rng, &dims)).unwrap();
    let cfg = JobConfig {
        pipeline: "sz3-lr".into(),
        bound: ErrorBound::Abs(1e-3),
        workers: 2,
        chunk_elems: 16 * 16 * 4, // 4 rows per chunk -> 8 chunks
        queue_depth: 2,
        ..Default::default()
    };
    let coord = Coordinator::from_config(&cfg).unwrap();
    let (artifact, _) = coord.run_to_container(vec![field]).unwrap();
    let full = sz3::container::decompress_container(&artifact, 2).unwrap().remove(0);

    // overlapping windows: rois[i] = i..i+6 clamped into 0..32
    let rois: Vec<std::ops::Range<usize>> =
        (0..16).map(|i| (i * 2)..((i * 2 + 6).min(32))).collect();
    let expected: Vec<Vec<u8>> = rois
        .iter()
        .map(|r| {
            sz3::coordinator::slice_rows(&full, (r.start, r.end))
                .unwrap()
                .values
                .to_le_bytes()
        })
        .collect();
    // each ROI of 6 rows at 4 rows/chunk touches 2 or 3 chunks
    let touches: usize = rois
        .iter()
        .map(|r| (0..8).filter(|c| c * 4 < r.end && (c + 1) * 4 > r.start).count())
        .sum();

    let reader = Arc::new(
        ContainerReader::from_slice(&artifact)
            .unwrap()
            .with_workers(2)
            .with_cache_bytes(16 << 20),
    );
    let n_threads = 8;
    std::thread::scope(|s| {
        for t in 0..n_threads {
            let reader = Arc::clone(&reader);
            let rois = &rois;
            let expected = &expected;
            s.spawn(move || {
                // every thread walks all ROIs, phase-shifted so cold
                // decodes and warm hits interleave across threads
                for k in 0..rois.len() {
                    let i = (k + t * 3) % rois.len();
                    let got = reader.read_region("t", rois[i].clone()).unwrap();
                    assert_eq!(
                        got.values.to_le_bytes(),
                        expected[i],
                        "thread {t} roi {i} diverged"
                    );
                }
            });
        }
    });

    let s = reader.stats();
    let total_touches = (touches * n_threads) as u64;
    assert_eq!(
        s.cache_hits + s.chunks_decoded,
        total_touches,
        "every chunk touch is exactly one hit or one decode"
    );
    assert!(s.chunks_decoded >= 8, "each of the 8 chunks decoded at least once");
    assert!(
        s.cache_hits > s.chunks_decoded,
        "warm traffic must dominate: {} hits vs {} decodes",
        s.cache_hits,
        s.chunks_decoded
    );
    assert_eq!(s.chunks_fetched, s.chunks_decoded, "fetch only to decode");
    assert_eq!(s.crc_verified, s.chunks_fetched, "v2 verifies every fetch");
}

#[test]
fn http_server_loopback_full_round_trip() {
    // list -> meta -> ROI -> raw over a real loopback socket, plus the
    // statsz cache-hit acceptance check from the issue.
    use sz3::config::Json;
    use sz3::reader::ContainerReader;
    use sz3::server::{self, ArtifactStore, HttpClient, StoreOptions};

    let dims = [24usize, 12, 12];
    let mut rng = Pcg32::seeded(2718);
    let field = Field::f32(
        "density",
        &dims,
        sz3::util::prop::smooth_field(&mut rng, &dims),
    )
    .unwrap();
    let cfg = JobConfig {
        pipeline: "sz3-lr".into(),
        bound: ErrorBound::Abs(1e-3),
        workers: 2,
        chunk_elems: 3 * 144, // 8 chunks
        queue_depth: 2,
        ..Default::default()
    };
    let coord = Coordinator::from_config(&cfg).unwrap();
    let (artifact, _) = coord.run_to_container(vec![field]).unwrap();

    let dir = std::env::temp_dir().join(format!("sz3_it_http_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("nyx.sz3c"), &artifact).unwrap();

    let store = ArtifactStore::open_dir(
        &dir,
        &StoreOptions { cache_bytes: 8 << 20, workers: 2, verify: true },
    )
    .unwrap();
    let handle = server::serve(store, "127.0.0.1:0", 2).unwrap();
    let addr = handle.addr();
    {
        let mut client = HttpClient::connect(addr).unwrap();

        // list
        let resp = client.get("/v1/artifacts").unwrap();
        assert_eq!(resp.status, 200);
        let j = Json::parse(resp.text().unwrap()).unwrap();
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("id").unwrap().as_str(), Some("nyx"));

        // meta
        let resp = client.get("/v1/artifacts/nyx").unwrap();
        assert_eq!(resp.status, 200);
        let j = Json::parse(resp.text().unwrap()).unwrap();
        let f = &j.get("fields").unwrap().as_arr().unwrap()[0];
        assert_eq!(f.get("name").unwrap().as_str(), Some("density"));
        assert_eq!(f.get("chunks").unwrap().as_usize(), Some(8));

        // ROI: exactly the bytes read_region produces
        let resp = client.get("/v1/artifacts/nyx/fields/density?rows=7..11").unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("x-sz3-dims"), Some("4,12,12"));
        let oracle = ContainerReader::from_slice(&artifact)
            .unwrap()
            .read_region("density", 7..11)
            .unwrap();
        assert_eq!(resp.body, oracle.values.to_le_bytes());
        assert_eq!(resp.body.len(), 4 * 12 * 12 * 4, "content-length framing");

        // raw chunk passthrough matches the local reader byte for byte
        let resp = client.get("/v1/artifacts/nyx/raw?chunk=0").unwrap();
        assert_eq!(resp.status, 200);
        let local = ContainerReader::from_slice(&artifact).unwrap();
        assert_eq!(resp.body, local.chunk_payload(0).unwrap());

        // error paths over the wire
        assert_eq!(client.get("/v1/artifacts/none").unwrap().status, 404);
        assert_eq!(
            client.get("/v1/artifacts/nyx/fields/density?rows=90..99").unwrap().status,
            416
        );
        assert_eq!(
            client.get("/v1/artifacts/nyx/fields/density?rows=oops").unwrap().status,
            400
        );

        // repeat the ROI: statsz must show the warm-cache hit
        client.get("/v1/artifacts/nyx/fields/density?rows=7..11").unwrap();
        let resp = client.get("/statsz").unwrap();
        let j = Json::parse(resp.text().unwrap()).unwrap();
        let nyx = j.get("artifacts").unwrap().get("nyx").unwrap();
        assert!(nyx.get("cache_hits").unwrap().as_usize().unwrap() >= 2);
        let roi = j.get("endpoints").unwrap().get("roi").unwrap();
        assert!(roi.get("count").unwrap().as_usize().unwrap() >= 4);
        assert!(roi.get("p99_us").unwrap().as_f64().unwrap() > 0.0);
    } // client drops -> connection closes -> worker frees
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
