//! Golden-artifact compatibility suite: every container format version
//! must keep decoding bit-identically through the current reader.
//!
//! Three independent locks (see `rust/tests/fixtures/README.md`):
//! committed fixture files vs their committed expected bytes, freshly
//! generated artifacts vs `fixtures::reference_decode` (an independent
//! decode implementation that never touches `sz3::reader`), and
//! cross-version bit-identity of the same chunk set packed as v1/v2/v3.

use std::path::PathBuf;
use sz3::container::{self, fixtures};
use sz3::reader::ContainerReader;

fn fixtures_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures")
}

/// Decode every `(snapshot, field)` of an artifact through the reader,
/// returning the same shape `reference_decode` produces.
fn reader_decode(artifact: &[u8]) -> Vec<(usize, String, Vec<u8>)> {
    let r = ContainerReader::from_slice(artifact).unwrap().with_workers(2);
    let mut out = Vec::new();
    for snapshot in 0..r.snapshot_count() {
        let names: Vec<String> =
            r.field_names_at(snapshot).into_iter().map(str::to_string).collect();
        for name in names {
            let field = r.read_field_at(snapshot, &name).unwrap();
            out.push((snapshot, name, field.values.to_le_bytes()));
        }
    }
    out
}

#[test]
fn fresh_corpus_decodes_identically_via_reader_and_reference() {
    for fx in fixtures::golden_set().unwrap() {
        let via_reader = reader_decode(&fx.artifact);
        assert_eq!(
            via_reader, fx.expected,
            "fixture '{}': reader and reference decode must agree bit-for-bit",
            fx.name
        );
        // spot-check a region against the reference slice on every
        // snapshot (covers delta-chain ROI resolution on the series)
        let r = ContainerReader::from_slice(&fx.artifact).unwrap();
        for snapshot in 0..r.snapshot_count() {
            let roi = r.read_region_at(snapshot, "a", 3..7).unwrap();
            let oracle =
                fixtures::reference_region(&fx.artifact, snapshot, "a", 3..7)
                    .unwrap();
            assert_eq!(
                roi.values.to_le_bytes(),
                oracle,
                "fixture '{}' snapshot {snapshot}: region mismatch",
                fx.name
            );
        }
    }
}

#[test]
fn same_chunks_decode_bit_identically_across_versions() {
    let set = fixtures::golden_set().unwrap();
    let by_name = |n: &str| {
        set.iter().find(|f| f.name == n).unwrap_or_else(|| panic!("fixture {n}"))
    };
    let (v1, v2, v3) = (by_name("v1"), by_name("v2"), by_name("v3"));
    assert_eq!(
        container::read_index_meta(&v1.artifact).unwrap().version,
        container::VERSION_V1
    );
    assert_eq!(
        container::read_index_meta(&v2.artifact).unwrap().version,
        container::VERSION_V2
    );
    assert_eq!(
        container::read_index_meta(&v3.artifact).unwrap().version,
        container::VERSION_V3
    );
    let d1 = reader_decode(&v1.artifact);
    let d2 = reader_decode(&v2.artifact);
    let d3 = reader_decode(&v3.artifact);
    assert_eq!(d1, d2, "v1 and v2 must decode identically");
    assert_eq!(d2, d3, "v2 and v3 must decode identically");
}

#[test]
fn committed_fixture_files_decode_unchanged() {
    let dir = fixtures_dir();
    let set = fixtures::golden_set().unwrap();
    let mut verified = 0usize;
    for fx in &set {
        let artifact_path = dir.join(fx.artifact_file());
        if !artifact_path.exists() {
            // first materialization: bootstrap the committed corpus from
            // the deterministic generator so the next run locks it
            std::fs::create_dir_all(&dir).unwrap();
            std::fs::write(&artifact_path, &fx.artifact).unwrap();
            for (snapshot, field, bytes) in &fx.expected {
                std::fs::write(dir.join(fx.expected_file(*snapshot, field)), bytes)
                    .unwrap();
            }
            eprintln!(
                "bootstrapped fixture '{}' ({} bytes) — commit rust/tests/fixtures",
                fx.name,
                fx.artifact.len()
            );
        }
        let artifact = std::fs::read(&artifact_path).unwrap();
        let decoded = reader_decode(&artifact);
        for (snapshot, field, bytes) in &decoded {
            let expected_path = dir.join(fx.expected_file(*snapshot, field));
            assert!(
                expected_path.exists(),
                "fixture '{}' missing expected file {}",
                fx.name,
                expected_path.display()
            );
            let expected = std::fs::read(&expected_path).unwrap();
            assert_eq!(
                bytes, &expected,
                "fixture '{}' (snapshot {snapshot}, field '{field}'): committed \
                 artifact no longer decodes to its committed bytes — a format or \
                 codec regression",
                fx.name
            );
            verified += 1;
        }
        // the committed artifact must also pass checksum verification
        let r = ContainerReader::from_slice(&artifact).unwrap();
        r.verify_checksums().unwrap();
    }
    assert!(verified >= set.len(), "every fixture verified at least one field");
}

#[test]
fn v3_series_fixture_exposes_snapshot_axis() {
    let set = fixtures::golden_set().unwrap();
    let fx = set.iter().find(|f| f.name == "v3-series").unwrap();
    let r = ContainerReader::from_slice(&fx.artifact).unwrap();
    assert_eq!(r.version(), container::VERSION_V3);
    assert_eq!(r.snapshot_count(), 3);
    assert_eq!(r.snapshot_tags(), &["t0", "t1", "t2"]);
    let meta = container::read_index_meta(&fx.artifact).unwrap();
    assert!(
        meta.index.entries.iter().any(|e| e.delta),
        "the series fixture must contain at least one delta chunk"
    );
    // legacy fixtures carry the implicit single snapshot
    let v1 = set.iter().find(|f| f.name == "v1").unwrap();
    let r1 = ContainerReader::from_slice(&v1.artifact).unwrap();
    assert_eq!(r1.snapshot_count(), 1);
    assert_eq!(r1.snapshot_tags(), &[String::new()]);
}
