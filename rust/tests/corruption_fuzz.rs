//! Deterministic byte-mutation sweep over a packed v3 series container:
//! every mutated artifact must fail with a clean `SzError` — never a
//! panic, never silently different decoded data. The v3 index checksum
//! makes this total for the index region (magic, version, counts, the
//! snapshot table, every chunk entry, even the tags); the per-chunk
//! CRC-32 makes it total for the payload.

use sz3::byteio::ByteWriter;
use sz3::container;
use sz3::reader::ContainerReader;
use sz3::util::crc32::crc32;

/// Decode every `(snapshot, field)` through the reader with one worker
/// (determinism and simple panic propagation).
fn decode_all(artifact: &[u8]) -> sz3::error::Result<Vec<(usize, String, Vec<u8>)>> {
    let r = ContainerReader::from_slice(artifact)?.with_workers(1);
    let mut out = Vec::new();
    for snapshot in 0..r.snapshot_count() {
        let names: Vec<String> =
            r.field_names_at(snapshot).into_iter().map(str::to_string).collect();
        for name in names {
            let field = r.read_field_at(snapshot, &name)?;
            out.push((snapshot, name, field.values.to_le_bytes()));
        }
    }
    Ok(out)
}

/// One mutation case: clean error, or bit-identical decode. Returns true
/// if the mutation was rejected with an error.
fn check_mutation(
    artifact: &[u8],
    baseline: &[(usize, String, Vec<u8>)],
    pos: usize,
    mutate: u8,
    label: &str,
) -> bool {
    let mut bad = artifact.to_vec();
    bad[pos] ^= mutate;
    if bad[pos] == artifact[pos] {
        return false; // xor with 0 — not a mutation
    }
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        decode_all(&bad)
    }));
    match caught {
        Err(_) => panic!("PANIC on {label} byte {pos} xor {mutate:#04x}"),
        Ok(Err(_)) => true,
        Ok(Ok(decoded)) => {
            assert_eq!(
                &decoded, baseline,
                "{label} byte {pos} xor {mutate:#04x}: mutation silently \
                 changed decoded data"
            );
            false
        }
    }
}

/// The sweep target: a 3-snapshot delta series (exercises the snapshot
/// table and delta flags) built from the deterministic fixture corpus.
fn series_artifact() -> Vec<u8> {
    container::fixtures::golden_set()
        .unwrap()
        .into_iter()
        .find(|f| f.name == "v3-series")
        .unwrap()
        .artifact
}

#[test]
fn index_mutation_sweep_never_panics_or_accepts_wrong_data() {
    let artifact = series_artifact();
    let baseline = decode_all(&artifact).unwrap();
    let meta = container::read_index_meta(&artifact).unwrap();
    let index_end = meta.payload_offset;
    let mut rejected = 0usize;
    for pos in 0..index_end {
        for mutate in [0x01u8, 0x80, 0xff] {
            // the v3 index checksum covers every byte up to the payload,
            // so *no* index mutation may decode at all — benign is 0
            assert!(
                check_mutation(&artifact, &baseline, pos, mutate, "index"),
                "index byte {pos} xor {mutate:#04x} was accepted"
            );
            rejected += 1;
        }
    }
    assert!(rejected >= 3 * index_end);
}

#[test]
fn payload_mutation_sweep_is_always_caught_by_crc() {
    let artifact = series_artifact();
    let baseline = decode_all(&artifact).unwrap();
    let meta = container::read_index_meta(&artifact).unwrap();
    let payload_start = meta.payload_offset;
    let payload_len = meta.payload_len as usize;
    assert_eq!(payload_start + payload_len, artifact.len());
    // stride through the payload plus both extremes of every chunk
    let mut positions: Vec<usize> = (0..payload_len).step_by(7).collect();
    for e in &meta.index.entries {
        positions.push(e.offset);
        positions.push(e.offset + e.len - 1);
    }
    for pos in positions {
        let ok = check_mutation(
            &artifact,
            &baseline,
            payload_start + pos,
            0x40,
            "payload",
        );
        // v3 carries a CRC per chunk: a payload flip can never be benign
        assert!(ok, "payload byte {pos}: corruption escaped the CRC check");
    }
}

#[test]
fn truncation_sweep_errors_cleanly_at_every_cut() {
    let artifact = series_artifact();
    for cut in 0..artifact.len().min(64) {
        let prefix = &artifact[..cut];
        let caught = std::panic::catch_unwind(|| {
            ContainerReader::from_slice(prefix).map(|r| r.read_all())
        });
        match caught {
            Err(_) => panic!("panic on truncation at {cut}"),
            Ok(Ok(Ok(_))) => panic!("truncated container decoded (cut={cut})"),
            Ok(_) => {}
        }
    }
    // coarser cuts across the rest of the artifact
    for cut in (64..artifact.len()).step_by(41) {
        let prefix = &artifact[..cut];
        let caught = std::panic::catch_unwind(|| {
            ContainerReader::from_slice(prefix).map(|r| r.read_all())
        });
        match caught {
            Err(_) => panic!("panic on truncation at {cut}"),
            Ok(Ok(Ok(_))) => panic!("truncated container decoded (cut={cut})"),
            Ok(_) => {}
        }
    }
}

/// Hand-assemble a v3 container (index body from `build`, then the v3
/// index CRC, then `payload`) so length fields can take values the
/// honest writer never produces. The CRC is made valid on purpose: the
/// adversarial values must be rejected by semantic validation, not by
/// the checksum happening to disagree.
fn crafted_v3(build: impl FnOnce(&mut ByteWriter), payload: &[u8]) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_bytes(container::CONTAINER_MAGIC);
    w.put_u8(container::VERSION_V3);
    build(&mut w);
    let mut bytes = w.finish();
    let c = crc32(&bytes);
    bytes.extend_from_slice(&c.to_le_bytes());
    bytes.extend_from_slice(payload);
    bytes
}

/// Index body for one single-chunk field with every length-ish knob
/// exposed to the test.
#[allow(clippy::too_many_arguments)]
fn single_chunk_index(
    w: &mut ByteWriter,
    dims: &[u64],
    row_end: u64,
    offset: u64,
    len: u64,
    payload_len: u64,
    payload_crc: u32,
) {
    w.put_varint(1); // chunk count
    w.put_varint(1); // field count
    w.put_varint(1); // snapshot table size
    w.put_str(""); // snapshot tag
    w.put_str("f"); // field name
    w.put_varint(0); // chunk_index
    w.put_varint(1); // chunk_count
    w.put_varint(0); // row_start
    w.put_varint(row_end);
    w.put_varint(dims.len() as u64);
    for &d in dims {
        w.put_varint(d);
    }
    w.put_str("sz3-lr"); // pipeline tag (informative)
    w.put_varint(offset);
    w.put_varint(len);
    w.put_u32(payload_crc);
    w.put_varint(0); // snapshot id
    w.put_u8(0); // flags
    w.put_varint(payload_len);
}

/// `offset + len` sums chosen to wrap: the extent check must use checked
/// arithmetic and report corruption, never wrap into an in-bounds range.
#[test]
fn chunk_extent_overflow_is_rejected_not_wrapped() {
    let payload = [0u8; 8];
    let crc = crc32(&payload);
    for (offset, len) in [
        (u64::MAX, 1),
        (u64::MAX - 3, 8),
        (u64::MAX / 2 + 1, u64::MAX / 2 + 1),
        (8, u64::MAX - 4),
    ] {
        let stream = crafted_v3(
            |w| single_chunk_index(w, &[16], 16, offset, len, 8, crc),
            &payload,
        );
        let caught = std::panic::catch_unwind(|| {
            container::read_index_meta(&stream).map(|_| ())
        });
        match caught {
            Err(_) => panic!("PANIC on chunk extent {offset}+{len}"),
            Ok(Ok(())) => panic!("chunk extent {offset}+{len} accepted"),
            Ok(Err(_)) => {}
        }
    }
}

/// Dimensions and element counts near `usize::MAX`: no decode attempt may
/// panic (overflowing stride/size arithmetic) or allocate from the claim.
#[test]
fn near_max_dims_error_cleanly() {
    let payload = [0xa5u8; 8];
    let crc = crc32(&payload);
    let dim_sets: [&[u64]; 4] = [
        &[u64::MAX],
        &[u64::MAX, u64::MAX],
        &[1 << 40, 1 << 40],
        &[u64::MAX / 2, 3],
    ];
    for dims in dim_sets {
        let stream = crafted_v3(
            |w| single_chunk_index(w, dims, dims[0], 0, 8, 8, crc),
            &payload,
        );
        let caught = std::panic::catch_unwind(|| {
            ContainerReader::from_slice(&stream)
                .map(|r| r.with_workers(1).read_all().map(|_| ()))
        });
        match caught {
            Err(_) => panic!("PANIC on dims {dims:?}"),
            Ok(Ok(Ok(()))) => panic!("container with dims {dims:?} decoded"),
            Ok(_) => {}
        }
    }
    // the shape layer itself must refuse overflowing element counts
    use sz3::data::shape::Shape;
    assert!(Shape::new(&[usize::MAX, 2]).is_err());
    assert!(Shape::new(&[1 << 40, 1 << 40, 2]).is_err());
}

/// Headers claiming more snapshots (or chunks) than the stream can hold:
/// the counts must be rejected against the remaining byte budget before
/// any allocation grows from them.
#[test]
fn oversized_header_counts_are_rejected() {
    for (n_chunks, n_snaps) in [
        (1u64, u64::MAX),
        (1, 1 << 40),
        (u64::MAX, 1),
        (1 << 40, 1),
        (1, 1000), // more snapshot tags than bytes left in the header
    ] {
        let stream = crafted_v3(
            |w| {
                w.put_varint(n_chunks);
                w.put_varint(1); // field count
                w.put_varint(n_snaps);
            },
            &[],
        );
        let caught = std::panic::catch_unwind(|| {
            container::read_index_meta(&stream).map(|_| ())
        });
        match caught {
            Err(_) => panic!("PANIC on counts chunks={n_chunks} snaps={n_snaps}"),
            Ok(Ok(())) => {
                panic!("counts chunks={n_chunks} snaps={n_snaps} accepted")
            }
            Ok(Err(_)) => {}
        }
    }
}

/// Run `f` under `catch_unwind` and demand a clean `Err`, never a panic
/// and never an `Ok`.
fn expect_clean_error<F: FnOnce() -> sz3::error::Result<()> + std::panic::UnwindSafe>(
    f: F,
    label: &str,
) {
    match std::panic::catch_unwind(f) {
        Err(_) => panic!("PANIC on {label}"),
        Ok(Ok(())) => panic!("{label} accepted"),
        Ok(Err(_)) => {}
    }
}

/// Hostile quantizer state fed straight into the `Quantizer::load` entry
/// points: huge unpredictable counts, counts larger than the remaining
/// byte budget, and zero/negative/non-finite error bounds must all come
/// back as `SzError` — allocation bombs and panics are both failures.
#[test]
fn hostile_quantizer_state_errors_not_panics() {
    use sz3::byteio::ByteReader;
    use sz3::quantizer::{
        LinearQuantizer, LogScaleQuantizer, Quantizer, UnpredAwareQuantizer,
    };

    fn linear_payload(eb: f64, radius: u32, count: u64, trailing: &[u8]) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_f64(eb);
        w.put_u32(radius);
        w.put_varint(count);
        w.put_bytes(trailing);
        w.finish()
    }
    let linear_cases: [(Vec<u8>, &str); 8] = [
        (linear_payload(1e-3, 512, u64::MAX, &[0u8; 8]), "count u64::MAX"),
        (linear_payload(1e-3, 512, 1 << 40, &[]), "count 2^40, empty payload"),
        (linear_payload(1e-3, 512, 1000, &[0u8; 16]), "count beyond byte budget"),
        (linear_payload(0.0, 512, 0, &[]), "zero eb"),
        (linear_payload(-1.0, 512, 0, &[]), "negative eb"),
        (linear_payload(f64::NAN, 512, 0, &[]), "NaN eb"),
        (linear_payload(f64::INFINITY, 512, 0, &[]), "infinite eb"),
        (linear_payload(1e-3, 0, 0, &[]), "zero radius"),
    ];
    for (payload, label) in &linear_cases {
        expect_clean_error(
            || {
                let mut q = LinearQuantizer::<f32>::new(0.5);
                q.load(&mut ByteReader::new(payload))
            },
            &format!("linear quantizer: {label}"),
        );
        // the f64 instantiation takes the same path with a different
        // element size in the budget check
        expect_clean_error(
            || {
                let mut q = LinearQuantizer::<f64>::new(0.5);
                q.load(&mut ByteReader::new(payload))
            },
            &format!("linear<f64> quantizer: {label}"),
        );
    }

    fn logscale_payload(
        eb: f64,
        alpha: f64,
        gamma: f64,
        radius: u32,
        count: u64,
    ) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_f64(eb);
        w.put_f64(alpha);
        w.put_f64(gamma);
        w.put_u32(radius);
        w.put_varint(count);
        w.finish()
    }
    let logscale_cases: [(Vec<u8>, &str); 7] = [
        (logscale_payload(1e-3, 0.5, 2.0, u32::MAX, 0), "radius u32::MAX (table bomb)"),
        (logscale_payload(1e-3, 0.5, 2.0, 1 << 30, 0), "radius beyond wire cap"),
        (logscale_payload(1e-3, 0.5, 2.0, 64, u64::MAX), "count u64::MAX"),
        (logscale_payload(0.0, 0.5, 2.0, 64, 0), "zero eb"),
        (logscale_payload(1e-3, 0.0, 2.0, 64, 0), "zero alpha"),
        (logscale_payload(1e-3, 2.0, 2.0, 64, 0), "alpha > 1"),
        (logscale_payload(1e-3, 0.5, 1.0, 64, 0), "gamma <= 1"),
    ];
    for (payload, label) in &logscale_cases {
        expect_clean_error(
            || {
                let mut q = LogScaleQuantizer::<f64>::new(0.5, 64);
                q.load(&mut ByteReader::new(payload))
            },
            &format!("log_scale quantizer: {label}"),
        );
    }

    fn unpred_payload(
        eb: f64,
        radius: u32,
        count: u64,
        nbits: u8,
        block: &[u8],
    ) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_f64(eb);
        w.put_u32(radius);
        w.put_varint(count);
        w.put_u8(nbits);
        w.put_u8(0); // value-major
        w.put_block(block);
        w.finish()
    }
    let unpred_cases: [(Vec<u8>, &str); 4] = [
        (unpred_payload(1e-3, 512, u64::MAX, 4, &[0u8; 8]), "count u64::MAX"),
        (unpred_payload(1e-3, 512, 1 << 40, 4, &[0u8; 8]), "count 2^40, 8-byte planes"),
        (unpred_payload(1e-3, 512, 1 << 20, 255, &[0u8; 64]), "nbits 255 overflow probe"),
        (unpred_payload(-0.5, 512, 0, 0, &[]), "negative eb"),
    ];
    for (payload, label) in &unpred_cases {
        expect_clean_error(
            || {
                let mut q = UnpredAwareQuantizer::<f32>::new(0.5, 512);
                q.load(&mut ByteReader::new(payload))
            },
            &format!("unpred_aware quantizer: {label}"),
        );
    }

    // regression coefficients: a hostile count must bounce off the byte
    // budget before sizing the output allocation
    for n in [usize::MAX, 1 << 40, 100] {
        expect_clean_error(
            || {
                let payload = [0u8; 8];
                sz3::predictor::RegressionFit::load_quantized(
                    n,
                    &mut ByteReader::new(&payload),
                )
                .map(|_| ())
            },
            &format!("regression coefficients: count {n}"),
        );
    }
}

/// The runtime-dispatched kernels must be bit-identical to their
/// always-scalar variants on whatever CPU the test runs on — this is the
/// public-API (integration) pin; the in-module property tests cover the
/// same contract per kernel in more depth.
#[test]
fn dispatched_kernels_match_scalar_bitexactly() {
    use sz3::util::simd;
    let mut rng = sz3::util::rng::Pcg32::seeded(0x51d3);
    for round in 0..20 {
        let n = 1 + (round * 37) % 300;
        let vals: Vec<f64> = (0..n).map(|_| rng.uniform(-1e4, 1e4)).collect();
        let preds: Vec<f64> = vals.iter().map(|v| v + rng.uniform(-1.0, 1.0)).collect();
        let eb = 10f64.powf(rng.uniform(-6.0, -1.0));

        // linear quantization
        let mut a = vals.clone();
        let mut b = vals.clone();
        let mut ca = vec![0u32; n];
        let mut cb = vec![0u32; n];
        let ea = simd::linear_quantize_f64(&mut a, &preds, eb, 512, &mut ca);
        let eb_count = simd::linear_quantize_f64_scalar(&mut b, &preds, eb, 512, &mut cb);
        assert_eq!(ea, eb_count);
        assert_eq!(ca, cb);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "quantize diverged");
        }

        // Lorenzo residual
        let mut r1 = vec![0.0; n];
        let mut r2 = vec![0.0; n];
        simd::lorenzo1_residual(&vals, &mut r1);
        simd::lorenzo1_residual_scalar(&vals, &mut r2);
        for (x, y) in r1.iter().zip(&r2) {
            assert_eq!(x.to_bits(), y.to_bits(), "lorenzo residual diverged");
        }

        // delta kernels
        let base: Vec<f64> = vals.iter().map(|v| v * 0.75).collect();
        let mut d1 = vec![0.0; n];
        let mut d2 = vec![0.0; n];
        simd::delta_sub_f64(&vals, &base, &mut d1);
        simd::delta_sub_f64_scalar(&vals, &base, &mut d2);
        for (x, y) in d1.iter().zip(&d2) {
            assert_eq!(x.to_bits(), y.to_bits(), "delta sub diverged");
        }

        // min/max and CRC
        assert_eq!(simd::minmax_f64(&vals), simd::minmax_f64_scalar(&vals));
        let bytes: Vec<u8> = (0..n * 3).map(|_| rng.below(256) as u8).collect();
        assert_eq!(
            simd::crc32_update(!0, &bytes),
            simd::crc32_update_scalar(!0, &bytes),
            "crc diverged"
        );
    }
}

#[test]
fn snapshot_table_specific_mutations_are_validated() {
    // target the bytes right after the fixed header: chunk count, field
    // count, snapshot count, then the tag strings — oversized counts and
    // flag bytes must be rejected structurally, not by allocation failure
    let artifact = series_artifact();
    let baseline = decode_all(&artifact).unwrap();
    // version byte: every other value must be rejected outright
    for v in [0u8, 4, 9, 0x7f, 0xff] {
        let mut bad = artifact.clone();
        bad[4] = v;
        assert!(
            ContainerReader::from_slice(&bad).is_err(),
            "version {v} accepted"
        );
    }
    // saturate the varints of the three leading counts
    for pos in 5..12 {
        for mutate in [0x7fu8, 0xff] {
            check_mutation(&artifact, &baseline, pos, mutate, "header-varint");
        }
    }
}
