//! Lorenzo predictor [34] and its higher-order variation (paper §3.2).
//!
//! The order-`m` Lorenzo predictor in `d` dimensions predicts
//! `f(x) = -Σ_{k≠0} Π_d (-1)^{k_d} C(m, k_d) · f(x - k)`, `k ∈ {0..m}^d`.
//! Order 1 reduces to the classic multidimensional difference predictor
//! (`a + b - c` in 2D); order 2 is the SZ-1.4 variation. Out-of-range
//! neighbors read as 0 (the cursor's boundary convention).

use super::Predictor;
use crate::data::{NdCursor, Scalar};

/// Dimension- and order-generic Lorenzo predictor.
///
/// Terms (offset/coefficient pairs) are precomputed per (ndim, order) at
/// construction, so `predict` is a flat dot product over neighbors.
#[derive(Clone)]
pub struct LorenzoPredictor {
    order: u32,
    ndim: usize,
    /// (offsets, coefficient) per term; offsets are ≤ 0.
    terms: Vec<(Vec<isize>, f64)>,
}

fn binomial(n: u32, k: u32) -> f64 {
    if k > n {
        return 0.0;
    }
    let mut r = 1.0;
    for i in 0..k {
        r = r * (n - i) as f64 / (i + 1) as f64;
    }
    r
}

impl LorenzoPredictor {
    /// Order-1 predictor for `ndim` dimensions.
    pub fn new(ndim: usize) -> Self {
        Self::with_order(ndim, 1)
    }

    /// Order-`order` predictor for `ndim` dimensions.
    pub fn with_order(ndim: usize, order: u32) -> Self {
        assert!(ndim >= 1 && ndim <= 4 && order >= 1 && order <= 3);
        let mut terms = Vec::new();
        let radix = order as usize + 1;
        let count = radix.pow(ndim as u32);
        for code in 1..count {
            // decode per-axis shifts k_d in 0..=order
            let mut k = vec![0u32; ndim];
            let mut c = code;
            for kd in k.iter_mut() {
                *kd = (c % radix) as u32;
                c /= radix;
            }
            let ksum: u32 = k.iter().sum();
            let mut coeff = -1.0;
            for &kd in &k {
                coeff *= binomial(order, kd);
            }
            if ksum % 2 == 1 {
                coeff = -coeff;
            }
            let offsets: Vec<isize> = k.iter().map(|&kd| -(kd as isize)).collect();
            terms.push((offsets, coeff));
        }
        LorenzoPredictor { order, ndim, terms }
    }

    /// Predictor order.
    pub fn order(&self) -> u32 {
        self.order
    }

    /// Decompression-noise factor for order-1 Lorenzo (SZ2 [8]): the
    /// expected extra error (in units of eb) introduced by predicting from
    /// decompressed rather than original neighbors. Used by the composite
    /// selector's estimation criterion.
    pub fn noise_factor(ndim: usize) -> f64 {
        match ndim {
            1 => 0.5,
            2 => 0.81,
            3 => 1.22,
            _ => 1.79,
        }
    }
}

impl<T: Scalar> Predictor<T> for LorenzoPredictor {
    fn name(&self) -> &'static str {
        match self.order {
            1 => "lorenzo",
            2 => "lorenzo2",
            _ => "lorenzo3",
        }
    }

    #[inline]
    fn predict(&self, c: &NdCursor<T>) -> f64 {
        debug_assert_eq!(c.ndim(), self.ndim);
        let mut pred = 0.0;
        for (off, coeff) in &self.terms {
            pred += coeff * c.neighbor_f64(off);
        }
        pred
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Shape;
    use crate::util::prop;

    fn predict_at(p: &LorenzoPredictor, dims: &[usize], data: &mut [f32], idx: &[usize]) -> f64 {
        let shape = Shape::new(dims).unwrap();
        let mut c = NdCursor::new(data, &shape);
        c.seek(idx);
        Predictor::<f32>::predict(p, &c)
    }

    #[test]
    fn order1_formulas() {
        // 1D: f(x-1)
        let p1 = LorenzoPredictor::new(1);
        let mut d = vec![3.0f32, 0.0];
        assert_eq!(predict_at(&p1, &[2], &mut d, &[1]), 3.0);
        // 2D: a + b - c
        let p2 = LorenzoPredictor::new(2);
        let mut d = vec![1.0f32, 2.0, 3.0, 0.0]; // [[1,2],[3,?]]
        assert_eq!(predict_at(&p2, &[2, 2], &mut d, &[1, 1]), 3.0 + 2.0 - 1.0);
        // 3D inclusion-exclusion: 7 terms
        let p3 = LorenzoPredictor::new(3);
        let mut d: Vec<f32> = (0..8).map(|x| x as f32).collect();
        // corners of unit cube: f(1,1,1) pred = f110+f101+f011-f100-f010-f001+f000
        let expect = 6.0 + 5.0 + 3.0 - 4.0 - 2.0 - 1.0 + 0.0;
        assert_eq!(predict_at(&p3, &[2, 2, 2], &mut d, &[1, 1, 1]), expect);
    }

    #[test]
    fn order2_1d_formula() {
        let p = LorenzoPredictor::with_order(1, 2);
        let mut d = vec![1.0f32, 4.0, 0.0];
        // 2*f(x-1) - f(x-2) = 8 - 1
        assert_eq!(predict_at(&p, &[3], &mut d, &[2]), 7.0);
    }

    #[test]
    fn exact_on_polynomials() {
        // Order-1 Lorenzo is exact on multilinear functions; order-2 on
        // quadratics along each axis.
        let p = LorenzoPredictor::with_order(2, 1);
        let dims = [8usize, 8];
        let mut data = vec![0f32; 64];
        for i in 0..8 {
            for j in 0..8 {
                data[i * 8 + j] = (2.0 * i as f64 + 3.0 * j as f64 + 1.0) as f32;
            }
        }
        let v = predict_at(&p, &dims, &mut data.clone(), &[4, 5]);
        assert!((v - data[4 * 8 + 5] as f64).abs() < 1e-5);

        // order-2 in 1D is exact on linear data and errs by exactly the
        // second difference on quadratics
        let p2 = LorenzoPredictor::with_order(1, 2);
        let mut lin: Vec<f32> = (0..16).map(|i| (3 * i + 1) as f32).collect();
        let v = predict_at(&p2, &[16], &mut lin, &[9]);
        assert!((v - 28.0).abs() < 1e-5);
        let mut quad: Vec<f32> = (0..16).map(|i| (i * i) as f32).collect();
        let v = predict_at(&p2, &[16], &mut quad, &[9]);
        assert!((v - (81.0 - 2.0)).abs() < 1e-5); // 2f(8)-f(7) = 79
    }

    #[test]
    fn prop_smooth_fields_predict_well() {
        prop::cases(20, 0x70e, |rng| {
            let dims = [12usize, 12, 12];
            let mut data = prop::smooth_field(rng, &dims);
            let range = {
                let (lo, hi) = data
                    .iter()
                    .fold((f32::MAX, f32::MIN), |(l, h), &x| (l.min(x), h.max(x)));
                (hi - lo) as f64
            };
            let p = LorenzoPredictor::new(3);
            let shape = Shape::new(&dims).unwrap();
            let mut c = NdCursor::new(&mut data, &shape);
            c.seek(&[6, 6, 6]);
            let err = (c.value() as f64 - Predictor::<f32>::predict(&p, &c)).abs();
            // interior prediction error on a smooth field stays well below
            // the value range (the field has up to 4 cycles per 12 samples,
            // so "smooth" is relative — 0.8·range is the meaningful line
            // between predictive and useless)
            assert!(err < 0.8 * range, "err {err} range {range}");
        });
    }
}
