//! Composite predictor selection (paper §3.2): the "multialgorithm" design
//! of SZ2 [8], generalized in SZ3 as an estimation criterion. For each data
//! block the selector compares the estimated error of the Lorenzo predictor
//! against the regression fit and picks the better one.
//!
//! Lorenzo's estimate is computed on *original* neighbors plus a
//! decompression-noise correction of `noise_factor(ndim) · eb` per point
//! (the statistical approach of [8]/[15]) — precisely the mis-estimation
//! SZ3-APS fixes by switching pipelines when eb is small (paper §5.2).

use super::lorenzo::LorenzoPredictor;
use super::regression::RegressionFit;

/// Outcome of per-block predictor selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompositeChoice {
    /// Use the Lorenzo predictor for this block.
    Lorenzo,
    /// Use the regression hyperplane for this block.
    Regression,
}

/// Per-block Lorenzo-vs-regression selector.
pub struct CompositeSelector {
    ndim: usize,
    /// When true, skip the noise correction (used by SZ3-APS near-lossless
    /// mode, where decompression noise is provably ~0).
    pub assume_noiseless: bool,
}

/// Result of analyzing one block: both error estimates and the choice.
#[derive(Clone, Debug)]
pub struct BlockAnalysis {
    /// Mean |error| estimate for Lorenzo (incl. noise correction).
    pub lorenzo_err: f64,
    /// Mean |error| estimate for the regression plane.
    pub regression_err: f64,
    /// The fitted plane (unquantized).
    pub fit: RegressionFit,
    /// Selected predictor.
    pub choice: CompositeChoice,
}

impl CompositeSelector {
    /// Selector for `ndim`-dimensional blocks.
    pub fn new(ndim: usize) -> Self {
        CompositeSelector { ndim, assume_noiseless: false }
    }

    /// Estimate the mean |Lorenzo residual| over a block of original data
    /// (order-1, all points, zero padding outside the block). This matches
    /// the L1 kernel `lorenzo_est.py`.
    pub fn lorenzo_block_error(block: &[f64], dims: &[usize]) -> f64 {
        let nd = dims.len();
        let strides = {
            let mut s = vec![1usize; nd];
            let mut acc = 1usize;
            for (st, &d) in s.iter_mut().zip(dims).rev() {
                *st = acc;
                acc = acc.saturating_mul(d);
            }
            s
        };
        let mut idx = vec![0usize; nd];
        let mut sum = 0.0;
        for (flat, &x) in block.iter().enumerate() {
            // inclusion-exclusion over backward neighbors inside the block
            let mut pred = 0.0;
            let nsubsets = 1usize << nd;
            'subset: for s in 1..nsubsets {
                let mut off = flat;
                for (d, (&stride, &i)) in strides.iter().zip(idx.iter()).enumerate() {
                    if s >> d & 1 == 1 {
                        if i == 0 {
                            continue 'subset; // zero padding
                        }
                        off -= stride;
                    }
                }
                let sign = if (s.count_ones() & 1) == 1 { 1.0 } else { -1.0 };
                pred += sign * block.get(off).copied().unwrap_or(0.0);
            }
            sum += (x - pred).abs();
            for (i, &d) in idx.iter_mut().zip(dims).rev() {
                *i += 1;
                if *i < d {
                    break;
                }
                *i = 0;
            }
        }
        sum / block.len() as f64
    }

    /// Analyze one block: fit regression, estimate both errors, choose.
    pub fn analyze(&self, block: &[f64], dims: &[usize], eb: f64) -> BlockAnalysis {
        debug_assert_eq!(dims.len(), self.ndim);
        let fit = RegressionFit::fit(block, dims);
        let regression_err = fit.mean_abs_error(block, dims);
        let mut lorenzo_err = Self::lorenzo_block_error(block, dims);
        if !self.assume_noiseless {
            lorenzo_err += LorenzoPredictor::noise_factor(self.ndim) * eb;
        }
        let choice = if lorenzo_err <= regression_err {
            CompositeChoice::Lorenzo
        } else {
            CompositeChoice::Regression
        };
        BlockAnalysis { lorenzo_err, regression_err, fit, choice }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Pcg32};

    #[test]
    fn planes_prefer_regression_at_high_eb() {
        // A noisy plane: regression fits it exactly; Lorenzo pays the noise
        // correction at high eb => regression wins.
        let dims = [6usize, 6, 6];
        let mut rng = Pcg32::seeded(3);
        let block: Vec<f64> = {
            let mut out = Vec::new();
            for i in 0..6 {
                for j in 0..6 {
                    for k in 0..6 {
                        out.push(
                            i as f64 + 2.0 * j as f64 - k as f64 + rng.normal() * 0.01,
                        );
                    }
                }
            }
            out
        };
        let sel = CompositeSelector::new(3);
        let high = sel.analyze(&block, &dims, 1.0);
        assert_eq!(high.choice, CompositeChoice::Regression);
        // At tiny eb the noise term vanishes; Lorenzo's residual on a plane
        // is ~the noise scale too, so selection flips when regression's
        // residual (also ~noise) exceeds lorenzo's — here they're close, so
        // just check the noise term moved the estimate.
        let low = sel.analyze(&block, &dims, 1e-9);
        assert!(low.lorenzo_err < high.lorenzo_err);
    }

    #[test]
    fn rough_data_prefers_lorenzo_at_low_eb() {
        // Smooth-but-curved data: plane fit has bias, Lorenzo tracks locally.
        let dims = [8usize, 8];
        let mut block = vec![0.0f64; 64];
        for i in 0..8 {
            for j in 0..8 {
                block[i * 8 + j] = ((i * i) as f64 * 0.5) + ((j * j) as f64 * 0.3);
            }
        }
        let sel = CompositeSelector::new(2);
        let a = sel.analyze(&block, &dims, 1e-6);
        assert_eq!(a.choice, CompositeChoice::Lorenzo);
    }

    #[test]
    fn lorenzo_block_error_zero_on_multilinear() {
        let dims = [5usize, 5];
        let mut block = vec![0.0f64; 25];
        for i in 0..5 {
            for j in 0..5 {
                block[i * 5 + j] = 3.0 * i as f64 + 4.0 * j as f64;
            }
        }
        // interior points predict exactly; boundary rows/cols see zero
        // padding, so error concentrates there
        let err = CompositeSelector::lorenzo_block_error(&block, &dims);
        let interior_only: f64 = {
            let mut s = 0.0;
            for i in 1..5 {
                for j in 1..5 {
                    let pred =
                        block[(i - 1) * 5 + j] + block[i * 5 + j - 1] - block[(i - 1) * 5 + j - 1];
                    s += (block[i * 5 + j] - pred).abs();
                }
            }
            s
        };
        assert!(interior_only < 1e-10);
        assert!(err > 0.0); // boundary contribution
    }

    #[test]
    fn prop_analysis_consistent(){
        prop::cases(30, 0xc0e, |rng| {
            let dims = [6usize, 6];
            let block: Vec<f64> = (0..36).map(|_| rng.uniform(-5.0, 5.0)).collect();
            let sel = CompositeSelector::new(2);
            let a = sel.analyze(&block, &dims, 0.1);
            let better = if a.lorenzo_err <= a.regression_err {
                CompositeChoice::Lorenzo
            } else {
                CompositeChoice::Regression
            };
            assert_eq!(a.choice, better);
            assert!(a.lorenzo_err >= 0.0 && a.regression_err >= 0.0);
        });
    }
}
