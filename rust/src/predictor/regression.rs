//! Regression-based predictor (paper §3.2, SZ2 [8]): fits a hyperplane
//! `f(i) = Σ_d b_d · i_d + b_n` to each block of original data and predicts
//! from the plane. Unlike Lorenzo it never reads decompressed neighbors, so
//! it carries no decompression noise — which is why it wins at high error
//! bounds.
//!
//! The closed-form fit exploits the regular grid: after centering each
//! coordinate, the normal equations diagonalize, so each slope is an
//! independent weighted sum. This exact computation is mirrored by the L1
//! Pallas kernel (`python/compile/kernels/regression.py`); the Rust path is
//! the reference/fallback and must stay bit-compatible with `ref.py`.

use crate::byteio::{ByteReader, ByteWriter};
use crate::error::{Result, SzError};

/// A fitted (and possibly coefficient-quantized) hyperplane for one block.
#[derive(Clone, Debug, PartialEq)]
pub struct RegressionFit {
    /// Per-axis slopes then intercept: `coeffs[d]` for axis `d`,
    /// `coeffs[ndim]` is the constant term (value at local origin).
    pub coeffs: Vec<f64>,
}

/// Advance a row-major multi-index one step within `dims`.
#[inline]
fn advance_row_major(idx: &mut [usize], dims: &[usize]) {
    for (i, &d) in idx.iter_mut().zip(dims).rev() {
        *i += 1;
        if *i < d {
            return;
        }
        *i = 0;
    }
}

impl RegressionFit {
    /// Fit a hyperplane to one block.
    ///
    /// `block`: row-major values of the block; `dims`: block dimensions.
    pub fn fit(block: &[f64], dims: &[usize]) -> Self {
        let nd = dims.len();
        let n: usize = dims.iter().product();
        debug_assert_eq!(block.len(), n);
        let mean = block.iter().sum::<f64>() / n as f64;
        // Σ_i (i_d - c_d) * x_i for each axis, with c_d = (n_d - 1)/2.
        let mut idx = vec![0usize; nd];
        let mut sums = vec![0.0; nd];
        for &x in block {
            for ((s, &i), &d) in sums.iter_mut().zip(idx.iter()).zip(dims) {
                *s += (i as f64 - (d as f64 - 1.0) / 2.0) * x;
            }
            advance_row_major(&mut idx, dims);
        }
        let mut slopes = vec![0.0; nd];
        for (slope, (&sum, &d)) in slopes.iter_mut().zip(sums.iter().zip(dims)) {
            let nd_f = d as f64;
            // Σ (i - c)^2 over the grid = N/n_d * n_d(n_d^2-1)/12
            let denom = n as f64 * (nd_f * nd_f - 1.0) / 12.0;
            *slope = if denom > 0.0 { sum / denom } else { 0.0 };
        }
        let intercept =
            mean - slopes.iter().zip(dims).map(|(b, &d)| b * (d as f64 - 1.0) / 2.0).sum::<f64>();
        let mut coeffs = slopes;
        coeffs.push(intercept);
        RegressionFit { coeffs }
    }

    /// Predicted value at local block index `idx`.
    #[inline]
    pub fn predict(&self, idx: &[usize]) -> f64 {
        let Some((intercept, slopes)) = self.coeffs.split_last() else {
            return 0.0;
        };
        let mut v = *intercept;
        for (&c, &i) in slopes.iter().zip(idx) {
            v += c * i as f64;
        }
        v
    }

    /// Mean |residual| of the fit over the block (selection criterion input).
    pub fn mean_abs_error(&self, block: &[f64], dims: &[usize]) -> f64 {
        let mut idx = vec![0usize; dims.len()];
        let mut sum = 0.0;
        for &x in block {
            sum += (x - self.predict(&idx)).abs();
            advance_row_major(&mut idx, dims);
        }
        sum / block.len() as f64
    }

    /// Quantize coefficients so compressor and decompressor share the exact
    /// same plane. Slopes use step `eb / (2·B·nd)`, intercept `eb / 2` —
    /// the induced prediction perturbation stays well under `eb`, and the
    /// quantizer downstream still enforces the bound regardless.
    pub fn quantize(&self, eb: f64, block_side: usize) -> (Vec<i64>, RegressionFit) {
        let nd = self.coeffs.len().saturating_sub(1);
        let slope_step = (eb / (2.0 * block_side as f64 * nd.max(1) as f64)).max(1e-300);
        let icpt_step = (eb / 2.0).max(1e-300);
        let mut q = Vec::with_capacity(nd + 1);
        let mut rec = Vec::with_capacity(nd + 1);
        let Some((intercept, slopes)) = self.coeffs.split_last() else {
            return (q, RegressionFit { coeffs: rec });
        };
        for &c in slopes {
            let qi = (c / slope_step).round();
            // clamp to i64-safe magnitude; huge coeffs mean terrible fit and
            // regression will lose selection anyway
            let qi = qi.clamp(-9e17, 9e17) as i64;
            q.push(qi);
            rec.push(qi as f64 * slope_step);
        }
        let qi = (*intercept / icpt_step).round().clamp(-9e17, 9e17) as i64;
        q.push(qi);
        rec.push(qi as f64 * icpt_step);
        (q, RegressionFit { coeffs: rec })
    }

    /// Rebuild the dequantized plane from stored integers.
    pub fn dequantize(q: &[i64], eb: f64, block_side: usize) -> RegressionFit {
        let nd = q.len().saturating_sub(1);
        let slope_step = (eb / (2.0 * block_side as f64 * nd.max(1) as f64)).max(1e-300);
        let icpt_step = (eb / 2.0).max(1e-300);
        let mut coeffs = Vec::with_capacity(q.len());
        let Some((icpt, slopes)) = q.split_last() else {
            return RegressionFit { coeffs };
        };
        for &qi in slopes {
            coeffs.push(qi as f64 * slope_step);
        }
        coeffs.push(*icpt as f64 * icpt_step);
        RegressionFit { coeffs }
    }

    /// Serialize quantized coefficients (zig-zag varints).
    pub fn save_quantized(q: &[i64], w: &mut ByteWriter) {
        for &v in q {
            let zz = ((v << 1) ^ (v >> 63)) as u64;
            w.put_varint(zz);
        }
    }

    /// Deserialize `n` quantized coefficients.
    pub fn load_quantized(n: usize, r: &mut ByteReader) -> Result<Vec<i64>> {
        // Each coefficient is at least one varint byte; cap the count by the
        // remaining payload so a hostile `n` cannot size the allocation.
        if n > r.remaining() {
            return Err(SzError::corrupt("regression: coefficient count exceeds payload"));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let zz = r.get_varint()?;
            out.push(((zz >> 1) as i64) ^ -((zz & 1) as i64));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn exact_on_planes() {
        let dims = [4usize, 5, 6];
        let n: usize = dims.iter().product();
        let mut block = vec![0.0; n];
        let mut idx = [0usize; 3];
        for v in block.iter_mut() {
            *v = 2.0 * idx[0] as f64 - 1.5 * idx[1] as f64 + 0.25 * idx[2] as f64 + 7.0;
            for d in (0..3).rev() {
                idx[d] += 1;
                if idx[d] < dims[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
        let fit = RegressionFit::fit(&block, &dims);
        assert!((fit.coeffs[0] - 2.0).abs() < 1e-10);
        assert!((fit.coeffs[1] + 1.5).abs() < 1e-10);
        assert!((fit.coeffs[2] - 0.25).abs() < 1e-10);
        assert!((fit.coeffs[3] - 7.0).abs() < 1e-10);
        assert!(fit.mean_abs_error(&block, &dims) < 1e-10);
    }

    #[test]
    fn quantize_roundtrip_bitexact() {
        let fit = RegressionFit { coeffs: vec![0.123456, -9.87, 1e-7, 3.0] };
        let (q, rec) = fit.quantize(1e-3, 6);
        let mut w = ByteWriter::new();
        RegressionFit::save_quantized(&q, &mut w);
        let buf = w.finish();
        let mut r = ByteReader::new(&buf);
        let q2 = RegressionFit::load_quantized(q.len(), &mut r).unwrap();
        assert_eq!(q, q2);
        let rec2 = RegressionFit::dequantize(&q2, 1e-3, 6);
        assert_eq!(rec, rec2); // bit-exact shared plane
    }

    #[test]
    fn prop_fit_is_least_squares_optimal() {
        // Perturbing any coefficient must not reduce the sum of squares.
        prop::cases(40, 0xf17, |rng| {
            let dims = [rng.below(5) + 2, rng.below(5) + 2];
            let n = dims[0] * dims[1];
            let block: Vec<f64> = (0..n).map(|_| rng.uniform(-10.0, 10.0)).collect();
            let fit = RegressionFit::fit(&block, &dims);
            let sse = |f: &RegressionFit| {
                let mut idx = [0usize; 2];
                let mut s = 0.0;
                for &x in &block {
                    let e = x - f.predict(&idx);
                    s += e * e;
                    idx[1] += 1;
                    if idx[1] == dims[1] {
                        idx[1] = 0;
                        idx[0] += 1;
                    }
                }
                s
            };
            let base = sse(&fit);
            for d in 0..3 {
                for delta in [-1e-3, 1e-3] {
                    let mut f2 = fit.clone();
                    f2.coeffs[d] += delta;
                    assert!(sse(&f2) >= base - 1e-9);
                }
            }
        });
    }
}
