//! Predictor stage (paper §3.2, Appendix A.2): value prediction for data
//! decorrelation.
//!
//! Point predictors ([`lorenzo::LorenzoPredictor`], [`ZeroPredictor`]) drive
//! the generic point-by-point compressor. Block-scoped prediction — the
//! regression hyperplane ([`regression`]) and the Lorenzo-vs-regression
//! composite selection ([`composite`]) — powers the SZ2-style block
//! compressor, and periodic-pattern prediction lives in the Pastri pipeline.

pub mod composite;
pub mod lorenzo;
pub mod regression;

pub use composite::{CompositeChoice, CompositeSelector};
pub use lorenzo::LorenzoPredictor;
pub use regression::RegressionFit;

use crate::byteio::{ByteReader, ByteWriter};
use crate::data::{NdCursor, Scalar};
use crate::error::Result;

/// Point predictor: predicts the value at the cursor from already
/// decompressed neighbors.
pub trait Predictor<T: Scalar>: Send {
    /// Instance name for configs and stream headers.
    fn name(&self) -> &'static str;

    /// Predicted value at the cursor (f64 domain). Must depend only on
    /// neighbors at strictly earlier row-major positions (which hold
    /// decompressed values) so compression and decompression agree.
    fn predict(&self, c: &NdCursor<T>) -> f64;

    /// Estimated |error| if this predictor were used at the cursor,
    /// evaluated on original data (used for predictor selection).
    fn estimate_error(&self, c: &NdCursor<T>) -> f64 {
        (c.value().to_f64() - self.predict(c)).abs()
    }

    /// Persist predictor metadata (paper's `save`). Default: stateless.
    fn save(&self, _w: &mut ByteWriter) -> Result<()> {
        Ok(())
    }

    /// Restore predictor metadata (paper's `load`). Default: stateless.
    fn load(&mut self, _r: &mut ByteReader) -> Result<()> {
        Ok(())
    }
}

/// Trivial predictor: always predicts zero. Baseline / anchor-point use.
#[derive(Default, Clone)]
pub struct ZeroPredictor;

impl<T: Scalar> Predictor<T> for ZeroPredictor {
    fn name(&self) -> &'static str {
        "zero"
    }
    fn predict(&self, _c: &NdCursor<T>) -> f64 {
        0.0
    }
}
