//! Compression-quality metrics: PSNR, bit rate, max error — the quantities
//! on the axes of every rate-distortion figure in the paper.

use crate::data::Field;

/// Quality/size metrics for one (original, decompressed, stream) triple.
#[derive(Clone, Copy, Debug)]
pub struct Metrics {
    /// Compression ratio = original bytes / compressed bytes.
    pub ratio: f64,
    /// Bit rate = bits per element in the compressed representation
    /// (`bits/cr` in the paper's definition).
    pub bit_rate: f64,
    /// Peak signal-to-noise ratio (dB); infinite for lossless.
    pub psnr: f64,
    /// Maximum absolute pointwise error.
    pub max_err: f64,
    /// Mean squared error.
    pub mse: f64,
    /// Value range of the original data.
    pub range: f64,
}

/// Compute metrics for a compressed stream.
pub fn evaluate(original: &Field, decompressed: &Field, stream_len: usize) -> Metrics {
    let o = original.values.to_f64_vec();
    let d = decompressed.values.to_f64_vec();
    assert_eq!(o.len(), d.len(), "metrics: length mismatch");
    let n = o.len().max(1);
    let mut mse = 0.0;
    let mut max_err = 0.0f64;
    for (a, b) in o.iter().zip(d.iter()) {
        let e = a - b;
        mse += e * e;
        max_err = max_err.max(e.abs());
    }
    mse /= n as f64;
    let (lo, hi) = original.value_range();
    let range = hi - lo;
    let psnr = if mse == 0.0 {
        f64::INFINITY
    } else {
        20.0 * (range.max(f64::MIN_POSITIVE)).log10() - 10.0 * mse.log10()
    };
    let bits = original.nbytes() as f64 * 8.0 / n as f64;
    let ratio = original.nbytes() as f64 / stream_len.max(1) as f64;
    Metrics { ratio, bit_rate: bits / ratio, psnr, max_err, mse, range }
}

impl std::fmt::Display for Metrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "ratio={:.2} bitrate={:.3} psnr={:.2}dB maxerr={:.3e}",
            self.ratio, self.bit_rate, self.psnr, self.max_err
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lossless_gives_infinite_psnr() {
        let f = Field::f32("x", &[4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let m = evaluate(&f, &f, 8);
        assert!(m.psnr.is_infinite());
        assert_eq!(m.max_err, 0.0);
        assert_eq!(m.ratio, 2.0);
        assert_eq!(m.bit_rate, 16.0);
    }

    #[test]
    fn psnr_matches_hand_computation() {
        let a = Field::f32("a", &[2], vec![0.0, 10.0]).unwrap();
        let b = Field::f32("b", &[2], vec![1.0, 9.0]).unwrap();
        let m = evaluate(&a, &b, 4);
        // mse = 1, range = 10 => psnr = 20*log10(10) = 20
        assert!((m.psnr - 20.0).abs() < 1e-9);
        assert_eq!(m.max_err, 1.0);
    }
}
