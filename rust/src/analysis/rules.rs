//! Audit rule set: panic-freedom and checked-arithmetic checks over the
//! lexed token stream of one source file.
//!
//! Rules fire only outside `#[cfg(test)]` scope. All rules except
//! `swallow` additionally require the file to be in the trust map (see
//! [`super::is_untrusted`]) — they encode the invariant "code that
//! touches attacker-controlled bytes must not be able to panic or wrap";
//! `swallow` (`let _ =` discarding a value, typically a `Result`) is a
//! correctness smell everywhere and applies to the whole library tree.
//!
//! The taint heuristic is lexical by design (no type information without
//! a compiler): an identifier is *tainted* when any snake_case component
//! matches a stem that decode code uses for lengths, counts and offsets.
//! That makes `payload_len + 4`, `base_offset * elems` and
//! `chunk_count << 3` findings, while `fa + fb` (Huffman weights) or
//! `a + b` stay silent. False negatives are accepted — the dynamic
//! corruption-fuzz suite backstops them — but every *flagged* site must
//! be fixed or carry an `audit:allow` with a reason.

use super::lexer::{Kind, Lexed, Token};

/// One rule violation.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Repo-relative source path.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// Rule id (see [`RULES`]).
    pub rule: &'static str,
    /// Trimmed source line.
    pub snippet: String,
}

/// Rule ids with one-line descriptions (the `docs/AUDIT.md` catalog is
/// generated from the same invariants).
pub const RULES: [(&str, &str); 7] = [
    ("panic", "panic!/unreachable!/todo!/unimplemented! in untrusted-input code"),
    ("unwrap", ".unwrap() in untrusted-input code"),
    ("expect", ".expect(...) in untrusted-input code"),
    ("index", "slice/array indexing with a non-literal index in untrusted-input code"),
    ("arith", "unchecked +, * or << on a length/offset/count-named value"),
    ("cast", "truncating `as` cast of a length/offset/count-named or freshly decoded value"),
    ("swallow", "`let _ =` discarding a value (handle it or annotate why)"),
];

/// Identifier stems treated as length/offset/count-tainted.
const TAINT_STEMS: [&str; 14] = [
    "len", "size", "count", "counts", "offset", "offsets", "off", "idx",
    "index", "pos", "dim", "dims", "elems", "nbytes",
];

/// Integer types an `as` cast can truncate a decoded 64-bit length into.
/// (`usize`/`isize` are 32-bit on some targets, so they are included.)
const NARROW_TYPES: [&str; 8] =
    ["u8", "u16", "u32", "i8", "i16", "i32", "usize", "isize"];

/// Decoder methods returning attacker-controlled 64-bit values whose
/// result must not be narrowed with a bare `as`.
const WIDE_DECODERS: [&str; 2] = ["get_varint", "get_u64"];

/// True if `name` contains a tainted snake_case component.
fn is_tainted(name: &str) -> bool {
    name.split('_').any(|part| {
        let p = part.to_ascii_lowercase();
        TAINT_STEMS.iter().any(|s| *s == p)
    })
}

/// True if the token can end an expression (making a following `[` an
/// index operation and a following binary operator binary).
fn ends_expr(t: &Token) -> bool {
    match t.kind {
        Kind::Ident => !matches!(
            t.text.as_str(),
            "return" | "break" | "continue" | "match" | "if" | "while"
                | "else" | "in" | "as" | "let" | "mut" | "ref" | "move"
        ),
        Kind::Num | Kind::Str => true,
        Kind::Life => false,
        Kind::Op => matches!(t.text.as_str(), ")" | "]" | "?"),
    }
}

/// Walk `toks[idx]` == `)` back to its matching `(` and return the index
/// of the token *before* that `(` (the callee), if any.
fn callee_before_close_paren(toks: &[Token], idx: usize) -> Option<usize> {
    let mut depth = 0usize;
    let mut j = idx;
    loop {
        match toks.get(j)?.text.as_str() {
            ")" => depth += 1,
            "(" => {
                if depth <= 1 {
                    return j.checked_sub(1);
                }
                depth -= 1;
            }
            _ => {}
        }
        j = j.checked_sub(1)?;
    }
}

/// Run every rule over one lexed file. `untrusted` gates all rules but
/// `swallow`. `lines` are the file's source lines for snippets.
pub fn check(
    file: &str,
    lexed: &Lexed,
    untrusted: bool,
    lines: &[&str],
) -> Vec<Finding> {
    let toks = &lexed.tokens;
    let mut out = Vec::new();
    let snippet = |line: usize| -> String {
        let s = lines.get(line.saturating_sub(1)).copied().unwrap_or("");
        let s = s.trim();
        if s.len() > 120 {
            let end = (0..=120).rev().find(|&e| s.is_char_boundary(e)).unwrap_or(0);
            format!("{}…", s.get(..end).unwrap_or(""))
        } else {
            s.to_string()
        }
    };
    let mut push = |line: usize, rule: &'static str| {
        out.push(Finding { file: file.to_string(), line, rule, snippet: snippet(line) });
    };
    for (i, t) in toks.iter().enumerate() {
        if t.test_scope {
            continue;
        }
        let prev = i.checked_sub(1).and_then(|p| toks.get(p));
        let next = toks.get(i + 1);
        let is_op = |tt: Option<&Token>, s: &str| {
            tt.map(|x| x.kind == Kind::Op && x.text == s).unwrap_or(false)
        };

        if untrusted && t.kind == Kind::Ident && is_op(next, "!") {
            if matches!(
                t.text.as_str(),
                "panic" | "unreachable" | "todo" | "unimplemented"
            ) {
                push(t.line, "panic");
            }
        }

        if untrusted && t.kind == Kind::Ident && is_op(prev, ".") {
            if t.text == "unwrap" && is_op(next, "(") && is_op(toks.get(i + 2), ")") {
                push(t.line, "unwrap");
            }
            if t.text == "expect" && is_op(next, "(") {
                push(t.line, "expect");
            }
        }

        // index: postfix `expr[ ... ]` whose brackets hold any non-literal
        if untrusted
            && t.kind == Kind::Op
            && t.text == "["
            && prev.map(ends_expr).unwrap_or(false)
        {
            let mut depth = 1usize;
            let mut j = i + 1;
            let mut non_literal = false;
            while depth > 0 {
                let Some(inner) = toks.get(j) else { break };
                match (inner.kind, inner.text.as_str()) {
                    (Kind::Op, "[") => depth += 1,
                    (Kind::Op, "]") => depth -= 1,
                    (Kind::Num, _) | (Kind::Op, "..") | (Kind::Op, "..=") => {}
                    _ if depth > 0 => non_literal = true,
                    _ => {}
                }
                j += 1;
            }
            if non_literal {
                push(t.line, "index");
            }
        }

        // arith: binary + / * / << with a tainted adjacent identifier
        if untrusted
            && t.kind == Kind::Op
            && matches!(t.text.as_str(), "+" | "*" | "<<")
            && prev.map(ends_expr).unwrap_or(false)
        {
            let tainted_side = |tt: Option<&Token>| {
                tt.map(|x| x.kind == Kind::Ident && is_tainted(&x.text))
                    .unwrap_or(false)
            };
            if tainted_side(prev) || tainted_side(next) {
                push(t.line, "arith");
            }
        }

        // cast: `tainted_ident as narrow` or `decode_call()? as narrow`
        if untrusted && t.kind == Kind::Ident && t.text == "as" {
            let narrow = next
                .map(|x| {
                    x.kind == Kind::Ident
                        && NARROW_TYPES.iter().any(|n| *n == x.text)
                })
                .unwrap_or(false);
            if narrow {
                let from_tainted = prev
                    .map(|x| x.kind == Kind::Ident && is_tainted(&x.text))
                    .unwrap_or(false);
                let from_decoder = is_op(prev, "?")
                    && i.checked_sub(2)
                        .and_then(|p| {
                            if is_op(toks.get(p), ")") {
                                callee_before_close_paren(toks, p)
                            } else {
                                None
                            }
                        })
                        .and_then(|c| toks.get(c))
                        .map(|c| {
                            c.kind == Kind::Ident
                                && WIDE_DECODERS.iter().any(|d| *d == c.text)
                        })
                        .unwrap_or(false);
                if from_tainted || from_decoder {
                    push(t.line, "cast");
                }
            }
        }

        // swallow: `let _ =` (library-wide, trust map or not)
        if t.kind == Kind::Ident
            && t.text == "let"
            && next.map(|x| x.kind == Kind::Ident && x.text == "_").unwrap_or(false)
            && is_op(toks.get(i + 2), "=")
        {
            push(t.line, "swallow");
        }
    }
    out
}
