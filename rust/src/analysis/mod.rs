//! `sz3 audit` — a dependency-free static-analysis pass that makes the
//! repo's panic-freedom invariant a build-time property.
//!
//! The corruption-fuzz suite (PR 4) proved *dynamically* that mutated
//! containers error instead of panicking. This module enforces the same
//! invariant *statically*: every module that parses attacker-controlled
//! bytes (the [trust map](TRUST_MAP)) is lexed ([`lexer`]) and checked
//! ([`rules`]) for `unwrap`/`expect`/`panic!`-family calls, non-literal
//! slice indexing, unchecked `+`/`*`/`<<` on length-named values,
//! truncating `as` casts of decoded values, and `let _ =` swallowed
//! results. Violations either get refactored into [`crate::SzError`]
//! returns or carry an explicit `// audit:allow(rule, reason = "...")`
//! annotation, which the tool counts and reports so every exception
//! stays visible.
//!
//! Run locally with `cargo run --release -- audit` (add `--strict` to
//! fail on findings, `--json` for machine-readable output); CI runs the
//! strict mode as a blocking job. Rule catalog and the rationale for
//! each trust-map entry live in `docs/AUDIT.md`.

pub mod lexer;
pub mod rules;

pub use rules::{Finding, RULES};

use crate::error::{Result, SzError};
use std::path::{Path, PathBuf};

/// Modules that parse or index attacker-controlled bytes. Entries ending
/// in `/` cover a whole directory. Paths are repo-relative with forward
/// slashes.
///
/// Deliberately *not* listed: `container/adaptive.rs` and
/// `container/fixtures.rs` (compression-side selection / test-corpus
/// generation — they consume trusted in-process data), and the
/// compression-side pipeline stages, whose inputs are the caller's own
/// fields. `quantizer/` and `predictor/` *are* listed: their `load()`
/// paths restore per-stream state straight from attacker-controlled
/// bytes. `docs/AUDIT.md` records the rationale per entry.
pub const TRUST_MAP: [&str; 15] = [
    "rust/src/byteio.rs",
    "rust/src/bitio.rs",
    "rust/src/container/mod.rs",
    "rust/src/container/delta.rs",
    "rust/src/reader/mod.rs",
    "rust/src/reader/source.rs",
    "rust/src/reader/cache.rs",
    "rust/src/server/http.rs",
    "rust/src/server/handlers.rs",
    "rust/src/obs/",
    "rust/src/encoder/",
    "rust/src/lossless/",
    "rust/src/quantizer/",
    "rust/src/predictor/",
    "rust/src/transform/",
];

/// True if `rel` (repo-relative, forward slashes) is in the trust map.
pub fn is_untrusted(rel: &str) -> bool {
    TRUST_MAP.iter().any(|entry| {
        if let Some(dir) = entry.strip_suffix('/') {
            rel.starts_with(dir)
                && rel.get(dir.len()..dir.len() + 1) == Some("/")
        } else {
            rel == *entry
        }
    })
}

/// One applied (or dangling) suppression annotation.
#[derive(Debug, Clone)]
pub struct SuppressionReport {
    /// Repo-relative file.
    pub file: String,
    /// Annotation line.
    pub line: usize,
    /// Rule it names.
    pub rule: String,
    /// How many findings it silenced (0 = dangling annotation).
    pub used: usize,
}

/// Full audit result over the library tree.
#[derive(Debug, Default)]
pub struct AuditReport {
    /// Unsuppressed violations (strict mode fails when non-empty).
    pub findings: Vec<Finding>,
    /// Every `audit:allow` annotation with its use count.
    pub suppressions: Vec<SuppressionReport>,
    /// `.rs` files scanned.
    pub files_scanned: usize,
    /// Files of those in the trust map.
    pub files_untrusted: usize,
}

impl AuditReport {
    /// Total findings silenced by annotations.
    pub fn suppressed_count(&self) -> usize {
        self.suppressions.iter().map(|s| s.used).sum()
    }
}

/// Recursively collect `.rs` files under `dir`, sorted for determinism.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| SzError::config(format!("audit: reading {}: {e}", dir.display())))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Audit one file's source text. `rel` decides trust-map membership.
/// Exposed for the self-test corpus, which feeds fixture snippets
/// through the same path the repo scan uses.
pub fn audit_source(rel: &str, src: &str) -> (Vec<Finding>, Vec<SuppressionReport>) {
    let lexed = lexer::lex(src);
    let lines: Vec<&str> = src.lines().collect();
    let raw = rules::check(rel, &lexed, is_untrusted(rel), &lines);
    let known_rule = |r: &str| RULES.iter().any(|(id, _)| *id == r);
    let mut used = vec![0usize; lexed.allows.len()];
    let mut findings = Vec::new();
    // a malformed annotation (unknown rule / missing reason) is itself a
    // finding, attributed to the `swallow`-style catch-all id "allow"
    for a in lexed.allows.iter() {
        if !known_rule(&a.rule) || !a.reason_ok {
            findings.push(Finding {
                file: rel.to_string(),
                line: a.line,
                rule: "allow",
                snippet: format!(
                    "audit:allow({}) {}",
                    a.rule,
                    if a.reason_ok { "names an unknown rule" } else { "is missing a reason" }
                ),
            });
        }
    }
    for f in raw {
        let hit = lexed.allows.iter().enumerate().find(|(_, a)| {
            a.rule == f.rule
                && a.reason_ok
                && (a.line == f.line || a.line + 1 == f.line)
        });
        match hit {
            Some((ai, _)) => {
                if let Some(slot) = used.get_mut(ai) {
                    *slot += 1;
                }
            }
            None => findings.push(f),
        }
    }
    let suppressions = lexed
        .allows
        .iter()
        .zip(used)
        .map(|(a, n)| SuppressionReport {
            file: rel.to_string(),
            line: a.line,
            rule: a.rule.clone(),
            used: n,
        })
        .collect();
    (findings, suppressions)
}

/// Audit the library tree under `root` (the repo root: scans
/// `rust/src/**/*.rs`). Tests, benches and examples are out of scope —
/// the invariant is about shipped decode paths.
pub fn audit_repo(root: &Path) -> Result<AuditReport> {
    let src_root = root.join("rust").join("src");
    let mut files = Vec::new();
    collect_rs(&src_root, &mut files)?;
    let mut report = AuditReport { files_scanned: files.len(), ..Default::default() };
    for path in &files {
        let rel_path = path.strip_prefix(root).unwrap_or(path);
        let rel = rel_path
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        // a file named tests.rs is an out-of-line `#[cfg(test)] mod tests;`
        // body: all test scope, like inline test modules
        if rel.ends_with("/tests.rs") {
            continue;
        }
        if is_untrusted(&rel) {
            report.files_untrusted += 1;
        }
        let src = std::fs::read_to_string(path)
            .map_err(|e| SzError::config(format!("audit: reading {rel}: {e}")))?;
        let (findings, suppressions) = audit_source(&rel, &src);
        report.findings.extend(findings);
        report.suppressions.extend(suppressions);
    }
    Ok(report)
}

/// Human-readable report text (what `sz3 audit` prints).
pub fn format_report(r: &AuditReport) -> String {
    let mut out = String::new();
    for f in &r.findings {
        out.push_str(&format!(
            "{}:{}: [{}] {}\n",
            f.file, f.line, f.rule, f.snippet
        ));
    }
    for s in &r.suppressions {
        if s.used == 0 {
            out.push_str(&format!(
                "{}:{}: warning: unused audit:allow({})\n",
                s.file, s.line, s.rule
            ));
        }
    }
    out.push_str(&format!(
        "audit: {} findings, {} suppressed by {} annotations, \
         {} files scanned ({} untrusted-input)\n",
        r.findings.len(),
        r.suppressed_count(),
        r.suppressions.len(),
        r.files_scanned,
        r.files_untrusted,
    ));
    out
}

/// Minimal JSON string escape (no serde offline).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// JSON report (what `sz3 audit --json` prints).
pub fn format_report_json(r: &AuditReport) -> String {
    let findings: Vec<String> = r
        .findings
        .iter()
        .map(|f| {
            format!(
                "{{\"file\":{},\"line\":{},\"rule\":{},\"snippet\":{}}}",
                json_str(&f.file),
                f.line,
                json_str(f.rule),
                json_str(&f.snippet)
            )
        })
        .collect();
    let sups: Vec<String> = r
        .suppressions
        .iter()
        .map(|s| {
            format!(
                "{{\"file\":{},\"line\":{},\"rule\":{},\"used\":{}}}",
                json_str(&s.file),
                s.line,
                json_str(&s.rule),
                s.used
            )
        })
        .collect();
    format!(
        "{{\"findings\":[{}],\"suppressions\":[{}],\
         \"files_scanned\":{},\"files_untrusted\":{}}}\n",
        findings.join(","),
        sups.join(","),
        r.files_scanned,
        r.files_untrusted
    )
}

#[cfg(test)]
mod tests;
