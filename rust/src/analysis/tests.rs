//! Audit self-test corpus: every rule must fire on its trigger fixture
//! and stay silent on its clean fixture, suppressions must silence and
//! be counted, and — the point of the whole subsystem — the repo itself
//! must audit clean. The fixtures live in `testdata/*.rs.txt` (non-`.rs`
//! so cargo never tries to compile them) and run through the exact
//! [`super::audit_source`] path the repo scan uses.

use super::{audit_source, is_untrusted, lexer, rules, TRUST_MAP};

/// A path inside the trust map, so every rule is active.
const HOT: &str = "rust/src/container/mod.rs";
/// A library path outside the trust map: only `swallow` applies.
const COLD: &str = "rust/src/metrics.rs";

fn rules_fired(path: &str, src: &str) -> Vec<&'static str> {
    let (findings, _) = audit_source(path, src);
    findings.iter().map(|f| f.rule).collect()
}

fn count_rule(path: &str, src: &str, rule: &str) -> usize {
    rules_fired(path, src).iter().filter(|r| **r == rule).count()
}

#[test]
fn trigger_fixtures_fire_their_rule() {
    let cases: [(&str, &str, usize); 7] = [
        ("panic", include_str!("testdata/trigger_panic.rs.txt"), 4),
        ("unwrap", include_str!("testdata/trigger_unwrap.rs.txt"), 1),
        ("expect", include_str!("testdata/trigger_expect.rs.txt"), 1),
        ("index", include_str!("testdata/trigger_index.rs.txt"), 2),
        ("arith", include_str!("testdata/trigger_arith.rs.txt"), 3),
        ("cast", include_str!("testdata/trigger_cast.rs.txt"), 2),
        ("swallow", include_str!("testdata/trigger_swallow.rs.txt"), 1),
    ];
    for (rule, src, expected) in cases {
        assert_eq!(
            count_rule(HOT, src, rule),
            expected,
            "rule '{rule}' trigger fixture: got {:?}",
            rules_fired(HOT, src)
        );
    }
}

#[test]
fn clean_fixtures_stay_silent() {
    let cases: [(&str, &str); 7] = [
        ("panic", include_str!("testdata/clean_panic.rs.txt")),
        ("unwrap", include_str!("testdata/clean_unwrap.rs.txt")),
        ("expect", include_str!("testdata/clean_expect.rs.txt")),
        ("index", include_str!("testdata/clean_index.rs.txt")),
        ("arith", include_str!("testdata/clean_arith.rs.txt")),
        ("cast", include_str!("testdata/clean_cast.rs.txt")),
        ("swallow", include_str!("testdata/clean_swallow.rs.txt")),
    ];
    for (rule, src) in cases {
        let fired = rules_fired(HOT, src);
        assert!(
            fired.is_empty(),
            "clean fixture for '{rule}' fired {fired:?}"
        );
    }
}

#[test]
fn outside_trust_map_only_swallow_applies() {
    let trigger_unwrap = include_str!("testdata/trigger_unwrap.rs.txt");
    assert!(rules_fired(COLD, trigger_unwrap).is_empty());
    let trigger_swallow = include_str!("testdata/trigger_swallow.rs.txt");
    assert_eq!(rules_fired(COLD, trigger_swallow), vec!["swallow"]);
}

#[test]
fn suppressions_silence_count_and_report_unused() {
    let src = include_str!("testdata/suppressed.rs.txt");
    let (findings, sups) = audit_source(HOT, src);
    assert!(findings.is_empty(), "suppressed fixture fired {findings:?}");
    assert_eq!(sups.len(), 3);
    let by_rule: Vec<(&str, usize)> =
        sups.iter().map(|s| (s.rule.as_str(), s.used)).collect();
    assert_eq!(
        by_rule,
        vec![("index", 1), ("unwrap", 1), ("panic", 0)],
        "next-line and same-line allows must each count once; the \
         dangling allow must report used=0"
    );
}

#[test]
fn malformed_allows_are_findings_and_do_not_suppress() {
    let src = include_str!("testdata/bad_allow.rs.txt");
    let (findings, _) = audit_source(HOT, src);
    let fired: Vec<&str> = findings.iter().map(|f| f.rule).collect();
    // reason-less allow + the unwrap it failed to cover + unknown rule id
    assert_eq!(fired, vec!["allow", "allow", "unwrap"]);
}

#[test]
fn trust_map_membership() {
    for entry in TRUST_MAP {
        if let Some(dir) = entry.strip_suffix('/') {
            assert!(is_untrusted(&format!("{dir}/anything.rs")), "{entry}");
        } else {
            assert!(is_untrusted(entry), "{entry}");
        }
    }
    assert!(!is_untrusted("rust/src/metrics.rs"));
    assert!(!is_untrusted("rust/src/container/adaptive.rs"));
    assert!(!is_untrusted("rust/src/container/fixtures.rs"));
    assert!(!is_untrusted("rust/src/encoder.rs"), "dir prefix must not match a sibling file");
}

#[test]
fn lexer_handles_strings_comments_lifetimes() {
    let src = r##"
        // comment with .unwrap() and panic!
        /* block /* nested */ with buf[i] */
        fn f<'a>(x: &'a str) -> char {
            let s = "a string with .unwrap() and \" escapes";
            let r = r#"raw with buf[i] and "quotes""#;
            let c = 'x';
            let esc = '\'';
            let _use = (s, r, c, esc);
            '\n'
        }
    "##;
    let lexed = lexer::lex(src);
    // none of the comment/string bodies may materialize as code tokens
    assert!(!lexed
        .tokens
        .iter()
        .any(|t| t.text == "unwrap" || t.text == "panic"));
    // lifetimes must not swallow the rest of the line as a char literal
    assert!(lexed.tokens.iter().any(|t| t.kind == lexer::Kind::Life));
    let idents: Vec<&str> = lexed
        .tokens
        .iter()
        .filter(|t| t.kind == lexer::Kind::Ident)
        .map(|t| t.text.as_str())
        .collect();
    assert!(idents.contains(&"esc") && idents.contains(&"_use"));
}

#[test]
fn lexer_separates_compound_ops_from_arith_ops() {
    let lexed = lexer::lex("a += b; c <<= d; e << f; g + h; i..j; k..=l;");
    let ops: Vec<&str> = lexed
        .tokens
        .iter()
        .filter(|t| t.kind == lexer::Kind::Op)
        .map(|t| t.text.as_str())
        .collect();
    assert!(ops.contains(&"+=") && ops.contains(&"<<="));
    assert!(ops.contains(&"<<") && ops.contains(&"+"));
    assert!(ops.contains(&"..") && ops.contains(&"..="));
    // exactly one bare `<<` and one bare `+`: compound forms not split
    assert_eq!(ops.iter().filter(|o| **o == "<<").count(), 1);
    assert_eq!(ops.iter().filter(|o| **o == "+").count(), 1);
}

#[test]
fn every_rule_id_has_a_description() {
    for (id, desc) in rules::RULES {
        assert!(!id.is_empty() && !desc.is_empty());
    }
}

/// The invariant this subsystem exists to hold: the shipped library tree
/// audits clean. Runs the same scan `sz3 audit --strict` and CI run.
#[test]
fn repo_source_tree_audits_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = super::audit_repo(root).expect("audit scan");
    assert!(report.files_scanned > 40, "scan found too few files");
    assert!(report.files_untrusted >= 15, "trust map resolved too few files");
    let rendered = super::format_report(&report);
    assert!(
        report.findings.is_empty(),
        "audit found unsuppressed violations:\n{rendered}"
    );
    // and the machine-readable output stays parseable in shape
    let json = super::format_report_json(&report);
    assert!(json.starts_with("{\"findings\":[") && json.ends_with("}\n"));
}
