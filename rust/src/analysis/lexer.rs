//! Hand-rolled Rust lexer for the `sz3 audit` static-analysis pass.
//!
//! `syn`/`proc-macro2` are unavailable offline, and the audit rules only
//! need a faithful *token* view of the source — not a parse tree — so
//! this lexer handles exactly the constructs that would otherwise corrupt
//! a token stream: line and (nested) block comments, string literals with
//! escapes, raw strings with arbitrary `#` fences, byte strings, char
//! literals vs. lifetimes, numeric literals with suffixes/exponents, and
//! multi-character operators (so `<<` is distinguishable from `<` and
//! `+=` from `+`).
//!
//! Two audit-specific extras ride on the lexer:
//! * `// audit:allow(rule, reason = "...")` comments are collected as
//!   [`Allow`] records instead of being discarded with other comments.
//! * a post-pass marks every token inside a `#[cfg(test)]` item as
//!   test-scope, so rules can exempt test code (tests exercise panics on
//!   purpose; the production invariant is about the shipped decode path).

/// Token classification — only as fine-grained as the rules require.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// Identifier or keyword.
    Ident,
    /// Numeric literal (int or float, any base/suffix).
    Num,
    /// String, raw-string, byte-string or char literal.
    Str,
    /// Lifetime (`'a`).
    Life,
    /// Operator / punctuation (multi-char ops are single tokens).
    Op,
}

/// One lexed token with its source position.
#[derive(Debug, Clone)]
pub struct Token {
    /// Token text (for `Op`, the operator itself, e.g. `"<<"`).
    pub text: String,
    /// Classification.
    pub kind: Kind,
    /// 1-based source line.
    pub line: usize,
    /// Inside a `#[cfg(test)]` item.
    pub test_scope: bool,
}

/// One `// audit:allow(rule, reason = "...")` annotation.
#[derive(Debug, Clone)]
pub struct Allow {
    /// 1-based line the comment sits on (suppresses findings on this line
    /// and the next).
    pub line: usize,
    /// Rule id named by the annotation.
    pub rule: String,
    /// Whether a non-empty `reason = "..."` was supplied.
    pub reason_ok: bool,
}

/// Multi-character operators, longest first so maximal munch works.
const MULTI_OPS: [&str; 22] = [
    "<<=", ">>=", "..=", "::", "->", "=>", "==", "!=", "<=", ">=", "&&",
    "||", "+=", "-=", "*=", "/=", "%=", "^=", "&=", "|=", "<<", "..",
];

/// Lexer output: the token stream plus every audit annotation seen.
pub struct Lexed {
    /// Tokens in source order (comments and whitespace dropped).
    pub tokens: Vec<Token>,
    /// `audit:allow` annotations in source order.
    pub allows: Vec<Allow>,
}

/// Parse an `audit:allow(...)` comment body (text after `//`, trimmed).
fn parse_allow(body: &str, line: usize) -> Option<Allow> {
    let rest = body.trim().strip_prefix("audit:allow(")?;
    let inner = rest.rsplit_once(')').map(|(i, _)| i).unwrap_or(rest);
    let (rule, tail) = match inner.split_once(',') {
        Some((r, t)) => (r.trim(), Some(t)),
        None => (inner.trim(), None),
    };
    let reason_ok = tail
        .and_then(|t| t.split_once('='))
        .map(|(k, v)| {
            k.trim() == "reason" && v.trim().trim_matches('"').trim().len() >= 3
        })
        .unwrap_or(false);
    Some(Allow { line, rule: rule.to_string(), reason_ok })
}

/// Lex `src` into tokens + annotations. Never panics: malformed input
/// (unterminated strings/comments) simply ends the current token at EOF.
pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut tokens = Vec::new();
    let mut allows = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    let at = |i: usize| chars.get(i).copied().unwrap_or('\0');
    while i < n {
        let c = at(i);
        if c == '\n' {
            line = line.saturating_add(1);
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment (and audit annotation collection)
        if c == '/' && at(i + 1) == '/' {
            let start = i + 2;
            while i < n && at(i) != '\n' {
                i += 1;
            }
            let body: String = chars.get(start..i).unwrap_or(&[]).iter().collect();
            if let Some(a) = parse_allow(&body, line) {
                allows.push(a);
            }
            continue;
        }
        // nested block comment
        if c == '/' && at(i + 1) == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if at(i) == '/' && at(i + 1) == '*' {
                    depth += 1;
                    i += 2;
                } else if at(i) == '*' && at(i + 1) == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if at(i) == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            continue;
        }
        // raw strings: r"..." / r#"..."# / br#"..."# with any fence depth
        if (c == 'r' || c == 'b') && !at(i).is_numeric() {
            let (prefix_len, is_raw) = if c == 'r' {
                (1, at(i + 1) == '"' || at(i + 1) == '#')
            } else if at(i + 1) == 'r' {
                (2, at(i + 2) == '"' || at(i + 2) == '#')
            } else {
                (0, false)
            };
            if is_raw {
                let mut j = i + prefix_len;
                let mut fence = 0usize;
                while at(j) == '#' {
                    fence += 1;
                    j += 1;
                }
                if at(j) == '"' {
                    j += 1;
                    // scan for `"` followed by `fence` hashes
                    loop {
                        if j >= n {
                            break;
                        }
                        if at(j) == '\n' {
                            line += 1;
                            j += 1;
                            continue;
                        }
                        if at(j) == '"' {
                            let mut k = j + 1;
                            let mut seen = 0usize;
                            while seen < fence && at(k) == '#' {
                                seen += 1;
                                k += 1;
                            }
                            if seen == fence {
                                j = k;
                                break;
                            }
                        }
                        j += 1;
                    }
                    tokens.push(Token {
                        text: String::new(),
                        kind: Kind::Str,
                        line,
                        test_scope: false,
                    });
                    i = j;
                    continue;
                }
            }
        }
        // string / byte-string literal with escapes
        if c == '"' || (c == 'b' && at(i + 1) == '"') {
            let mut j = if c == 'b' { i + 2 } else { i + 1 };
            while j < n {
                match at(j) {
                    '\\' => j += 2,
                    '"' => {
                        j += 1;
                        break;
                    }
                    '\n' => {
                        line += 1;
                        j += 1;
                    }
                    _ => j += 1,
                }
            }
            tokens.push(Token {
                text: String::new(),
                kind: Kind::Str,
                line,
                test_scope: false,
            });
            i = j;
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            let nx = at(i + 1);
            if nx == '\\' {
                // escaped char literal: '\n', '\u{1F600}', '\'' ...
                // skip the escaped character so '\'' closes correctly
                let mut j = i + 3;
                if at(i + 2) == 'u' && at(j) == '{' {
                    while j < n && at(j) != '}' {
                        j += 1;
                    }
                }
                while j < n && at(j) != '\'' {
                    j += 1;
                }
                tokens.push(Token {
                    text: String::new(),
                    kind: Kind::Str,
                    line,
                    test_scope: false,
                });
                i = j + 1;
                continue;
            }
            if at(i + 2) == '\'' && nx != '\'' {
                // 'x'
                tokens.push(Token {
                    text: String::new(),
                    kind: Kind::Str,
                    line,
                    test_scope: false,
                });
                i += 3;
                continue;
            }
            // lifetime: 'ident (no closing quote)
            let mut j = i + 1;
            while j < n && (at(j).is_alphanumeric() || at(j) == '_') {
                j += 1;
            }
            tokens.push(Token {
                text: String::new(),
                kind: Kind::Life,
                line,
                test_scope: false,
            });
            i = j.max(i + 1);
            continue;
        }
        // identifier / keyword
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < n && (at(i).is_alphanumeric() || at(i) == '_') {
                i += 1;
            }
            let text: String = chars.get(start..i).unwrap_or(&[]).iter().collect();
            tokens.push(Token { text, kind: Kind::Ident, line, test_scope: false });
            continue;
        }
        // numeric literal (loose: base prefixes, underscores, suffixes,
        // exponents; stops before `..` so ranges lex as Num Op Num)
        if c.is_ascii_digit() {
            let start = i;
            i += 1;
            while i < n {
                let d = at(i);
                if d.is_alphanumeric() || d == '_' {
                    i += 1;
                } else if d == '.' && at(i + 1) != '.' && at(i + 1) != '\0' {
                    // float point, but not a range and not a method call
                    if at(i + 1).is_ascii_digit() {
                        i += 1;
                    } else {
                        break;
                    }
                } else if (d == '+' || d == '-')
                    && matches!(at(i.saturating_sub(1)), 'e' | 'E')
                {
                    i += 1; // exponent sign: 1e-3
                } else {
                    break;
                }
            }
            let text: String = chars.get(start..i).unwrap_or(&[]).iter().collect();
            tokens.push(Token { text, kind: Kind::Num, line, test_scope: false });
            continue;
        }
        // operators: maximal munch over the multi-char table
        let mut matched = None;
        for op in MULTI_OPS {
            let oc: Vec<char> = op.chars().collect();
            if chars.get(i..i + oc.len()) == Some(&oc[..]) {
                matched = Some(op);
                break;
            }
        }
        if let Some(op) = matched {
            tokens.push(Token {
                text: op.to_string(),
                kind: Kind::Op,
                line,
                test_scope: false,
            });
            i += op.len();
            continue;
        }
        tokens.push(Token {
            text: c.to_string(),
            kind: Kind::Op,
            line,
            test_scope: false,
        });
        i += 1;
    }
    mark_test_scope(&mut tokens);
    Lexed { tokens, allows }
}

/// Mark every token belonging to a `#[cfg(test)]` item (the attribute
/// itself, through the end of the following braced item or statement).
fn mark_test_scope(tokens: &mut [Token]) {
    let is = |t: Option<&Token>, s: &str| t.map(|t| t.text == s).unwrap_or(false);
    let mut i = 0usize;
    while i < tokens.len() {
        let hit = is(tokens.get(i), "#")
            && is(tokens.get(i + 1), "[")
            && is(tokens.get(i + 2), "cfg")
            && is(tokens.get(i + 3), "(")
            && is(tokens.get(i + 4), "test")
            && is(tokens.get(i + 5), ")")
            && is(tokens.get(i + 6), "]");
        if !hit {
            i += 1;
            continue;
        }
        // span the following item: to the matching `}` of its first brace
        // block, or to `;` for brace-less items (`#[cfg(test)] use x;`)
        let mut j = i + 7;
        let mut depth = 0usize;
        let mut braced = false;
        while j < tokens.len() {
            match tokens.get(j).map(|t| t.text.as_str()) {
                Some("{") => {
                    depth += 1;
                    braced = true;
                }
                Some("}") => {
                    depth = depth.saturating_sub(1);
                    if braced && depth == 0 {
                        j += 1;
                        break;
                    }
                }
                Some(";") if !braced => {
                    j += 1;
                    break;
                }
                _ => {}
            }
            j += 1;
        }
        for t in tokens.iter_mut().take(j).skip(i) {
            t.test_scope = true;
        }
        i = j;
    }
}
