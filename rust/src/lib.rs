//! # SZ3 — a modular framework for composing prediction-based
//! # error-bounded lossy compressors
//!
//! Rust + JAX + Pallas reproduction of *SZ3: A Modular Framework for
//! Composing Prediction-Based Error-Bounded Lossy Compressors* (Liang,
//! Zhao, Di, et al., 2021), structured as three layers:
//!
//! * **L3 (this crate)** — the modular compression framework
//!   (preprocessor → predictor → quantizer → encoder → lossless), the
//!   composed pipelines (SZ3-LR, SZ3-Interp, SZ3-Truncation, SZ3-Pastri,
//!   SZ3-APS), and a streaming coordinator for multi-field scientific
//!   snapshots.
//! * **L2/L1 (python/compile, build-time only)** — the block-analysis
//!   compute hot-spot (regression fit + predictor-error estimation)
//!   expressed in JAX/Pallas and AOT-lowered to HLO text.
//! * **runtime** — loads `artifacts/*.hlo.txt` through PJRT (`xla` crate)
//!   and serves batched block analysis to the L3 hot path. Python never
//!   runs at request time.
//!
//! On top of the compression framework sits the **serving stack**: the
//! [`container`] module packs coordinator output into self-describing
//! chunked `SZ3C` artifacts (per-chunk CRC-32, per-chunk pipeline
//! selection, and — since v3 — a snapshot axis with per-chunk delta
//! encoding for whole time series in one artifact); [`reader`] opens
//! them for indexed-seek region reads at any snapshot with a
//! byte-budgeted decoded-chunk cache; and [`server`] publishes a
//! directory of artifacts over HTTP range queries (`sz3 serve-http`,
//! `?snapshot=K`).
//! Architecture notes live in `docs/ARCHITECTURE.md`, the container
//! byte layout in `docs/CONTAINER.md`, and the HTTP API contract in
//! `docs/SERVE.md`.
//!
//! Quickstart (`no_run`: rustdoc does not apply the workspace rpath flags,
//! so doctest binaries cannot locate libxla_extension's bundled libstdc++
//! in this image — the same code runs as `examples/quickstart.rs` and is
//! covered by the test suite):
//! ```no_run
//! use sz3::data::Field;
//! use sz3::pipeline::{build, decompress_any, CompressConf, ErrorBound};
//!
//! let values: Vec<f32> = (0..4096).map(|i| (i as f32 * 0.01).sin()).collect();
//! let field = Field::f32("wave", &[64, 64], values).unwrap();
//! // registry alias — or any composed spec, e.g.
//! // build("block(lorenzo+regression)/linear/huffman/lzhuf")
//! let pipeline = build("sz3-lr").unwrap();
//! let conf = CompressConf::new(ErrorBound::Abs(1e-3));
//! let stream = pipeline.compress(&field, &conf).unwrap();
//! let restored = decompress_any(&stream).unwrap();
//! assert_eq!(restored.shape.dims(), field.shape.dims());
//! ```

pub mod analysis;
pub mod bench_harness;
pub mod bitio;
pub mod byteio;
pub mod cli;
pub mod config;
pub mod container;
pub mod coordinator;
pub mod data;
pub mod datagen;
pub mod encoder;
pub mod error;
pub mod lossless;
pub mod metrics;
pub mod obs;
pub mod pipeline;
pub mod predictor;
pub mod preprocessor;
pub mod quantizer;
pub mod reader;
pub mod runtime;
pub mod server;
pub mod transform;
pub mod util;

pub use error::{Result, SzError};

/// Crate version.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
