//! Byte-level serialization substrate.
//!
//! Little-endian primitives + length-prefixed blocks, used by every module's
//! `save`/`load` to persist metadata (Huffman tables, regression
//! coefficients, unpredictable-value stores, ...) into the compressed stream.

use crate::error::{Result, SzError};

/// Append-only byte writer.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    /// New empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Consume and return the buffer.
    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True if nothing has been written.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Write raw bytes.
    pub fn put_bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    /// Write a u8.
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Write a u16 (LE).
    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write a u32 (LE).
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write a u64 (LE).
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write an i32 (LE).
    pub fn put_i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write an i64 (LE).
    pub fn put_i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write an f32 (LE bits).
    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write an f64 (LE bits).
    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write a usize as u64.
    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    /// Write a LEB128-style varint (space-efficient for small counts).
    pub fn put_varint(&mut self, mut v: u64) {
        loop {
            let byte = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(byte);
                break;
            }
            self.buf.push(byte | 0x80);
        }
    }

    /// Write a length-prefixed byte block.
    pub fn put_block(&mut self, b: &[u8]) {
        self.put_varint(b.len() as u64);
        self.put_bytes(b);
    }

    /// Write a length-prefixed UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_block(s.as_bytes());
    }
}

/// Sequential byte reader.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    /// Reader over `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    /// Bytes remaining.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Current read offset.
    pub fn pos(&self) -> usize {
        self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // checked end-of-range: `pos + n` on attacker-supplied lengths
        // must neither wrap nor index past the buffer
        let slice = self
            .pos
            .checked_add(n)
            .and_then(|end| self.buf.get(self.pos..end));
        match slice {
            Some(s) => {
                self.pos += n;
                Ok(s)
            }
            None => Err(SzError::corrupt(format!(
                "need {n} bytes, have {}",
                self.remaining()
            ))),
        }
    }

    /// Take exactly `N` bytes as a fixed array (panic-free `try_into`
    /// replacement for the primitive getters).
    fn take_arr<const N: usize>(&mut self) -> Result<[u8; N]> {
        let s = self.take(N)?;
        let mut a = [0u8; N];
        for (slot, &b) in a.iter_mut().zip(s) {
            *slot = b;
        }
        Ok(a)
    }

    /// Read raw bytes.
    pub fn get_bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    /// Read a u8.
    pub fn get_u8(&mut self) -> Result<u8> {
        let [b] = self.take_arr()?;
        Ok(b)
    }

    /// Read a u16 (LE).
    pub fn get_u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take_arr()?))
    }

    /// Read a u32 (LE).
    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take_arr()?))
    }

    /// Read a u64 (LE).
    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take_arr()?))
    }

    /// Read an i32 (LE).
    pub fn get_i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take_arr()?))
    }

    /// Read an i64 (LE).
    pub fn get_i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take_arr()?))
    }

    /// Read an f32.
    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take_arr()?))
    }

    /// Read an f64.
    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take_arr()?))
    }

    /// Read a usize (stored as u64).
    pub fn get_usize(&mut self) -> Result<usize> {
        usize::try_from(self.get_u64()?)
            .map_err(|_| SzError::corrupt("stored size exceeds this platform's usize"))
    }

    /// Read a varint.
    pub fn get_varint(&mut self) -> Result<u64> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let byte = self.get_u8()?;
            if shift >= 64 {
                return Err(SzError::corrupt("varint overflow"));
            }
            v |= ((byte & 0x7f) as u64) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    /// Read a length-prefixed block.
    pub fn get_block(&mut self) -> Result<&'a [u8]> {
        let len = usize::try_from(self.get_varint()?)
            .map_err(|_| SzError::corrupt("block length exceeds this platform's usize"))?;
        self.take(len)
    }

    /// Read a length-prefixed UTF-8 string.
    pub fn get_str(&mut self) -> Result<String> {
        let b = self.get_block()?;
        String::from_utf8(b.to_vec()).map_err(|_| SzError::corrupt("invalid utf8"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn roundtrip_primitives() {
        let mut w = ByteWriter::new();
        w.put_u8(0xab);
        w.put_u32(123456);
        w.put_i32(-77);
        w.put_f64(3.14159);
        w.put_str("hello");
        w.put_block(&[1, 2, 3]);
        let buf = w.finish();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.get_u8().unwrap(), 0xab);
        assert_eq!(r.get_u32().unwrap(), 123456);
        assert_eq!(r.get_i32().unwrap(), -77);
        assert_eq!(r.get_f64().unwrap(), 3.14159);
        assert_eq!(r.get_str().unwrap(), "hello");
        assert_eq!(r.get_block().unwrap(), &[1, 2, 3]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn truncated_read_errors() {
        let mut r = ByteReader::new(&[1, 2]);
        assert!(r.get_u32().is_err());
    }

    #[test]
    fn exhaustion_errors_are_recognizable() {
        // the reader's incremental index-probe loop retries exactly these;
        // lock the message shape `take` emits to the classifier
        let mut r = ByteReader::new(&[1, 2]);
        assert!(r.get_u32().unwrap_err().is_exhaustion());
        let mut r = ByteReader::new(&[0xff; 2]);
        assert!(r.get_varint().unwrap_err().is_exhaustion());
        assert!(!SzError::corrupt("bad magic").is_exhaustion());
        assert!(!SzError::corrupt("varint overflow").is_exhaustion());
    }

    #[test]
    fn prop_varint_roundtrip() {
        prop::cases(300, 0x5eed, |rng| {
            let v = rng.next_u64() >> (rng.below(64) as u32);
            let mut w = ByteWriter::new();
            w.put_varint(v);
            let buf = w.finish();
            let mut r = ByteReader::new(&buf);
            assert_eq!(r.get_varint().unwrap(), v);
            assert_eq!(r.remaining(), 0);
        });
    }
}
