//! Configuration system: JSON parsing substrate plus the typed job config
//! consumed by the CLI and the streaming coordinator.

pub mod json;

pub use json::Json;

use crate::error::{Result, SzError};
use crate::pipeline::{CompressConf, ErrorBound};

/// A full compression job description (CLI `--config` file):
///
/// ```json
/// {
///   "pipeline": "sz3-lr",
///   "bound": {"mode": "abs", "value": 1e-3},
///   "radius": 32768,
///   "workers": 4,
///   "chunk_elems": 1048576,
///   "queue_depth": 8,
///   "use_pjrt": true,
///   "adaptive": true,
///   "candidates": ["sz3-lr", "sz3-interp", "sz3-truncation"]
/// }
/// ```
#[derive(Clone, Debug)]
pub struct JobConfig {
    /// Pipeline — a registry alias (`sz3-lr`, …) or a composed spec like
    /// `block(lorenzo+regression)/linear/huffman/lzhuf` (see
    /// `docs/PIPELINES.md`); validated by
    /// [`crate::coordinator::Coordinator::from_config`].
    pub pipeline: String,
    /// Error-bound mode + value.
    pub bound: ErrorBound,
    /// Quantizer radius.
    pub radius: u32,
    /// Coordinator worker threads.
    pub workers: usize,
    /// Elements per streamed chunk.
    pub chunk_elems: usize,
    /// Bounded queue depth (backpressure window).
    pub queue_depth: usize,
    /// Use the PJRT analysis engine when artifacts are present.
    pub use_pjrt: bool,
    /// Pick the best-fit registry pipeline per chunk (container runs record
    /// the choice in the chunk index).
    pub adaptive: bool,
    /// Candidate pipelines for adaptive selection — aliases or raw specs;
    /// empty means the selector's default set.
    pub candidates: Vec<String>,
    /// Score candidates by compressing a stratified chunk sample instead
    /// of the residual proxy (implies `adaptive`).
    pub measured: bool,
    /// Objective for measured selection: `ratio` | `speed` | `balanced`.
    pub optimize: String,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig {
            pipeline: "sz3-lr".to_string(),
            bound: ErrorBound::Rel(1e-3),
            radius: 32768,
            workers: crate::util::default_workers(),
            chunk_elems: 1 << 21,
            queue_depth: 8,
            use_pjrt: false,
            adaptive: false,
            candidates: Vec::new(),
            measured: false,
            optimize: "ratio".to_string(),
        }
    }
}

impl JobConfig {
    /// Parse from a JSON document; unknown keys are rejected to catch typos.
    pub fn from_json(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let obj = j
            .as_obj()
            .ok_or_else(|| SzError::config("job config must be a JSON object"))?;
        let mut cfg = JobConfig::default();
        for (key, val) in obj {
            match key.as_str() {
                "pipeline" => {
                    cfg.pipeline = val
                        .as_str()
                        .ok_or_else(|| SzError::config("pipeline must be a string"))?
                        .to_string();
                }
                "bound" => {
                    let mode = val
                        .get("mode")
                        .and_then(Json::as_str)
                        .ok_or_else(|| SzError::config("bound.mode missing"))?;
                    let value = val
                        .get("value")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| SzError::config("bound.value missing"))?;
                    cfg.bound = match mode {
                        "abs" => ErrorBound::Abs(value),
                        "rel" => ErrorBound::Rel(value),
                        "pwrel" => ErrorBound::PwRel(value),
                        other => {
                            return Err(SzError::config(format!("unknown bound mode {other}")))
                        }
                    };
                }
                "radius" => {
                    cfg.radius = val
                        .as_usize()
                        .ok_or_else(|| SzError::config("radius must be a number"))?
                        as u32;
                }
                "workers" => {
                    cfg.workers = val
                        .as_usize()
                        .ok_or_else(|| SzError::config("workers must be a number"))?
                        .max(1);
                }
                "chunk_elems" => {
                    cfg.chunk_elems = val
                        .as_usize()
                        .ok_or_else(|| SzError::config("chunk_elems must be a number"))?
                        .max(1024);
                }
                "queue_depth" => {
                    cfg.queue_depth = val
                        .as_usize()
                        .ok_or_else(|| SzError::config("queue_depth must be a number"))?
                        .max(1);
                }
                "use_pjrt" => {
                    cfg.use_pjrt = val
                        .as_bool()
                        .ok_or_else(|| SzError::config("use_pjrt must be a bool"))?;
                }
                "adaptive" => {
                    cfg.adaptive = val
                        .as_bool()
                        .ok_or_else(|| SzError::config("adaptive must be a bool"))?;
                }
                "measured" => {
                    cfg.measured = val
                        .as_bool()
                        .ok_or_else(|| SzError::config("measured must be a bool"))?;
                }
                "optimize" => {
                    let name = val
                        .as_str()
                        .ok_or_else(|| SzError::config("optimize must be a string"))?;
                    // validate eagerly so a typo fails at config load, not
                    // mid-stream
                    crate::container::OptimizeTarget::from_name(name)?;
                    cfg.optimize = name.to_string();
                }
                "candidates" => {
                    let arr = val
                        .as_arr()
                        .ok_or_else(|| SzError::config("candidates must be an array"))?;
                    cfg.candidates = arr
                        .iter()
                        .map(|v| {
                            v.as_str().map(str::to_string).ok_or_else(|| {
                                SzError::config("candidates entries must be strings")
                            })
                        })
                        .collect::<Result<Vec<String>>>()?;
                }
                other => {
                    return Err(SzError::config(format!("unknown config key '{other}'")))
                }
            }
        }
        Ok(cfg)
    }

    /// The per-field compression configuration.
    pub fn compress_conf(&self) -> CompressConf {
        CompressConf::with_radius(self.bound, self.radius)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_config() {
        let cfg = JobConfig::from_json(
            r#"{"pipeline": "sz3-interp", "bound": {"mode": "abs", "value": 0.001},
                "radius": 512, "workers": 2, "chunk_elems": 4096,
                "queue_depth": 3, "use_pjrt": true}"#,
        )
        .unwrap();
        assert_eq!(cfg.pipeline, "sz3-interp");
        assert_eq!(cfg.bound, ErrorBound::Abs(0.001));
        assert_eq!(cfg.radius, 512);
        assert_eq!(cfg.workers, 2);
        assert_eq!(cfg.chunk_elems, 4096);
        assert!(cfg.use_pjrt);
    }

    #[test]
    fn defaults_fill_in() {
        let cfg = JobConfig::from_json(r#"{"pipeline": "sz3-lr"}"#).unwrap();
        assert_eq!(cfg.pipeline, "sz3-lr");
        assert!(cfg.workers >= 1);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(JobConfig::from_json(r#"{"pipelin": "typo"}"#).is_err());
    }

    #[test]
    fn adaptive_and_candidates_parse() {
        let cfg = JobConfig::from_json(
            r#"{"adaptive": true, "candidates": ["sz3-lr", "sz3-truncation"]}"#,
        )
        .unwrap();
        assert!(cfg.adaptive);
        assert_eq!(cfg.candidates, vec!["sz3-lr", "sz3-truncation"]);
        assert!(JobConfig::from_json(r#"{"candidates": [1]}"#).is_err());
        assert!(JobConfig::from_json(r#"{"adaptive": "yes"}"#).is_err());
        // defaults stay off
        assert!(!JobConfig::from_json(r#"{}"#).unwrap().adaptive);
    }

    #[test]
    fn measured_and_optimize_parse() {
        let cfg = JobConfig::from_json(
            r#"{"adaptive": true, "measured": true, "optimize": "balanced"}"#,
        )
        .unwrap();
        assert!(cfg.measured);
        assert_eq!(cfg.optimize, "balanced");
        for t in ["ratio", "speed", "balanced"] {
            let cfg =
                JobConfig::from_json(&format!(r#"{{"optimize": "{t}"}}"#)).unwrap();
            assert_eq!(cfg.optimize, t);
        }
        // a typo in the objective fails at load time, not mid-stream
        assert!(JobConfig::from_json(r#"{"optimize": "best"}"#).is_err());
        assert!(JobConfig::from_json(r#"{"optimize": 3}"#).is_err());
        assert!(JobConfig::from_json(r#"{"measured": "yes"}"#).is_err());
        let d = JobConfig::from_json(r#"{}"#).unwrap();
        assert!(!d.measured);
        assert_eq!(d.optimize, "ratio");
    }
}
