//! Minimal JSON parser (no serde offline). Supports the full JSON grammar
//! minus exotic number forms; used for the artifact manifest and the
//! coordinator's job configs.

use crate::error::{Result, SzError};
use std::collections::BTreeMap;

/// Parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// null
    Null,
    /// true/false
    Bool(bool),
    /// Any number (stored as f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(SzError::config(format!("trailing JSON at byte {}", p.i)));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value as usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Array items.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object map.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Boolean value.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(SzError::config(format!(
                "expected '{}' at byte {}",
                c as char, self.i
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(SzError::config(format!("unexpected JSON at byte {}", self.i))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(SzError::config(format!("bad literal at byte {}", self.i)))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| SzError::config(format!("bad number at byte {start}")))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(SzError::config("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self
                        .peek()
                        .ok_or_else(|| SzError::config("bad escape"))?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(SzError::config("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| SzError::config("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| SzError::config("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(SzError::config("unknown escape")),
                    }
                }
                Some(c) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    self.i = (start + len).min(self.b.len());
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| SzError::config("invalid utf8 in string"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(SzError::config("expected , or ] in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            map.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(SzError::config("expected , or } in object")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{
            "batch": 4096,
            "block_shapes": {"3": [6, 6, 6]},
            "artifacts": {"analysis_3d": "analysis_3d.hlo.txt"},
            "ok": true, "missing": null, "pi": 3.14, "neg": -2e-3
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("batch").unwrap().as_usize(), Some(4096));
        assert_eq!(
            j.get("block_shapes").unwrap().get("3").unwrap().as_arr().unwrap().len(),
            3
        );
        assert_eq!(
            j.get("artifacts").unwrap().get("analysis_3d").unwrap().as_str(),
            Some("analysis_3d.hlo.txt")
        );
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("missing"), Some(&Json::Null));
        assert!((j.get("pi").unwrap().as_f64().unwrap() - 3.14).abs() < 1e-12);
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{'a':1}").is_err());
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1,2],[3],[]]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[0].as_arr().unwrap()[1].as_f64(), Some(2.0));
    }
}
