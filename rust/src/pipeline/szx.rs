//! The `constblock` family — an SZx-style ultra-fast compressor (Yu et
//! al., arXiv 2201.13020, "Ultrafast Error-Bounded Lossy Compression for
//! Scientific Datasets"): scan fixed-size blocks, emit each *constant*
//! block (every value within `eb` of a single representative) as one
//! stored mean plus a bitmap bit, and byte-truncate the values of the
//! remaining blocks exactly like [`super::truncation`]. No prediction, no
//! quantization, no entropy coding — every loop is flat and feeds the
//! runtime-dispatched kernels in [`crate::util::simd`], which is what buys
//! the order-of-magnitude throughput gap on constant-heavy data.
//!
//! Spec grammar: `constblock(B)/truncation[@kN]/raw/<lossless>` — the
//! encoder slot must be `raw` (there is nothing to entropy-code), mirroring
//! how `pastri` pins its encoder.
//!
//! Stream layout after the common [`StreamHeader`]:
//!
//! ```text
//! u32 block_elems · u8 keep_bytes · str lossless ·
//! block(bitmap)   — bit i set ⇔ block i is constant, LSB-first
//! block(consts)   — one scalar (LE) per constant block, in block order
//! block(lossless(planes)) — non-constant values, plane-major truncated
//! ```
//!
//! Every length is cross-checked against the header's element count before
//! any allocation is sized from stream bytes.

use super::truncation::{from_planes, to_planes, truncation_abs_error};
use super::{CompressConf, Compressor, StreamHeader};
use crate::byteio::{ByteReader, ByteWriter};
use crate::data::{Field, FieldValues, Scalar};
use crate::error::{Result, SzError};
use crate::lossless;
use crate::util::simd;

/// Largest accepted block size (elements). Big enough for any sensible
/// configuration; small enough that a corrupt stream cannot turn one
/// bitmap bit into an unbounded fill.
pub const MAX_BLOCK_ELEMS: usize = 1 << 20;

/// The SZx-style constant-block compressor.
pub struct SzxCompressor {
    /// Stream-header identity (canonical spec for spec-built instances,
    /// the `szx` alias for [`Default`]).
    pub name: String,
    /// Elements per scan block.
    pub block: usize,
    /// Most-significant bytes kept for non-constant values (`None` =
    /// derive the smallest k honoring the bound, as in truncation).
    pub keep_bytes: Option<usize>,
    /// Lossless stage applied to the truncated planes.
    pub lossless: String,
}

impl Default for SzxCompressor {
    fn default() -> Self {
        SzxCompressor {
            name: "szx".to_string(),
            block: 32,
            keep_bytes: None,
            lossless: "zstd".to_string(),
        }
    }
}

/// Per-dtype constant-block scan: returns `(bitmap, const_bytes,
/// nonconst_raw)`. A block is constant when the representative the
/// decompressor will materialize — `T::from_f64((lo+hi)/2)` — sits within
/// `eb` of both extremes, which bounds every element's error by `eb`.
fn scan_blocks<T: Scalar>(values: &[T], block: usize, eb: f64) -> (Vec<u8>, Vec<u8>, Vec<u8>) {
    let nblocks = values.len().div_ceil(block);
    let mut bitmap = vec![0u8; nblocks.div_ceil(8)];
    let mut consts = ByteWriter::new();
    let mut rest = ByteWriter::new();
    for (bi, chunk) in values.chunks(block).enumerate() {
        let (lo, hi) = simd::minmax(chunk);
        let mut constant = false;
        // an all-NaN or NaN-containing block never satisfies the bound
        // check (comparisons with NaN are false), so it stays verbatim
        if lo.is_finite() && hi.is_finite() {
            let rec = T::from_f64((lo + hi) / 2.0);
            let r = rec.to_f64();
            if (r - lo).abs() <= eb && (r - hi).abs() <= eb {
                bitmap[bi / 8] |= 1 << (bi % 8);
                rec.write(&mut consts);
                constant = true;
            }
        }
        if !constant {
            for &x in chunk {
                x.write(&mut rest);
            }
        }
    }
    (bitmap, consts.finish(), rest.finish())
}

/// Rebuild the value vector from bitmap + constants + truncated remainder.
fn rebuild<T: Scalar>(
    n: usize,
    block: usize,
    keep: usize,
    bitmap: &[u8],
    consts: &[u8],
    planes: &[u8],
) -> Result<Vec<T>> {
    let nblocks = n.div_ceil(block);
    if bitmap.len() != nblocks.div_ceil(8) {
        return Err(SzError::corrupt(format!(
            "constblock: {} bitmap bytes for {nblocks} blocks",
            bitmap.len()
        )));
    }
    let is_const = |bi: usize| bitmap[bi / 8] >> (bi % 8) & 1 == 1;
    let block_len = |bi: usize| if bi + 1 == nblocks { n - bi * block } else { block };
    let mut const_blocks = 0usize;
    let mut rest_elems = 0usize;
    for bi in 0..nblocks {
        if is_const(bi) {
            const_blocks += 1;
        } else {
            rest_elems += block_len(bi);
        }
    }
    let want_consts = const_blocks
        .checked_mul(T::SIZE)
        .ok_or_else(|| SzError::corrupt("constblock: constant byte count overflows"))?;
    if consts.len() != want_consts {
        return Err(SzError::corrupt(format!(
            "constblock: {} constant bytes for {const_blocks} constant blocks",
            consts.len()
        )));
    }
    let want_planes = rest_elems
        .checked_mul(keep)
        .ok_or_else(|| SzError::corrupt("constblock: plane size overflows"))?;
    if planes.len() != want_planes {
        return Err(SzError::corrupt(format!(
            "constblock: {} plane bytes for {rest_elems} elements × {keep} kept",
            planes.len()
        )));
    }
    let raw = from_planes(planes, rest_elems, T::SIZE, keep);
    let mut cr = ByteReader::new(consts);
    let mut rr = ByteReader::new(&raw);
    let mut out = Vec::with_capacity(n);
    for bi in 0..nblocks {
        let len = block_len(bi);
        if is_const(bi) {
            let v = T::read(&mut cr)?;
            out.extend(std::iter::repeat(v).take(len));
        } else {
            for _ in 0..len {
                out.push(T::read(&mut rr)?);
            }
        }
    }
    Ok(out)
}

impl SzxCompressor {
    /// Smallest `keep` honoring the absolute bound for the non-constant
    /// remainder (same derivation as [`super::truncation`]).
    fn derive_keep(&self, field: &Field, eb: f64, max_abs: f64) -> Result<usize> {
        let total = match &field.values {
            FieldValues::F32(_) | FieldValues::I32(_) => 4,
            FieldValues::F64(_) => 8,
        };
        if let Some(k) = self.keep_bytes {
            if k == 0 || k > total {
                return Err(SzError::config(format!(
                    "keep_bytes {k} invalid for {total}-byte data"
                )));
            }
            return Ok(k);
        }
        let integer = matches!(field.values, FieldValues::I32(_));
        for k in 1..total {
            let err = if integer {
                (8.0 * (total - k) as f64).exp2()
            } else {
                truncation_abs_error(max_abs, total, k)
            };
            if err <= eb {
                return Ok(k);
            }
        }
        Ok(total)
    }
}

impl Compressor for SzxCompressor {
    fn name(&self) -> &str {
        &self.name
    }

    fn compress(&self, field: &Field, conf: &CompressConf) -> Result<Vec<u8>> {
        if self.block == 0 || self.block > MAX_BLOCK_ELEMS {
            return Err(SzError::config(format!(
                "constblock: block size {} outside 1..={MAX_BLOCK_ELEMS}",
                self.block
            )));
        }
        let (lo, hi) = field.value_range();
        let eb = conf.bound.to_abs_with_range(|| (lo, hi))?;
        let keep = self.derive_keep(field, eb, lo.abs().max(hi.abs()))?;
        let mut w = ByteWriter::new();
        StreamHeader::for_field(&self.name, field).write(&mut w);
        w.put_u32(self.block as u32);
        w.put_u8(keep as u8);
        w.put_str(&self.lossless);
        let (bitmap, consts, rest, bytes_per) = match &field.values {
            FieldValues::F32(v) => {
                let (b, c, r) = scan_blocks(v, self.block, eb);
                (b, c, r, 4)
            }
            FieldValues::F64(v) => {
                let (b, c, r) = scan_blocks(v, self.block, eb);
                (b, c, r, 8)
            }
            FieldValues::I32(v) => {
                let (b, c, r) = scan_blocks(v, self.block, eb);
                (b, c, r, 4)
            }
        };
        w.put_block(&bitmap);
        w.put_block(&consts);
        let planes = to_planes(&rest, bytes_per, keep);
        let ll = lossless::by_name(&self.lossless)
            .ok_or_else(|| SzError::config(format!("unknown lossless {}", self.lossless)))?;
        w.put_block(&ll.compress(&planes)?);
        Ok(w.finish())
    }

    fn decompress(&self, stream: &[u8]) -> Result<Field> {
        let mut r = ByteReader::new(stream);
        let header = StreamHeader::read(&mut r)?;
        let block = r.get_u32()? as usize;
        if block == 0 || block > MAX_BLOCK_ELEMS {
            return Err(SzError::corrupt(format!(
                "constblock: block size {block} outside 1..={MAX_BLOCK_ELEMS}"
            )));
        }
        let keep = r.get_u8()? as usize;
        let ll_name = r.get_str()?;
        let ll = lossless::by_name(&ll_name)
            .ok_or_else(|| SzError::corrupt(format!("unknown lossless {ll_name}")))?;
        let bytes_per = match header.dtype.as_str() {
            "f32" | "i32" => 4,
            "f64" => 8,
            other => return Err(SzError::corrupt(format!("unknown dtype {other}"))),
        };
        if keep == 0 || keep > bytes_per {
            return Err(SzError::corrupt(format!(
                "constblock: keep {keep} invalid for {bytes_per}-byte data"
            )));
        }
        let bitmap = r.get_block()?.to_vec();
        let consts = r.get_block()?.to_vec();
        let planes = ll.decompress(r.get_block()?)?;
        let n = header.len();
        let values = match header.dtype.as_str() {
            "f32" => FieldValues::F32(rebuild(n, block, keep, &bitmap, &consts, &planes)?),
            "f64" => FieldValues::F64(rebuild(n, block, keep, &bitmap, &consts, &planes)?),
            "i32" => FieldValues::I32(rebuild(n, block, keep, &bitmap, &consts, &planes)?),
            other => return Err(SzError::corrupt(format!("unknown dtype {other}"))),
        };
        Field::new(header.field_name, &header.dims, values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::{decompress_any, test_support::roundtrip_bound_check, ErrorBound};
    use crate::util::prop;

    fn constant_heavy(n: usize, rng: &mut crate::util::rng::Pcg32) -> Vec<f32> {
        // long constant plateaus with occasional noisy bursts — the SZx
        // design target (instrument backgrounds, sparse events)
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            if rng.below(5) == 0 {
                let burst = (rng.below(40) + 1).min(n - out.len());
                for _ in 0..burst {
                    out.push(rng.uniform(-100.0, 100.0) as f32);
                }
            } else {
                let level = rng.uniform(-10.0, 10.0) as f32;
                let run = (rng.below(200) + 20).min(n - out.len());
                out.extend(std::iter::repeat(level).take(run));
            }
        }
        out
    }

    #[test]
    fn roundtrip_respects_bound_on_mixed_data() {
        prop::cases(40, 0x5a1, |rng| {
            let n = rng.below(3000) + 10;
            let vals = constant_heavy(n, rng);
            let f = Field::f32("x", &[n], vals).unwrap();
            let eb = 10f64.powf(rng.uniform(-4.0, -1.0));
            let conf = CompressConf::new(ErrorBound::Abs(eb));
            let block = [8usize, 32, 256][rng.below(3)];
            let c = SzxCompressor { block, ..Default::default() };
            roundtrip_bound_check(&c, &f, &conf);
        });
    }

    #[test]
    fn all_dtypes_roundtrip() {
        let conf = CompressConf::new(ErrorBound::Abs(0.5));
        let c = SzxCompressor::default();
        let f32s = Field::f32("a", &[100], vec![7.0; 100]).unwrap();
        let f64s = Field::f64("b", &[100], (0..100).map(|i| (i / 40) as f64).collect()).unwrap();
        let i32s =
            Field::new("c", &[100], FieldValues::I32(vec![3; 100])).unwrap();
        for f in [&f32s, &f64s, &i32s] {
            roundtrip_bound_check(&c, f, &conf);
        }
    }

    #[test]
    fn constant_field_compresses_hard() {
        let f = Field::f32("flat", &[1 << 14], vec![42.5; 1 << 14]).unwrap();
        let conf = CompressConf::new(ErrorBound::Abs(1e-3));
        let ratio = roundtrip_bound_check(&SzxCompressor::default(), &f, &conf);
        // 16384 f32 = 64 KiB; 512 blocks → 64 B bitmap + 2 KiB consts,
        // zstd squeezes the constants further
        assert!(ratio > 25.0, "constant field ratio {ratio}");
    }

    #[test]
    fn partial_last_block_roundtrips() {
        for n in [1usize, 31, 32, 33, 63, 65] {
            let vals: Vec<f32> = (0..n).map(|i| i as f32 * 0.25).collect();
            let f = Field::f32("p", &[n], vals).unwrap();
            let conf = CompressConf::new(ErrorBound::Abs(1e-6));
            roundtrip_bound_check(&SzxCompressor::default(), &f, &conf);
        }
    }

    #[test]
    fn nan_blocks_stay_verbatim_nonconstant() {
        let mut vals = vec![1.0f32; 64];
        vals[40] = f32::NAN;
        let f = Field::f32("nan", &[64], vals).unwrap();
        let conf = CompressConf::new(ErrorBound::Abs(1e-3));
        let c = SzxCompressor { block: 32, keep_bytes: Some(4), ..Default::default() };
        let out = decompress_any(&c.compress(&f, &conf).unwrap()).unwrap();
        let FieldValues::F32(dec) = &out.values else { panic!("dtype") };
        assert!(dec[40].is_nan(), "NaN must survive the verbatim path");
        assert_eq!(dec[0], 1.0);
    }

    #[test]
    fn invalid_block_sizes_rejected() {
        let f = Field::f32("x", &[8], vec![0.0; 8]).unwrap();
        let conf = CompressConf::new(ErrorBound::Abs(0.1));
        for block in [0usize, MAX_BLOCK_ELEMS + 1] {
            let c = SzxCompressor { block, ..Default::default() };
            assert!(c.compress(&f, &conf).is_err(), "block {block}");
        }
    }

    #[test]
    fn corrupt_sections_error_not_panic() {
        let vals: Vec<f32> = (0..300).map(|i| (i / 100) as f32).collect();
        let f = Field::f32("x", &[300], vals).unwrap();
        let conf = CompressConf::new(ErrorBound::Abs(1e-4));
        let c = SzxCompressor::default();
        let stream = c.compress(&f, &conf).unwrap();
        // truncating the stream at every prefix must error cleanly
        for cut in 0..stream.len() {
            assert!(c.decompress(&stream[..cut]).is_err(), "prefix {cut} accepted");
        }
        // flipping bytes across the stream must never panic (it may decode
        // to junk values, but structural checks catch length lies)
        for at in 0..stream.len() {
            let mut bad = stream.clone();
            bad[at] ^= 0xA5;
            let _ = std::panic::catch_unwind(|| c.decompress(&bad))
                .expect("decompress must not panic");
        }
    }
}
