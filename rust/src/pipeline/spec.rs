//! Declarative pipeline-spec API (paper §3.3): parse, canonicalize, and
//! build compression pipelines from a stage-composition grammar instead of
//! a closed registry.
//!
//! A spec is a `/`-separated stage list with an optional preprocessor
//! prefix:
//!
//! ```text
//! [preprocessor/]predictor/quantizer/encoder/lossless
//! ```
//!
//! e.g. `block(lorenzo+regression)/linear@r512/huffman/lzhuf` or
//! `log/lorenzo/linear/arithmetic/bypass`. The predictor stage determines
//! the pipeline *family* and with it which later stages apply:
//!
//! | predictor token | family | remaining stages |
//! |---|---|---|
//! | `lorenzo[@N]`, `zero` | point (Algorithm 1) | quantizer, encoder, lossless |
//! | `block(lorenzo+regression)[@s]` | SZ2-style blockwise | `linear` quantizer, encoder, lossless |
//! | `interp(cubic\|linear)` | level-by-level interpolation | `linear` quantizer, encoder, lossless |
//! | `truncation[@kN]` | byte truncation (module bypass) | lossless |
//! | `constblock(B)` | SZx-style constant blocks | `truncation[@kN]`, `raw` encoder, lossless |
//! | `tblock(4)` | ZFP-style transform coding | `bitplane[@pN]`, `raw` encoder, lossless |
//! | `pastri(bitplane\|value)[@pN]` | GAMESS periodic patterns | `fixed_huffman` encoder, lossless |
//! | `aps[@EB]` | adaptive APS meta-pipeline | (composes its own stages) |
//!
//! The lossless token optionally carries a backend level (`zstd@l19`,
//! `gzip@l9`); unleveled tokens keep each backend's default.
//!
//! [`PipelineSpec::parse`] validates a spec, [`PipelineSpec::canonical`]
//! renders the unique canonical string (parse → canonicalize → parse is a
//! fixed point), and [`PipelineSpec::build`] constructs the composed
//! [`Compressor`] whose stream headers carry the canonical spec — so any
//! composed pipeline is self-describing and
//! [`crate::pipeline::decompress_any`] reconstructs the exact stage stack
//! from the header alone. The historical registry names survive as
//! [`ALIASES`] that resolve to canonical specs ([`resolve`] accepts both),
//! which is also how streams written by older releases keep decoding.
//!
//! The full grammar, stage catalog, and composition recipes live in
//! `docs/PIPELINES.md`.

use super::aps::ApsCompressor;
use super::block::BlockCompressor;
use super::interp::{InterpCompressor, InterpMode};
use super::pastri::PastriCompressor;
use super::point::{PredictorKind, PreprocessorKind, QuantizerKind, SzCompressor};
use super::szx::SzxCompressor;
use super::truncation::TruncationCompressor;
use super::{CompressConf, Compressor, StreamHeader};
use crate::byteio::{ByteReader, ByteWriter};
use crate::data::Field;
use crate::error::{Result, SzError};
use crate::preprocessor::{Linearize, LogTransform, Preprocessor};

/// Registry aliases: historical pipeline names and the canonical spec each
/// resolves to. [`resolve`] consults this table first, so `sz3-lr` and its
/// canonical spec build bit-identical compressors, and streams whose
/// headers carry an alias (older artifacts) keep decoding.
pub const ALIASES: &[(&str, &str)] = &[
    ("sz3-lr", "block(lorenzo+regression)/linear/huffman/zstd"),
    ("sz3-lr-s", "block(lorenzo+regression)@s/linear/huffman/zstd"),
    ("sz3-interp", "interp(cubic)/linear/huffman/zstd"),
    ("sz3-truncation", "truncation/bypass"),
    ("sz3-pastri", "pastri(bitplane)/fixed_huffman/zstd"),
    ("sz-pastri", "pastri(value)/fixed_huffman/bypass"),
    ("sz-pastri-zstd", "pastri(value)/fixed_huffman/zstd"),
    ("sz3-aps", "aps"),
    ("szx", "constblock(32)/truncation/raw/zstd"),
    ("zfp-like", "tblock(4)/bitplane/raw/zstd"),
    ("lorenzo-1d", "linearize/lorenzo/linear/huffman/zstd"),
    ("fpzip-like", "lorenzo/linear/arithmetic/bypass"),
];

/// Canonical spec for a registry alias, if `name` is one.
pub fn alias_canonical(name: &str) -> Option<&'static str> {
    ALIASES.iter().find(|(a, _)| *a == name).map(|(_, s)| *s)
}

/// The registry alias closest to `name` by edit distance — the recovery
/// hint for unknown-pipeline errors.
pub fn nearest_alias(name: &str) -> &'static str {
    // cap the probe so an adversarially long header string cannot make the
    // distance computation quadratic in the stream size (byte slice: a
    // split UTF-8 char only perturbs the distance, never panics)
    let bytes = name.as_bytes();
    let probe = &bytes[..bytes.len().min(64)];
    ALIASES
        .iter()
        .map(|(a, _)| (*a, edit_distance(probe, a.as_bytes())))
        .min_by_key(|&(_, d)| d)
        .map(|(a, _)| a)
        .expect("alias table is non-empty")
}

/// Plain Levenshtein distance (byte granularity is fine for hints).
fn edit_distance(a: &[u8], b: &[u8]) -> usize {
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Preprocessor stage of a spec (the optional leading token).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PreSpec {
    /// No preprocessing (canonical form omits the token).
    Identity,
    /// Reshape to 1-D (`linearize`).
    Linearize,
    /// Pointwise-relative → absolute bounds via `ln|x|` (`log`).
    Log,
}

/// Predictor stage — determines the pipeline family.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PredSpec {
    /// Point-family order-N Lorenzo (`lorenzo`, `lorenzo@2`).
    Lorenzo(u32),
    /// Point-family always-zero baseline (`zero`).
    Zero,
    /// SZ2-style blockwise Lorenzo⊕regression composite
    /// (`block(lorenzo+regression)`, `@s` = dimension-specialized codecs).
    Block {
        /// Use the dimension-specialized prediction codecs (SZ3-LR-s).
        specialized: bool,
    },
    /// Level-by-level interpolation (`interp(cubic)` / `interp(linear)`).
    Interp(InterpMode),
    /// Byte truncation (`truncation`, `truncation@k2` pins kept bytes).
    Truncation {
        /// Most-significant bytes to keep; `None` derives from the bound.
        keep: Option<usize>,
    },
    /// SZx-style constant-block fast family (`constblock(32)`); the spec's
    /// second stage is a `truncation[@kN]` token carrying the keep-bytes
    /// for non-constant blocks, and the encoder slot must be `raw`.
    ConstBlock {
        /// Elements per scan block (1..=2^20).
        block: u32,
        /// Most-significant bytes kept for non-constant values; `None`
        /// derives from the bound.
        keep: Option<usize>,
    },
    /// ZFP-style fixed 4^d-block transform family (`tblock(4)`): lifted
    /// integer decorrelation plus embedded bitplane coding. The spec's
    /// second stage is a `bitplane[@pN]` token optionally pinning a
    /// minimum kept-plane count, and the encoder slot must be `raw`.
    Transform {
        /// Minimum kept bitplanes per coded block (1..=64); `None`
        /// derives the cutoff from the error bound alone.
        planes: Option<u32>,
    },
    /// PaSTRI periodic-pattern prediction (`pastri(bitplane|value)`,
    /// `@pN` pins the pattern period instead of autocorrelation detection).
    Pastri {
        /// Bitplane-coded unpredictables (SZ3-Pastri) vs value-major.
        bitplane: bool,
        /// Fixed pattern period; `None` = detect.
        period: Option<usize>,
    },
    /// Adaptive APS meta-pipeline (`aps`, `aps@0.75` sets the switch
    /// error bound).
    Aps {
        /// Error-bound threshold that flips the inner pipeline.
        switch_eb: f64,
    },
}

/// Quantizer stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantSpec {
    /// Linear-scaling quantizer; `@rN` overrides the configured radius.
    Linear {
        /// Index radius override (`None` = use [`CompressConf::radius`]).
        radius: Option<u32>,
    },
    /// Geometric-then-linear binning (`logscale`).
    LogScale,
    /// Linear with bitplane-coded unpredictables (`unpred`, §4.2).
    UnpredAware,
}

/// Encoder stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EncSpec {
    /// Canonical per-stream Huffman (`huffman`).
    Huffman,
    /// Predefined-tree Huffman (`fixed_huffman`).
    FixedHuffman,
    /// Adaptive arithmetic coding (`arithmetic`).
    Arithmetic,
    /// Uncoded index passthrough (`raw`).
    Raw,
}

impl EncSpec {
    fn token(self) -> &'static str {
        match self {
            EncSpec::Huffman => "huffman",
            EncSpec::FixedHuffman => "fixed_huffman",
            EncSpec::Arithmetic => "arithmetic",
            EncSpec::Raw => "raw",
        }
    }

    fn parse(name: &str) -> Option<EncSpec> {
        match name {
            "huffman" => Some(EncSpec::Huffman),
            "fixed_huffman" => Some(EncSpec::FixedHuffman),
            "arithmetic" => Some(EncSpec::Arithmetic),
            "raw" => Some(EncSpec::Raw),
            _ => None,
        }
    }
}

/// Lossless stage tokens (canonical spellings).
const LOSSLESS_TOKENS: &[&str] = &["zstd", "gzip", "lzhuf", "rle", "bypass"];

fn canon_lossless(name: &str) -> Option<&'static str> {
    match name {
        "bypass" | "none" => Some("bypass"),
        _ => LOSSLESS_TOKENS.iter().find(|&&t| t == name).copied(),
    }
}

/// A parsed, validated pipeline spec. Construct via [`PipelineSpec::parse`]
/// or [`PipelineBuilder`]; hand-built values are re-validated by
/// [`PipelineSpec::build`].
#[derive(Clone, Debug, PartialEq)]
pub struct PipelineSpec {
    /// Optional preprocessor prefix.
    pub pre: PreSpec,
    /// Predictor stage (family determiner).
    pub pred: PredSpec,
    /// Quantizer stage (`None` for truncation/pastri/aps families).
    pub quant: Option<QuantSpec>,
    /// Encoder stage (`None` for truncation/aps families).
    pub enc: Option<EncSpec>,
    /// Lossless stage (`None` for the aps family).
    pub lossless: Option<&'static str>,
    /// Lossless backend level (`zstd@l19`); `None` = backend default.
    /// Only zstd (1..=22) and gzip (1..=9) take one.
    pub lossless_level: Option<u32>,
}

/// One parsed stage token: `name`, optional `(arg+arg)` list, optional
/// `@param` suffix.
struct Token<'a> {
    name: &'a str,
    args: Vec<&'a str>,
    param: Option<&'a str>,
    raw: &'a str,
}

impl<'a> Token<'a> {
    fn parse(raw: &'a str) -> Result<Token<'a>> {
        let bad = |why: &str| {
            SzError::config(format!("stage '{raw}': {why}"))
        };
        let (base, param) = if let Some(open) = raw.find('(') {
            let close = raw.rfind(')').ok_or_else(|| bad("unclosed '('"))?;
            if close < open {
                return Err(bad("')' before '('"));
            }
            let after = &raw[close + 1..];
            let param = if after.is_empty() {
                None
            } else if let Some(p) = after.strip_prefix('@') {
                if p.is_empty() {
                    return Err(bad("empty '@' parameter"));
                }
                Some(p)
            } else {
                return Err(bad("unexpected text after ')'"));
            };
            (&raw[..close + 1], param)
        } else if let Some(at) = raw.find('@') {
            let p = &raw[at + 1..];
            if p.is_empty() {
                return Err(bad("empty '@' parameter"));
            }
            (&raw[..at], Some(p))
        } else {
            (raw, None)
        };
        let (name, args) = if let Some(open) = base.find('(') {
            let inner = &base[open + 1..base.len() - 1];
            if inner.trim().is_empty() {
                return Err(bad("empty argument list"));
            }
            let args: Vec<&str> = inner.split(['+', ',']).map(str::trim).collect();
            if args.iter().any(|a| a.is_empty()) {
                return Err(bad("empty argument"));
            }
            (&base[..open], args)
        } else {
            (base, Vec::new())
        };
        if name.is_empty() {
            return Err(bad("missing stage name"));
        }
        Ok(Token { name, args, param, raw })
    }

    fn no_args(&self) -> Result<()> {
        if self.args.is_empty() {
            Ok(())
        } else {
            Err(SzError::config(format!(
                "stage '{}': '{}' takes no argument list",
                self.raw, self.name
            )))
        }
    }

    fn no_param(&self) -> Result<()> {
        if self.param.is_none() {
            Ok(())
        } else {
            Err(SzError::config(format!(
                "stage '{}': '{}' takes no '@' parameter",
                self.raw, self.name
            )))
        }
    }
}

const PRE_NAMES: &[&str] = &["identity", "linearize", "log", "log_transform"];
const PRED_NAMES: &[&str] = &[
    "lorenzo", "zero", "block", "interp", "truncation", "constblock", "tblock",
    "pastri", "aps",
];

fn parse_pre(t: &Token) -> Result<PreSpec> {
    t.no_args()?;
    t.no_param()?;
    match t.name {
        "identity" => Ok(PreSpec::Identity),
        "linearize" => Ok(PreSpec::Linearize),
        "log" | "log_transform" => Ok(PreSpec::Log),
        _ => unreachable!("caller checked PRE_NAMES"),
    }
}

fn parse_pred(t: &Token) -> Result<PredSpec> {
    match t.name {
        "lorenzo" => {
            t.no_args()?;
            let order = match t.param {
                None => 1,
                Some(p) => p
                    .parse::<u32>()
                    .ok()
                    .filter(|o| (1..=3).contains(o))
                    .ok_or_else(|| {
                        SzError::config(format!(
                            "stage '{}': lorenzo order must be 1..=3",
                            t.raw
                        ))
                    })?,
            };
            Ok(PredSpec::Lorenzo(order))
        }
        "zero" => {
            t.no_args()?;
            t.no_param()?;
            Ok(PredSpec::Zero)
        }
        "block" => {
            if t.args != ["lorenzo", "regression"] {
                return Err(SzError::config(format!(
                    "stage '{}': the block composite is block(lorenzo+regression)",
                    t.raw
                )));
            }
            let specialized = match t.param {
                None => false,
                Some("s") => true,
                Some(p) => {
                    return Err(SzError::config(format!(
                        "stage '{}': unknown block parameter '@{p}' (only '@s' \
                         selects the dimension-specialized codecs)",
                        t.raw
                    )))
                }
            };
            Ok(PredSpec::Block { specialized })
        }
        "interp" => {
            t.no_param()?;
            let mode = match t.args.as_slice() {
                [] | ["cubic"] => InterpMode::Cubic,
                ["linear"] => InterpMode::Linear,
                _ => {
                    return Err(SzError::config(format!(
                        "stage '{}': interp basis is (cubic) or (linear)",
                        t.raw
                    )))
                }
            };
            Ok(PredSpec::Interp(mode))
        }
        "truncation" => {
            t.no_args()?;
            let keep = match t.param {
                None => None,
                Some(p) => Some(
                    p.strip_prefix('k')
                        .and_then(|k| k.parse::<usize>().ok())
                        .filter(|k| (1..=8).contains(k))
                        .ok_or_else(|| {
                            SzError::config(format!(
                                "stage '{}': truncation keep-bytes is @k1..@k8",
                                t.raw
                            ))
                        })?,
                ),
            };
            Ok(PredSpec::Truncation { keep })
        }
        "constblock" => {
            t.no_param()?;
            let block = match t.args.as_slice() {
                [] => 32,
                [b] => b
                    .parse::<u32>()
                    .ok()
                    .filter(|&b| (1..=1 << 20).contains(&b))
                    .ok_or_else(|| {
                        SzError::config(format!(
                            "stage '{}': constblock block size is (N) with N \
                             in 1..=2^20",
                            t.raw
                        ))
                    })?,
                _ => {
                    return Err(SzError::config(format!(
                        "stage '{}': constblock takes a single block-size \
                         argument",
                        t.raw
                    )))
                }
            };
            // keep-bytes ride on the spec's truncation mid-token; the
            // family-shape match below fills them in
            Ok(PredSpec::ConstBlock { block, keep: None })
        }
        "tblock" => {
            t.no_param()?;
            match t.args.as_slice() {
                [] | ["4"] => {}
                _ => {
                    return Err(SzError::config(format!(
                        "stage '{}': the transform block side is fixed at 4 \
                         (tblock or tblock(4))",
                        t.raw
                    )))
                }
            }
            // pinned planes ride on the spec's bitplane mid-token; the
            // family-shape match below fills them in
            Ok(PredSpec::Transform { planes: None })
        }
        "pastri" => {
            let bitplane = match t.args.as_slice() {
                [] | ["bitplane"] => true,
                ["value"] => false,
                _ => {
                    return Err(SzError::config(format!(
                        "stage '{}': pastri unpredictable layout is (bitplane) \
                         or (value)",
                        t.raw
                    )))
                }
            };
            let period = match t.param {
                None => None,
                Some(p) => Some(
                    p.strip_prefix('p')
                        .and_then(|v| v.parse::<usize>().ok())
                        .filter(|&v| v >= 1)
                        .ok_or_else(|| {
                            SzError::config(format!(
                                "stage '{}': pastri period is @pN with N >= 1",
                                t.raw
                            ))
                        })?,
                ),
            };
            Ok(PredSpec::Pastri { bitplane, period })
        }
        "aps" => {
            t.no_args()?;
            let switch_eb = match t.param {
                None => 0.5,
                Some(p) => p
                    .parse::<f64>()
                    .ok()
                    .filter(|v| v.is_finite() && *v > 0.0)
                    .ok_or_else(|| {
                        SzError::config(format!(
                            "stage '{}': aps switch bound must be a positive number",
                            t.raw
                        ))
                    })?,
            };
            Ok(PredSpec::Aps { switch_eb })
        }
        other => Err(SzError::config(format!(
            "unknown predictor stage '{other}' (known: {})",
            PRED_NAMES.join(", ")
        ))),
    }
}

fn parse_quant(t: &Token) -> Result<QuantSpec> {
    t.no_args()?;
    match t.name {
        "linear" => {
            let radius = match t.param {
                None => None,
                Some(p) => Some(
                    p.strip_prefix('r')
                        .and_then(|r| r.parse::<u32>().ok())
                        .filter(|&r| (1..=1 << 30).contains(&r))
                        .ok_or_else(|| {
                            SzError::config(format!(
                                "stage '{}': linear radius is @rN with N in \
                                 1..=2^30",
                                t.raw
                            ))
                        })?,
                ),
            };
            Ok(QuantSpec::Linear { radius })
        }
        "logscale" => {
            t.no_param()?;
            Ok(QuantSpec::LogScale)
        }
        "unpred" | "unpred_aware" => {
            t.no_param()?;
            Ok(QuantSpec::UnpredAware)
        }
        other => Err(SzError::config(format!(
            "unknown quantizer stage '{other}' (known: linear, logscale, unpred)"
        ))),
    }
}

fn parse_enc(t: &Token) -> Result<EncSpec> {
    t.no_args()?;
    t.no_param()?;
    EncSpec::parse(t.name).ok_or_else(|| {
        SzError::config(format!(
            "unknown encoder stage '{}' (known: huffman, fixed_huffman, \
             arithmetic, raw)",
            t.name
        ))
    })
}

fn parse_lossless(t: &Token) -> Result<(&'static str, Option<u32>)> {
    t.no_args()?;
    let token = canon_lossless(t.name).ok_or_else(|| {
        SzError::config(format!(
            "unknown lossless stage '{}' (known: {})",
            t.name,
            LOSSLESS_TOKENS.join(", ")
        ))
    })?;
    let level = match t.param {
        None => None,
        Some(p) => {
            let lvl = p.strip_prefix('l').and_then(|v| v.parse::<u32>().ok());
            let ok = match (token, lvl) {
                ("zstd", Some(l)) => (1..=22).contains(&l),
                ("gzip", Some(l)) => (1..=9).contains(&l),
                _ => false,
            };
            if !ok {
                return Err(SzError::config(format!(
                    "stage '{}': lossless level is @lN (zstd 1..=22, gzip \
                     1..=9; other backends take none)",
                    t.raw
                )));
            }
            lvl
        }
    };
    Ok((token, level))
}

impl PipelineSpec {
    /// Parse and validate a spec string. Aliases are *not* accepted here —
    /// use [`resolve`] (or [`crate::pipeline::build`]) for strings that may
    /// be either.
    pub fn parse(s: &str) -> Result<PipelineSpec> {
        let s = s.trim();
        if s.is_empty() {
            return Err(SzError::config("empty pipeline spec"));
        }
        let raw_toks: Vec<&str> = s.split('/').map(str::trim).collect();
        if raw_toks.iter().any(|t| t.is_empty()) {
            return Err(SzError::config(format!(
                "pipeline spec '{s}' has an empty stage (doubled or trailing '/')"
            )));
        }
        let toks: Vec<Token> =
            raw_toks.iter().map(|t| Token::parse(t)).collect::<Result<_>>()?;
        let mut i = 0;
        let pre = if PRE_NAMES.contains(&toks[0].name) {
            i = 1;
            parse_pre(&toks[0])?
        } else {
            PreSpec::Identity
        };
        if i >= toks.len() {
            return Err(SzError::config(format!(
                "pipeline spec '{s}' names only a preprocessor; a predictor \
                 stage must follow (known: {})",
                PRED_NAMES.join(", ")
            )));
        }
        if PRE_NAMES.contains(&toks[i].name) {
            return Err(SzError::config(format!(
                "pipeline spec '{s}': at most one preprocessor prefix"
            )));
        }
        let pred = parse_pred(&toks[i])?;
        let rest = &toks[i + 1..];
        let shape_err = |family: &str, expect: &str| {
            SzError::config(format!(
                "pipeline spec '{s}': the {family} family takes {expect} after \
                 the predictor, got {} stage(s)",
                rest.len()
            ))
        };
        let spec = match pred {
            PredSpec::Lorenzo(_) | PredSpec::Zero => {
                if rest.len() != 3 {
                    return Err(shape_err("point", "quantizer/encoder/lossless"));
                }
                let (ll, lvl) = parse_lossless(&rest[2])?;
                PipelineSpec {
                    pre,
                    pred,
                    quant: Some(parse_quant(&rest[0])?),
                    enc: Some(parse_enc(&rest[1])?),
                    lossless: Some(ll),
                    lossless_level: lvl,
                }
            }
            PredSpec::Block { .. } | PredSpec::Interp(_) => {
                if rest.len() != 3 {
                    return Err(shape_err(
                        if matches!(pred, PredSpec::Block { .. }) {
                            "block"
                        } else {
                            "interp"
                        },
                        "quantizer/encoder/lossless",
                    ));
                }
                let quant = parse_quant(&rest[0])?;
                if !matches!(quant, QuantSpec::Linear { .. }) {
                    return Err(SzError::config(format!(
                        "pipeline spec '{s}': the block and interp families \
                         support only the linear quantizer"
                    )));
                }
                let (ll, lvl) = parse_lossless(&rest[2])?;
                PipelineSpec {
                    pre,
                    pred,
                    quant: Some(quant),
                    enc: Some(parse_enc(&rest[1])?),
                    lossless: Some(ll),
                    lossless_level: lvl,
                }
            }
            PredSpec::Truncation { .. } => {
                if rest.len() != 1 {
                    return Err(shape_err("truncation", "exactly a lossless stage"));
                }
                let (ll, lvl) = parse_lossless(&rest[0])?;
                PipelineSpec {
                    pre,
                    pred,
                    quant: None,
                    enc: None,
                    lossless: Some(ll),
                    lossless_level: lvl,
                }
            }
            PredSpec::ConstBlock { block, .. } => {
                if rest.len() != 3 {
                    return Err(shape_err(
                        "constblock",
                        "truncation[@kN]/raw/<lossless>",
                    ));
                }
                // the mid stage reuses the truncation token so keep-bytes
                // share one grammar (`@k1..@k8`) across both families
                if rest[0].name != "truncation" {
                    return Err(SzError::config(format!(
                        "pipeline spec '{s}': the constblock family's second \
                         stage is truncation[@kN] (got '{}')",
                        rest[0].raw
                    )));
                }
                let keep = match parse_pred(&rest[0])? {
                    PredSpec::Truncation { keep } => keep,
                    _ => unreachable!("token name checked above"),
                };
                let enc = parse_enc(&rest[1])?;
                if enc != EncSpec::Raw {
                    return Err(SzError::config(format!(
                        "pipeline spec '{s}': the constblock family supports \
                         only the raw encoder"
                    )));
                }
                let (ll, lvl) = parse_lossless(&rest[2])?;
                PipelineSpec {
                    pre,
                    pred: PredSpec::ConstBlock { block, keep },
                    quant: None,
                    enc: Some(enc),
                    lossless: Some(ll),
                    lossless_level: lvl,
                }
            }
            PredSpec::Transform { .. } => {
                if rest.len() != 3 {
                    return Err(shape_err(
                        "transform",
                        "bitplane[@pN]/raw/<lossless>",
                    ));
                }
                // the mid stage names the embedded bitplane coder and
                // carries the optional pinned-plane floor
                if rest[0].name != "bitplane" {
                    return Err(SzError::config(format!(
                        "pipeline spec '{s}': the transform family's second \
                         stage is bitplane[@pN] (got '{}')",
                        rest[0].raw
                    )));
                }
                rest[0].no_args()?;
                let planes = match rest[0].param {
                    None => None,
                    Some(p) => Some(
                        p.strip_prefix('p')
                            .and_then(|v| v.parse::<u32>().ok())
                            .filter(|v| (1..=64).contains(v))
                            .ok_or_else(|| {
                                SzError::config(format!(
                                    "stage '{}': bitplane pinned planes is \
                                     @p1..@p64",
                                    rest[0].raw
                                ))
                            })?,
                    ),
                };
                let enc = parse_enc(&rest[1])?;
                if enc != EncSpec::Raw {
                    return Err(SzError::config(format!(
                        "pipeline spec '{s}': the transform family supports \
                         only the raw encoder"
                    )));
                }
                let (ll, lvl) = parse_lossless(&rest[2])?;
                PipelineSpec {
                    pre,
                    pred: PredSpec::Transform { planes },
                    quant: None,
                    enc: Some(enc),
                    lossless: Some(ll),
                    lossless_level: lvl,
                }
            }
            PredSpec::Pastri { .. } => {
                if rest.len() != 2 {
                    return Err(shape_err("pastri", "encoder/lossless"));
                }
                let enc = parse_enc(&rest[0])?;
                if enc != EncSpec::FixedHuffman {
                    return Err(SzError::config(format!(
                        "pipeline spec '{s}': the pastri family supports only \
                         the fixed_huffman encoder"
                    )));
                }
                let (ll, lvl) = parse_lossless(&rest[1])?;
                PipelineSpec {
                    pre,
                    pred,
                    quant: None,
                    enc: Some(enc),
                    lossless: Some(ll),
                    lossless_level: lvl,
                }
            }
            PredSpec::Aps { .. } => {
                if !rest.is_empty() {
                    return Err(shape_err("aps", "no further stages"));
                }
                PipelineSpec {
                    pre,
                    pred,
                    quant: None,
                    enc: None,
                    lossless: None,
                    lossless_level: None,
                }
            }
        };
        spec.validate()?;
        Ok(spec)
    }

    /// The unique canonical rendering of this spec.
    /// `parse(x).canonical()` re-parses to an equal spec (fixed point).
    pub fn canonical(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        match self.pre {
            PreSpec::Identity => {}
            PreSpec::Linearize => parts.push("linearize".into()),
            PreSpec::Log => parts.push("log".into()),
        }
        parts.push(match self.pred {
            PredSpec::Lorenzo(1) => "lorenzo".into(),
            PredSpec::Lorenzo(o) => format!("lorenzo@{o}"),
            PredSpec::Zero => "zero".into(),
            PredSpec::Block { specialized: false } => {
                "block(lorenzo+regression)".into()
            }
            PredSpec::Block { specialized: true } => {
                "block(lorenzo+regression)@s".into()
            }
            PredSpec::Interp(InterpMode::Cubic) => "interp(cubic)".into(),
            PredSpec::Interp(InterpMode::Linear) => "interp(linear)".into(),
            PredSpec::Truncation { keep: None } => "truncation".into(),
            PredSpec::Truncation { keep: Some(k) } => format!("truncation@k{k}"),
            PredSpec::ConstBlock { block, .. } => format!("constblock({block})"),
            PredSpec::Transform { .. } => "tblock(4)".into(),
            PredSpec::Pastri { bitplane, period } => {
                let base =
                    if bitplane { "pastri(bitplane)" } else { "pastri(value)" };
                match period {
                    None => base.into(),
                    Some(p) => format!("{base}@p{p}"),
                }
            }
            PredSpec::Aps { switch_eb } => {
                if switch_eb == 0.5 {
                    "aps".into()
                } else {
                    format!("aps@{switch_eb}")
                }
            }
        });
        // the constblock family's keep-bytes render as the spec's
        // truncation mid-token (it occupies the quantizer slot, which is
        // None for this family)
        if let PredSpec::ConstBlock { keep, .. } = self.pred {
            parts.push(match keep {
                None => "truncation".into(),
                Some(k) => format!("truncation@k{k}"),
            });
        }
        // likewise the transform family's pinned planes render as the
        // spec's bitplane mid-token
        if let PredSpec::Transform { planes } = self.pred {
            parts.push(match planes {
                None => "bitplane".into(),
                Some(p) => format!("bitplane@p{p}"),
            });
        }
        if let Some(q) = self.quant {
            parts.push(match q {
                QuantSpec::Linear { radius: None } => "linear".into(),
                QuantSpec::Linear { radius: Some(r) } => format!("linear@r{r}"),
                QuantSpec::LogScale => "logscale".into(),
                QuantSpec::UnpredAware => "unpred".into(),
            });
        }
        if let Some(e) = self.enc {
            parts.push(e.token().into());
        }
        if let Some(l) = self.lossless {
            parts.push(match self.lossless_level {
                None => l.into(),
                Some(n) => format!("{l}@l{n}"),
            });
        }
        parts.join("/")
    }

    /// The lossless stage rendered as a backend token (`zstd`,
    /// `zstd@l19`) — the exact string [`crate::lossless::by_name`]
    /// accepts.
    pub fn lossless_token(&self) -> Option<String> {
        let base = self.lossless?;
        Some(match self.lossless_level {
            None => base.to_string(),
            Some(n) => format!("{base}@l{n}"),
        })
    }

    /// Re-check the family invariants ([`parse`](Self::parse) and
    /// [`PipelineBuilder`] always produce valid specs; this guards
    /// hand-built values).
    pub fn validate(&self) -> Result<()> {
        let want = |cond: bool, msg: &str| -> Result<()> {
            if cond {
                Ok(())
            } else {
                Err(SzError::config(format!("invalid pipeline spec: {msg}")))
            }
        };
        // parse/validate symmetry: every parameter the grammar bounds must
        // be re-bounded here, or a hand-built spec could canonicalize to a
        // string its own header can never re-parse
        if let Some(QuantSpec::Linear { radius: Some(r) }) = self.quant {
            want((1..=1 << 30).contains(&r), "linear radius must be 1..=2^30")?;
        }
        if let Some(n) = self.lossless_level {
            let ok = match self.lossless {
                Some("zstd") => (1..=22).contains(&n),
                Some("gzip") => (1..=9).contains(&n),
                _ => false,
            };
            want(ok, "lossless level applies to zstd (1..=22) and gzip (1..=9)")?;
        }
        match self.pred {
            PredSpec::Lorenzo(o) => {
                want((1..=3).contains(&o), "lorenzo order must be 1..=3")?;
                want(
                    self.quant.is_some() && self.enc.is_some() && self.lossless.is_some(),
                    "the point family needs quantizer, encoder, and lossless stages",
                )
            }
            PredSpec::Zero => want(
                self.quant.is_some() && self.enc.is_some() && self.lossless.is_some(),
                "the point family needs quantizer, encoder, and lossless stages",
            ),
            PredSpec::Block { .. } | PredSpec::Interp(_) => {
                want(
                    matches!(self.quant, Some(QuantSpec::Linear { .. })),
                    "the block and interp families support only the linear quantizer",
                )?;
                want(
                    self.enc.is_some() && self.lossless.is_some(),
                    "the block and interp families need encoder and lossless stages",
                )
            }
            PredSpec::Truncation { keep } => {
                want(
                    keep.map(|k| (1..=8).contains(&k)).unwrap_or(true),
                    "truncation keep-bytes must be 1..=8",
                )?;
                want(
                    self.quant.is_none() && self.enc.is_none(),
                    "the truncation family bypasses quantizer and encoder stages",
                )?;
                want(self.lossless.is_some(), "truncation needs a lossless stage")
            }
            PredSpec::ConstBlock { block, keep } => {
                want(
                    (1..=1 << 20).contains(&block),
                    "constblock block size must be 1..=2^20",
                )?;
                want(
                    keep.map(|k| (1..=8).contains(&k)).unwrap_or(true),
                    "constblock keep-bytes must be 1..=8",
                )?;
                want(
                    self.quant.is_none(),
                    "the constblock family bypasses the quantizer stage",
                )?;
                want(
                    matches!(self.enc, Some(EncSpec::Raw)),
                    "the constblock family supports only the raw encoder",
                )?;
                want(self.lossless.is_some(), "constblock needs a lossless stage")
            }
            PredSpec::Transform { planes } => {
                want(
                    planes.map(|p| (1..=64).contains(&p)).unwrap_or(true),
                    "transform pinned planes must be 1..=64",
                )?;
                want(
                    self.quant.is_none(),
                    "the transform family bypasses the quantizer stage",
                )?;
                want(
                    matches!(self.enc, Some(EncSpec::Raw)),
                    "the transform family supports only the raw encoder",
                )?;
                want(self.lossless.is_some(), "transform needs a lossless stage")
            }
            PredSpec::Pastri { period, .. } => {
                want(
                    period.map(|p| p >= 1).unwrap_or(true),
                    "pastri period must be >= 1",
                )?;
                want(
                    self.quant.is_none(),
                    "the pastri family owns its quantizer (unpred-aware)",
                )?;
                want(
                    matches!(self.enc, Some(EncSpec::FixedHuffman)),
                    "the pastri family supports only the fixed_huffman encoder",
                )?;
                want(self.lossless.is_some(), "pastri needs a lossless stage")
            }
            PredSpec::Aps { switch_eb } => {
                want(
                    switch_eb.is_finite() && switch_eb > 0.0,
                    "aps switch bound must be a positive number",
                )?;
                want(
                    self.quant.is_none() && self.enc.is_none() && self.lossless.is_none(),
                    "the aps family composes its own inner stages",
                )
            }
        }
    }

    /// Construct the composed compressor. Its [`Compressor::name`] — and
    /// with it every stream header it writes — is the canonical spec.
    pub fn build(&self) -> Result<Box<dyn Compressor>> {
        self.validate()?;
        if matches!(self.pred, PredSpec::Lorenzo(_) | PredSpec::Zero) {
            // the point family carries its preprocessor in-stream
            return Ok(Box::new(self.point_compressor()));
        }
        let stripped = PipelineSpec { pre: PreSpec::Identity, ..self.clone() };
        let stack = stripped.build_stack();
        if self.pre == PreSpec::Identity {
            Ok(stack)
        } else {
            Ok(Box::new(PreprocessedCompressor {
                name: self.canonical(),
                pre: self.pre,
                inner: stack,
            }))
        }
    }

    /// The point-family compressor for this spec (pre-validated).
    fn point_compressor(&self) -> SzCompressor {
        let pre = match self.pre {
            PreSpec::Identity => PreprocessorKind::Identity,
            PreSpec::Linearize => PreprocessorKind::Linearize,
            PreSpec::Log => PreprocessorKind::Log,
        };
        let pred = match self.pred {
            PredSpec::Lorenzo(o) => PredictorKind::Lorenzo(o),
            PredSpec::Zero => PredictorKind::Zero,
            _ => unreachable!("point_compressor is gated on the point family"),
        };
        let (quant, radius) = match self.quant.expect("validated") {
            QuantSpec::Linear { radius } => (QuantizerKind::Linear, radius),
            QuantSpec::LogScale => (QuantizerKind::LogScale, None),
            QuantSpec::UnpredAware => (QuantizerKind::UnpredAware, None),
        };
        SzCompressor {
            name: self.canonical(),
            preprocessor: pre,
            predictor: pred,
            quantizer: quant,
            encoder: self.enc.expect("validated").token().to_string(),
            lossless: self.lossless_token().expect("validated"),
            radius,
        }
    }

    /// The non-point family stack, named by this spec's canonical string
    /// (callers strip the preprocessor first).
    fn build_stack(&self) -> Box<dyn Compressor> {
        let name = self.canonical();
        let radius = match self.quant {
            Some(QuantSpec::Linear { radius }) => radius,
            _ => None,
        };
        match self.pred {
            PredSpec::Block { .. } => Box::new(
                // single construction site for spec-built block pipelines —
                // the PJRT path reaches the same function
                self.block_compressor()
                    .expect("validated block family with no preprocessor"),
            ),
            PredSpec::Interp(mode) => Box::new(InterpCompressor {
                name,
                mode,
                encoder: self.enc.expect("validated").token().to_string(),
                lossless: self.lossless_token().expect("validated"),
                radius,
            }),
            PredSpec::Truncation { keep } => Box::new(TruncationCompressor {
                name,
                keep_bytes: keep,
                lossless: self.lossless_token().expect("validated"),
            }),
            PredSpec::ConstBlock { block, keep } => Box::new(SzxCompressor {
                name,
                block: block as usize,
                keep_bytes: keep,
                lossless: self.lossless_token().expect("validated"),
            }),
            PredSpec::Transform { planes } => {
                Box::new(crate::transform::TransformCompressor {
                    name,
                    planes,
                    lossless: self.lossless_token().expect("validated"),
                })
            }
            PredSpec::Pastri { bitplane, period } => Box::new(PastriCompressor {
                name,
                bitplane_unpred: bitplane,
                lossless: self.lossless_token().expect("validated"),
                period,
            }),
            PredSpec::Aps { switch_eb } => {
                Box::new(ApsCompressor { name, switch_eb })
            }
            PredSpec::Lorenzo(_) | PredSpec::Zero => {
                unreachable!("point family is built by point_compressor")
            }
        }
    }

    /// The concrete block-family compressor for this spec, when its
    /// predictor is the blockwise composite and no preprocessor prefix is
    /// set — lets callers swap in a custom
    /// [`super::analysis::BlockAnalyzer`] (e.g. PJRT) before boxing.
    pub fn block_compressor(&self) -> Option<BlockCompressor> {
        if self.pre != PreSpec::Identity {
            return None;
        }
        match self.pred {
            PredSpec::Block { specialized } => Some(BlockCompressor {
                name: self.canonical(),
                analyzer: std::sync::Arc::new(super::analysis::NativeAnalyzer),
                encoder: self.enc?.token().to_string(),
                lossless: self.lossless_token()?,
                assume_noiseless: false,
                specialized,
                radius: match self.quant {
                    Some(QuantSpec::Linear { radius }) => radius,
                    _ => None,
                },
            }),
            _ => None,
        }
    }
}

/// Resolve a registry alias or spec string into a validated spec.
pub fn resolve(name_or_spec: &str) -> Result<PipelineSpec> {
    if let Some(canon) = alias_canonical(name_or_spec.trim()) {
        return PipelineSpec::parse(canon);
    }
    PipelineSpec::parse(name_or_spec)
}

/// Canonical spec string for an alias or spec (the exact string
/// [`PipelineSpec::build`] writes into stream headers).
pub fn canonical(name_or_spec: &str) -> Result<String> {
    Ok(resolve(name_or_spec)?.canonical())
}

/// Uniform corrupt-artifact error for a pipeline string that failed to
/// resolve — names the offender, carries the parse error, and hints the
/// nearest registry alias. Shared by [`crate::pipeline::decompress_any`]
/// and the container reader so the recovery hint cannot drift.
pub fn unknown_pipeline_error(context: &str, name: &str, err: &SzError) -> SzError {
    SzError::corrupt(format!(
        "{context} names unknown pipeline '{name}' ({err}); nearest known \
         alias is '{}' — `sz3 pipelines` lists aliases and stages, \
         docs/PIPELINES.md the spec grammar",
        nearest_alias(name)
    ))
}

impl std::fmt::Display for PipelineSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.canonical())
    }
}

impl std::str::FromStr for PipelineSpec {
    type Err = SzError;
    fn from_str(s: &str) -> Result<PipelineSpec> {
        PipelineSpec::parse(s)
    }
}

/// Typed builder over [`PipelineSpec`]: start from a family constructor,
/// chain stage setters, [`finish`](Self::finish) validates and yields the
/// spec (family defaults fill unset stages).
///
/// ```no_run
/// use sz3::pipeline::spec::PipelineBuilder;
/// let spec = PipelineBuilder::block()
///     .lossless("lzhuf")
///     .radius(512)
///     .finish()
///     .unwrap();
/// assert_eq!(spec.canonical(), "block(lorenzo+regression)/linear@r512/huffman/lzhuf");
/// ```
#[derive(Clone, Debug)]
pub struct PipelineBuilder {
    pre: PreSpec,
    pred: PredSpec,
    quant: Option<QuantSpec>,
    enc: Option<EncSpec>,
    lossless: Option<String>,
    err: Option<String>,
}

impl PipelineBuilder {
    fn new(pred: PredSpec) -> Self {
        PipelineBuilder {
            pre: PreSpec::Identity,
            pred,
            quant: None,
            enc: None,
            lossless: None,
            err: None,
        }
    }

    /// Blockwise Lorenzo⊕regression family (SZ3-LR shape).
    pub fn block() -> Self {
        Self::new(PredSpec::Block { specialized: false })
    }

    /// Interpolation family.
    pub fn interp(mode: InterpMode) -> Self {
        Self::new(PredSpec::Interp(mode))
    }

    /// Point family with an order-N Lorenzo predictor.
    pub fn lorenzo(order: u32) -> Self {
        Self::new(PredSpec::Lorenzo(order))
    }

    /// Point family with the always-zero predictor.
    pub fn zero() -> Self {
        Self::new(PredSpec::Zero)
    }

    /// Byte-truncation family.
    pub fn truncation() -> Self {
        Self::new(PredSpec::Truncation { keep: None })
    }

    /// SZx-style constant-block fast family.
    pub fn constblock(block: u32) -> Self {
        Self::new(PredSpec::ConstBlock { block, keep: None })
    }

    /// ZFP-style 4^d-block transform family.
    pub fn transform() -> Self {
        Self::new(PredSpec::Transform { planes: None })
    }

    /// PaSTRI family (`bitplane` selects the SZ3 unpredictable layout).
    pub fn pastri(bitplane: bool) -> Self {
        Self::new(PredSpec::Pastri { bitplane, period: None })
    }

    /// Adaptive APS meta-pipeline.
    pub fn aps() -> Self {
        Self::new(PredSpec::Aps { switch_eb: 0.5 })
    }

    /// Set the preprocessor prefix.
    pub fn preprocess(mut self, pre: PreSpec) -> Self {
        self.pre = pre;
        self
    }

    /// Use the dimension-specialized block codecs (block family only).
    pub fn specialized(mut self) -> Self {
        match self.pred {
            PredSpec::Block { .. } => {
                self.pred = PredSpec::Block { specialized: true };
            }
            _ => self.set_err("specialized() applies to the block family"),
        }
        self
    }

    /// Pin the kept most-significant bytes (truncation and constblock
    /// families).
    pub fn keep_bytes(mut self, k: usize) -> Self {
        match self.pred {
            PredSpec::Truncation { .. } => {
                self.pred = PredSpec::Truncation { keep: Some(k) };
            }
            PredSpec::ConstBlock { block, .. } => {
                self.pred = PredSpec::ConstBlock { block, keep: Some(k) };
            }
            _ => self.set_err(
                "keep_bytes() applies to the truncation and constblock families",
            ),
        }
        self
    }

    /// Pin the minimum kept bitplanes (transform family only).
    pub fn planes(mut self, p: u32) -> Self {
        match self.pred {
            PredSpec::Transform { .. } => {
                self.pred = PredSpec::Transform { planes: Some(p) };
            }
            _ => self.set_err("planes() applies to the transform family"),
        }
        self
    }

    /// Pin the pastri pattern period (pastri family only).
    pub fn period(mut self, p: usize) -> Self {
        match self.pred {
            PredSpec::Pastri { bitplane, .. } => {
                self.pred = PredSpec::Pastri { bitplane, period: Some(p) };
            }
            _ => self.set_err("period() applies to the pastri family"),
        }
        self
    }

    /// Set the aps switch error bound (aps family only).
    pub fn switch_eb(mut self, eb: f64) -> Self {
        match self.pred {
            PredSpec::Aps { .. } => self.pred = PredSpec::Aps { switch_eb: eb },
            _ => self.set_err("switch_eb() applies to the aps family"),
        }
        self
    }

    /// Set the quantizer stage.
    pub fn quantizer(mut self, q: QuantSpec) -> Self {
        self.quant = Some(q);
        self
    }

    /// Override the linear quantizer's index radius.
    pub fn radius(mut self, r: u32) -> Self {
        match self.quant {
            None | Some(QuantSpec::Linear { .. }) => {
                self.quant = Some(QuantSpec::Linear { radius: Some(r) });
            }
            _ => self.set_err("radius() applies to the linear quantizer"),
        }
        self
    }

    /// Set the encoder stage.
    pub fn encoder(mut self, e: EncSpec) -> Self {
        self.enc = Some(e);
        self
    }

    /// Set the lossless stage by token name (`zstd`, `gzip`, `lzhuf`,
    /// `rle`, `bypass`), optionally leveled (`zstd@l19`, `gzip@l9`).
    pub fn lossless(mut self, name: &str) -> Self {
        self.lossless = Some(name.to_string());
        self
    }

    fn set_err(&mut self, msg: &str) {
        if self.err.is_none() {
            self.err = Some(msg.to_string());
        }
    }

    /// Validate and produce the spec; unset stages take family defaults
    /// (linear / huffman / zstd where they apply, bypass for truncation).
    pub fn finish(self) -> Result<PipelineSpec> {
        if let Some(e) = self.err {
            return Err(SzError::config(e));
        }
        let (lossless, lossless_level) = match &self.lossless {
            // full token grammar, so `.lossless("zstd@l19")` works too
            Some(name) => {
                let tok = Token::parse(name)?;
                let (l, lvl) = parse_lossless(&tok)?;
                (Some(l), lvl)
            }
            None => (None, None),
        };
        let spec = match self.pred {
            PredSpec::Lorenzo(_)
            | PredSpec::Zero
            | PredSpec::Block { .. }
            | PredSpec::Interp(_) => PipelineSpec {
                pre: self.pre,
                pred: self.pred,
                quant: Some(self.quant.unwrap_or(QuantSpec::Linear { radius: None })),
                enc: Some(self.enc.unwrap_or(EncSpec::Huffman)),
                lossless: Some(lossless.unwrap_or("zstd")),
                lossless_level,
            },
            PredSpec::Truncation { .. } => PipelineSpec {
                pre: self.pre,
                pred: self.pred,
                quant: self.quant,
                enc: self.enc,
                lossless: Some(lossless.unwrap_or("bypass")),
                lossless_level,
            },
            PredSpec::ConstBlock { .. } | PredSpec::Transform { .. } => {
                PipelineSpec {
                    pre: self.pre,
                    pred: self.pred,
                    quant: self.quant,
                    enc: Some(self.enc.unwrap_or(EncSpec::Raw)),
                    lossless: Some(lossless.unwrap_or("zstd")),
                    lossless_level,
                }
            }
            PredSpec::Pastri { .. } => PipelineSpec {
                pre: self.pre,
                pred: self.pred,
                quant: self.quant,
                enc: Some(self.enc.unwrap_or(EncSpec::FixedHuffman)),
                lossless: Some(lossless.unwrap_or("zstd")),
                lossless_level,
            },
            PredSpec::Aps { .. } => PipelineSpec {
                pre: self.pre,
                pred: self.pred,
                quant: self.quant,
                enc: self.enc,
                lossless,
                lossless_level,
            },
        };
        spec.validate()?;
        Ok(spec)
    }
}

/// One entry of the unified stage catalog.
#[derive(Clone, Copy, Debug)]
pub struct StageInfo {
    /// Stage slot: "preprocessor" | "predictor" | "quantizer" | "encoder"
    /// | "lossless".
    pub kind: &'static str,
    /// Spec token.
    pub token: &'static str,
    /// Parameter syntax, empty when the stage takes none.
    pub params: &'static str,
    /// One-line description.
    pub summary: &'static str,
}

/// The unified stage catalog — every token the spec grammar accepts, with
/// its parameter syntax. `sz3 pipelines` prints this inventory; the stage
/// modules' `by_name` constructors are reached exclusively through these
/// tokens.
pub fn catalog() -> &'static [StageInfo] {
    &[
        StageInfo { kind: "preprocessor", token: "log", params: "", summary: "pointwise-relative bounds via ln|x| (requires --pwrel)" },
        StageInfo { kind: "preprocessor", token: "linearize", params: "", summary: "treat N-d data as 1-d" },
        StageInfo { kind: "predictor", token: "lorenzo", params: "@N order 1..=3", summary: "point-family order-N Lorenzo" },
        StageInfo { kind: "predictor", token: "zero", params: "", summary: "point-family always-zero baseline" },
        StageInfo { kind: "predictor", token: "block(lorenzo+regression)", params: "@s specialized codecs", summary: "SZ2-style blockwise composite (SZ3-LR)" },
        StageInfo { kind: "predictor", token: "interp", params: "(cubic|linear)", summary: "level-by-level spline interpolation (SZ3-Interp)" },
        StageInfo { kind: "predictor", token: "truncation", params: "@kN keep bytes 1..=8", summary: "byte truncation, module bypass (SZ3-Truncation)" },
        StageInfo { kind: "predictor", token: "constblock", params: "(N) block elems 1..=2^20, then truncation[@kN]/raw", summary: "SZx-style constant-block fast path" },
        StageInfo { kind: "predictor", token: "tblock", params: "(4) fixed block side, then bitplane[@pN]/raw", summary: "ZFP-style lifted transform + embedded bitplanes" },
        StageInfo { kind: "predictor", token: "pastri", params: "(bitplane|value) @pN period", summary: "periodic-pattern prediction for GAMESS ERI (SZ3-Pastri)" },
        StageInfo { kind: "predictor", token: "aps", params: "@EB switch bound", summary: "adaptive APS meta-pipeline (composes its own stages)" },
        StageInfo { kind: "quantizer", token: "linear", params: "@rN radius override", summary: "linear-scaling quantizer" },
        StageInfo { kind: "quantizer", token: "logscale", params: "", summary: "geometric-then-linear binning" },
        StageInfo { kind: "quantizer", token: "unpred", params: "", summary: "linear with bitplane-coded unpredictables (§4.2)" },
        StageInfo { kind: "encoder", token: "huffman", params: "", summary: "canonical per-stream Huffman" },
        StageInfo { kind: "encoder", token: "fixed_huffman", params: "", summary: "predefined-tree Huffman" },
        StageInfo { kind: "encoder", token: "arithmetic", params: "", summary: "adaptive arithmetic coding" },
        StageInfo { kind: "encoder", token: "raw", params: "", summary: "uncoded index passthrough" },
        StageInfo { kind: "lossless", token: "zstd", params: "@lN level 1..=22", summary: "zstd proxy (default stage)" },
        StageInfo { kind: "lossless", token: "gzip", params: "@lN level 1..=9", summary: "DEFLATE proxy" },
        StageInfo { kind: "lossless", token: "lzhuf", params: "", summary: "from-scratch LZ+Huffman backend" },
        StageInfo { kind: "lossless", token: "rle", params: "", summary: "byte run-length encoding" },
        StageInfo { kind: "lossless", token: "bypass", params: "", summary: "no lossless stage (module bypass)" },
    ]
}

/// Generic preprocessor wrapper: applies a spec's preprocessor prefix
/// around any non-point family (the point family embeds its preprocessor
/// in-stream). The outer stream is `header(canonical spec, original
/// dims) · state block · inner stream`, so decompression rebuilds the
/// exact stack from the header and reverses the transform from the
/// carried state.
struct PreprocessedCompressor {
    name: String,
    pre: PreSpec,
    inner: Box<dyn Compressor>,
}

impl PreprocessedCompressor {
    fn instantiate(&self) -> Box<dyn Preprocessor> {
        match self.pre {
            PreSpec::Identity => Box::new(crate::preprocessor::Identity),
            PreSpec::Linearize => Box::new(Linearize),
            PreSpec::Log => Box::new(LogTransform::default()),
        }
    }
}

impl Compressor for PreprocessedCompressor {
    fn name(&self) -> &str {
        &self.name
    }

    fn compress(&self, field: &Field, conf: &CompressConf) -> Result<Vec<u8>> {
        let mut w = ByteWriter::new();
        // outer header carries the ORIGINAL dims; postprocess restores them
        StreamHeader::for_field(&self.name, field).write(&mut w);
        let mut f = field.clone();
        let mut c = conf.clone();
        let t_pre = std::time::Instant::now();
        let state = self.instantiate().process(&mut f, &mut c)?;
        crate::obs::stage(crate::obs::ST_PREPROCESS).record(
            t_pre,
            field.len() as u64,
            f.len() as u64,
        );
        w.put_block(&state);
        w.put_block(&self.inner.compress(&f, &c)?);
        Ok(w.finish())
    }

    fn decompress(&self, stream: &[u8]) -> Result<Field> {
        let mut r = ByteReader::new(stream);
        let header = StreamHeader::read(&mut r)?;
        let state = r.get_block()?.to_vec();
        let inner_stream = r.get_block()?;
        let mut field = self.inner.decompress(inner_stream)?;
        let t_post = std::time::Instant::now();
        self.instantiate().postprocess(&mut field, &state)?;
        crate::obs::stage(crate::obs::ST_POSTPROCESS).record(
            t_post,
            0,
            field.len() as u64,
        );
        if field.len() != header.len() {
            return Err(SzError::corrupt(format!(
                "preprocessed stream: {} elements after postprocess, header \
                 declares {}",
                field.len(),
                header.len()
            )));
        }
        field.name = header.field_name;
        Ok(field)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::FieldValues;
    use crate::pipeline::{self, decompress_any, ErrorBound};
    use crate::util::{prop, rng::Pcg32};

    #[test]
    fn aliases_resolve_and_canonicalize_stably() {
        for (alias, canon) in ALIASES {
            let spec = resolve(alias).unwrap_or_else(|e| panic!("{alias}: {e}"));
            assert_eq!(&spec.canonical(), canon, "{alias}");
            // the canonical spec is its own fixed point
            let reparsed = PipelineSpec::parse(canon).unwrap();
            assert_eq!(reparsed, spec, "{alias}");
            assert_eq!(reparsed.canonical(), *canon, "{alias}");
        }
    }

    /// Random valid spec over the whole grammar.
    fn random_spec(rng: &mut Pcg32) -> PipelineSpec {
        let pred = match rng.below(9) {
            0 => PredSpec::Lorenzo(rng.below(3) as u32 + 1),
            1 => PredSpec::Zero,
            2 => PredSpec::Block { specialized: rng.below(2) == 0 },
            3 => PredSpec::Interp(if rng.below(2) == 0 {
                InterpMode::Cubic
            } else {
                InterpMode::Linear
            }),
            4 => PredSpec::Truncation {
                keep: if rng.below(2) == 0 { None } else { Some(rng.below(8) + 1) },
            },
            5 => PredSpec::Pastri {
                bitplane: rng.below(2) == 0,
                period: if rng.below(2) == 0 { None } else { Some(rng.below(200) + 1) },
            },
            6 => PredSpec::ConstBlock {
                block: [1u32, 2, 32, 256, 1 << 20][rng.below(5)],
                keep: if rng.below(2) == 0 { None } else { Some(rng.below(8) + 1) },
            },
            7 => PredSpec::Transform {
                planes: if rng.below(2) == 0 {
                    None
                } else {
                    Some(rng.below(64) as u32 + 1)
                },
            },
            _ => PredSpec::Aps {
                switch_eb: [0.5, 0.25, 2.0, 0.75][rng.below(4)],
            },
        };
        let linearish = QuantSpec::Linear {
            radius: if rng.below(2) == 0 { None } else { Some(rng.below(4096) as u32 + 1) },
        };
        let pre = match rng.below(3) {
            0 => PreSpec::Identity,
            1 => PreSpec::Linearize,
            _ => PreSpec::Log,
        };
        let enc_any = [EncSpec::Huffman, EncSpec::FixedHuffman, EncSpec::Arithmetic, EncSpec::Raw]
            [rng.below(4)];
        let ll = LOSSLESS_TOKENS[rng.below(LOSSLESS_TOKENS.len())];
        let lvl = match ll {
            "zstd" if rng.below(3) == 0 => Some(rng.below(22) as u32 + 1),
            "gzip" if rng.below(3) == 0 => Some(rng.below(9) as u32 + 1),
            _ => None,
        };
        match pred {
            PredSpec::Lorenzo(_) | PredSpec::Zero => PipelineSpec {
                pre,
                pred,
                quant: Some(match rng.below(3) {
                    0 => linearish,
                    1 => QuantSpec::LogScale,
                    _ => QuantSpec::UnpredAware,
                }),
                enc: Some(enc_any),
                lossless: Some(ll),
                lossless_level: lvl,
            },
            PredSpec::Block { .. } | PredSpec::Interp(_) => PipelineSpec {
                pre,
                pred,
                quant: Some(linearish),
                enc: Some(enc_any),
                lossless: Some(ll),
                lossless_level: lvl,
            },
            PredSpec::Truncation { .. } => PipelineSpec {
                pre,
                pred,
                quant: None,
                enc: None,
                lossless: Some(ll),
                lossless_level: lvl,
            },
            PredSpec::ConstBlock { .. } | PredSpec::Transform { .. } => {
                PipelineSpec {
                    pre,
                    pred,
                    quant: None,
                    enc: Some(EncSpec::Raw),
                    lossless: Some(ll),
                    lossless_level: lvl,
                }
            }
            PredSpec::Pastri { .. } => PipelineSpec {
                pre,
                pred,
                quant: None,
                enc: Some(EncSpec::FixedHuffman),
                lossless: Some(ll),
                lossless_level: lvl,
            },
            PredSpec::Aps { .. } => PipelineSpec {
                pre,
                pred,
                quant: None,
                enc: None,
                lossless: None,
                lossless_level: None,
            },
        }
    }

    #[test]
    fn prop_parse_canonicalize_is_a_fixed_point() {
        prop::cases(80, 0x5bec, |rng| {
            let spec = random_spec(rng);
            spec.validate().expect("random_spec builds valid specs");
            let canon = spec.canonical();
            let parsed = PipelineSpec::parse(&canon)
                .unwrap_or_else(|e| panic!("'{canon}': {e}"));
            assert_eq!(parsed, spec, "'{canon}' reparses to the same spec");
            assert_eq!(parsed.canonical(), canon, "'{canon}' is a fixed point");
            // resolve() treats a canonical spec as itself
            assert_eq!(super::canonical(&canon).unwrap(), canon);
        });
    }

    #[test]
    fn aliases_roundtrip_bit_identically_through_canonical_specs() {
        let mut rng = Pcg32::seeded(0xa1145);
        let dims = [12usize, 12, 12];
        let f = crate::data::Field::f32("x", &dims, prop::smooth_field(&mut rng, &dims))
            .unwrap();
        let conf = crate::pipeline::CompressConf::with_radius(ErrorBound::Abs(1e-3), 512);
        for (alias, canon) in ALIASES {
            let a = pipeline::build(alias).unwrap();
            let c = pipeline::build(canon).unwrap();
            assert_eq!(a.name(), c.name(), "{alias}: same canonical identity");
            let sa = a.compress(&f, &conf).unwrap();
            let sc = c.compress(&f, &conf).unwrap();
            assert_eq!(sa, sc, "{alias}: alias and canonical spec streams differ");
            let da = decompress_any(&sa).unwrap();
            let dc = decompress_any(&sc).unwrap();
            assert_eq!(da.values, dc.values, "{alias}");
            assert_eq!(da.shape.dims(), f.shape.dims(), "{alias}");
        }
    }

    #[test]
    fn malformed_specs_error_cleanly() {
        let bad = [
            "",
            "/",
            "lorenzo/linear/huffman/zstd/",          // trailing '/'
            "lorenzo//huffman/zstd",                 // empty stage
            "nope/linear/huffman/zstd",              // unknown predictor
            "lorenzo/linear/huffman",                // missing lossless
            "lorenzo/linear/huffman/zstd/extra",     // too many stages
            "lorenzo@9/linear/huffman/zstd",         // bad order
            "lorenzo/linear@rX/huffman/zstd",        // bad radius
            "lorenzo/linear@r0/huffman/zstd",        // zero radius
            "lorenzo/linear/huffman/nada",           // unknown lossless
            "lorenzo/linear/morse/zstd",             // unknown encoder
            "block(lorenzo)/linear/huffman/zstd",    // unsupported composite
            "block(lorenzo+regression)/logscale/huffman/zstd", // non-linear quant
            "interp(quintic)/linear/huffman/zstd",   // unknown basis
            "truncation@k9/bypass",                  // keep out of range
            "truncation/huffman/zstd",               // truncation takes 1 stage
            "constblock(0)/truncation/raw/zstd",     // zero block size
            "constblock(2000000)/truncation/raw/zstd", // block > 2^20
            "constblock(8+8)/truncation/raw/zstd",   // one argument only
            "constblock@k2/truncation/raw/zstd",     // keep rides the mid-token
            "constblock(32)/linear/raw/zstd",        // mid stage must be truncation
            "constblock(32)/truncation@k9/raw/zstd", // keep out of range
            "constblock(32)/truncation/huffman/zstd", // constblock needs raw
            "constblock(32)/truncation/raw",         // missing lossless
            "constblock(32)/raw/zstd",               // missing mid stage
            "tblock(8)/bitplane/raw/zstd",           // block side fixed at 4
            "tblock(4+4)/bitplane/raw/zstd",         // one argument only
            "tblock@p4/bitplane/raw/zstd",           // planes ride the mid-token
            "tblock(4)/linear/raw/zstd",             // mid stage must be bitplane
            "tblock(4)/bitplane(x)/raw/zstd",        // bitplane takes no args
            "tblock(4)/bitplane@p0/raw/zstd",        // planes out of range
            "tblock(4)/bitplane@p65/raw/zstd",       // planes out of range
            "tblock(4)/bitplane/huffman/zstd",       // transform needs raw
            "tblock(4)/bitplane/raw",                // missing lossless
            "tblock(4)/raw/zstd",                    // missing mid stage
            "lorenzo/linear/huffman/zstd@l0",        // zstd level out of range
            "lorenzo/linear/huffman/zstd@l23",       // zstd level out of range
            "lorenzo/linear/huffman/gzip@l10",       // gzip level out of range
            "lorenzo/linear/huffman/bypass@l3",      // bypass takes no level
            "lorenzo/linear/huffman/lzhuf@l2",       // lzhuf takes no level
            "lorenzo/linear/huffman/zstd@lx",        // malformed level
            "lorenzo/linear/huffman/zstd@19",        // missing 'l' prefix
            "pastri(bitplane)/huffman/zstd",         // pastri needs fixed_huffman
            "pastri(sideways)/fixed_huffman/zstd",   // unknown layout
            "aps/linear/huffman/zstd",               // aps takes no stages
            "aps@-1",                                // bad switch bound
            "log",                                   // preprocessor alone
            "log/linearize/lorenzo/linear/huffman/zstd", // two preprocessors
            "lorenzo(x)/linear/huffman/zstd",        // stray args
            "lorenzo/linear(/huffman/zstd",          // unbalanced paren
        ];
        for s in bad {
            assert!(
                PipelineSpec::parse(s).is_err(),
                "'{s}' should fail to parse"
            );
            assert!(resolve(s).is_err(), "'{s}' should fail to resolve");
        }
    }

    #[test]
    fn nearest_alias_suggests_recovery() {
        assert_eq!(nearest_alias("sz3-lrr"), "sz3-lr");
        assert_eq!(nearest_alias("sz3_interp"), "sz3-interp");
        assert_eq!(nearest_alias("lorenzo1d"), "lorenzo-1d");
        // arbitrary garbage still yields *some* alias
        assert!(ALIASES.iter().any(|(a, _)| *a == nearest_alias("???")));
    }

    #[test]
    fn builder_composes_and_validates() {
        let spec = PipelineBuilder::block().lossless("lzhuf").radius(512).finish().unwrap();
        assert_eq!(
            spec.canonical(),
            "block(lorenzo+regression)/linear@r512/huffman/lzhuf"
        );
        let spec = PipelineBuilder::lorenzo(2)
            .preprocess(PreSpec::Linearize)
            .quantizer(QuantSpec::UnpredAware)
            .encoder(EncSpec::Arithmetic)
            .lossless("rle")
            .finish()
            .unwrap();
        assert_eq!(spec.canonical(), "linearize/lorenzo@2/unpred/arithmetic/rle");
        // defaults fill in
        assert_eq!(
            PipelineBuilder::interp(InterpMode::Linear).finish().unwrap().canonical(),
            "interp(linear)/linear/huffman/zstd"
        );
        assert_eq!(
            PipelineBuilder::truncation().keep_bytes(2).finish().unwrap().canonical(),
            "truncation@k2/bypass"
        );
        // misapplied setters surface at finish()
        assert!(PipelineBuilder::block().keep_bytes(2).finish().is_err());
        assert!(PipelineBuilder::aps().switch_eb(-1.0).finish().is_err());
        assert!(PipelineBuilder::block().lossless("nada").finish().is_err());
        // out-of-grammar parameters are caught too, so a built spec can
        // never canonicalize to a string its own header cannot re-parse
        assert!(PipelineBuilder::block().radius(u32::MAX).finish().is_err());
        assert!(PipelineBuilder::lorenzo(9).finish().is_err());
        // builder and parse agree
        let b = PipelineBuilder::block().specialized().finish().unwrap();
        let p = PipelineSpec::parse("block(lorenzo+regression)@s/linear/huffman/zstd").unwrap();
        assert_eq!(b, p);
        // transform family: defaults, pinned planes, misapplied setters
        assert_eq!(
            PipelineBuilder::transform().finish().unwrap().canonical(),
            "tblock(4)/bitplane/raw/zstd"
        );
        let b = PipelineBuilder::transform().planes(12).lossless("gzip").finish().unwrap();
        let p = PipelineSpec::parse("tblock(4)/bitplane@p12/raw/gzip").unwrap();
        assert_eq!(b, p);
        assert!(PipelineBuilder::block().planes(4).finish().is_err());
        assert!(PipelineBuilder::transform().planes(65).finish().is_err());
        assert!(PipelineBuilder::transform().keep_bytes(2).finish().is_err());
    }

    #[test]
    fn lossless_levels_are_first_class_spec_parameters() {
        // parse → canonical is a fixed point with the level preserved
        let spec = PipelineSpec::parse("lorenzo/linear/huffman/zstd@l19").unwrap();
        assert_eq!(spec.lossless, Some("zstd"));
        assert_eq!(spec.lossless_level, Some(19));
        assert_eq!(spec.canonical(), "lorenzo/linear/huffman/zstd@l19");
        assert_eq!(spec.lossless_token().unwrap(), "zstd@l19");
        // the builder accepts the same token grammar
        let b = PipelineBuilder::lorenzo(1).lossless("zstd@l19").finish().unwrap();
        assert_eq!(b, spec);
        assert!(PipelineBuilder::lorenzo(1).lossless("zstd@l0").finish().is_err());
        // hand-built out-of-range levels are caught by validate()
        let mut bad = spec.clone();
        bad.lossless_level = Some(23);
        assert!(bad.validate().is_err());
        let mut bad = spec;
        bad.lossless = Some("rle");
        assert!(bad.validate().is_err());
        // a leveled pipeline compresses, names itself canonically, and
        // decodes via decompress_any from the header alone
        let mut rng = Pcg32::seeded(0x11f7);
        let dims = [24usize, 24];
        let f = crate::data::Field::f32("x", &dims, prop::smooth_field(&mut rng, &dims))
            .unwrap();
        let conf = crate::pipeline::CompressConf::new(ErrorBound::Abs(1e-3));
        for s in ["truncation@k3/gzip@l9", "lorenzo/linear/huffman/zstd@l19"] {
            let c = pipeline::build(s).unwrap();
            assert_eq!(c.name(), super::canonical(s).unwrap(), "{s}");
            let stream = c.compress(&f, &conf).unwrap();
            let out = decompress_any(&stream).unwrap();
            assert_eq!(out.shape.dims(), f.shape.dims(), "{s}");
        }
    }

    #[test]
    fn composed_non_registry_specs_roundtrip() {
        let mut rng = Pcg32::seeded(0xc0de);
        let dims = [10usize, 8, 8];
        let f = crate::data::Field::f32("x", &dims, prop::smooth_field(&mut rng, &dims))
            .unwrap();
        let conf = crate::pipeline::CompressConf::with_radius(ErrorBound::Abs(1e-3), 512);
        for s in [
            "block(lorenzo+regression)/linear/huffman/lzhuf",
            "interp(cubic)/linear/huffman/rle",
            "linearize/lorenzo/linear/arithmetic/rle",
            "lorenzo@2/logscale/huffman/gzip",
            "linearize/block(lorenzo+regression)/linear@r256/huffman/bypass",
            "truncation@k3/rle",
            "tblock(4)/bitplane@p8/raw/gzip",
            "linearize/tblock(4)/bitplane/raw/zstd@l19",
            "interp(cubic)/linear/huffman/gzip@l9",
        ] {
            let canon = super::canonical(s).unwrap();
            assert!(
                ALIASES.iter().all(|(_, c)| *c != canon),
                "'{s}' must not collide with a registry alias"
            );
            let c = pipeline::build(s).unwrap();
            assert_eq!(c.name(), canon, "{s}");
            let stream = c.compress(&f, &conf).unwrap();
            let h = crate::pipeline::peek_header(&stream).unwrap();
            assert_eq!(h.pipeline, canon, "{s}: header carries the canonical spec");
            let out = decompress_any(&stream).unwrap();
            assert_eq!(out.shape.dims(), f.shape.dims(), "{s}");
            for (o, d) in f.values.to_f64_vec().iter().zip(out.values.to_f64_vec()) {
                assert!((o - d).abs() <= 1e-3 * (1.0 + 1e-12), "{s}");
            }
        }
    }

    #[test]
    fn log_prefix_gives_pointwise_relative_bounds_to_any_family() {
        // pwrel through the wrapper (interp family) and the point family
        let n = 2048usize;
        let vals: Vec<f64> = (0..n)
            .map(|i| {
                let t = i as f64 / 300.0;
                (t.sin() + 1.5) * 10f64.powf(2.0 * (t * 0.2).cos())
            })
            .collect();
        let f = crate::data::Field::f64("w", &[n], vals.clone()).unwrap();
        let rel = 1e-3;
        let conf = crate::pipeline::CompressConf::new(ErrorBound::PwRel(rel));
        for s in ["log/lorenzo/linear/huffman/zstd", "log/interp(cubic)/linear/huffman/zstd"] {
            let c = pipeline::build(s).unwrap();
            let stream = c.compress(&f, &conf).unwrap();
            let out = decompress_any(&stream).unwrap();
            assert!(matches!(out.values, FieldValues::F64(_)), "{s}");
            for (o, d) in vals.iter().zip(out.values.to_f64_vec()) {
                assert!(
                    (d / o - 1.0).abs() <= rel * (1.0 + 1e-9),
                    "{s}: rel err at {o} vs {d}"
                );
            }
        }
    }

    #[test]
    fn grammar_tokens_reach_real_stage_constructors() {
        // drift guard for the token sets duplicated across the grammar,
        // the catalog, and the stage modules' by_name registries: every
        // encoder/lossless token the grammar accepts must construct
        for t in ["huffman", "fixed_huffman", "arithmetic", "raw"] {
            assert!(EncSpec::parse(t).is_some(), "{t} missing from grammar");
            assert!(crate::encoder::by_name(t, 64).is_some(), "{t} missing from registry");
        }
        for &t in LOSSLESS_TOKENS {
            assert!(crate::lossless::by_name(t).is_some(), "{t} missing from registry");
        }
        // leveled tokens the grammar accepts construct; out-of-grammar
        // levels are rejected by the registry too
        for t in ["zstd@l1", "zstd@l22", "gzip@l1", "gzip@l9"] {
            assert!(crate::lossless::by_name(t).is_some(), "{t} missing from registry");
        }
        for t in ["zstd@l0", "zstd@l23", "gzip@l10", "bypass@l1", "rle@l2"] {
            assert!(crate::lossless::by_name(t).is_none(), "{t} should be rejected");
        }
        // and every grammar token appears in the printed catalog
        for t in ["huffman", "fixed_huffman", "arithmetic", "raw"]
            .iter()
            .chain(LOSSLESS_TOKENS)
        {
            assert!(
                catalog().iter().any(|i| i.token == *t),
                "{t} missing from spec::catalog()"
            );
        }
    }

    #[test]
    fn catalog_tokens_are_spec_parseable() {
        // every predictor token in the catalog heads at least one valid spec
        for info in catalog() {
            match info.kind {
                "predictor" => {
                    let head = match info.token {
                        "interp" => "interp(cubic)".to_string(),
                        "pastri" => "pastri(bitplane)".to_string(),
                        "constblock" => "constblock(32)".to_string(),
                        "tblock" => "tblock(4)".to_string(),
                        t => t.to_string(),
                    };
                    let tail = match info.token {
                        "truncation" => "/bypass",
                        "constblock" => "/truncation/raw/zstd",
                        "tblock" => "/bitplane/raw/zstd",
                        "pastri" => "/fixed_huffman/zstd",
                        "aps" => "",
                        _ => "/linear/huffman/zstd",
                    };
                    let s = format!("{head}{tail}");
                    PipelineSpec::parse(&s).unwrap_or_else(|e| panic!("{s}: {e}"));
                }
                "quantizer" => {
                    let s = format!("lorenzo/{}/huffman/zstd", info.token);
                    PipelineSpec::parse(&s).unwrap_or_else(|e| panic!("{s}: {e}"));
                }
                "encoder" => {
                    let s = format!("lorenzo/linear/{}/zstd", info.token);
                    PipelineSpec::parse(&s).unwrap_or_else(|e| panic!("{s}: {e}"));
                }
                "lossless" => {
                    let s = format!("lorenzo/linear/huffman/{}", info.token);
                    PipelineSpec::parse(&s).unwrap_or_else(|e| panic!("{s}: {e}"));
                }
                "preprocessor" => {
                    let s = format!("{}/lorenzo/linear/huffman/zstd", info.token);
                    PipelineSpec::parse(&s).unwrap_or_else(|e| panic!("{s}: {e}"));
                }
                other => panic!("unknown catalog kind {other}"),
            }
        }
    }
}
