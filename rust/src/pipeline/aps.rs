//! SZ3-APS (paper §5): the adaptive pipeline for APS ptychography stacks.
//!
//! The data is a time stack of diffraction frames with strong temporal and
//! weak spatial correlation. The pipeline switches on the error bound:
//!
//! * `eb >= 0.5` — high-bound regime: the 3-D blockwise Lorenzo⊕regression
//!   compressor exploits what multidimensional correlation there is.
//! * `eb < 0.5` — near-lossless regime: transpose time-last, treat the
//!   field as y·x 1-D time series, 1-D Lorenzo + unpred-aware quantizer +
//!   fixed Huffman + zstd. For integer-valued detector counts the bin-width-1
//!   quantization recovers values *exactly*, so decompression noise is zero
//!   (the paper's lossless/infinite-PSNR case) — exactly why the generic
//!   SZ2.1 noise estimate mis-selects regression here (§5.3).

use super::block::BlockCompressor;
use super::point::{PredictorKind, PreprocessorKind, QuantizerKind, SzCompressor};
use super::{CompressConf, Compressor, ErrorBound, StreamHeader};
use crate::byteio::{ByteReader, ByteWriter};
use crate::data::{Field, FieldValues};
use crate::error::{Result, SzError};
use crate::preprocessor::{Preprocessor, Transpose};

/// Adaptive APS compressor.
pub struct ApsCompressor {
    /// Stream-header identity (canonical spec for spec-built instances,
    /// the legacy `sz3-aps` for [`Default`]).
    pub name: String,
    /// Error-bound threshold that flips the pipeline (paper: 0.5).
    pub switch_eb: f64,
}

impl Default for ApsCompressor {
    fn default() -> Self {
        ApsCompressor { name: "sz3-aps".to_string(), switch_eb: 0.5 }
    }
}

fn is_integer_valued(field: &Field) -> bool {
    match &field.values {
        FieldValues::I32(_) => true,
        FieldValues::F32(v) => v.iter().all(|x| x.fract() == 0.0 && x.abs() < 1e7),
        FieldValues::F64(v) => v.iter().all(|x| x.fract() == 0.0 && x.abs() < 1e15),
    }
}

fn time_series_pipeline() -> SzCompressor {
    SzCompressor::custom(
        "aps-inner-1d",
        PreprocessorKind::Linearize,
        PredictorKind::Lorenzo(1),
        QuantizerKind::UnpredAware,
        "fixed_huffman",
        "zstd",
    )
}

impl Compressor for ApsCompressor {
    fn name(&self) -> &str {
        &self.name
    }

    fn compress(&self, field: &Field, conf: &CompressConf) -> Result<Vec<u8>> {
        let eb = conf.bound.to_abs(field)?;
        let mut w = ByteWriter::new();
        StreamHeader::for_field(&self.name, field).write(&mut w);
        if eb < self.switch_eb && field.shape.ndim() >= 2 {
            // near-lossless regime: transpose time-last + 1-D Lorenzo
            w.put_u8(1);
            let mut tfield = field.clone();
            let mut tconf = conf.clone();
            let perm: Vec<usize> = (1..field.shape.ndim()).chain([0]).collect();
            let tr = Transpose::new(perm);
            let state = tr.process(&mut tfield, &mut tconf)?;
            w.put_block(&state);
            // integer-valued counts: bin width 1 recovers exactly; keep the
            // user's bound otherwise.
            let eff_eb = if is_integer_valued(&tfield) { 0.5 } else { eb };
            let inner_conf = CompressConf::with_radius(ErrorBound::Abs(eff_eb), conf.radius);
            let inner = time_series_pipeline().compress(&tfield, &inner_conf)?;
            w.put_block(&inner);
        } else {
            // high-bound regime: 3-D blockwise Lorenzo⊕regression
            w.put_u8(0);
            let inner = BlockCompressor::sz3_lr()
                .compress(field, &CompressConf::with_radius(ErrorBound::Abs(eb), conf.radius))?;
            w.put_block(&inner);
        }
        Ok(w.finish())
    }

    fn decompress(&self, stream: &[u8]) -> Result<Field> {
        let mut r = ByteReader::new(stream);
        let header = StreamHeader::read(&mut r)?;
        let mode = r.get_u8()?;
        match mode {
            1 => {
                let state = r.get_block()?.to_vec();
                let inner = r.get_block()?;
                let mut field = time_series_pipeline().decompress(inner)?;
                // postprocess with any Transpose instance: the permutation
                // travels in the state bytes
                Transpose::new(vec![0]).postprocess(&mut field, &state)?;
                field.name = header.field_name;
                Ok(field)
            }
            0 => {
                let inner = r.get_block()?;
                let mut field = BlockCompressor::sz3_lr().decompress(inner)?;
                field.name = header.field_name;
                Ok(field)
            }
            _ => Err(SzError::corrupt("aps: unknown mode")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::test_support::roundtrip_bound_check;
    use crate::util::rng::Pcg32;

    /// Miniature APS-like stack: (time, y, x) Poisson counts of a decaying
    /// radial pattern that drifts slowly in time.
    pub fn aps_like(rng: &mut Pcg32, t: usize, h: usize, w: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(t * h * w);
        for ti in 0..t {
            let drift = (ti as f64 * 0.01).sin() * 2.0;
            for y in 0..h {
                for x in 0..w {
                    let dy = y as f64 - h as f64 / 2.0 + drift;
                    let dx = x as f64 - w as f64 / 2.0;
                    let r2 = (dy * dy + dx * dx) / (h as f64 * w as f64 / 16.0);
                    let intensity = 400.0 * (-r2).exp() + 0.2;
                    out.push(rng.poisson(intensity) as f32);
                }
            }
        }
        out
    }

    #[test]
    fn near_lossless_mode_is_exact_on_counts() {
        let mut rng = Pcg32::seeded(61);
        let data = aps_like(&mut rng, 16, 12, 12);
        let f = Field::f32("pillar", &[16, 12, 12], data.clone()).unwrap();
        let conf = CompressConf::new(ErrorBound::Abs(0.1)); // < 0.5 => mode 1
        let c = ApsCompressor::default();
        let stream = c.compress(&f, &conf).unwrap();
        let out = c.decompress(&stream).unwrap();
        assert_eq!(out.values, f.values, "integer counts must be exact");
    }

    #[test]
    fn high_bound_mode_roundtrips() {
        let mut rng = Pcg32::seeded(62);
        let data = aps_like(&mut rng, 12, 12, 12);
        let f = Field::f32("chip", &[12, 12, 12], data).unwrap();
        let conf = CompressConf::new(ErrorBound::Abs(4.0)); // >= 0.5 => mode 0
        roundtrip_bound_check(&ApsCompressor::default(), &f, &conf);
    }

    #[test]
    fn non_integer_data_respects_user_bound_in_mode_1() {
        let mut rng = Pcg32::seeded(63);
        let data: Vec<f32> =
            aps_like(&mut rng, 8, 8, 8).iter().map(|&x| x + 0.25).collect();
        let f = Field::f32("frac", &[8, 8, 8], data).unwrap();
        let conf = CompressConf::new(ErrorBound::Abs(0.05));
        roundtrip_bound_check(&ApsCompressor::default(), &f, &conf);
    }
}
