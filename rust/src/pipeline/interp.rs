//! SZ3-Interp (paper §6.2): interpolation-based prediction [17]. Data is
//! decompressed level-by-level on dyadic grids: each level halves the
//! stride and predicts the new points by linear or cubic spline
//! interpolation *along one axis at a time* from already-recovered points.
//!
//! Compared with Lorenzo, interpolation has no error-accumulation chain and
//! stores no per-block coefficients, which is why it dominates at low bit
//! rates (paper Fig. 7).

use super::{CompressConf, Compressor, StreamHeader};
use crate::byteio::{ByteReader, ByteWriter};
use crate::data::{Field, FieldValues, Scalar, Shape};
use crate::encoder::{self, Encoder};
use crate::error::{Result, SzError};
use crate::lossless;
use crate::quantizer::{LinearQuantizer, Quantizer};

/// Interpolation basis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InterpMode {
    /// Midpoint average of the two stride-neighbors.
    Linear,
    /// 4-point cubic spline `(9(b+c) - (a+d)) / 16`.
    Cubic,
}

/// Level-by-level interpolation compressor.
pub struct InterpCompressor {
    /// Stream-header identity (canonical spec for spec-built instances,
    /// the legacy `sz3-interp` for [`Default`]).
    pub name: String,
    /// Interpolation basis (cubic by default, as in [17]).
    pub mode: InterpMode,
    /// Encoder stage name for the quantization indices.
    pub encoder: String,
    /// Lossless backend name.
    pub lossless: String,
    /// Quantizer index-radius override (`None` = use the configured
    /// [`CompressConf::radius`]); set by `linear@rN` specs.
    pub radius: Option<u32>,
}

impl Default for InterpCompressor {
    fn default() -> Self {
        InterpCompressor {
            name: "sz3-interp".to_string(),
            mode: InterpMode::Cubic,
            encoder: "huffman".to_string(),
            lossless: "zstd".to_string(),
            radius: None,
        }
    }
}

/// Visit every point of the dyadic interpolation schedule exactly once.
/// Calls `f(flat_index, dim, stride)` for each predicted point; the anchor
/// (index 0) is visited first with `dim = usize::MAX, stride = 0`.
fn traverse<F: FnMut(usize, usize, usize)>(shape: &Shape, mut f: F) {
    let dims = shape.dims();
    let strides = shape.strides();
    let nd = dims.len();
    let max_dim = *dims.iter().max().unwrap();
    let mut levels = 0u32;
    while (1usize << levels) < max_dim {
        levels += 1;
    }
    f(0, usize::MAX, 0);
    let mut idx = vec![0usize; nd];
    for level in (1..=levels.max(1)).rev() {
        let s = 1usize << (level - 1);
        for dim in 0..nd {
            // iterate points with idx[dim] ≡ s (mod 2s); dims before `dim`
            // at any multiple of s; dims after at multiples of 2s.
            idx.iter_mut().for_each(|v| *v = 0);
            idx[dim] = s;
            if idx[dim] >= dims[dim] {
                continue;
            }
            'outer: loop {
                let flat: usize = idx.iter().zip(strides).map(|(&i, &st)| i * st).sum();
                f(flat, dim, s);
                // advance: innermost axis last, respecting per-axis steps
                for d in (0..nd).rev() {
                    let step = if d == dim {
                        2 * s
                    } else if d < dim {
                        s
                    } else {
                        2 * s
                    };
                    idx[d] += step;
                    if idx[d] < dims[d] {
                        continue 'outer;
                    }
                    idx[d] = if d == dim { s } else { 0 };
                }
                break;
            }
        }
    }
}

/// Predict the value at `flat` by interpolating along `dim` with `stride`.
#[inline]
fn interp_predict<T: Scalar>(
    buf: &[T],
    dims: &[usize],
    strides: &[usize],
    flat: usize,
    dim: usize,
    stride: usize,
    mode: InterpMode,
) -> f64 {
    if dim == usize::MAX {
        return 0.0; // anchor
    }
    let pos = flat / strides[dim] % dims[dim];
    let len = dims[dim];
    let st = strides[dim];
    let has = |k: isize| -> bool {
        let p = pos as isize + k * stride as isize;
        p >= 0 && (p as usize) < len
    };
    let at = |k: isize| -> f64 {
        let off = (flat as isize + k * (stride * st) as isize) as usize;
        buf[off].to_f64()
    };
    let lo = has(-1);
    let hi = has(1);
    match (lo, hi) {
        (true, true) => {
            if mode == InterpMode::Cubic && has(-3) && has(3) {
                (9.0 * (at(-1) + at(1)) - (at(-3) + at(3))) / 16.0
            } else {
                0.5 * (at(-1) + at(1))
            }
        }
        (true, false) => at(-1),
        (false, true) => at(1),
        (false, false) => 0.0,
    }
}

impl InterpCompressor {
    fn compress_typed<T: Scalar>(
        &self,
        values: &mut [T],
        shape: &Shape,
        eb: f64,
        radius: u32,
        w: &mut ByteWriter,
    ) -> Result<()> {
        let mut quantizer = LinearQuantizer::<T>::with_radius(eb, radius);
        let mut indices = Vec::with_capacity(shape.len());
        let dims = shape.dims().to_vec();
        let strides = shape.strides().to_vec();
        let mode = self.mode;
        // Safety: traverse visits disjoint indices; we mutate through a raw
        // pointer because the closure needs &buf for neighbor reads and
        // writes to the visited cell only.
        let buf_ptr = values.as_mut_ptr();
        let buf_len = values.len();
        traverse(shape, |flat, dim, stride| {
            // The shared view is dropped before the single-cell write, so the
            // raw-pointer accesses never alias a live reference.
            let (pred, cur) = {
                let buf = unsafe { std::slice::from_raw_parts(buf_ptr, buf_len) };
                (interp_predict(buf, &dims, &strides, flat, dim, stride, mode), buf[flat])
            };
            let (qi, rec) = quantizer.quantize(cur, pred);
            indices.push(qi);
            unsafe { *buf_ptr.add(flat) = rec };
        });
        debug_assert_eq!(indices.len(), shape.len());
        let ll = lossless::by_name(&self.lossless)
            .ok_or_else(|| SzError::config(format!("unknown lossless {}", self.lossless)))?;
        let enc = encoder::by_name(&self.encoder, radius)
            .ok_or_else(|| SzError::config(format!("unknown encoder {}", self.encoder)))?;
        let mut inner = ByteWriter::new();
        inner.put_u8(match self.mode {
            InterpMode::Linear => 0,
            InterpMode::Cubic => 1,
        });
        quantizer.save(&mut inner)?;
        enc.encode(&indices, &mut inner)?;
        w.put_block(&ll.compress(&inner.finish())?);
        Ok(())
    }

    fn decompress_typed<T: Scalar>(
        &self,
        shape: &Shape,
        radius: u32,
        r: &mut ByteReader,
    ) -> Result<Vec<T>> {
        let ll = lossless::by_name(&self.lossless)
            .ok_or_else(|| SzError::config(format!("unknown lossless {}", self.lossless)))?;
        let enc = encoder::by_name(&self.encoder, radius)
            .ok_or_else(|| SzError::config(format!("unknown encoder {}", self.encoder)))?;
        let inner = ll.decompress(r.get_block()?)?;
        let mut ir = ByteReader::new(&inner);
        let mode = match ir.get_u8()? {
            0 => InterpMode::Linear,
            1 => InterpMode::Cubic,
            _ => return Err(SzError::corrupt("bad interp mode")),
        };
        let mut quantizer = LinearQuantizer::<T>::with_radius(1.0, radius);
        quantizer.load(&mut ir)?;
        let indices = enc.decode(&mut ir, shape.len())?;
        let mut values = vec![T::zero(); shape.len()];
        let dims = shape.dims().to_vec();
        let strides = shape.strides().to_vec();
        let buf_ptr = values.as_mut_ptr();
        let buf_len = values.len();
        let mut pos = 0usize;
        traverse(shape, |flat, dim, stride| {
            let pred = {
                let buf = unsafe { std::slice::from_raw_parts(buf_ptr, buf_len) };
                interp_predict(buf, &dims, &strides, flat, dim, stride, mode)
            };
            let rec = quantizer.recover(pred, indices[pos]);
            pos += 1;
            unsafe { *buf_ptr.add(flat) = rec };
        });
        Ok(values)
    }
}

impl Compressor for InterpCompressor {
    fn name(&self) -> &str {
        &self.name
    }

    fn compress(&self, field: &Field, conf: &CompressConf) -> Result<Vec<u8>> {
        let eb = conf.bound.to_abs(field)?;
        let radius = self.radius.unwrap_or(conf.radius);
        let mut w = ByteWriter::new();
        StreamHeader::for_field(&self.name, field).write(&mut w);
        w.put_u32(radius);
        match &field.values {
            FieldValues::F32(v) => {
                let mut buf = v.clone();
                self.compress_typed::<f32>(&mut buf, &field.shape, eb, radius, &mut w)?
            }
            FieldValues::F64(v) => {
                let mut buf = v.clone();
                self.compress_typed::<f64>(&mut buf, &field.shape, eb, radius, &mut w)?
            }
            FieldValues::I32(v) => {
                let mut buf = v.clone();
                self.compress_typed::<i32>(&mut buf, &field.shape, eb, radius, &mut w)?
            }
        }
        Ok(w.finish())
    }

    fn decompress(&self, stream: &[u8]) -> Result<Field> {
        let mut r = ByteReader::new(stream);
        let header = StreamHeader::read(&mut r)?;
        let radius = r.get_u32()?;
        let shape = Shape::new(&header.dims)?;
        let values = match header.dtype.as_str() {
            "f32" => FieldValues::F32(self.decompress_typed::<f32>(&shape, radius, &mut r)?),
            "f64" => FieldValues::F64(self.decompress_typed::<f64>(&shape, radius, &mut r)?),
            "i32" => FieldValues::I32(self.decompress_typed::<i32>(&shape, radius, &mut r)?),
            other => return Err(SzError::corrupt(format!("unknown dtype {other}"))),
        };
        Field::new(header.field_name, &header.dims, values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::test_support::roundtrip_bound_check;
    use crate::pipeline::ErrorBound;
    use crate::util::prop;

    #[test]
    fn traverse_covers_every_point_once() {
        for dims in [vec![1usize], vec![7usize], vec![8usize, 8], vec![5usize, 9, 3],
                     vec![2usize, 2, 2, 2], vec![16usize, 1, 5]] {
            let shape = Shape::new(&dims).unwrap();
            let mut seen = vec![0u32; shape.len()];
            traverse(&shape, |flat, _, _| seen[flat] += 1);
            assert!(
                seen.iter().all(|&c| c == 1),
                "dims {dims:?}: coverage {:?}",
                seen.iter().filter(|&&c| c != 1).count()
            );
        }
    }

    #[test]
    fn roundtrip_smooth_beats_lr_at_low_bitrate() {
        let mut rng = crate::util::rng::Pcg32::seeded(41);
        let dims = [32usize, 32, 32];
        let data = prop::smooth_field(&mut rng, &dims);
        let f = Field::f32("cube", &dims, data).unwrap();
        let conf = CompressConf::new(ErrorBound::Rel(1e-2)); // high eb / low bitrate
        let ri = roundtrip_bound_check(&InterpCompressor::default(), &f, &conf);
        let rl = roundtrip_bound_check(&super::super::BlockCompressor::sz3_lr(), &f, &conf);
        assert!(
            ri > rl * 0.8,
            "interp should be competitive at low bitrate: interp {ri} lr {rl}"
        );
    }

    #[test]
    fn linear_mode_roundtrip() {
        let mut rng = crate::util::rng::Pcg32::seeded(42);
        let dims = [50usize, 40];
        let data = prop::smooth_field(&mut rng, &dims);
        let f = Field::f32("lin", &dims, data).unwrap();
        let c = InterpCompressor { mode: InterpMode::Linear, ..Default::default() };
        let conf = CompressConf::new(ErrorBound::Abs(1e-3));
        roundtrip_bound_check(&c, &f, &conf);
    }

    #[test]
    fn prop_bound_holds_arbitrary_dims() {
        prop::cases(15, 0x1e7, |rng| {
            let nd = rng.below(3) + 1;
            let dims: Vec<usize> = (0..nd).map(|_| rng.below(20) + 1).collect();
            let data = prop::smooth_field(rng, &dims);
            let f = Field::f32("nd", &dims, data).unwrap();
            let eb = 10f64.powf(rng.uniform(-5.0, -1.0));
            let conf = CompressConf::new(ErrorBound::Abs(eb));
            roundtrip_bound_check(&InterpCompressor::default(), &f, &conf);
        });
    }
}
