//! The general point-by-point compressor — a literal implementation of the
//! paper's Algorithm 1: for every point, predict → quantize → (write back
//! the recovered value) → encode → lossless.
//!
//! Module instances are selected by name/kind, mirroring the paper's
//! template composition (`SZ_Compressor<T, N, Preprocessor, Predictor,
//! Quantizer, Encoder, Lossless>`): any [`Predictor`], [`Quantizer`],
//! [`Encoder`] and [`Lossless`] combination is a valid pipeline.

use super::{CompressConf, Compressor, StreamHeader};
use crate::byteio::{ByteReader, ByteWriter};
use crate::data::{Field, FieldValues, NdCursor, Scalar, Shape};
use crate::encoder::{self, Encoder};
use crate::error::{Result, SzError};
use crate::lossless::{self, Lossless};
use crate::predictor::{LorenzoPredictor, Predictor, ZeroPredictor};
use crate::preprocessor::{Identity, Linearize, Preprocessor};
use crate::quantizer::{
    LinearQuantizer, LogScaleQuantizer, Quantizer, UnpredAwareQuantizer,
};

/// Predictor selection for the point pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PredictorKind {
    /// Order-`n` Lorenzo (dimensionality taken from the data).
    Lorenzo(u32),
    /// Always-zero baseline.
    Zero,
}

impl PredictorKind {
    fn build<T: Scalar>(self, ndim: usize) -> Box<dyn Predictor<T>> {
        match self {
            PredictorKind::Lorenzo(order) => {
                Box::new(LorenzoPredictor::with_order(ndim, order))
            }
            PredictorKind::Zero => Box::new(ZeroPredictor),
        }
    }

    /// Display name for logs and diagnostics.
    pub fn tag(self) -> &'static str {
        match self {
            PredictorKind::Lorenzo(1) => "lorenzo",
            PredictorKind::Lorenzo(2) => "lorenzo2",
            PredictorKind::Lorenzo(_) => "lorenzoN",
            PredictorKind::Zero => "zero",
        }
    }
}

/// Quantizer selection for the point pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantizerKind {
    /// Linear-scaling quantizer.
    Linear,
    /// Geometric-then-linear binning.
    LogScale,
    /// Linear with bitplane-coded unpredictables (§4.2).
    UnpredAware,
}

impl QuantizerKind {
    fn build<T: Scalar>(self, eb: f64, radius: u32) -> Box<dyn Quantizer<T>> {
        match self {
            QuantizerKind::Linear => Box::new(LinearQuantizer::with_radius(eb, radius)),
            QuantizerKind::LogScale => Box::new(LogScaleQuantizer::new(eb, radius)),
            QuantizerKind::UnpredAware => Box::new(UnpredAwareQuantizer::new(eb, radius)),
        }
    }

    fn tag(self) -> u8 {
        match self {
            QuantizerKind::Linear => 0,
            QuantizerKind::LogScale => 1,
            QuantizerKind::UnpredAware => 2,
        }
    }

    fn from_tag(t: u8) -> Result<Self> {
        match t {
            0 => Ok(QuantizerKind::Linear),
            1 => Ok(QuantizerKind::LogScale),
            2 => Ok(QuantizerKind::UnpredAware),
            _ => Err(SzError::corrupt("unknown quantizer tag")),
        }
    }
}

/// Preprocessor selection (only stateless, name-reconstructible ones here;
/// pipelines needing parameterized preprocessors embed them directly).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PreprocessorKind {
    /// No preprocessing.
    Identity,
    /// Reshape to 1-D.
    Linearize,
    /// Pointwise-relative → absolute bounds via `ln|x|` (spec prefix
    /// `log/`).
    Log,
}

impl PreprocessorKind {
    fn build(self) -> Box<dyn Preprocessor> {
        match self {
            PreprocessorKind::Identity => Box::new(Identity),
            PreprocessorKind::Linearize => Box::new(Linearize),
            PreprocessorKind::Log => {
                Box::new(crate::preprocessor::LogTransform::default())
            }
        }
    }

    fn tag(self) -> u8 {
        match self {
            PreprocessorKind::Identity => 0,
            PreprocessorKind::Linearize => 1,
            PreprocessorKind::Log => 2,
        }
    }

    fn from_tag(t: u8) -> Result<Self> {
        match t {
            0 => Ok(PreprocessorKind::Identity),
            1 => Ok(PreprocessorKind::Linearize),
            2 => Ok(PreprocessorKind::Log),
            _ => Err(SzError::corrupt("unknown preprocessor tag")),
        }
    }
}

/// Composed point-by-point pipeline (Algorithm 1).
pub struct SzCompressor {
    /// Stream-header identity (canonical spec for spec-built instances,
    /// legacy registry name for the historical constructors).
    pub name: String,
    /// Preprocessor stage.
    pub preprocessor: PreprocessorKind,
    /// Predictor stage.
    pub predictor: PredictorKind,
    /// Quantizer stage.
    pub quantizer: QuantizerKind,
    /// Encoder stage (by name: "huffman", "fixed_huffman", "arithmetic", "raw").
    pub encoder: String,
    /// Lossless stage (by name: "zstd", "gzip", "lzhuf", "rle", "bypass").
    pub lossless: String,
    /// Quantizer index-radius override (`None` = use the configured
    /// [`CompressConf::radius`]); set by `linear@rN` specs.
    pub radius: Option<u32>,
}

impl SzCompressor {
    /// Fully custom composition.
    pub fn custom(
        name: impl Into<String>,
        preprocessor: PreprocessorKind,
        predictor: PredictorKind,
        quantizer: QuantizerKind,
        encoder: impl Into<String>,
        lossless: impl Into<String>,
    ) -> Self {
        SzCompressor {
            name: name.into(),
            preprocessor,
            predictor,
            quantizer,
            encoder: encoder.into(),
            lossless: lossless.into(),
            radius: None,
        }
    }

    /// 1-D Lorenzo pipeline (linearized), SZ1.4-flavored.
    pub fn lorenzo_1d() -> Self {
        Self::custom(
            "lorenzo-1d",
            PreprocessorKind::Linearize,
            PredictorKind::Lorenzo(1),
            QuantizerKind::Linear,
            "huffman",
            "zstd",
        )
    }

    /// FPZIP-like pipeline (paper Fig. 1): no preprocessing, Lorenzo,
    /// arithmetic coding, no separate lossless stage.
    pub fn fpzip_like() -> Self {
        Self::custom(
            "fpzip-like",
            PreprocessorKind::Identity,
            PredictorKind::Lorenzo(1),
            QuantizerKind::Linear,
            "arithmetic",
            "bypass",
        )
    }

    fn compress_typed<T: Scalar>(
        &self,
        values: &mut [T],
        shape: &Shape,
        eb: f64,
        radius: u32,
        w: &mut ByteWriter,
    ) -> Result<()> {
        let predictor: Box<dyn Predictor<T>> = self.predictor.build(shape.ndim());
        let mut quantizer: Box<dyn Quantizer<T>> = self.quantizer.build(eb, radius);
        let enc = encoder::by_name(&self.encoder, radius)
            .ok_or_else(|| SzError::config(format!("unknown encoder {}", self.encoder)))?;
        let ll = lossless::by_name(&self.lossless)
            .ok_or_else(|| SzError::config(format!("unknown lossless {}", self.lossless)))?;

        let n = shape.len();
        let mut indices = Vec::with_capacity(n);
        let mut cursor = NdCursor::new(values, shape);
        loop {
            let pred = predictor.predict(&cursor);
            let (idx, rec) = quantizer.quantize(cursor.value(), pred);
            indices.push(idx);
            cursor.set(rec);
            if !cursor.advance() {
                break;
            }
        }
        // inner body: predictor meta, quantizer meta (incl. unpredictables),
        // encoded indices — all wrapped by the lossless stage (Algorithm 1
        // lines 6-11).
        let mut inner = ByteWriter::new();
        predictor.save(&mut inner)?;
        quantizer.save(&mut inner)?;
        enc.encode(&indices, &mut inner)?;
        let packed = ll.compress(&inner.finish())?;
        w.put_block(&packed);
        Ok(())
    }

    fn decompress_typed<T: Scalar>(
        &self,
        shape: &Shape,
        radius: u32,
        r: &mut ByteReader,
    ) -> Result<Vec<T>> {
        let ll = lossless::by_name(&self.lossless)
            .ok_or_else(|| SzError::config(format!("unknown lossless {}", self.lossless)))?;
        let enc = encoder::by_name(&self.encoder, radius)
            .ok_or_else(|| SzError::config(format!("unknown encoder {}", self.encoder)))?;
        let inner = ll.decompress(r.get_block()?)?;
        let mut ir = ByteReader::new(&inner);
        let mut predictor: Box<dyn Predictor<T>> = self.predictor.build(shape.ndim());
        predictor.load(&mut ir)?;
        // quantizer params are self-describing via load
        let mut quantizer: Box<dyn Quantizer<T>> = self.quantizer.build(1.0, radius);
        quantizer.load(&mut ir)?;
        let n = shape.len();
        let indices = enc.decode(&mut ir, n)?;
        let mut values = vec![T::zero(); n];
        let mut cursor = NdCursor::new(&mut values, shape);
        for &idx in &indices {
            let pred = predictor.predict(&cursor);
            let rec = quantizer.recover(pred, idx);
            cursor.set(rec);
            if !cursor.advance() {
                break;
            }
        }
        Ok(values)
    }
}

impl Compressor for SzCompressor {
    fn name(&self) -> &str {
        &self.name
    }

    fn compress(&self, field: &Field, conf: &CompressConf) -> Result<Vec<u8>> {
        let mut field = field.clone();
        let mut conf = conf.clone();
        let pre = self.preprocessor.build();
        let state = pre.process(&mut field, &mut conf)?;
        let eb = conf.bound.to_abs(&field)?;
        let radius = self.radius.unwrap_or(conf.radius);

        let mut w = ByteWriter::new();
        StreamHeader::for_field(&self.name, &field).write(&mut w);
        w.put_u8(self.preprocessor.tag());
        w.put_block(&state);
        w.put_u8(self.quantizer.tag());
        w.put_u32(radius);
        // `field` is already this function's private clone (the
        // preprocessor mutated it), so quantization can write recovered
        // values straight into it — no second full-array copy
        let shape = field.shape.clone();
        match &mut field.values {
            FieldValues::F32(v) => {
                self.compress_typed::<f32>(v, &shape, eb, radius, &mut w)?
            }
            FieldValues::F64(v) => {
                self.compress_typed::<f64>(v, &shape, eb, radius, &mut w)?
            }
            FieldValues::I32(v) => {
                self.compress_typed::<i32>(v, &shape, eb, radius, &mut w)?
            }
        }
        Ok(w.finish())
    }

    fn decompress(&self, stream: &[u8]) -> Result<Field> {
        let mut r = ByteReader::new(stream);
        let header = StreamHeader::read(&mut r)?;
        let pre_kind = PreprocessorKind::from_tag(r.get_u8()?)?;
        let state = r.get_block()?.to_vec();
        let _qtag = QuantizerKind::from_tag(r.get_u8()?)?;
        let radius = r.get_u32()?;
        let shape = Shape::new(&header.dims)?;
        let values = match header.dtype.as_str() {
            "f32" => FieldValues::F32(self.decompress_typed::<f32>(&shape, radius, &mut r)?),
            "f64" => FieldValues::F64(self.decompress_typed::<f64>(&shape, radius, &mut r)?),
            "i32" => FieldValues::I32(self.decompress_typed::<i32>(&shape, radius, &mut r)?),
            other => return Err(SzError::corrupt(format!("unknown dtype {other}"))),
        };
        let mut field = Field::new(header.field_name, &header.dims, values)?;
        pre_kind.build().postprocess(&mut field, &state)?;
        Ok(field)
    }
}

/// Compile-time composed variant — the paper's template polymorphism
/// (Appendix A.6) expressed with Rust generics. Zero dynamic dispatch in
/// the hot loop; used by the performance-oriented paths and benches.
pub struct StaticSzCompressor<T, P, Q, E, L>
where
    T: Scalar,
    P: Predictor<T>,
    Q: Quantizer<T>,
    E: Encoder,
    L: Lossless,
{
    /// Predictor instance.
    pub predictor: P,
    /// Quantizer instance.
    pub quantizer: Q,
    /// Encoder instance.
    pub encoder: E,
    /// Lossless instance.
    pub lossless: L,
    _t: std::marker::PhantomData<T>,
}

impl<T, P, Q, E, L> StaticSzCompressor<T, P, Q, E, L>
where
    T: Scalar,
    P: Predictor<T>,
    Q: Quantizer<T>,
    E: Encoder,
    L: Lossless,
{
    /// Compose a static pipeline from instances.
    pub fn new(predictor: P, quantizer: Q, encoder: E, lossless: L) -> Self {
        StaticSzCompressor {
            predictor,
            quantizer,
            encoder,
            lossless,
            _t: std::marker::PhantomData,
        }
    }

    /// Compress `values` shaped by `shape`; fully static dispatch.
    pub fn compress(&mut self, values: &mut [T], shape: &Shape) -> Result<Vec<u8>> {
        self.quantizer.reset();
        let mut indices = Vec::with_capacity(shape.len());
        let mut cursor = NdCursor::new(values, shape);
        loop {
            let pred = self.predictor.predict(&cursor);
            let (idx, rec) = self.quantizer.quantize(cursor.value(), pred);
            indices.push(idx);
            cursor.set(rec);
            if !cursor.advance() {
                break;
            }
        }
        let mut inner = ByteWriter::new();
        self.predictor.save(&mut inner)?;
        self.quantizer.save(&mut inner)?;
        self.encoder.encode(&indices, &mut inner)?;
        self.lossless.compress(&inner.finish())
    }

    /// Decompress into a buffer shaped by `shape`.
    pub fn decompress(&mut self, stream: &[u8], shape: &Shape) -> Result<Vec<T>> {
        let inner = self.lossless.decompress(stream)?;
        let mut ir = ByteReader::new(&inner);
        self.predictor.load(&mut ir)?;
        self.quantizer.load(&mut ir)?;
        let indices = self.encoder.decode(&mut ir, shape.len())?;
        let mut values = vec![T::zero(); shape.len()];
        let mut cursor = NdCursor::new(&mut values, shape);
        for &idx in &indices {
            let pred = self.predictor.predict(&cursor);
            let rec = self.quantizer.recover(pred, idx);
            cursor.set(rec);
            if !cursor.advance() {
                break;
            }
        }
        Ok(values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::test_support::roundtrip_bound_check;
    use crate::pipeline::ErrorBound;
    use crate::util::prop;

    #[test]
    fn lorenzo_1d_roundtrip_smooth() {
        let vals: Vec<f32> = (0..4096).map(|i| (i as f32 * 0.01).sin() * 50.0).collect();
        let f = Field::f32("sine", &[4096], vals).unwrap();
        let conf = CompressConf::new(ErrorBound::Abs(1e-2));
        let ratio = roundtrip_bound_check(&SzCompressor::lorenzo_1d(), &f, &conf);
        assert!(ratio > 4.0, "ratio {ratio}");
    }

    #[test]
    fn fpzip_like_roundtrip_3d() {
        let mut rng = crate::util::rng::Pcg32::seeded(21);
        let data = prop::smooth_field(&mut rng, &[16, 16, 16]);
        let f = Field::f32("cube", &[16, 16, 16], data).unwrap();
        let conf = CompressConf::new(ErrorBound::Rel(1e-3));
        roundtrip_bound_check(&SzCompressor::fpzip_like(), &f, &conf);
    }

    #[test]
    fn prop_all_module_combinations_respect_bound() {
        // The composability claim: every (predictor, quantizer, encoder,
        // lossless) combination must produce a valid error-bounded codec.
        let preds = [PredictorKind::Lorenzo(1), PredictorKind::Lorenzo(2), PredictorKind::Zero];
        let quants =
            [QuantizerKind::Linear, QuantizerKind::LogScale, QuantizerKind::UnpredAware];
        let encs = ["huffman", "arithmetic", "raw"];
        let lls = ["zstd", "bypass", "lzhuf"];
        prop::cases(10, 0xa11, |rng| {
            let dims = [rng.below(6) + 3, rng.below(6) + 3];
            let data = prop::smooth_field(rng, &dims);
            let f = Field::f32("combo", &dims, data).unwrap();
            let eb = 10f64.powf(rng.uniform(-4.0, -1.0));
            let conf = CompressConf::with_radius(ErrorBound::Abs(eb), 512);
            let p = preds[rng.below(preds.len())];
            let q = quants[rng.below(quants.len())];
            let e = encs[rng.below(encs.len())];
            let l = lls[rng.below(lls.len())];
            let c = SzCompressor::custom("lorenzo-1d", PreprocessorKind::Identity, p, q, e, l);
            // name reuse is fine: decompress dispatches through the same
            // module tags stored in the stream
            let stream = c.compress(&f, &conf).unwrap();
            let out = c.decompress(&stream).unwrap();
            let orig = f.values.to_f64_vec();
            let dec = out.values.to_f64_vec();
            for (o, d) in orig.iter().zip(dec.iter()) {
                assert!((o - d).abs() <= eb * (1.0 + 1e-12), "p={p:?} q={q:?} e={e} l={l}");
            }
        });
    }

    #[test]
    fn static_composition_matches_dynamic() {
        use crate::encoder::HuffmanEncoder;
        use crate::lossless::Bypass;
        use crate::predictor::LorenzoPredictor;
        use crate::quantizer::LinearQuantizer;
        let mut rng = crate::util::rng::Pcg32::seeded(5);
        let dims = [32usize, 32];
        let data = prop::smooth_field(&mut rng, &dims);
        let shape = Shape::new(&dims).unwrap();
        let mut stat = StaticSzCompressor::new(
            LorenzoPredictor::new(2),
            LinearQuantizer::<f32>::with_radius(1e-3, 32768),
            HuffmanEncoder::new(),
            Bypass,
        );
        let mut buf = data.clone();
        let stream = stat.compress(&mut buf, &shape).unwrap();
        let out = stat.decompress(&stream, &shape).unwrap();
        for (o, d) in data.iter().zip(out.iter()) {
            assert!((o - d).abs() <= 1e-3 * (1.0 + 1e-12));
        }
    }

    #[test]
    fn i32_fields_supported() {
        let vals: Vec<i32> = (0..1000).map(|i| (i % 50) * 3).collect();
        let f = Field::new("ints", &[1000], FieldValues::I32(vals)).unwrap();
        let conf = CompressConf::new(ErrorBound::Abs(0.5));
        // eb=0.5 on integers => lossless
        let c = SzCompressor::lorenzo_1d();
        let stream = c.compress(&f, &conf).unwrap();
        let out = c.decompress(&stream).unwrap();
        assert_eq!(out.values, f.values);
    }
}
