//! SZ3-Truncation (paper §6.2): the speed-first pipeline. Keeps the `k`
//! most-significant bytes of every float and discards the rest, bypassing
//! prediction, quantization and encoding entirely (the paper's "module
//! bypass" tradeoff). ~GB/s throughput, low ratio, and an error bound that
//! follows from the IEEE-754 mantissa truncation.
//!
//! Note: truncation provides a *relative*-style guarantee (mantissa bits),
//! so `compress` derives the per-field worst-case absolute error and
//! refuses configurations it cannot honor. The byte planes are stored
//! plane-major (all byte-0s, then byte-1s, ...) which helps the optional
//! lossless stage.

use super::{CompressConf, Compressor, StreamHeader};
use crate::byteio::{ByteReader, ByteWriter};
use crate::data::{Field, FieldValues};
use crate::error::{Result, SzError};
use crate::lossless;

/// Byte-truncation compressor.
pub struct TruncationCompressor {
    /// Stream-header identity (canonical spec for spec-built instances,
    /// the legacy `sz3-truncation` for [`Default`]).
    pub name: String,
    /// How many most-significant bytes to keep (1..=3 for f32, 1..=7 f64).
    /// `None` = derive the smallest k that satisfies the requested bound.
    pub keep_bytes: Option<usize>,
    /// Optional lossless stage ("bypass" for max speed, the default).
    pub lossless: String,
}

impl Default for TruncationCompressor {
    fn default() -> Self {
        TruncationCompressor {
            name: "sz3-truncation".to_string(),
            keep_bytes: None,
            lossless: "bypass".to_string(),
        }
    }
}

/// Worst-case absolute error of keeping `keep` of `total` bytes, given the
/// largest exponent present in the data: dropping `b` low bytes of the
/// mantissa changes the value by < 2^(8b) ulps.
pub(super) fn truncation_abs_error(max_abs: f64, total: usize, keep: usize) -> f64 {
    if max_abs == 0.0 {
        return 0.0;
    }
    let dropped_bits = 8 * (total - keep) as i32;
    let mant_bits = if total == 4 { 23 } else { 52 };
    let exp = max_abs.log2().floor();
    // ulp at max exponent * 2^dropped_bits
    (exp - mant_bits as f64 + dropped_bits as f64).exp2()
}

impl TruncationCompressor {
    fn pick_keep(&self, field: &Field, conf: &CompressConf) -> Result<usize> {
        let total = match &field.values {
            FieldValues::F32(_) | FieldValues::I32(_) => 4,
            FieldValues::F64(_) => 8,
        };
        if let Some(k) = self.keep_bytes {
            if k == 0 || k > total {
                return Err(SzError::config(format!("keep_bytes {k} invalid for {total}-byte data")));
            }
            return Ok(k);
        }
        let eb = conf.bound.to_abs(field)?;
        let (lo, hi) = field.value_range();
        let max_abs = lo.abs().max(hi.abs());
        let integer = matches!(field.values, FieldValues::I32(_));
        for k in 1..total {
            // integers: dropping b low bytes changes the value by < 2^(8b)
            let err = if integer {
                (8.0 * (total - k) as f64).exp2()
            } else {
                truncation_abs_error(max_abs, total, k)
            };
            if err <= eb {
                return Ok(k);
            }
        }
        Ok(total) // lossless fallback: keep everything
    }
}

/// Split `bytes_per` per-value bytes into plane-major order keeping `keep`.
/// Shared with the `constblock` family, which truncates its non-constant
/// remainder through the exact same layout.
pub(super) fn to_planes(raw: &[u8], bytes_per: usize, keep: usize) -> Vec<u8> {
    let n = raw.len() / bytes_per;
    let mut out = Vec::with_capacity(n * keep);
    // plane 0 = most significant byte (little-endian: index bytes_per-1)
    for p in 0..keep {
        let b = bytes_per - 1 - p;
        for i in 0..n {
            out.push(raw[i * bytes_per + b]);
        }
    }
    out
}

pub(super) fn from_planes(planes: &[u8], n: usize, bytes_per: usize, keep: usize) -> Vec<u8> {
    let mut raw = vec![0u8; n * bytes_per];
    for p in 0..keep {
        let b = bytes_per - 1 - p;
        for i in 0..n {
            raw[i * bytes_per + b] = planes[p * n + i];
        }
    }
    raw
}

impl Compressor for TruncationCompressor {
    fn name(&self) -> &str {
        &self.name
    }

    fn compress(&self, field: &Field, conf: &CompressConf) -> Result<Vec<u8>> {
        let keep = self.pick_keep(field, conf)?;
        let mut w = ByteWriter::new();
        StreamHeader::for_field(&self.name, field).write(&mut w);
        let (raw, bytes_per): (Vec<u8>, usize) = match &field.values {
            FieldValues::F32(v) => {
                (v.iter().flat_map(|x| x.to_le_bytes()).collect(), 4)
            }
            FieldValues::F64(v) => {
                (v.iter().flat_map(|x| x.to_le_bytes()).collect(), 8)
            }
            FieldValues::I32(v) => {
                (v.iter().flat_map(|x| x.to_le_bytes()).collect(), 4)
            }
        };
        w.put_u8(keep as u8);
        w.put_str(&self.lossless);
        let planes = to_planes(&raw, bytes_per, keep);
        let ll = lossless::by_name(&self.lossless)
            .ok_or_else(|| SzError::config(format!("unknown lossless {}", self.lossless)))?;
        w.put_block(&ll.compress(&planes)?);
        Ok(w.finish())
    }

    fn decompress(&self, stream: &[u8]) -> Result<Field> {
        let mut r = ByteReader::new(stream);
        let header = StreamHeader::read(&mut r)?;
        let keep = r.get_u8()? as usize;
        let ll_name = r.get_str()?;
        let ll = lossless::by_name(&ll_name)
            .ok_or_else(|| SzError::corrupt(format!("unknown lossless {ll_name}")))?;
        let planes = ll.decompress(r.get_block()?)?;
        let n = header.len();
        let bytes_per = match header.dtype.as_str() {
            "f32" | "i32" => 4,
            "f64" => 8,
            other => return Err(SzError::corrupt(format!("unknown dtype {other}"))),
        };
        if keep == 0 || keep > bytes_per {
            return Err(SzError::corrupt(format!(
                "keep {keep} invalid for {bytes_per}-byte data"
            )));
        }
        // Cross-check the header's element count against the decoded
        // payload before sizing any allocation from it: `to_planes` always
        // emits exactly keep·n bytes, so anything else is corruption.
        let expect = n
            .checked_mul(keep)
            .ok_or_else(|| SzError::corrupt("plane size overflows"))?;
        if planes.len() != expect {
            return Err(SzError::corrupt(format!(
                "{} plane bytes for {n} elements × {keep} kept",
                planes.len()
            )));
        }
        let values = match header.dtype.as_str() {
            "f32" => {
                let raw = from_planes(&planes, n, 4, keep);
                FieldValues::F32(
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )
            }
            "f64" => {
                let raw = from_planes(&planes, n, 8, keep);
                FieldValues::F64(
                    raw.chunks_exact(8)
                        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )
            }
            "i32" => {
                let raw = from_planes(&planes, n, 4, keep);
                FieldValues::I32(
                    raw.chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )
            }
            other => return Err(SzError::corrupt(format!("unknown dtype {other}"))),
        };
        Field::new(header.field_name, &header.dims, values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::{decompress_any, ErrorBound};
    use crate::util::prop;

    #[test]
    fn keep_all_is_lossless() {
        let vals = vec![1.5f32, -2.25, 3.0e-8, 1e20];
        let f = Field::f32("x", &[4], vals.clone()).unwrap();
        let c = TruncationCompressor { keep_bytes: Some(4), ..Default::default() };
        let conf = CompressConf::new(ErrorBound::Abs(1e-30));
        let out = decompress_any(&c.compress(&f, &conf).unwrap()).unwrap();
        assert_eq!(out.values, f.values);
    }

    #[test]
    fn derived_keep_respects_bound() {
        prop::cases(40, 0x77c, |rng| {
            let n = rng.below(500) + 1;
            let vals: Vec<f32> = (0..n).map(|_| rng.uniform(-100.0, 100.0) as f32).collect();
            let f = Field::f32("t", &[n], vals.clone()).unwrap();
            let eb = 10f64.powf(rng.uniform(-4.0, 1.0));
            let conf = CompressConf::new(ErrorBound::Abs(eb));
            let c = TruncationCompressor::default();
            let out = decompress_any(&c.compress(&f, &conf).unwrap()).unwrap();
            let dec = out.values.to_f64_vec();
            for (o, d) in vals.iter().zip(dec.iter()) {
                assert!(
                    (*o as f64 - d).abs() <= eb,
                    "err {} > {eb}",
                    (*o as f64 - d).abs()
                );
            }
        });
    }

    #[test]
    fn inflated_dims_error_not_panic() {
        // corrupt header claiming more elements than the payload carries
        // used to index past the decoded planes (or attempt a huge alloc)
        let vals: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let f = Field::f32("x", &[64], vals).unwrap();
        let c = TruncationCompressor::default();
        let stream = c.compress(&f, &CompressConf::new(ErrorBound::Abs(0.5))).unwrap();
        let mut r = ByteReader::new(&stream);
        let mut h = StreamHeader::read(&mut r).unwrap();
        let body = stream[r.pos()..].to_vec();
        for dims in [vec![65usize], vec![63], vec![1 << 30]] {
            h.dims = dims;
            let mut w = ByteWriter::new();
            h.write(&mut w);
            w.put_bytes(&body);
            assert!(decompress_any(&w.finish()).is_err());
        }
    }

    #[test]
    fn ratio_is_bytes_fraction() {
        let vals: Vec<f32> = (0..10000).map(|i| i as f32).collect();
        let f = Field::f32("r", &[10000], vals).unwrap();
        let c = TruncationCompressor { keep_bytes: Some(2), ..Default::default() };
        let conf = CompressConf::new(ErrorBound::Abs(1e9));
        let stream = c.compress(&f, &conf).unwrap();
        let ratio = f.nbytes() as f64 / stream.len() as f64;
        assert!(ratio > 1.9 && ratio < 2.1, "ratio {ratio}");
    }
}
