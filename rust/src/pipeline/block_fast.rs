//! Dimension-specialized block codecs — the SZ3-LR-s predictor module
//! (paper §6.2): "the predictor contains several codecs, each of which
//! handles data in a specific dimension". Identical math to the generic
//! multidimensional-iterator path, but with direct index arithmetic, no
//! per-point allocation, and branch-light interior fast paths.

use super::block::block_side;
use crate::data::Scalar;
use crate::predictor::RegressionFit;
use crate::quantizer::{LinearQuantizer, Quantizer};

/// Compress one 3-D block: quantize every point against the chosen
/// predictor, writing recovered values back into `values`.
#[allow(clippy::too_many_arguments)]
pub(super) fn compress_block_3d<T: Scalar>(
    values: &mut [T],
    dims: &[usize],
    origin: &[usize],
    bdims: &[usize],
    fit: Option<&RegressionFit>,
    quantizer: &mut LinearQuantizer<T>,
    indices: &mut Vec<u32>,
) {
    let (d1, d2) = (dims[1], dims[2]);
    let (o0, o1, o2) = (origin[0], origin[1], origin[2]);
    let (b0, b1, b2) = (bdims[0], bdims[1], bdims[2]);
    let s0 = d1 * d2;
    let s1 = d2;
    match fit {
        Some(f) => {
            let (c0, c1, c2, c3) =
                (f.coeffs[0], f.coeffs[1], f.coeffs[2], f.coeffs[3]);
            // Regression rows have no serial dependence, so whole rows go
            // through the bulk SIMD quantize path (bit-identical to the
            // pointwise loop — pinned by the quantize_row tests).
            let mut preds = vec![0.0f64; b2];
            let mut codes = vec![0u32; b2];
            for z in 0..b0 {
                let pz = c0 * z as f64 + c3;
                for y in 0..b1 {
                    let pzy = pz + c1 * y as f64;
                    let base = (o0 + z) * s0 + (o1 + y) * s1 + o2;
                    for (x, p) in preds.iter_mut().enumerate() {
                        *p = pzy + c2 * x as f64;
                    }
                    quantizer.quantize_row(&mut values[base..base + b2], &preds, &mut codes);
                    indices.extend_from_slice(&codes);
                }
            }
        }
        None => {
            for z in 0..b0 {
                let gz = o0 + z;
                for y in 0..b1 {
                    let gy = o1 + y;
                    let base = gz * s0 + gy * s1 + o2;
                    for x in 0..b2 {
                        let gx = o2 + x;
                        let flat = base + x;
                        // order-1 Lorenzo with zero padding at the global
                        // boundary; interior points take the branchless path
                        let pred = if gz > 0 && gy > 0 && gx > 0 {
                            let a = values[flat - 1].to_f64();
                            let b = values[flat - s1].to_f64();
                            let c = values[flat - s0].to_f64();
                            let ab = values[flat - s1 - 1].to_f64();
                            let ac = values[flat - s0 - 1].to_f64();
                            let bc = values[flat - s0 - s1].to_f64();
                            let abc = values[flat - s0 - s1 - 1].to_f64();
                            a + b + c - ab - ac - bc + abc
                        } else {
                            lorenzo3_boundary(values, gz, gy, gx, s0, s1)
                        };
                        let (qi, rec) = quantizer.quantize(values[flat], pred);
                        indices.push(qi);
                        values[flat] = rec;
                    }
                }
            }
        }
    }
}

/// Decompress one 3-D block (mirror of [`compress_block_3d`]).
#[allow(clippy::too_many_arguments)]
pub(super) fn decompress_block_3d<T: Scalar>(
    values: &mut [T],
    dims: &[usize],
    origin: &[usize],
    bdims: &[usize],
    fit: Option<&RegressionFit>,
    quantizer: &mut LinearQuantizer<T>,
    indices: &[u32],
    qpos: &mut usize,
) {
    let (d1, d2) = (dims[1], dims[2]);
    let (o0, o1, o2) = (origin[0], origin[1], origin[2]);
    let (b0, b1, b2) = (bdims[0], bdims[1], bdims[2]);
    let s0 = d1 * d2;
    let s1 = d2;
    match fit {
        Some(f) => {
            let (c0, c1, c2, c3) =
                (f.coeffs[0], f.coeffs[1], f.coeffs[2], f.coeffs[3]);
            for z in 0..b0 {
                let pz = c0 * z as f64 + c3;
                for y in 0..b1 {
                    let pzy = pz + c1 * y as f64;
                    let base = (o0 + z) * s0 + (o1 + y) * s1 + o2;
                    for x in 0..b2 {
                        let pred = pzy + c2 * x as f64;
                        values[base + x] = quantizer.recover(pred, indices[*qpos]);
                        *qpos += 1;
                    }
                }
            }
        }
        None => {
            for z in 0..b0 {
                let gz = o0 + z;
                for y in 0..b1 {
                    let gy = o1 + y;
                    let base = gz * s0 + gy * s1 + o2;
                    for x in 0..b2 {
                        let gx = o2 + x;
                        let flat = base + x;
                        let pred = if gz > 0 && gy > 0 && gx > 0 {
                            let a = values[flat - 1].to_f64();
                            let b = values[flat - s1].to_f64();
                            let c = values[flat - s0].to_f64();
                            let ab = values[flat - s1 - 1].to_f64();
                            let ac = values[flat - s0 - 1].to_f64();
                            let bc = values[flat - s0 - s1].to_f64();
                            let abc = values[flat - s0 - s1 - 1].to_f64();
                            a + b + c - ab - ac - bc + abc
                        } else {
                            lorenzo3_boundary(values, gz, gy, gx, s0, s1)
                        };
                        values[flat] = quantizer.recover(pred, indices[*qpos]);
                        *qpos += 1;
                    }
                }
            }
        }
    }
}

#[inline]
fn lorenzo3_boundary<T: Scalar>(
    values: &[T],
    gz: usize,
    gy: usize,
    gx: usize,
    s0: usize,
    s1: usize,
) -> f64 {
    let flat = gz * s0 + gy * s1 + gx;
    let at = |dz: usize, dy: usize, dx: usize| -> f64 {
        if (dz == 1 && gz == 0) || (dy == 1 && gy == 0) || (dx == 1 && gx == 0) {
            0.0
        } else {
            values[flat - dz * s0 - dy * s1 - dx].to_f64()
        }
    };
    at(0, 0, 1) + at(0, 1, 0) + at(1, 0, 0) - at(0, 1, 1) - at(1, 0, 1) - at(1, 1, 0)
        + at(1, 1, 1)
}

/// Compress one 2-D block.
#[allow(clippy::too_many_arguments)]
pub(super) fn compress_block_2d<T: Scalar>(
    values: &mut [T],
    dims: &[usize],
    origin: &[usize],
    bdims: &[usize],
    fit: Option<&RegressionFit>,
    quantizer: &mut LinearQuantizer<T>,
    indices: &mut Vec<u32>,
) {
    let s0 = dims[1];
    let (o0, o1) = (origin[0], origin[1]);
    let (b0, b1) = (bdims[0], bdims[1]);
    match fit {
        Some(f) => {
            let (c0, c1, c2) = (f.coeffs[0], f.coeffs[1], f.coeffs[2]);
            // Bulk SIMD quantize per row, as in the 3-D regression path.
            let mut preds = vec![0.0f64; b1];
            let mut codes = vec![0u32; b1];
            for y in 0..b0 {
                let py = c0 * y as f64 + c2;
                let base = (o0 + y) * s0 + o1;
                for (x, p) in preds.iter_mut().enumerate() {
                    *p = py + c1 * x as f64;
                }
                quantizer.quantize_row(&mut values[base..base + b1], &preds, &mut codes);
                indices.extend_from_slice(&codes);
            }
        }
        None => {
            for y in 0..b0 {
                let gy = o0 + y;
                let base = gy * s0 + o1;
                for x in 0..b1 {
                    let gx = o1 + x;
                    let flat = base + x;
                    let pred = if gy > 0 && gx > 0 {
                        values[flat - 1].to_f64() + values[flat - s0].to_f64()
                            - values[flat - s0 - 1].to_f64()
                    } else if gy > 0 {
                        values[flat - s0].to_f64()
                    } else if gx > 0 {
                        values[flat - 1].to_f64()
                    } else {
                        0.0
                    };
                    let (qi, rec) = quantizer.quantize(values[flat], pred);
                    indices.push(qi);
                    values[flat] = rec;
                }
            }
        }
    }
}

/// Decompress one 2-D block.
#[allow(clippy::too_many_arguments)]
pub(super) fn decompress_block_2d<T: Scalar>(
    values: &mut [T],
    dims: &[usize],
    origin: &[usize],
    bdims: &[usize],
    fit: Option<&RegressionFit>,
    quantizer: &mut LinearQuantizer<T>,
    indices: &[u32],
    qpos: &mut usize,
) {
    let s0 = dims[1];
    let (o0, o1) = (origin[0], origin[1]);
    let (b0, b1) = (bdims[0], bdims[1]);
    match fit {
        Some(f) => {
            let (c0, c1, c2) = (f.coeffs[0], f.coeffs[1], f.coeffs[2]);
            for y in 0..b0 {
                let py = c0 * y as f64 + c2;
                let base = (o0 + y) * s0 + o1;
                for x in 0..b1 {
                    values[base + x] = quantizer.recover(py + c1 * x as f64, indices[*qpos]);
                    *qpos += 1;
                }
            }
        }
        None => {
            for y in 0..b0 {
                let gy = o0 + y;
                let base = gy * s0 + o1;
                for x in 0..b1 {
                    let gx = o1 + x;
                    let flat = base + x;
                    let pred = if gy > 0 && gx > 0 {
                        values[flat - 1].to_f64() + values[flat - s0].to_f64()
                            - values[flat - s0 - 1].to_f64()
                    } else if gy > 0 {
                        values[flat - s0].to_f64()
                    } else if gx > 0 {
                        values[flat - 1].to_f64()
                    } else {
                        0.0
                    };
                    values[flat] = quantizer.recover(pred, indices[*qpos]);
                    *qpos += 1;
                }
            }
        }
    }
}

/// True when the specialized path covers this dimensionality.
pub(super) fn supports(ndim: usize) -> bool {
    ndim == 2 || ndim == 3
}

#[cfg(test)]
mod tests {
    use super::super::block::BlockCompressor;
    use crate::data::Field;
    use crate::pipeline::{CompressConf, Compressor, ErrorBound};
    use crate::util::prop;

    #[test]
    fn specialized_matches_generic_bitexactly() {
        // SZ3-LR-s must produce byte-identical streams to SZ3-LR (same
        // math, different codegen) apart from the pipeline name in the
        // header — so compare decompressed values and stream sizes.
        prop::cases(10, 0x5bfa, |rng| {
            let nd = rng.below(2) + 2; // 2 or 3 dims
            let dims: Vec<usize> = (0..nd).map(|_| rng.below(15) + 4).collect();
            let data = prop::smooth_field(rng, &dims);
            let f = Field::f32("cmp", &dims, data).unwrap();
            let eb = 10f64.powf(rng.uniform(-4.0, -1.0));
            let conf = CompressConf::new(ErrorBound::Abs(eb));
            let generic = BlockCompressor::sz3_lr();
            let fast = BlockCompressor::sz3_lr_s();
            let sg = generic.compress(&f, &conf).unwrap();
            let sf = fast.compress(&f, &conf).unwrap();
            let og = generic.decompress(&sg).unwrap();
            let of = fast.decompress(&sf).unwrap();
            assert_eq!(
                og.values, of.values,
                "specialized codec diverged from the iterator path"
            );
            // stream size may differ only by the header name length
            let name_delta = 2; // "sz3-lr-s" vs "sz3-lr"
            assert!(
                (sg.len() as i64 - sf.len() as i64).unsigned_abs() as usize <= name_delta,
                "sizes diverged: {} vs {}",
                sg.len(),
                sf.len()
            );
        });
    }

    #[test]
    fn fast_block_side_is_consistent() {
        assert_eq!(super::block_side(3), 6);
        assert!(super::supports(2) && super::supports(3) && !super::supports(1));
    }
}
