//! Block-analysis abstraction: the compute hot-spot of the SZ3-LR pipeline
//! (regression fit + Lorenzo/regression error estimation per block),
//! factored behind a trait so it can run either natively in Rust
//! ([`NativeAnalyzer`]) or on the AOT-compiled XLA executable produced by
//! the L2 JAX model (`runtime::PjrtAnalyzer`). Both must compute the same
//! math — `python/compile/kernels/ref.py` is the shared oracle.

use crate::error::Result;
use crate::predictor::composite::CompositeSelector;
use crate::predictor::regression::RegressionFit;

/// Raw per-block analysis results (no selection policy applied).
#[derive(Clone, Debug)]
pub struct RawAnalysis {
    /// Mean |Lorenzo residual| on original data (no noise correction).
    pub lorenzo_err: f64,
    /// Mean |regression residual|.
    pub regression_err: f64,
    /// Fitted hyperplane coefficients (slopes then intercept).
    pub coeffs: Vec<f64>,
}

/// Batched analysis of equally-shaped blocks.
pub trait BlockAnalyzer: Send + Sync {
    /// Analyze `blocks` (concatenated row-major blocks, each of shape
    /// `dims`). Returns one [`RawAnalysis`] per block.
    fn analyze_batch(&self, blocks: &[f64], dims: &[usize]) -> Result<Vec<RawAnalysis>>;

    /// Human-readable backend name (for logs/metrics).
    fn backend(&self) -> &'static str;
}

/// Pure-Rust analyzer (reference implementation and fallback).
#[derive(Default, Clone)]
pub struct NativeAnalyzer;

impl BlockAnalyzer for NativeAnalyzer {
    fn analyze_batch(&self, blocks: &[f64], dims: &[usize]) -> Result<Vec<RawAnalysis>> {
        let block_len: usize = dims.iter().product();
        debug_assert_eq!(blocks.len() % block_len, 0);
        let mut out = Vec::with_capacity(blocks.len() / block_len);
        for chunk in blocks.chunks_exact(block_len) {
            out.push(match dims.len() {
                3 => analyze_block_3d(chunk, dims),
                2 => analyze_block_2d(chunk, dims),
                _ => {
                    let fit = RegressionFit::fit(chunk, dims);
                    let regression_err = fit.mean_abs_error(chunk, dims);
                    let lorenzo_err = CompositeSelector::lorenzo_block_error(chunk, dims);
                    RawAnalysis { lorenzo_err, regression_err, coeffs: fit.coeffs }
                }
            });
        }
        Ok(out)
    }

    fn backend(&self) -> &'static str {
        "native"
    }
}

/// Dimension-specialized 3-D analysis: identical math to the generic path
/// (verified by `batch_matches_single_block_math`), direct indexing.
fn analyze_block_3d(b: &[f64], dims: &[usize]) -> RawAnalysis {
    let (n0, n1, n2) = (dims[0], dims[1], dims[2]);
    let n = (n0 * n1 * n2) as f64;
    let (c0, c1, c2) =
        ((n0 as f64 - 1.0) / 2.0, (n1 as f64 - 1.0) / 2.0, (n2 as f64 - 1.0) / 2.0);
    let s0 = n1 * n2;
    let s1 = n2;
    // fused pass: fit sums + lorenzo residuals
    let (mut sum, mut sz, mut sy, mut sx, mut lor) = (0.0, 0.0, 0.0, 0.0, 0.0);
    for z in 0..n0 {
        let zc = z as f64 - c0;
        for y in 0..n1 {
            let yc = y as f64 - c1;
            let base = z * s0 + y * s1;
            for x in 0..n2 {
                let v = b[base + x];
                sum += v;
                sz += zc * v;
                sy += yc * v;
                sx += (x as f64 - c2) * v;
                let flat = base + x;
                let pred = if z > 0 && y > 0 && x > 0 {
                    b[flat - 1] + b[flat - s1] + b[flat - s0] - b[flat - s1 - 1]
                        - b[flat - s0 - 1]
                        - b[flat - s0 - s1]
                        + b[flat - s0 - s1 - 1]
                } else {
                    let at = |dz: usize, dy: usize, dx: usize| -> f64 {
                        if (dz == 1 && z == 0) || (dy == 1 && y == 0) || (dx == 1 && x == 0)
                        {
                            0.0
                        } else {
                            b[flat - dz * s0 - dy * s1 - dx]
                        }
                    };
                    at(0, 0, 1) + at(0, 1, 0) + at(1, 0, 0) - at(0, 1, 1) - at(1, 0, 1)
                        - at(1, 1, 0)
                        + at(1, 1, 1)
                };
                lor += (v - pred).abs();
            }
        }
    }
    let denom = |nd: usize| n * ((nd * nd) as f64 - 1.0) / 12.0;
    let b0 = sz / denom(n0);
    let b1 = sy / denom(n1);
    let b2 = sx / denom(n2);
    let b3 = sum / n - b0 * c0 - b1 * c1 - b2 * c2;
    // second pass: regression residual
    let mut reg = 0.0;
    for z in 0..n0 {
        let pz = b0 * z as f64 + b3;
        for y in 0..n1 {
            let pzy = pz + b1 * y as f64;
            let base = z * s0 + y * s1;
            for x in 0..n2 {
                reg += (b[base + x] - (pzy + b2 * x as f64)).abs();
            }
        }
    }
    RawAnalysis {
        lorenzo_err: lor / n,
        regression_err: reg / n,
        coeffs: vec![b0, b1, b2, b3],
    }
}

/// Dimension-specialized 2-D analysis.
fn analyze_block_2d(b: &[f64], dims: &[usize]) -> RawAnalysis {
    let (n0, n1) = (dims[0], dims[1]);
    let n = (n0 * n1) as f64;
    let (c0, c1) = ((n0 as f64 - 1.0) / 2.0, (n1 as f64 - 1.0) / 2.0);
    let (mut sum, mut sy, mut sx, mut lor) = (0.0, 0.0, 0.0, 0.0);
    for y in 0..n0 {
        let yc = y as f64 - c0;
        let base = y * n1;
        for x in 0..n1 {
            let v = b[base + x];
            sum += v;
            sy += yc * v;
            sx += (x as f64 - c1) * v;
            let flat = base + x;
            let pred = if y > 0 && x > 0 {
                b[flat - 1] + b[flat - n1] - b[flat - n1 - 1]
            } else if y > 0 {
                b[flat - n1]
            } else if x > 0 {
                b[flat - 1]
            } else {
                0.0
            };
            lor += (v - pred).abs();
        }
    }
    let denom = |nd: usize| n * ((nd * nd) as f64 - 1.0) / 12.0;
    let b0 = sy / denom(n0);
    let b1 = sx / denom(n1);
    let b2 = sum / n - b0 * c0 - b1 * c1;
    let mut reg = 0.0;
    for y in 0..n0 {
        let py = b0 * y as f64 + b2;
        let base = y * n1;
        for x in 0..n1 {
            reg += (b[base + x] - (py + b1 * x as f64)).abs();
        }
    }
    RawAnalysis { lorenzo_err: lor / n, regression_err: reg / n, coeffs: vec![b0, b1, b2] }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn batch_matches_single_block_math() {
        prop::cases(20, 0xaa1, |rng| {
            let dims = [6usize, 6, 6];
            let nb = rng.below(5) + 1;
            let blocks: Vec<f64> =
                (0..nb * 216).map(|_| rng.uniform(-10.0, 10.0)).collect();
            let res = NativeAnalyzer.analyze_batch(&blocks, &dims).unwrap();
            assert_eq!(res.len(), nb);
            for (b, r) in blocks.chunks_exact(216).zip(&res) {
                let fit = RegressionFit::fit(b, &dims);
                for (a, c) in fit.coeffs.iter().zip(&r.coeffs) {
                    assert!((a - c).abs() <= 1e-12 * a.abs().max(1.0), "{a} vs {c}");
                }
                let reg = fit.mean_abs_error(b, &dims);
                assert!((reg - r.regression_err).abs() <= 1e-12 * reg.max(1.0));
                let lor = CompositeSelector::lorenzo_block_error(b, &dims);
                assert!((lor - r.lorenzo_err).abs() <= 1e-12 * lor.max(1.0));
            }
        });
    }
}
