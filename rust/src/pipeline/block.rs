//! Blockwise composite compressor — SZ3-LR (paper §6.2), the SZ3 port of
//! SZ2 [8]: the field is partitioned into fixed-size blocks (6³ in 3-D,
//! 12² in 2-D, 128 in 1-D); each block is analyzed (regression fit +
//! Lorenzo/regression error estimates) and the better predictor is chosen
//! per block. Analysis is batched behind [`BlockAnalyzer`] so it can run on
//! the PJRT executable compiled from the L2 JAX model.

use super::analysis::{BlockAnalyzer, NativeAnalyzer, RawAnalysis};
use super::block_fast;
use super::{CompressConf, Compressor, StreamHeader};
use crate::bitio::{BitReader, BitWriter};
use crate::byteio::{ByteReader, ByteWriter};
use crate::data::{Field, FieldValues, NdCursor, Scalar, Shape};
use crate::encoder::{self, Encoder};
use crate::error::{Result, SzError};
use crate::lossless::{self};
use crate::obs;
use crate::predictor::{CompositeChoice, LorenzoPredictor, Predictor, RegressionFit};
use crate::quantizer::{LinearQuantizer, Quantizer};
use std::sync::Arc;
use std::time::Instant;

/// Block side length per dimensionality (SZ2 conventions).
pub fn block_side(ndim: usize) -> usize {
    match ndim {
        1 => 128,
        2 => 12,
        3 => 6,
        _ => 4,
    }
}

/// SZ2-style blockwise Lorenzo⊕regression compressor.
pub struct BlockCompressor {
    /// Stream-header identity (canonical spec for spec-built instances,
    /// legacy registry name for the historical constructors).
    pub name: String,
    /// Batched analysis backend (native or PJRT).
    pub analyzer: Arc<dyn BlockAnalyzer>,
    /// Encoder stage name for the quantization indices.
    pub encoder: String,
    /// Lossless backend name.
    pub lossless: String,
    /// Skip the Lorenzo decompression-noise correction (SZ3-APS mode).
    pub assume_noiseless: bool,
    /// Use the dimension-specialized prediction codecs (SZ3-LR-s, §6.2)
    /// instead of the generic multidimensional iterator.
    pub specialized: bool,
    /// Quantizer index-radius override (`None` = use the configured
    /// [`CompressConf::radius`]); set by `linear@rN` specs.
    pub radius: Option<u32>,
}

impl BlockCompressor {
    /// SZ3-LR: iterator-based predictor module (paper §6.2).
    pub fn sz3_lr() -> Self {
        BlockCompressor {
            name: "sz3-lr".to_string(),
            analyzer: Arc::new(NativeAnalyzer),
            encoder: "huffman".to_string(),
            lossless: "zstd".to_string(),
            assume_noiseless: false,
            specialized: false,
            radius: None,
        }
    }

    /// SZ3-LR-s: same logic, dimension-specialized codecs (paper §6.2).
    pub fn sz3_lr_s() -> Self {
        BlockCompressor { name: "sz3-lr-s".to_string(), specialized: true, ..Self::sz3_lr() }
    }

    /// Replace the analysis backend (e.g. with the PJRT engine).
    pub fn with_analyzer(mut self, a: Arc<dyn BlockAnalyzer>) -> Self {
        self.analyzer = a;
        self
    }

    fn compress_typed<T: Scalar>(
        &self,
        values: &mut [T],
        shape: &Shape,
        eb: f64,
        radius: u32,
        w: &mut ByteWriter,
    ) -> Result<()> {
        let nd = shape.ndim();
        let dims = shape.dims().to_vec();
        let side = block_side(nd);
        let nblocks_per_dim: Vec<usize> = dims.iter().map(|&d| d.div_ceil(side)).collect();
        let total_blocks: usize = nblocks_per_dim.iter().product();
        let lorenzo = LorenzoPredictor::new(nd);
        let noise = if self.assume_noiseless {
            0.0
        } else {
            LorenzoPredictor::noise_factor(nd) * eb
        };

        // ---- Pass 1: batched analysis of all *full* blocks ----
        let full_dims = vec![side; nd];
        let block_len: usize = full_dims.iter().product();
        let mut full_blocks_data: Vec<f64> = Vec::new();
        let mut block_origins: Vec<Vec<usize>> = Vec::with_capacity(total_blocks);
        let mut is_full: Vec<bool> = Vec::with_capacity(total_blocks);
        let mut bidx = vec![0usize; nd];
        for _ in 0..total_blocks {
            let origin: Vec<usize> = bidx.iter().map(|&b| b * side).collect();
            let full = origin.iter().zip(&dims).all(|(&o, &d)| o + side <= d);
            if full {
                // extract block values (original data) as f64
                extract_block(values, shape, &origin, &full_dims, &mut full_blocks_data);
            }
            block_origins.push(origin);
            is_full.push(full);
            // advance block grid index
            for d in (0..nd).rev() {
                bidx[d] += 1;
                if bidx[d] < nblocks_per_dim[d] {
                    break;
                }
                bidx[d] = 0;
            }
        }
        let t_analyze = Instant::now();
        let analyses: Vec<RawAnalysis> = if full_blocks_data.is_empty() {
            Vec::new()
        } else {
            self.analyzer.analyze_batch(&full_blocks_data, &full_dims)?
        };
        obs::stage(obs::ST_ANALYZE).record(
            t_analyze,
            (full_blocks_data.len() as u64).saturating_mul(8),
            (analyses.len() as u64).saturating_mul((nd as u64).saturating_add(3)).saturating_mul(8),
        );
        debug_assert_eq!(analyses.len() * block_len, full_blocks_data.len());

        // ---- Pass 2: per-block selection + prediction + quantization ----
        let t_predict = Instant::now();
        let mut quantizer = LinearQuantizer::<T>::with_radius(eb, radius);
        let mut indices: Vec<u32> = Vec::with_capacity(shape.len());
        let mut selections = BitWriter::new();
        let mut coeff_ints: Vec<i64> = Vec::new();
        let use_fast = self.specialized && block_fast::supports(nd);
        let mut next_analysis = 0usize;
        let scratch_block: Vec<f64> = Vec::with_capacity(block_len);
        for (origin, &full) in block_origins.iter().zip(&is_full) {
            let bdims: Vec<usize> =
                origin.iter().zip(&dims).map(|(&o, &d)| side.min(d - o)).collect();
            // choice: full blocks use batched analysis; partial blocks
            // always use Lorenzo (as SZ2 does for irregular remainders).
            let choice = if full {
                let a = &analyses[next_analysis];
                next_analysis += 1;
                if a.lorenzo_err + noise <= a.regression_err {
                    CompositeChoice::Lorenzo
                } else {
                    CompositeChoice::Regression
                }
            } else {
                CompositeChoice::Lorenzo
            };
            let fit = match choice {
                CompositeChoice::Regression => {
                    let a = &analyses[next_analysis - 1];
                    let raw = RegressionFit { coeffs: a.coeffs.clone() };
                    let (q, rec) = raw.quantize(eb, side);
                    coeff_ints.extend_from_slice(&q);
                    selections.put_bit(1);
                    Some(rec)
                }
                CompositeChoice::Lorenzo => {
                    selections.put_bit(0);
                    None
                }
            };
            // audit:allow(swallow, reason = "discards an unused borrow, not a Result; the binding is kept for API stability")
            let _ = &scratch_block; // kept for API stability
            if use_fast {
                // dimension-specialized codec (SZ3-LR-s, §6.2)
                match nd {
                    3 => block_fast::compress_block_3d(
                        values, &dims, origin, &bdims, fit.as_ref(), &mut quantizer,
                        &mut indices,
                    ),
                    _ => block_fast::compress_block_2d(
                        values, &dims, origin, &bdims, fit.as_ref(), &mut quantizer,
                        &mut indices,
                    ),
                }
                continue;
            }
            // generic multidimensional-iterator walk (SZ3-LR)
            let mut cursor = NdCursor::new(values, shape);
            let mut lidx = vec![0usize; nd];
            let mut gidx = vec![0usize; nd];
            loop {
                for d in 0..nd {
                    gidx[d] = origin[d] + lidx[d];
                }
                cursor.seek(&gidx);
                let pred = match &fit {
                    Some(f) => f.predict(&lidx),
                    None => lorenzo.predict(&cursor),
                };
                let (qi, rec) = quantizer.quantize(cursor.value(), pred);
                indices.push(qi);
                cursor.set(rec);
                // advance local index
                let mut done = true;
                for d in (0..nd).rev() {
                    lidx[d] += 1;
                    if lidx[d] < bdims[d] {
                        done = false;
                        break;
                    }
                    lidx[d] = 0;
                }
                if done {
                    break;
                }
            }
        }
        obs::stage(obs::ST_PREDICT).record(
            t_predict,
            (shape.len() as u64).saturating_mul(std::mem::size_of::<T>() as u64),
            (indices.len() as u64).saturating_mul(4),
        );

        // ---- Serialize ----
        let ll = lossless::by_name(&self.lossless)
            .ok_or_else(|| SzError::config(format!("unknown lossless {}", self.lossless)))?;
        let enc = encoder::by_name(&self.encoder, radius)
            .ok_or_else(|| SzError::config(format!("unknown encoder {}", self.encoder)))?;
        let mut inner = ByteWriter::new();
        inner.put_varint(total_blocks as u64);
        inner.put_block(&selections.finish());
        inner.put_varint(coeff_ints.len() as u64);
        RegressionFit::save_quantized(&coeff_ints, &mut inner);
        quantizer.save(&mut inner)?;
        enc.encode(&indices, &mut inner)?;
        let packed = ll.compress(&inner.finish())?;
        w.put_block(&packed);
        Ok(())
    }

    fn decompress_typed<T: Scalar>(
        &self,
        shape: &Shape,
        radius: u32,
        r: &mut ByteReader,
    ) -> Result<Vec<T>> {
        let nd = shape.ndim();
        let dims = shape.dims().to_vec();
        let side = block_side(nd);
        let nblocks_per_dim: Vec<usize> = dims.iter().map(|&d| d.div_ceil(side)).collect();
        let total_blocks: usize = nblocks_per_dim.iter().product();

        let ll = lossless::by_name(&self.lossless)
            .ok_or_else(|| SzError::config(format!("unknown lossless {}", self.lossless)))?;
        let enc = encoder::by_name(&self.encoder, radius)
            .ok_or_else(|| SzError::config(format!("unknown encoder {}", self.encoder)))?;
        let inner = ll.decompress(r.get_block()?)?;
        let mut ir = ByteReader::new(&inner);
        let stored_blocks = ir.get_varint()? as usize;
        if stored_blocks != total_blocks {
            return Err(SzError::corrupt("block count mismatch"));
        }
        let sel_bytes = ir.get_block()?.to_vec();
        let n_coeffs = ir.get_varint()? as usize;
        let coeff_ints = RegressionFit::load_quantized(n_coeffs, &mut ir)?;
        let mut quantizer = LinearQuantizer::<T>::with_radius(1.0, radius);
        quantizer.load(&mut ir)?;
        let eb = quantizer.eb();
        let indices = enc.decode(&mut ir, shape.len())?;

        let t_reconstruct = Instant::now();
        let lorenzo = LorenzoPredictor::new(nd);
        let mut values = vec![T::zero(); shape.len()];
        let use_fast = self.specialized && block_fast::supports(nd);
        let mut selections = BitReader::new(&sel_bytes);
        let mut coeff_pos = 0usize;
        let mut qpos = 0usize;
        let mut bidx = vec![0usize; nd];
        for _ in 0..total_blocks {
            let origin: Vec<usize> = bidx.iter().map(|&b| b * side).collect();
            let full = origin.iter().zip(&dims).all(|(&o, &d)| o + side <= d);
            let bdims: Vec<usize> =
                origin.iter().zip(&dims).map(|(&o, &d)| side.min(d - o)).collect();
            let use_regression = selections.get_bit()? == 1;
            if use_regression && !full {
                return Err(SzError::corrupt("regression on partial block"));
            }
            let fit = if use_regression {
                if coeff_pos + nd + 1 > coeff_ints.len() {
                    return Err(SzError::corrupt("coefficient stream exhausted"));
                }
                let q = &coeff_ints[coeff_pos..coeff_pos + nd + 1];
                coeff_pos += nd + 1;
                Some(RegressionFit::dequantize(q, eb, side))
            } else {
                None
            };
            if use_fast {
                match nd {
                    3 => block_fast::decompress_block_3d(
                        &mut values, &dims, &origin, &bdims, fit.as_ref(),
                        &mut quantizer, &indices, &mut qpos,
                    ),
                    _ => block_fast::decompress_block_2d(
                        &mut values, &dims, &origin, &bdims, fit.as_ref(),
                        &mut quantizer, &indices, &mut qpos,
                    ),
                }
            } else {
                let mut cursor = NdCursor::new(&mut values, shape);
                let mut lidx = vec![0usize; nd];
                let mut gidx = vec![0usize; nd];
                loop {
                    for d in 0..nd {
                        gidx[d] = origin[d] + lidx[d];
                    }
                    cursor.seek(&gidx);
                    let pred = match &fit {
                        Some(f) => f.predict(&lidx),
                        None => lorenzo.predict(&cursor),
                    };
                    let rec = quantizer.recover(pred, indices[qpos]);
                    qpos += 1;
                    cursor.set(rec);
                    let mut done = true;
                    for d in (0..nd).rev() {
                        lidx[d] += 1;
                        if lidx[d] < bdims[d] {
                            done = false;
                            break;
                        }
                        lidx[d] = 0;
                    }
                    if done {
                        break;
                    }
                }
            }
            for d in (0..nd).rev() {
                bidx[d] += 1;
                if bidx[d] < nblocks_per_dim[d] {
                    break;
                }
                bidx[d] = 0;
            }
        }
        obs::stage(obs::ST_RECONSTRUCT).record(
            t_reconstruct,
            (indices.len() as u64).saturating_mul(4),
            (values.len() as u64).saturating_mul(std::mem::size_of::<T>() as u64),
        );
        Ok(values)
    }
}

/// Extract one block (f64) from a typed buffer into `out`.
fn extract_block<T: Scalar>(
    values: &[T],
    shape: &Shape,
    origin: &[usize],
    bdims: &[usize],
    out: &mut Vec<f64>,
) {
    let nd = shape.ndim();
    let strides = shape.strides();
    let base: usize = origin.iter().zip(strides).map(|(&o, &s)| o * s).sum();
    match nd {
        3 => {
            // hot path: direct triple loop, contiguous inner axis
            let (s0, s1) = (strides[0], strides[1]);
            for z in 0..bdims[0] {
                for y in 0..bdims[1] {
                    let row = base + z * s0 + y * s1;
                    out.extend(values[row..row + bdims[2]].iter().map(|v| v.to_f64()));
                }
            }
        }
        2 => {
            let s0 = strides[0];
            for y in 0..bdims[0] {
                let row = base + y * s0;
                out.extend(values[row..row + bdims[1]].iter().map(|v| v.to_f64()));
            }
        }
        1 => out.extend(values[base..base + bdims[0]].iter().map(|v| v.to_f64())),
        _ => {
            let mut lidx = vec![0usize; nd];
            let n: usize = bdims.iter().product();
            for _ in 0..n {
                let off: usize = lidx.iter().zip(strides).map(|(&l, &s)| l * s).sum();
                out.push(values[base + off].to_f64());
                for d in (0..nd).rev() {
                    lidx[d] += 1;
                    if lidx[d] < bdims[d] {
                        break;
                    }
                    lidx[d] = 0;
                }
            }
        }
    }
}

impl Compressor for BlockCompressor {
    fn name(&self) -> &str {
        &self.name
    }

    fn compress(&self, field: &Field, conf: &CompressConf) -> Result<Vec<u8>> {
        let eb = conf.bound.to_abs(field)?;
        let radius = self.radius.unwrap_or(conf.radius);
        let mut w = ByteWriter::new();
        StreamHeader::for_field(&self.name, field).write(&mut w);
        w.put_u32(radius);
        match &field.values {
            FieldValues::F32(v) => {
                let mut buf = v.clone();
                self.compress_typed::<f32>(&mut buf, &field.shape, eb, radius, &mut w)?
            }
            FieldValues::F64(v) => {
                let mut buf = v.clone();
                self.compress_typed::<f64>(&mut buf, &field.shape, eb, radius, &mut w)?
            }
            FieldValues::I32(v) => {
                let mut buf = v.clone();
                self.compress_typed::<i32>(&mut buf, &field.shape, eb, radius, &mut w)?
            }
        }
        Ok(w.finish())
    }

    fn decompress(&self, stream: &[u8]) -> Result<Field> {
        let mut r = ByteReader::new(stream);
        let header = StreamHeader::read(&mut r)?;
        let radius = r.get_u32()?;
        let shape = Shape::new(&header.dims)?;
        let values = match header.dtype.as_str() {
            "f32" => FieldValues::F32(self.decompress_typed::<f32>(&shape, radius, &mut r)?),
            "f64" => FieldValues::F64(self.decompress_typed::<f64>(&shape, radius, &mut r)?),
            "i32" => FieldValues::I32(self.decompress_typed::<i32>(&shape, radius, &mut r)?),
            other => return Err(SzError::corrupt(format!("unknown dtype {other}"))),
        };
        Field::new(header.field_name, &header.dims, values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::test_support::roundtrip_bound_check;
    use crate::pipeline::ErrorBound;
    use crate::util::prop;

    #[test]
    fn roundtrip_3d_smooth() {
        let mut rng = crate::util::rng::Pcg32::seeded(31);
        let dims = [20usize, 20, 20];
        let data = prop::smooth_field(&mut rng, &dims);
        let f = Field::f32("cube", &dims, data).unwrap();
        for eb in [1e-1, 1e-3, 1e-5] {
            let conf = CompressConf::new(ErrorBound::Rel(eb));
            let ratio = roundtrip_bound_check(&BlockCompressor::sz3_lr(), &f, &conf);
            assert!(ratio > 1.0, "eb {eb} ratio {ratio}");
        }
    }

    #[test]
    fn roundtrip_partial_blocks() {
        // dims not divisible by block side
        let mut rng = crate::util::rng::Pcg32::seeded(32);
        for dims in [vec![7usize, 13], vec![5usize, 6, 11], vec![131usize]] {
            let data = prop::smooth_field(&mut rng, &dims);
            let f = Field::f32("odd", &dims, data).unwrap();
            let conf = CompressConf::new(ErrorBound::Abs(1e-3));
            roundtrip_bound_check(&BlockCompressor::sz3_lr(), &f, &conf);
        }
    }

    #[test]
    fn regression_wins_on_noisy_planes_at_high_eb() {
        // Construct data where regression should be selected: steep plane +
        // noise, compressed at high eb.
        let dims = [24usize, 24, 24];
        let mut rng = crate::util::rng::Pcg32::seeded(33);
        let mut vals = Vec::with_capacity(24 * 24 * 24);
        for i in 0..24 {
            for j in 0..24 {
                for k in 0..24 {
                    vals.push(
                        (3.0 * i as f64 - 2.0 * j as f64 + k as f64
                            + rng.normal() * 0.05) as f32,
                    );
                }
            }
        }
        let f = Field::f32("plane", &dims, vals).unwrap();
        let conf = CompressConf::new(ErrorBound::Abs(0.5));
        let ratio = roundtrip_bound_check(&BlockCompressor::sz3_lr(), &f, &conf);
        assert!(ratio > 20.0, "plane data should compress hard, got {ratio}");
    }

    #[test]
    fn prop_bound_holds_1d_2d_3d_4d() {
        prop::cases(12, 0xb10c, |rng| {
            let nd = rng.below(4) + 1;
            let dims: Vec<usize> = (0..nd).map(|_| rng.below(12) + 5).collect();
            let data = prop::smooth_field(rng, &dims);
            let f = Field::f32("nd", &dims, data).unwrap();
            let eb = 10f64.powf(rng.uniform(-5.0, -1.0));
            let conf = CompressConf::new(ErrorBound::Abs(eb));
            roundtrip_bound_check(&BlockCompressor::sz3_lr(), &f, &conf);
        });
    }

    #[test]
    fn f64_fields() {
        let mut rng = crate::util::rng::Pcg32::seeded(35);
        let dims = [16usize, 16];
        let data: Vec<f64> =
            prop::smooth_field(&mut rng, &dims).iter().map(|&x| x as f64).collect();
        let f = Field::f64("dbl", &dims, data).unwrap();
        let conf = CompressConf::new(ErrorBound::Abs(1e-8));
        roundtrip_bound_check(&BlockCompressor::sz3_lr(), &f, &conf);
    }
}
