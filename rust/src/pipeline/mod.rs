//! Compression-pipeline composition (paper §3.3, Algorithm 1).
//!
//! A pipeline = preprocessor → predictor → quantizer → encoder → lossless.
//! [`point::SzCompressor`] is the literal Algorithm 1 over any point
//! predictor; [`block::BlockCompressor`] is the SZ2-style blockwise
//! composite (SZ3-LR); [`interp::InterpCompressor`] is SZ3-Interp;
//! [`truncation::TruncationCompressor`] is SZ3-Truncation;
//! [`pastri::PastriCompressor`] is SZ-Pastri/SZ3-Pastri (§4);
//! [`aps::ApsCompressor`] is the adaptive APS pipeline (§5).
//!
//! Every compressed stream begins with a common header (pipeline name,
//! dtype, shape), so [`decompress_any`] can dispatch to the right pipeline.

pub mod analysis;
pub mod aps;
pub mod block;
mod block_fast;
pub mod interp;
pub mod pastri;
pub mod point;
pub mod truncation;

pub use analysis::{BlockAnalyzer, NativeAnalyzer};
pub use aps::ApsCompressor;
pub use block::BlockCompressor;
pub use interp::InterpCompressor;
pub use pastri::PastriCompressor;
pub use point::SzCompressor;
pub use truncation::TruncationCompressor;

use crate::byteio::{ByteReader, ByteWriter};
use crate::data::Field;
use crate::error::{Result, SzError};

/// Error-bound mode (user requirement).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ErrorBound {
    /// Absolute: `|x' - x| <= eb`.
    Abs(f64),
    /// Value-range relative: `|x' - x| <= rel * (max - min)`.
    Rel(f64),
    /// Pointwise relative: `|x'/x - 1| <= rel` (via log transform).
    PwRel(f64),
}

impl ErrorBound {
    /// Resolve to an absolute bound for the given field.
    pub fn to_abs(self, field: &Field) -> Result<f64> {
        match self {
            ErrorBound::Abs(e) if e > 0.0 => Ok(e),
            ErrorBound::Rel(r) if r > 0.0 => {
                let (lo, hi) = field.value_range();
                let range = (hi - lo).max(f64::MIN_POSITIVE);
                Ok(r * range)
            }
            ErrorBound::PwRel(_) => Err(SzError::config(
                "pointwise-relative bound requires the log-transform preprocessor",
            )),
            _ => Err(SzError::config("error bound must be positive")),
        }
    }
}

/// Compression configuration handed to a pipeline.
#[derive(Clone, Debug)]
pub struct CompressConf {
    /// Requested error bound.
    pub bound: ErrorBound,
    /// Quantizer index radius (alphabet = 2·radius).
    pub radius: u32,
}

impl CompressConf {
    /// Config with the default SZ radius.
    pub fn new(bound: ErrorBound) -> Self {
        CompressConf { bound, radius: 32768 }
    }

    /// Config with an explicit radius.
    pub fn with_radius(bound: ErrorBound, radius: u32) -> Self {
        CompressConf { bound, radius }
    }
}

/// A composed error-bounded lossy compressor (the paper's
/// `SZ_Compressor<T, N, Preprocessor, Predictor, Quantizer, Encoder,
/// Lossless>` — Appendix A.6).
pub trait Compressor: Send + Sync {
    /// Pipeline name (stored in the stream header).
    fn name(&self) -> &'static str;
    /// Compress `field` under `conf`.
    fn compress(&self, field: &Field, conf: &CompressConf) -> Result<Vec<u8>>;
    /// Decompress a stream produced by this pipeline.
    fn decompress(&self, stream: &[u8]) -> Result<Field>;
}

const MAGIC: &[u8; 4] = b"SZ3R";
const VERSION: u8 = 1;

/// Common stream header.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamHeader {
    /// Pipeline name that produced the stream.
    pub pipeline: String,
    /// Field name.
    pub field_name: String,
    /// Element dtype tag ("f32"/"f64"/"i32").
    pub dtype: String,
    /// Dimensions.
    pub dims: Vec<usize>,
}

impl StreamHeader {
    /// Build a header for `field` under `pipeline`.
    pub fn for_field(pipeline: &str, field: &Field) -> Self {
        StreamHeader {
            pipeline: pipeline.to_string(),
            field_name: field.name.clone(),
            dtype: field.values.dtype().to_string(),
            dims: field.shape.dims().to_vec(),
        }
    }

    /// Serialize the header.
    pub fn write(&self, w: &mut ByteWriter) {
        w.put_bytes(MAGIC);
        w.put_u8(VERSION);
        w.put_str(&self.pipeline);
        w.put_str(&self.field_name);
        w.put_str(&self.dtype);
        w.put_varint(self.dims.len() as u64);
        for &d in &self.dims {
            w.put_varint(d as u64);
        }
    }

    /// Parse a header.
    pub fn read(r: &mut ByteReader) -> Result<Self> {
        let magic = r.get_bytes(4)?;
        if magic != MAGIC {
            return Err(SzError::corrupt("bad magic"));
        }
        let ver = r.get_u8()?;
        if ver != VERSION {
            return Err(SzError::corrupt(format!("unsupported version {ver}")));
        }
        let pipeline = r.get_str()?;
        let field_name = r.get_str()?;
        let dtype = r.get_str()?;
        let nd = r.get_varint()? as usize;
        let mut dims = Vec::with_capacity(nd);
        for _ in 0..nd {
            dims.push(r.get_varint()? as usize);
        }
        Ok(StreamHeader { pipeline, field_name, dtype, dims })
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    /// True for degenerate headers.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Peek the header of a compressed stream without decompressing.
pub fn peek_header(stream: &[u8]) -> Result<StreamHeader> {
    StreamHeader::read(&mut ByteReader::new(stream))
}

/// Construct a pipeline by registry name with default modules.
///
/// Known names: `sz3-lr`, `sz3-lr-s`, `sz3-interp`, `sz3-truncation`,
/// `sz3-pastri`, `sz-pastri`, `sz-pastri-zstd`, `sz3-aps`, `lorenzo-1d`,
/// `fpzip-like`.
pub fn by_name(name: &str) -> Option<Box<dyn Compressor>> {
    match name {
        "sz3-lr" => Some(Box::new(BlockCompressor::sz3_lr())),
        "sz3-lr-s" => Some(Box::new(BlockCompressor::sz3_lr_s())),
        "sz3-interp" => Some(Box::new(InterpCompressor::default())),
        "sz3-truncation" => Some(Box::new(TruncationCompressor::default())),
        "sz3-pastri" => Some(Box::new(PastriCompressor::sz3())),
        "sz-pastri" => Some(Box::new(PastriCompressor::sz())),
        "sz-pastri-zstd" => Some(Box::new(PastriCompressor::sz_with_zstd())),
        "sz3-aps" => Some(Box::new(ApsCompressor::default())),
        "lorenzo-1d" => Some(Box::new(SzCompressor::lorenzo_1d())),
        "fpzip-like" => Some(Box::new(SzCompressor::fpzip_like())),
        _ => None,
    }
}

/// Decompress any stream by dispatching on its header's pipeline name.
pub fn decompress_any(stream: &[u8]) -> Result<Field> {
    let header = peek_header(stream)?;
    let pipeline = by_name(&header.pipeline).ok_or_else(|| {
        SzError::corrupt(format!("unknown pipeline '{}' in stream", header.pipeline))
    })?;
    pipeline.decompress(stream)
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::data::FieldValues;

    /// Compress + decompress + verify error bound on every element.
    /// Returns compression ratio.
    pub fn roundtrip_bound_check(
        c: &dyn Compressor,
        field: &Field,
        conf: &CompressConf,
    ) -> f64 {
        let stream = c.compress(field, conf).expect("compress");
        let out = decompress_any(&stream).expect("decompress");
        assert_eq!(out.shape.dims(), field.shape.dims(), "{}: dims", c.name());
        let abs = match conf.bound {
            ErrorBound::PwRel(_) => f64::NAN, // checked separately
            b => b.to_abs(field).unwrap(),
        };
        let orig = field.values.to_f64_vec();
        let dec = out.values.to_f64_vec();
        match conf.bound {
            ErrorBound::PwRel(r) => {
                for (i, (o, d)) in orig.iter().zip(dec.iter()).enumerate() {
                    if *o == 0.0 {
                        assert_eq!(*d, 0.0, "{}: zero not preserved at {i}", c.name());
                    } else {
                        let rel = (d / o - 1.0).abs();
                        assert!(
                            rel <= r * (1.0 + 1e-9),
                            "{}: rel err {rel} > {r} at {i}",
                            c.name()
                        );
                    }
                }
            }
            _ => {
                for (i, (o, d)) in orig.iter().zip(dec.iter()).enumerate() {
                    let err = (o - d).abs();
                    assert!(
                        err <= abs * (1.0 + 1e-12),
                        "{}: err {err} > bound {abs} at {i} (orig {o} dec {d})",
                        c.name()
                    );
                }
            }
        }
        // dtype must be preserved
        match (&field.values, &out.values) {
            (FieldValues::F32(_), FieldValues::F32(_))
            | (FieldValues::F64(_), FieldValues::F64(_))
            | (FieldValues::I32(_), FieldValues::I32(_)) => {}
            _ => panic!("{}: dtype changed", c.name()),
        }
        field.nbytes() as f64 / stream.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip() {
        let f = Field::f32("abc", &[3, 4], vec![0.0; 12]).unwrap();
        let h = StreamHeader::for_field("sz3-lr", &f);
        let mut w = ByteWriter::new();
        h.write(&mut w);
        let buf = w.finish();
        let h2 = StreamHeader::read(&mut ByteReader::new(&buf)).unwrap();
        assert_eq!(h, h2);
    }

    #[test]
    fn bad_magic_rejected() {
        assert!(peek_header(b"NOPE....").is_err());
    }

    #[test]
    fn rel_bound_resolves_via_range() {
        let f = Field::f32("x", &[2], vec![0.0, 10.0]).unwrap();
        let b = ErrorBound::Rel(1e-2).to_abs(&f).unwrap();
        assert!((b - 0.1).abs() < 1e-12);
    }
}
