//! Compression-pipeline composition (paper §3.3, Algorithm 1).
//!
//! A pipeline = preprocessor → predictor → quantizer → encoder → lossless.
//! [`point::SzCompressor`] is the literal Algorithm 1 over any point
//! predictor; [`block::BlockCompressor`] is the SZ2-style blockwise
//! composite (SZ3-LR); [`interp::InterpCompressor`] is SZ3-Interp;
//! [`truncation::TruncationCompressor`] is SZ3-Truncation;
//! [`pastri::PastriCompressor`] is SZ-Pastri/SZ3-Pastri (§4);
//! [`aps::ApsCompressor`] is the adaptive APS pipeline (§5);
//! [`szx::SzxCompressor`] is the SZx-style constant-block fast family.
//!
//! Every compressed stream begins with a common header (the pipeline's
//! canonical spec, dtype, shape), so [`decompress_any`] reconstructs the
//! exact stage stack from the stream alone.
//!
//! Pipelines are constructed from declarative **specs** (module [`spec`],
//! grammar in `docs/PIPELINES.md`): [`build`] accepts either a composition
//! like `block(lorenzo+regression)/linear/huffman/lzhuf` or one of the
//! historical registry aliases (`sz3-lr`, …), which resolve to canonical
//! specs via [`spec::ALIASES`].

pub mod analysis;
pub mod aps;
pub mod block;
mod block_fast;
pub mod interp;
pub mod pastri;
pub mod point;
pub mod spec;
pub mod szx;
pub mod truncation;

pub use analysis::{BlockAnalyzer, NativeAnalyzer};
pub use aps::ApsCompressor;
pub use block::BlockCompressor;
pub use interp::InterpCompressor;
pub use pastri::PastriCompressor;
pub use point::SzCompressor;
pub use spec::{canonical, PipelineBuilder, PipelineSpec};
pub use szx::SzxCompressor;
pub use truncation::TruncationCompressor;

use crate::byteio::{ByteReader, ByteWriter};
use crate::data::Field;
use crate::error::{Result, SzError};

/// Error-bound mode (user requirement).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ErrorBound {
    /// Absolute: `|x' - x| <= eb`.
    Abs(f64),
    /// Value-range relative: `|x' - x| <= rel * (max - min)`.
    Rel(f64),
    /// Pointwise relative: `|x'/x - 1| <= rel` (via log transform).
    PwRel(f64),
}

impl ErrorBound {
    /// Resolve to an absolute bound for the given field.
    pub fn to_abs(self, field: &Field) -> Result<f64> {
        self.to_abs_with_range(|| field.value_range())
    }

    /// Resolve to an absolute bound given a (lazily computed) value range —
    /// lets callers that already scanned the data avoid a second pass.
    pub fn to_abs_with_range<F: FnOnce() -> (f64, f64)>(self, value_range: F) -> Result<f64> {
        match self {
            ErrorBound::Abs(e) if e > 0.0 => Ok(e),
            ErrorBound::Rel(r) if r > 0.0 => {
                let (lo, hi) = value_range();
                let range = hi - lo;
                if range > 0.0 {
                    // Deliberately unclamped: for a tiny-but-positive range
                    // the user's bound is satisfiable and must be honored —
                    // a subnormal eb only degrades ratio (the quantizer's
                    // safety net falls back to storing values exactly),
                    // whereas clamping it up would violate the bound.
                    Ok(r * range)
                } else {
                    // Constant field: the range is zero, so the literal bound
                    // (r·0) is unsatisfiable as stated. Scaling by
                    // f64::MIN_POSITIVE used to produce a subnormal bound
                    // whose reciprocal overflows the quantizer (every value
                    // became "unpredictable"). Substitute a vanishing
                    // fraction of the value magnitude: small enough that
                    // every pipeline stays effectively exact (in particular
                    // sz3-truncation's per-byte errors are ulp-scale,
                    // ≈ mag·1.2e-7 for f32, so it keeps all bytes rather
                    // than spending the slack), large enough to stay a
                    // normal float with a finite quantizer step.
                    let mag = lo.abs().max(hi.abs());
                    Ok((r * mag * 1e-6).max(1e-150))
                }
            }
            ErrorBound::PwRel(_) => Err(SzError::config(
                "pointwise-relative bound requires the log-transform preprocessor",
            )),
            _ => Err(SzError::config("error bound must be positive")),
        }
    }
}

/// Compression configuration handed to a pipeline.
#[derive(Clone, Debug)]
pub struct CompressConf {
    /// Requested error bound.
    pub bound: ErrorBound,
    /// Quantizer index radius (alphabet = 2·radius).
    pub radius: u32,
}

impl CompressConf {
    /// Config with the default SZ radius.
    pub fn new(bound: ErrorBound) -> Self {
        CompressConf { bound, radius: 32768 }
    }

    /// Config with an explicit radius.
    pub fn with_radius(bound: ErrorBound, radius: u32) -> Self {
        CompressConf { bound, radius }
    }
}

/// A composed error-bounded lossy compressor (the paper's
/// `SZ_Compressor<T, N, Preprocessor, Predictor, Quantizer, Encoder,
/// Lossless>` — Appendix A.6).
pub trait Compressor: Send + Sync {
    /// Pipeline identity stored in the stream header — the canonical spec
    /// for spec-built pipelines ([`build`]), a legacy registry name for
    /// directly-constructed ones.
    fn name(&self) -> &str;
    /// Compress `field` under `conf`.
    fn compress(&self, field: &Field, conf: &CompressConf) -> Result<Vec<u8>>;
    /// Decompress a stream produced by this pipeline.
    fn decompress(&self, stream: &[u8]) -> Result<Field>;
}

const MAGIC: &[u8; 4] = b"SZ3R";
const VERSION: u8 = 1;

/// Upper bound on the element count a stream header may declare (2^40
/// elements ≈ 4 TB of f32). Real fields sit far below this; corrupt
/// headers above it are rejected before any allocation is sized from them.
pub const MAX_HEADER_ELEMS: usize = 1 << 40;

/// Common stream header.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamHeader {
    /// Pipeline name that produced the stream.
    pub pipeline: String,
    /// Field name.
    pub field_name: String,
    /// Element dtype tag ("f32"/"f64"/"i32").
    pub dtype: String,
    /// Dimensions.
    pub dims: Vec<usize>,
}

impl StreamHeader {
    /// Build a header for `field` under `pipeline`.
    pub fn for_field(pipeline: &str, field: &Field) -> Self {
        StreamHeader {
            pipeline: pipeline.to_string(),
            field_name: field.name.clone(),
            dtype: field.values.dtype().to_string(),
            dims: field.shape.dims().to_vec(),
        }
    }

    /// Serialize the header.
    pub fn write(&self, w: &mut ByteWriter) {
        w.put_bytes(MAGIC);
        w.put_u8(VERSION);
        w.put_str(&self.pipeline);
        w.put_str(&self.field_name);
        w.put_str(&self.dtype);
        w.put_varint(self.dims.len() as u64);
        for &d in &self.dims {
            w.put_varint(d as u64);
        }
    }

    /// Parse a header.
    pub fn read(r: &mut ByteReader) -> Result<Self> {
        let magic = r.get_bytes(4)?;
        if magic != MAGIC {
            return Err(SzError::corrupt("bad magic"));
        }
        let ver = r.get_u8()?;
        if ver != VERSION {
            return Err(SzError::corrupt(format!("unsupported version {ver}")));
        }
        let pipeline = r.get_str()?;
        let field_name = r.get_str()?;
        let dtype = r.get_str()?;
        // Adversarial hardening: `nd` and the dims are attacker-controlled.
        // Cap the dimension count before allocating, reject zero-length axes,
        // and bound the element count with overflow-checked arithmetic so a
        // corrupt header cannot drive huge downstream allocations.
        let nd = r.get_varint()? as usize;
        if nd == 0 || nd > crate::data::shape::MAX_DIMS {
            return Err(SzError::corrupt(format!(
                "dim count {nd} outside 1..={}",
                crate::data::shape::MAX_DIMS
            )));
        }
        let mut dims = Vec::with_capacity(nd);
        let mut elems = 1usize;
        for _ in 0..nd {
            let d = r.get_varint()? as usize;
            if d == 0 {
                return Err(SzError::corrupt("zero-length dimension in header"));
            }
            elems = elems
                .checked_mul(d)
                .filter(|&e| e <= MAX_HEADER_ELEMS)
                .ok_or_else(|| {
                    SzError::corrupt(format!("element count overflows cap {MAX_HEADER_ELEMS}"))
                })?;
            dims.push(d);
        }
        Ok(StreamHeader { pipeline, field_name, dtype, dims })
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    /// True for degenerate headers.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Peek the header of a compressed stream without decompressing.
pub fn peek_header(stream: &[u8]) -> Result<StreamHeader> {
    StreamHeader::read(&mut ByteReader::new(stream))
}

/// Construct a pipeline from a spec string or registry alias — the
/// primary construction path. Accepts compositions like
/// `block(lorenzo+regression)/linear@r512/huffman/lzhuf` (grammar in
/// [`spec`] / `docs/PIPELINES.md`) and the historical aliases (`sz3-lr`,
/// `sz3-interp`, …), which resolve through [`spec::ALIASES`] to canonical
/// specs, so an alias and its canonical spec build bit-identical
/// compressors.
pub fn build(name_or_spec: &str) -> Result<Box<dyn Compressor>> {
    spec::resolve(name_or_spec)?.build()
}

/// Construct a pipeline by registry name with default modules.
///
/// Known names: `sz3-lr`, `sz3-lr-s`, `sz3-interp`, `sz3-truncation`,
/// `sz3-pastri`, `sz-pastri`, `sz-pastri-zstd`, `sz3-aps`, `lorenzo-1d`,
/// `fpzip-like`.
#[deprecated(
    note = "use pipeline::build, which accepts both registry aliases and \
            composable pipeline specs"
)]
pub fn by_name(name: &str) -> Option<Box<dyn Compressor>> {
    build(name).ok()
}

/// Decompress any artifact by dispatching on its magic: chunked containers
/// (`SZ3C`, see [`crate::container`]) holding a single field decompress in
/// parallel and reassemble; single streams (`SZ3R`) rebuild their stage
/// stack from the header's pipeline spec (registry aliases written by
/// older releases keep resolving via [`spec::ALIASES`]). Multi-field
/// containers must go through
/// [`crate::container::decompress_container`], which returns all fields.
pub fn decompress_any(stream: &[u8]) -> Result<Field> {
    if crate::container::is_container(stream) {
        // parses the index once, rejects multi-field containers before any
        // chunk is decompressed, then fans out across the worker pool
        return crate::container::decompress_single_field(
            stream,
            crate::util::default_workers(),
        );
    }
    let header = peek_header(stream)?;
    let pipeline = build(&header.pipeline).map_err(|e| {
        spec::unknown_pipeline_error("stream header", &header.pipeline, &e)
    })?;
    pipeline.decompress(stream)
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::data::FieldValues;

    /// Compress + decompress + verify error bound on every element.
    /// Returns compression ratio.
    pub fn roundtrip_bound_check(
        c: &dyn Compressor,
        field: &Field,
        conf: &CompressConf,
    ) -> f64 {
        let stream = c.compress(field, conf).expect("compress");
        let out = decompress_any(&stream).expect("decompress");
        assert_eq!(out.shape.dims(), field.shape.dims(), "{}: dims", c.name());
        let abs = match conf.bound {
            ErrorBound::PwRel(_) => f64::NAN, // checked separately
            b => b.to_abs(field).unwrap(),
        };
        let orig = field.values.to_f64_vec();
        let dec = out.values.to_f64_vec();
        match conf.bound {
            ErrorBound::PwRel(r) => {
                for (i, (o, d)) in orig.iter().zip(dec.iter()).enumerate() {
                    if *o == 0.0 {
                        assert_eq!(*d, 0.0, "{}: zero not preserved at {i}", c.name());
                    } else {
                        let rel = (d / o - 1.0).abs();
                        assert!(
                            rel <= r * (1.0 + 1e-9),
                            "{}: rel err {rel} > {r} at {i}",
                            c.name()
                        );
                    }
                }
            }
            _ => {
                for (i, (o, d)) in orig.iter().zip(dec.iter()).enumerate() {
                    let err = (o - d).abs();
                    assert!(
                        err <= abs * (1.0 + 1e-12),
                        "{}: err {err} > bound {abs} at {i} (orig {o} dec {d})",
                        c.name()
                    );
                }
            }
        }
        // dtype must be preserved
        match (&field.values, &out.values) {
            (FieldValues::F32(_), FieldValues::F32(_))
            | (FieldValues::F64(_), FieldValues::F64(_))
            | (FieldValues::I32(_), FieldValues::I32(_)) => {}
            _ => panic!("{}: dtype changed", c.name()),
        }
        field.nbytes() as f64 / stream.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip() {
        let f = Field::f32("abc", &[3, 4], vec![0.0; 12]).unwrap();
        let h = StreamHeader::for_field("sz3-lr", &f);
        let mut w = ByteWriter::new();
        h.write(&mut w);
        let buf = w.finish();
        let h2 = StreamHeader::read(&mut ByteReader::new(&buf)).unwrap();
        assert_eq!(h, h2);
    }

    #[test]
    fn bad_magic_rejected() {
        assert!(peek_header(b"NOPE....").is_err());
    }

    #[test]
    fn rel_bound_resolves_via_range() {
        let f = Field::f32("x", &[2], vec![0.0, 10.0]).unwrap();
        let b = ErrorBound::Rel(1e-2).to_abs(&f).unwrap();
        assert!((b - 0.1).abs() < 1e-12);
    }

    #[test]
    fn rel_bound_on_constant_field_is_not_subnormal() {
        // zero range used to resolve to r * f64::MIN_POSITIVE — a subnormal
        // whose reciprocal overflows the quantizer's bin computation.
        for c in [0.0f32, 7.25, -3.0e-3] {
            let f = Field::f32("c", &[64], vec![c; 64]).unwrap();
            let b = ErrorBound::Rel(1e-3).to_abs(&f).unwrap();
            assert!(b >= 1e-150, "bound {b} is degenerate for constant {c}");
            assert!((1.0 / (2.0 * b)).is_finite(), "quantizer step overflows");
        }
        // magnitude-relative: scales with the constant, at a vanishing
        // fraction (1e-6) so no pipeline spends the slack as real error
        let f = Field::f64("big", &[8], vec![1e9; 8]).unwrap();
        let b = ErrorBound::Rel(1e-3).to_abs(&f).unwrap();
        assert!((b - 1.0).abs() <= 1e-9);
    }

    #[test]
    fn constant_field_truncation_stays_exact_under_rel_bound() {
        // zero-range data must not lose mantissa bits: the substituted
        // bound sits far below truncation's smallest per-byte error, so
        // pick_keep falls back to keeping every byte
        let f = Field::f32("flat", &[64], vec![1e9; 64]).unwrap();
        let conf = CompressConf::new(ErrorBound::Rel(1e-3));
        let c = build("sz3-truncation").unwrap();
        let out = decompress_any(&c.compress(&f, &conf).unwrap()).unwrap();
        assert_eq!(out.values, f.values);
    }

    #[test]
    fn constant_field_roundtrips_under_rel_bound() {
        for name in ["sz3-lr", "sz3-interp", "lorenzo-1d"] {
            let f = Field::f32("flat", &[16, 16], vec![42.5; 256]).unwrap();
            let conf = CompressConf::new(ErrorBound::Rel(1e-3));
            let ratio = test_support::roundtrip_bound_check(
                build(name).unwrap().as_ref(),
                &f,
                &conf,
            );
            assert!(ratio > 4.0, "{name}: constant field should compress hard, got {ratio}");
        }
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_by_name_is_a_thin_build_wrapper() {
        assert!(by_name("sz3-lr").is_some());
        assert!(by_name("block(lorenzo+regression)/linear/huffman/zstd").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn unknown_pipeline_error_names_nearest_alias() {
        // a stream whose header names a misspelled pipeline must surface
        // both the name and the nearest registry alias as a recovery hint
        let f = Field::f32("x", &[32], (0..32).map(|i| i as f32).collect()).unwrap();
        let conf = CompressConf::new(ErrorBound::Abs(1e-3));
        let stream = BlockCompressor::sz3_lr().compress(&f, &conf).unwrap();
        let mut r = ByteReader::new(&stream);
        let mut h = StreamHeader::read(&mut r).unwrap();
        let body = stream[r.pos()..].to_vec();
        h.pipeline = "sz3-lrr".to_string();
        let mut w = ByteWriter::new();
        h.write(&mut w);
        w.put_bytes(&body);
        let err = decompress_any(&w.finish()).unwrap_err().to_string();
        assert!(err.contains("sz3-lrr"), "error must name the bad pipeline: {err}");
        assert!(err.contains("'sz3-lr'"), "error must hint the nearest alias: {err}");
    }

    #[test]
    fn legacy_alias_headers_keep_decoding() {
        // directly-constructed pipelines still write their legacy registry
        // names (exactly what pre-spec releases produced); decompress_any
        // must keep routing them via the alias fallback
        let f = Field::f32("x", &[16, 16], vec![1.5; 256]).unwrap();
        let conf = CompressConf::new(ErrorBound::Abs(1e-3));
        for (stream, legacy) in [
            (BlockCompressor::sz3_lr().compress(&f, &conf).unwrap(), "sz3-lr"),
            (InterpCompressor::default().compress(&f, &conf).unwrap(), "sz3-interp"),
            (SzCompressor::lorenzo_1d().compress(&f, &conf).unwrap(), "lorenzo-1d"),
        ] {
            assert_eq!(peek_header(&stream).unwrap().pipeline, legacy);
            let out = decompress_any(&stream).unwrap();
            assert_eq!(out.shape.dims(), f.shape.dims(), "{legacy}");
        }
    }

    fn header_with_dims_raw(nd: u64, dims: &[u64]) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_bytes(MAGIC);
        w.put_u8(VERSION);
        w.put_str("sz3-lr");
        w.put_str("f");
        w.put_str("f32");
        w.put_varint(nd);
        for &d in dims {
            w.put_varint(d);
        }
        w.finish()
    }

    #[test]
    fn adversarial_dim_count_rejected() {
        // huge nd varint must not drive a huge Vec::with_capacity
        let buf = header_with_dims_raw(u64::MAX >> 1, &[]);
        assert!(StreamHeader::read(&mut ByteReader::new(&buf)).is_err());
        let buf = header_with_dims_raw(5, &[1, 1, 1, 1, 1]);
        assert!(StreamHeader::read(&mut ByteReader::new(&buf)).is_err());
        let buf = header_with_dims_raw(0, &[]);
        assert!(StreamHeader::read(&mut ByteReader::new(&buf)).is_err());
    }

    #[test]
    fn adversarial_dims_product_rejected() {
        // element-count overflow via dims product
        let buf = header_with_dims_raw(2, &[u64::MAX >> 8, u64::MAX >> 8]);
        assert!(StreamHeader::read(&mut ByteReader::new(&buf)).is_err());
        // above the element cap without overflowing usize
        let buf = header_with_dims_raw(2, &[1 << 30, 1 << 30]);
        assert!(StreamHeader::read(&mut ByteReader::new(&buf)).is_err());
        // zero-length axis
        let buf = header_with_dims_raw(2, &[4, 0]);
        assert!(StreamHeader::read(&mut ByteReader::new(&buf)).is_err());
        // sane dims still parse
        let buf = header_with_dims_raw(2, &[4, 8]);
        let h = StreamHeader::read(&mut ByteReader::new(&buf)).unwrap();
        assert_eq!(h.dims, vec![4, 8]);
    }
}
