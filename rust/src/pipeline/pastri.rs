//! PaSTRI-family pipelines for GAMESS ERI data (paper §4).
//!
//! The ERI stream exhibits *periodic scaled patterns*: consecutive windows
//! ("repetitions") of length `P` are near-multiples of a shared pattern.
//! Per block of `R` repetitions the pipeline:
//!   1. picks the peak-magnitude repetition as the block pattern,
//!   2. quantizes the pattern values           → pattern stream,
//!   3. fits one scale per repetition           → scale stream,
//!   4. quantizes `x - scale·pattern` residuals → data stream,
//! then entropy-codes the three integer streams with the fixed Huffman
//! tree. The three streams are exactly Fig. 3's histogram components.
//!
//! Variants (Table 1):
//!   `sz()`           SZ-Pastri: value-major unpredictables, no lossless.
//!   `sz_with_zstd()` SZ-Pastri + zstd.
//!   `sz3()`          SZ3-Pastri: bitplane unpredictables (unpred-aware
//!                    quantizer, §4.2) + zstd — the paper's contribution.

use super::{CompressConf, Compressor, StreamHeader};
use crate::byteio::{ByteReader, ByteWriter};
use crate::data::{Field, FieldValues, Scalar};
use crate::encoder::{Encoder, FixedHuffmanEncoder};
use crate::error::{Result, SzError};
use crate::lossless;
use crate::quantizer::{Quantizer, UnpredAwareQuantizer};

/// Number of repetitions per block (PaSTRI block = R repetitions).
const REPS_PER_BLOCK: usize = 16;

/// PaSTRI-family compressor.
pub struct PastriCompressor {
    /// Stream-header identity (canonical spec for spec-built instances,
    /// legacy registry names for the historical constructors).
    pub name: String,
    /// Bitplane (true) vs value-major (false) unpredictable storage.
    pub bitplane_unpred: bool,
    /// Lossless backend name.
    pub lossless: String,
    /// Fixed pattern period; `None` = detect by autocorrelation scan
    /// (the SZ-Pastri preprocessing step, paper §3.2).
    pub period: Option<usize>,
}

impl PastriCompressor {
    /// Original SZ-Pastri: truncation-layout unpredictables, no lossless.
    pub fn sz() -> Self {
        PastriCompressor {
            name: "sz-pastri".to_string(),
            bitplane_unpred: false,
            lossless: "bypass".to_string(),
            period: None,
        }
    }

    /// SZ-Pastri with a zstd stage appended (Table 1 middle rows).
    pub fn sz_with_zstd() -> Self {
        PastriCompressor {
            name: "sz-pastri-zstd".to_string(),
            lossless: "zstd".to_string(),
            ..Self::sz()
        }
    }

    /// SZ3-Pastri: unpred-aware quantizer + lossless stage (paper §4.2).
    pub fn sz3() -> Self {
        PastriCompressor {
            name: "sz3-pastri".to_string(),
            bitplane_unpred: true,
            lossless: "zstd".to_string(),
            period: None,
        }
    }

    /// Detect the dominant period (the pattern-identification preprocessing
    /// of SZ-Pastri). Candidate periods are scored by the mean *Spearman*
    /// rank correlation between adjacent length-`p` windows: for the true
    /// period, windows are scaled copies of the pattern, so their rank
    /// orders match (ρ ≈ 1) regardless of the per-repetition scale — and
    /// rank correlation shrugs off the sparse outliers that destroy raw
    /// autocorrelation on ERI-like streams.
    pub fn detect_period(data: &[f64]) -> usize {
        let n = data.len().min(1 << 13);
        if n < 16 {
            return 1.max(n / 4);
        }
        let x = &data[..n];
        let max_p = (n / 4).min(1024).max(4);
        let rank_of = |w: &[f64]| -> Vec<f64> {
            let mut order: Vec<usize> = (0..w.len()).collect();
            order.sort_by(|&a, &b| w[a].partial_cmp(&w[b]).unwrap_or(std::cmp::Ordering::Equal));
            let mut r = vec![0.0; w.len()];
            for (rank, &i) in order.iter().enumerate() {
                r[i] = rank as f64;
            }
            r
        };
        let mut best_p = 4;
        let mut best_score = f64::NEG_INFINITY;
        for p in 4..=max_p {
            let m = n / p;
            if m < 3 {
                break;
            }
            let pairs = (m - 1).min(256);
            let mut sum = 0.0;
            let mut cnt = 0usize;
            let mut prev_rank = rank_of(&x[0..p]);
            for k in 1..=pairs {
                let cur_rank = rank_of(&x[k * p..(k + 1) * p]);
                // Spearman rho = 1 - 6 Σ d² / (p (p² - 1))
                let d2: f64 = prev_rank
                    .iter()
                    .zip(&cur_rank)
                    .map(|(&a, &b)| (a - b) * (a - b))
                    .sum();
                let pf = p as f64;
                sum += 1.0 - 6.0 * d2 / (pf * (pf * pf - 1.0));
                cnt += 1;
                prev_rank = cur_rank;
            }
            if cnt == 0 {
                continue;
            }
            // slight preference for shorter periods on near-ties
            let score =
                sum / cnt as f64 - 0.05 * (p as f64).log2() / (max_p as f64).log2();
            if score > best_score {
                best_score = score;
                best_p = p;
            }
        }
        best_p
    }

    fn quant_for<T: Scalar>(&self, eb: f64, radius: u32) -> UnpredAwareQuantizer<T> {
        if self.bitplane_unpred {
            UnpredAwareQuantizer::new(eb, radius)
        } else {
            UnpredAwareQuantizer::value_major(eb, radius)
        }
    }

    /// Compress and also return the three quantization-index streams
    /// (data, pattern, scale) — the Fig. 3 instrumentation.
    pub fn compress_instrumented(
        &self,
        field: &Field,
        conf: &CompressConf,
    ) -> Result<(Vec<u8>, [Vec<u32>; 3])> {
        let eb = conf.bound.to_abs(field)?;
        let mut w = ByteWriter::new();
        StreamHeader::for_field(&self.name, field).write(&mut w);
        let streams = match &field.values {
            FieldValues::F32(v) => {
                self.compress_typed::<f32>(v, eb, conf.radius, &mut w)?
            }
            FieldValues::F64(v) => {
                self.compress_typed::<f64>(v, eb, conf.radius, &mut w)?
            }
            FieldValues::I32(v) => {
                self.compress_typed::<i32>(v, eb, conf.radius, &mut w)?
            }
        };
        Ok((w.finish(), streams))
    }

    fn compress_typed<T: Scalar>(
        &self,
        values: &[T],
        eb: f64,
        radius: u32,
        w: &mut ByteWriter,
    ) -> Result<[Vec<u32>; 3]> {
        let n = values.len();
        let as_f64: Vec<f64> = values.iter().map(|v| v.to_f64()).collect();
        let period = self.period.unwrap_or_else(|| Self::detect_period(&as_f64)).max(1);
        let block = period * REPS_PER_BLOCK;

        let mut data_q = self.quant_for::<T>(eb, radius);
        let mut pat_q = self.quant_for::<f64>(eb, radius);
        // Scale quantization bound: scale error × pattern magnitude must stay
        // under ~eb/2 for every block, so derive it from the global peak
        // magnitude (per-block bounds would desynchronize the ratio budget
        // across blocks of very different scale). Ratio knob only — data_q
        // still enforces the real bound.
        let global_max = as_f64.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
        let scale_eb = if global_max > 0.0 { eb / (2.0 * global_max) } else { eb };
        let mut data_idx: Vec<u32> = Vec::with_capacity(n);
        let mut pat_idx: Vec<u32> = Vec::new();
        let mut scale_idx: Vec<u32> = Vec::new();
        let mut scale_q: Option<UnpredAwareQuantizer<f64>> = None;

        let mut pos = 0usize;
        while pos < n {
            let blen = block.min(n - pos);
            let chunk = &as_f64[pos..pos + blen];
            let nreps = blen.div_ceil(period);
            // 1. peak repetition = pattern. Only complete repetitions are
            // candidates so the pattern length is always period.min(blen) —
            // the decompressor relies on that invariant.
            let full_reps = blen / period;
            let candidates = if full_reps > 0 { full_reps } else { 1 };
            let mut best_rep = 0usize;
            let mut best_mag = f64::NEG_INFINITY;
            // Peak by *median* |value|: a max-based peak would elect reps
            // whose maximum is a stray outlier, poisoning the whole block's
            // pattern (and with it every repetition's prediction).
            let mut mags: Vec<f64> = Vec::with_capacity(period);
            for rp in 0..candidates {
                let s = rp * period;
                let e = (s + period).min(blen);
                mags.clear();
                mags.extend(chunk[s..e].iter().map(|v| v.abs()));
                let mid = mags.len() / 2;
                let mag = *mags
                    .select_nth_unstable_by(mid, |a, b| {
                        a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal)
                    })
                    .1;
                if mag > best_mag {
                    best_mag = mag;
                    best_rep = rp;
                }
            }
            // 2a. refine the pattern: element-wise median of scale-normalized
            // repetitions. The peak rep alone would freeze its outliers into
            // the pattern, corrupting that position in *every* repetition;
            // the median keeps the unpredictable rate at the outlier rate.
            let ps = best_rep * period;
            let pe = (ps + period).min(blen);
            let p0: Vec<f64> = chunk[ps..pe].to_vec();
            let p0_ref = {
                let mut mags: Vec<f64> = p0.iter().map(|v| v.abs()).collect();
                let k = ((mags.len() * 3) / 4).min(mags.len() - 1);
                *mags
                    .select_nth_unstable_by(k, |a, b| {
                        a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal)
                    })
                    .1
            };
            let median = |v: &mut Vec<f64>| -> f64 {
                if v.is_empty() {
                    return 0.0;
                }
                let mid = v.len() / 2;
                *v.select_nth_unstable_by(mid, |a, b| {
                    a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal)
                })
                .1
            };
            let mut refined = p0.clone();
            if full_reps >= 3 {
                // initial robust scales against the peak rep
                let mut s0 = vec![0.0f64; full_reps];
                for (rp, sc) in s0.iter_mut().enumerate() {
                    let rep = &chunk[rp * period..rp * period + p0.len()];
                    let mut ratios: Vec<f64> = rep
                        .iter()
                        .zip(&p0)
                        .filter(|(_, &pv)| pv.abs() > 0.5 * p0_ref)
                        .map(|(&x, &pv)| x / pv)
                        .collect();
                    *sc = median(&mut ratios);
                }
                for (i, rv) in refined.iter_mut().enumerate() {
                    let mut vals: Vec<f64> = (0..full_reps)
                        .filter(|&rp| s0[rp].abs() > 1e-300)
                        .map(|rp| chunk[rp * period + i] / s0[rp])
                        .collect();
                    if !vals.is_empty() {
                        *rv = median(&mut vals);
                    }
                }
            }
            // 2b. quantize pattern values (pred = 0) -> recovered pattern
            let mut pattern_rec = vec![0.0f64; period];
            for (i, &pv) in refined.iter().enumerate() {
                let (qi, rec) = pat_q.quantize(pv, 0.0);
                pat_idx.push(qi);
                pattern_rec[i] = rec;
            }
            let pat_energy: f64 = pattern_rec.iter().map(|v| v * v).sum();
            // Robust magnitude reference (75th percentile of |pattern|): the
            // significance mask below must not collapse onto an outlier.
            let pat_ref = {
                let mut mags: Vec<f64> = pattern_rec.iter().map(|v| v.abs()).collect();
                let k = (mags.len() * 3) / 4;
                let k = k.min(mags.len() - 1);
                *mags
                    .select_nth_unstable_by(k, |a, b| {
                        a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal)
                    })
                    .1
            };
            let sq = scale_q.get_or_insert_with(|| {
                self.quant_for::<f64>(scale_eb.max(1e-300), radius)
            });
            // 3+4. per repetition: scale fit then residual quantization
            for rp in 0..nreps {
                let s = rp * period;
                let e = (s + period).min(blen);
                let rep = &chunk[s..e];
                // Robust scale: median of x_i / pattern_i over positions with
                // significant pattern magnitude. A least-squares dot product
                // lets one outlier sample corrupt the whole repetition's
                // prediction; the median confines damage to the outlier.
                let mut ratios: Vec<f64> = rep
                    .iter()
                    .zip(&pattern_rec)
                    .filter(|(_, &p)| p.abs() > 0.5 * pat_ref)
                    .map(|(&x, &p)| x / p)
                    .collect();
                let scale = if !ratios.is_empty() {
                    let mid = ratios.len() / 2;
                    *ratios
                        .select_nth_unstable_by(mid, |a, b| {
                            a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal)
                        })
                        .1
                } else if pat_energy > 0.0 {
                    rep.iter().zip(&pattern_rec).map(|(&x, &p)| x * p).sum::<f64>()
                        / pat_energy
                } else {
                    0.0
                };
                let (si, scale_rec) = sq.quantize(scale, 0.0);
                scale_idx.push(si);
                let scale_rec = scale_rec;
                for (i, _) in rep.iter().enumerate() {
                    let pred = scale_rec * pattern_rec[i];
                    let (qi, rec) = data_q.quantize(values[pos + s + i], pred);
                    data_idx.push(qi);
                    // audit:allow(swallow, reason = "discards a reconstruction value, not a Result; pattern prediction never feeds back")
                    let _ = rec; // pattern prediction never feeds back
                }
            }
            pos += blen;
        }

        // serialize: params, quantizer states, encoded streams, lossless-wrapped
        let enc = FixedHuffmanEncoder::new(radius);
        let mut inner = ByteWriter::new();
        inner.put_varint(period as u64);
        inner.put_varint(n as u64);
        data_q.save(&mut inner)?;
        pat_q.save(&mut inner)?;
        match &scale_q {
            Some(sq) => {
                inner.put_u8(1);
                inner.put_f64(0.0); // reserved
                sq.save(&mut inner)?;
            }
            None => inner.put_u8(0),
        }
        inner.put_varint(pat_idx.len() as u64);
        inner.put_varint(scale_idx.len() as u64);
        enc.encode(&data_idx, &mut inner)?;
        enc.encode(&pat_idx, &mut inner)?;
        enc.encode(&scale_idx, &mut inner)?;
        let ll = lossless::by_name(&self.lossless)
            .ok_or_else(|| SzError::config(format!("unknown lossless {}", self.lossless)))?;
        w.put_str(&self.lossless);
        w.put_block(&ll.compress(&inner.finish())?);
        Ok([data_idx, pat_idx, scale_idx])
    }

    fn decompress_typed<T: Scalar>(
        &self,
        n_total: usize,
        radius: u32,
        r: &mut ByteReader,
    ) -> Result<Vec<T>> {
        let ll_name = r.get_str()?;
        let ll = lossless::by_name(&ll_name)
            .ok_or_else(|| SzError::corrupt(format!("unknown lossless {ll_name}")))?;
        let inner = ll.decompress(r.get_block()?)?;
        let mut ir = ByteReader::new(&inner);
        let period = ir.get_varint()? as usize;
        let n = ir.get_varint()? as usize;
        if n != n_total {
            return Err(SzError::corrupt("pastri: length mismatch"));
        }
        let mut data_q = UnpredAwareQuantizer::<T>::new(1.0, radius);
        data_q.load(&mut ir)?;
        let mut pat_q = UnpredAwareQuantizer::<f64>::new(1.0, radius);
        pat_q.load(&mut ir)?;
        let mut scale_q = if ir.get_u8()? == 1 {
            // the legacy scale hint is parsed (so the cursor advances) but unused
            ir.get_f64()?;
            let mut q = UnpredAwareQuantizer::<f64>::new(1.0, radius);
            q.load(&mut ir)?;
            Some(q)
        } else {
            None
        };
        let n_pat = ir.get_varint()? as usize;
        let n_scale = ir.get_varint()? as usize;
        let enc = FixedHuffmanEncoder::new(radius);
        let data_idx = enc.decode(&mut ir, n)?;
        let pat_idx = enc.decode(&mut ir, n_pat)?;
        let scale_idx = enc.decode(&mut ir, n_scale)?;

        let block = period * REPS_PER_BLOCK;
        let mut out = vec![T::zero(); n];
        let (mut dp, mut pp, mut sp) = (0usize, 0usize, 0usize);
        let mut pos = 0usize;
        while pos < n {
            let blen = block.min(n - pos);
            let nreps = blen.div_ceil(period);
            let mut pattern_rec = vec![0.0f64; period];
            let pat_len = period.min(blen);
            for prv in pattern_rec.iter_mut().take(pat_len) {
                *prv = pat_q.recover(0.0, pat_idx[pp]);
                pp += 1;
            }
            for rp in 0..nreps {
                let s = rp * period;
                let e = (s + period).min(blen);
                let sq = scale_q
                    .as_mut()
                    .ok_or_else(|| SzError::corrupt("pastri: missing scale quantizer"))?;
                let scale_rec = sq.recover(0.0, scale_idx[sp]);
                sp += 1;
                for i in 0..(e - s) {
                    let pred = scale_rec * pattern_rec[i];
                    out[pos + s + i] = data_q.recover(pred, data_idx[dp]);
                    dp += 1;
                }
            }
            pos += blen;
        }
        Ok(out)
    }
}

impl Compressor for PastriCompressor {
    fn name(&self) -> &str {
        &self.name
    }

    fn compress(&self, field: &Field, conf: &CompressConf) -> Result<Vec<u8>> {
        Ok(self.compress_instrumented(field, conf)?.0)
    }

    fn decompress(&self, stream: &[u8]) -> Result<Field> {
        let mut r = ByteReader::new(stream);
        let header = StreamHeader::read(&mut r)?;
        let n = header.len();
        // radius travels inside the quantizer state; use default for the
        // fixed-huffman alphabet derivation, which is stored per-stream.
        let radius = 32768;
        let values = match header.dtype.as_str() {
            "f32" => FieldValues::F32(self.decompress_typed::<f32>(n, radius, &mut r)?),
            "f64" => FieldValues::F64(self.decompress_typed::<f64>(n, radius, &mut r)?),
            "i32" => FieldValues::I32(self.decompress_typed::<i32>(n, radius, &mut r)?),
            other => return Err(SzError::corrupt(format!("unknown dtype {other}"))),
        };
        Field::new(header.field_name, &header.dims, values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::test_support::roundtrip_bound_check;
    use crate::pipeline::ErrorBound;
    use crate::util::rng::Pcg32;

    /// ERI-like signal: periodic pattern scaled per repetition + noise.
    pub(crate) fn eri_like(rng: &mut Pcg32, n: usize, period: usize) -> Vec<f64> {
        let pattern: Vec<f64> = (0..period)
            .map(|i| {
                let t = i as f64 / period as f64;
                (t * 12.0).sin() * (-4.0 * t).exp() + rng.normal() * 0.05
            })
            .collect();
        let mut out = Vec::with_capacity(n);
        let mut scale = 1.0f64;
        for i in 0..n {
            if i % period == 0 {
                scale = 10f64.powf(rng.uniform(-3.0, 0.0));
            }
            let noise = rng.normal() * 1e-6;
            let outlier = if rng.below(50) == 0 { rng.normal() * 0.5 } else { 0.0 };
            out.push(scale * pattern[i % period] + noise + outlier);
        }
        out
    }

    #[test]
    fn detect_period_finds_truth() {
        let mut rng = Pcg32::seeded(51);
        for truth in [16usize, 37, 100] {
            let data = eri_like(&mut rng, 8192, truth);
            let p = PastriCompressor::detect_period(&data);
            assert!(
                p == truth || p % truth == 0 || truth % p == 0,
                "detected {p}, truth {truth}"
            );
        }
    }

    #[test]
    fn all_variants_roundtrip_with_bound() {
        let mut rng = Pcg32::seeded(52);
        let data = eri_like(&mut rng, 10000, 64);
        let f = Field::f64("eri", &[10000], data).unwrap();
        for c in [PastriCompressor::sz(), PastriCompressor::sz_with_zstd(), PastriCompressor::sz3()]
        {
            let conf = CompressConf::with_radius(ErrorBound::Abs(1e-7), 64);
            // decompress_any dispatches by name; all three are registered
            roundtrip_bound_check(&c, &f, &conf);
        }
    }

    #[test]
    fn sz3_beats_sz_and_zstd_variant_on_eri() {
        // The Table 1 ordering: SZ3-Pastri > SZ-Pastri+zstd > SZ-Pastri.
        let mut rng = Pcg32::seeded(53);
        let data = eri_like(&mut rng, 60000, 64);
        let f = Field::f64("eri", &[60000], data).unwrap();
        let conf = CompressConf::with_radius(ErrorBound::Abs(1e-7), 64);
        let size = |c: &PastriCompressor| c.compress(&f, &conf).unwrap().len();
        let s_sz = size(&PastriCompressor::sz());
        let s_zstd = size(&PastriCompressor::sz_with_zstd());
        let s_sz3 = size(&PastriCompressor::sz3());
        assert!(s_zstd < s_sz, "zstd variant {s_zstd} !< sz {s_sz}");
        assert!(s_sz3 < s_zstd, "sz3 {s_sz3} !< zstd variant {s_zstd}");
    }

    #[test]
    fn instrumentation_exposes_three_streams() {
        let mut rng = Pcg32::seeded(54);
        let data = eri_like(&mut rng, 4096, 32);
        let f = Field::f64("eri", &[4096], data).unwrap();
        let conf = CompressConf::with_radius(ErrorBound::Abs(1e-6), 64);
        let c = PastriCompressor { period: Some(32), ..PastriCompressor::sz3() };
        let (_, [d, p, s]) = c.compress_instrumented(&f, &conf).unwrap();
        assert_eq!(d.len(), 4096);
        assert_eq!(p.len(), 32 * (4096usize.div_ceil(32 * REPS_PER_BLOCK)));
        assert_eq!(s.len(), 4096 / 32);
        // distribution centered around the zero bin (= radius = 64), as in
        // Fig. 3: the bulk of predictable indices lie within a few bins
        let near_center = d
            .iter()
            .filter(|&&x| x != 0 && (x as i64 - 64).abs() <= 4)
            .count();
        let predictable = d.iter().filter(|&&x| x != 0).count();
        assert!(
            near_center * 10 > predictable * 9,
            "{near_center} of {predictable} predictable indices near center"
        );
    }
}

#[cfg(test)]
mod robustness_tests {
    use super::*;
    use crate::pipeline::{CompressConf, ErrorBound};
    use crate::util::rng::Pcg32;

    #[test]
    fn outliers_stay_contained() {
        // Regression test for the robust pattern/scale fit: sparse outliers
        // (~2% of samples) must not poison whole repetitions. With a
        // max-based peak choice and least-squares scales the unpredictable
        // rate was >60%; the robust fit keeps it near the outlier rate.
        let mut rng = Pcg32::seeded(54);
        let data = super::tests::eri_like(&mut rng, 4096, 32);
        let f = Field::f64("eri", &[4096], data.clone()).unwrap();
        let conf = CompressConf::with_radius(ErrorBound::Abs(1e-6), 64);
        let c = PastriCompressor { period: Some(32), ..PastriCompressor::sz3() };
        let (stream, [d, _p, _s]) = c.compress_instrumented(&f, &conf).unwrap();
        let unpred = d.iter().filter(|&&x| x == 0).count();
        assert!(
            unpred * 10 < d.len(),
            "unpredictable rate too high: {unpred}/{}",
            d.len()
        );
        // and the stream still respects the bound
        let out = c.decompress(&stream).unwrap();
        for (o, dc) in data.iter().zip(&out.values.to_f64_vec()) {
            assert!((o - dc).abs() <= 1e-6 * (1.0 + 1e-12));
        }
    }
}
