//! Benchmark harness substrate (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive [`Bench`]:
//! warmup, fixed-time measurement, mean/σ/min reporting, and a CSV-ish
//! line format the experiment scripts grep. Also hosts the
//! rate-distortion sweep runner shared by the figure-regeneration benches.

use crate::container;
use crate::coordinator::Coordinator;
use crate::data::Field;
use crate::error::{Result, SzError};
use crate::metrics::{self, Metrics};
use crate::pipeline::{decompress_any, CompressConf, Compressor, ErrorBound};
use std::time::{Duration, Instant};

/// Timing statistics for one benchmark case.
#[derive(Clone, Debug)]
pub struct Sample {
    /// Case label.
    pub name: String,
    /// Mean iteration time.
    pub mean: Duration,
    /// Standard deviation.
    pub stddev: Duration,
    /// Fastest iteration.
    pub min: Duration,
    /// Iterations measured.
    pub iters: usize,
}

impl std::fmt::Display for Sample {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} mean {:>10.3?}  σ {:>9.3?}  min {:>10.3?}  n={}",
            self.name, self.mean, self.stddev, self.min, self.iters
        )
    }
}

/// Simple time-budgeted benchmark runner.
pub struct Bench {
    /// Warmup budget per case.
    pub warmup: Duration,
    /// Measurement budget per case.
    pub measure: Duration,
    /// Hard cap on iterations.
    pub max_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: Duration::from_millis(300), measure: Duration::from_secs(2), max_iters: 1000 }
    }
}

impl Bench {
    /// Quick profile for CI-ish runs.
    pub fn quick() -> Self {
        Bench { warmup: Duration::from_millis(50), measure: Duration::from_millis(400), max_iters: 50 }
    }

    /// Run `f` repeatedly and report stats. The closure's return value is
    /// black-boxed to keep the optimizer honest.
    pub fn run<R, F: FnMut() -> R>(&self, name: &str, mut f: F) -> Sample {
        let warm_until = Instant::now() + self.warmup;
        while Instant::now() < warm_until {
            std::hint::black_box(f());
        }
        let mut times = Vec::new();
        let stop = Instant::now() + self.measure;
        while Instant::now() < stop && times.len() < self.max_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed());
        }
        if times.is_empty() {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed());
        }
        let n = times.len();
        let mean_ns = times.iter().map(|t| t.as_nanos() as f64).sum::<f64>() / n as f64;
        let var = times
            .iter()
            .map(|t| {
                let d = t.as_nanos() as f64 - mean_ns;
                d * d
            })
            .sum::<f64>()
            / n as f64;
        Sample {
            name: name.to_string(),
            mean: Duration::from_nanos(mean_ns as u64),
            stddev: Duration::from_nanos(var.sqrt() as u64),
            min: *times.iter().min().unwrap(),
            iters: n,
        }
    }

    /// Measure throughput in MB/s for a body processing `bytes` per call.
    pub fn throughput<R, F: FnMut() -> R>(&self, name: &str, bytes: usize, f: F) -> (Sample, f64) {
        let s = self.run(name, f);
        let mbs = bytes as f64 / 1e6 / s.mean.as_secs_f64().max(1e-12);
        (s, mbs)
    }
}

/// One point on a rate-distortion curve.
#[derive(Clone, Debug)]
pub struct RdPoint {
    /// Relative (value-range) error bound used.
    pub rel_eb: f64,
    /// Quality metrics at that bound.
    pub metrics: Metrics,
}

/// Sweep a pipeline over relative error bounds — the generator behind every
/// rate-distortion figure (Figs. 4, 6, 7).
pub fn rd_sweep(
    compressor: &dyn Compressor,
    field: &Field,
    rel_bounds: &[f64],
    radius: u32,
) -> Vec<RdPoint> {
    let mut out = Vec::with_capacity(rel_bounds.len());
    for &rel in rel_bounds {
        let conf = CompressConf::with_radius(ErrorBound::Rel(rel), radius);
        let stream = match compressor.compress(field, &conf) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("# {} failed at rel={rel}: {e}", compressor.name());
                continue;
            }
        };
        let len = stream.len();
        match decompress_any(&stream) {
            Ok(dec) => out.push(RdPoint { rel_eb: rel, metrics: metrics::evaluate(field, &dec, len) }),
            Err(e) => eprintln!("# {} decode failed at rel={rel}: {e}", compressor.name()),
        }
    }
    out
}

/// Outcome of one coordinator → container → parallel-decompress round trip.
#[derive(Clone, Debug)]
pub struct ContainerRun {
    /// Compression-side coordinator report.
    pub report: crate::coordinator::RunReport,
    /// Container artifact size in bytes.
    pub artifact_bytes: usize,
    /// Wall-clock of the parallel decompression fan-out.
    pub decompress_wall: Duration,
    /// Chunk counts per pipeline (the adaptive-selection mix).
    pub per_pipeline: Vec<(String, usize)>,
}

impl ContainerRun {
    /// End-to-end ratio over the container artifact (index included).
    pub fn ratio(&self) -> f64 {
        self.report.bytes_in as f64 / self.artifact_bytes.max(1) as f64
    }

    /// Decompression throughput over uncompressed bytes (MB/s).
    pub fn decompress_mbs(&self) -> f64 {
        self.report.bytes_in as f64 / 1e6 / self.decompress_wall.as_secs_f64().max(1e-9)
    }
}

/// Drive `fields` through the coordinator into a container, decompress it
/// across `coord.workers` threads, and verify every field's shape and name
/// roundtripped. The workhorse behind the container benches.
pub fn container_roundtrip(coord: &Coordinator, fields: Vec<Field>) -> Result<ContainerRun> {
    let shapes: Vec<(String, Vec<usize>)> = fields
        .iter()
        .map(|f| (f.name.clone(), f.shape.dims().to_vec()))
        .collect();
    let (artifact, report) = coord.run_to_container(fields)?;
    let per_pipeline: Vec<(String, usize)> =
        report.per_pipeline.iter().map(|(p, &n)| (p.clone(), n)).collect();
    let t0 = Instant::now();
    let decoded = container::decompress_container(&artifact, coord.workers)?;
    let decompress_wall = t0.elapsed();
    if decoded.len() != shapes.len() {
        return Err(SzError::corrupt(format!(
            "container returned {} of {} fields",
            decoded.len(),
            shapes.len()
        )));
    }
    for (f, (name, dims)) in decoded.iter().zip(&shapes) {
        if f.name != *name || f.shape.dims() != dims.as_slice() {
            return Err(SzError::corrupt(format!(
                "field {name}: roundtrip shape {:?} != {dims:?}",
                f.shape.dims()
            )));
        }
    }
    Ok(ContainerRun {
        artifact_bytes: artifact.len(),
        report,
        decompress_wall,
        per_pipeline,
    })
}

/// Machine-readable perf summary the bench targets emit (e.g.
/// `BENCH_PR2.json`): a flat metric → value map in insertion order, so CI
/// can diff throughput trajectories across PRs without parsing the human
/// bench lines. Hand-rolled JSON (serde is unavailable offline).
#[derive(Clone, Debug, Default)]
pub struct PerfSummary {
    metrics: Vec<(String, f64)>,
}

impl PerfSummary {
    /// Empty summary.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record (or overwrite) one metric, conventionally a throughput in
    /// MB/s or a unitless ratio; the name should say which
    /// (`compress_mbs`, `roi_warm_mbs`, `ratio`).
    pub fn record(&mut self, name: &str, value: f64) {
        match self.metrics.iter_mut().find(|(n, _)| n == name) {
            Some((_, v)) => *v = value,
            None => self.metrics.push((name.to_string(), value)),
        }
    }

    /// Metrics recorded so far.
    pub fn metrics(&self) -> &[(String, f64)] {
        &self.metrics
    }

    /// Serialize as a JSON object. Non-finite values (a bench that failed
    /// to produce a rate) serialize as null, which JSON parsers accept and
    /// trend tooling treats as a gap.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        for (i, (name, value)) in self.metrics.iter().enumerate() {
            let sep = if i + 1 == self.metrics.len() { "" } else { "," };
            if value.is_finite() {
                out.push_str(&format!("  \"{name}\": {value:.4}{sep}\n"));
            } else {
                out.push_str(&format!("  \"{name}\": null{sep}\n"));
            }
        }
        out.push('}');
        out
    }

    /// Write the JSON summary to `path`.
    pub fn write_json(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json() + "\n")?;
        Ok(())
    }
}

/// Print an RD series in the grep-able format used by EXPERIMENTS.md:
/// `rd,<figure>,<dataset>,<pipeline>,<rel_eb>,<bitrate>,<psnr>,<ratio>`.
pub fn print_rd_series(figure: &str, dataset: &str, pipeline: &str, points: &[RdPoint]) {
    for p in points {
        println!(
            "rd,{figure},{dataset},{pipeline},{:.3e},{:.4},{:.2},{:.2}",
            p.rel_eb, p.metrics.bit_rate, p.metrics.psnr, p.metrics.ratio
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline;
    use crate::util::prop;

    #[test]
    fn bench_reports_sane_numbers() {
        let b = Bench { warmup: Duration::ZERO, measure: Duration::from_millis(30), max_iters: 10 };
        let s = b.run("noop", || 1 + 1);
        assert!(s.iters >= 1);
        assert!(s.min <= s.mean + s.stddev);
    }

    #[test]
    fn container_roundtrip_verifies_shapes() {
        let cfg = crate::config::JobConfig {
            pipeline: "sz3-lr".into(),
            bound: ErrorBound::Abs(1e-3),
            workers: 2,
            chunk_elems: 2048,
            queue_depth: 2,
            ..Default::default()
        };
        let coord = crate::coordinator::Coordinator::from_config(&cfg).unwrap();
        let mut rng = crate::util::rng::Pcg32::seeded(19);
        let dims = [16usize, 16, 16];
        let f = Field::f32("cube", &dims, prop::smooth_field(&mut rng, &dims)).unwrap();
        let run = container_roundtrip(&coord, vec![f]).unwrap();
        assert!(run.ratio() > 1.0);
        assert_eq!(
            run.per_pipeline,
            vec![(pipeline::canonical("sz3-lr").unwrap(), run.report.chunks)]
        );
    }

    #[test]
    fn perf_summary_json_is_well_formed() {
        let mut s = PerfSummary::new();
        s.record("compress_mbs", 123.456);
        s.record("roi_cold_mbs", 7.0);
        s.record("compress_mbs", 200.0); // overwrite keeps position
        s.record("broken", f64::NAN);
        let json = s.to_json();
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"compress_mbs\": 200.0000"));
        assert!(json.contains("\"roi_cold_mbs\": 7.0000"));
        assert!(json.contains("\"broken\": null"));
        // the overwritten key appears exactly once
        assert_eq!(json.matches("compress_mbs").count(), 1);
        // reuse the crate's own JSON parser as the well-formedness oracle
        let parsed = crate::config::Json::parse(&json).unwrap();
        assert!(parsed.get("compress_mbs").is_some());
    }

    #[test]
    fn rd_sweep_monotonic_ratio() {
        let mut rng = crate::util::rng::Pcg32::seeded(17);
        let dims = [32usize, 32];
        let f = Field::f32("t", &dims, prop::smooth_field(&mut rng, &dims)).unwrap();
        let c = pipeline::build("sz3-lr").unwrap();
        let pts = rd_sweep(c.as_ref(), &f, &[1e-1, 1e-3, 1e-5], 32768);
        assert_eq!(pts.len(), 3);
        // looser bound => higher ratio (weak monotonicity with slack)
        assert!(pts[0].metrics.ratio >= pts[2].metrics.ratio * 0.8);
        // tighter bound => higher psnr
        assert!(pts[2].metrics.psnr > pts[0].metrics.psnr);
    }
}
