//! Synthetic dataset generators standing in for the paper's proprietary /
//! facility data (see DESIGN.md §2 for the substitution rationale).
//!
//! * [`gamess`] — ERI-like periodic scaled-pattern streams (paper §4).
//! * [`aps`] — ptychography-like diffraction stacks (paper §5).
//! * [`fields`] — the eight-application survey of Table 3 / Figs. 7-8 at
//!   reduced dimensions, each generator reproducing the correlation
//!   structure its domain is known for.

pub mod aps;
pub mod fields;
pub mod gamess;

use crate::data::Field;

/// A named dataset: a set of fields plus provenance notes.
pub struct Dataset {
    /// Registry name (e.g. "nyx").
    pub name: &'static str,
    /// Science domain (Table 3 column).
    pub domain: &'static str,
    /// Generated fields.
    pub fields: Vec<Field>,
    /// What the generator mimics and why it is a valid stand-in.
    pub notes: &'static str,
}

impl Dataset {
    /// Total bytes across fields.
    pub fn nbytes(&self) -> usize {
        self.fields.iter().map(|f| f.nbytes()).sum()
    }
}

/// Registry of the Table 3 survey datasets (reduced-size stand-ins).
pub fn survey(seed: u64) -> Vec<Dataset> {
    vec![
        fields::hacc(seed),
        fields::atm(seed),
        fields::hurricane(seed),
        fields::nyx(seed),
        fields::scale_letkf(seed),
        fields::qmcpack(seed),
        fields::rtm(seed),
        fields::miranda(seed),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn survey_has_eight_apps() {
        let sets = survey(1);
        assert_eq!(sets.len(), 8);
        for ds in &sets {
            assert!(!ds.fields.is_empty(), "{} has no fields", ds.name);
            for f in &ds.fields {
                assert!(f.len() > 0);
                let (lo, hi) = f.value_range();
                assert!(hi >= lo);
                assert!(
                    f.values.to_f64_vec().iter().all(|v| v.is_finite()),
                    "{}/{} has non-finite values",
                    ds.name,
                    f.name
                );
            }
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = fields::miranda(7);
        let b = fields::miranda(7);
        assert_eq!(a.fields[0].values, b.fields[0].values);
        let c = fields::miranda(8);
        assert_ne!(a.fields[0].values, c.fields[0].values);
    }
}
