//! APS ptychography-like stack generator (paper §5 substitution).
//!
//! A Dectris Eiger detector records diffraction patterns as the X-ray beam
//! scans the sample; frames are stacked along time. The properties the
//! SZ3-APS pipeline keys on, reproduced here:
//!   * integer photon counts (Poisson statistics),
//!   * strong frame-to-frame (time) correlation — the beam moves slowly
//!     relative to the frame rate, so consecutive frames see nearly the
//!     same diffraction pattern,
//!   * weak in-frame spatial correlation (speckle + Airy rings),
//!   * an isolated-sample variant ("chip pillar": compact support, dark
//!     background) and an extended-sample variant ("flat chip": signal
//!     across the frame).

use crate::data::Field;
use crate::util::rng::Pcg32;

/// Sample geometry (the paper's two acquisitions).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sample {
    /// Isolated computer-chip pillar: compact diffraction, dark field.
    ChipPillar,
    /// Extended flat chip: structured signal across the detector.
    FlatChip,
}

impl Sample {
    /// Dataset name as in Fig. 6.
    pub fn name(self) -> &'static str {
        match self {
            Sample::ChipPillar => "chip-pillar",
            Sample::FlatChip => "flat-chip",
        }
    }
}

/// Generate a (time, h, w) stack of diffraction-like Poisson counts.
pub fn diffraction_stack(sample: Sample, t: usize, h: usize, w: usize, seed: u64) -> Field {
    let mut rng = Pcg32::new(seed, sample as u64 + 200);
    // static speckle field (sample structure) — frozen across time
    let speckle: Vec<f64> =
        (0..h * w).map(|_| rng.uniform(0.3, 1.7)).collect();
    let mut out = Vec::with_capacity(t * h * w);
    let (peak, bg, ring_scale) = match sample {
        Sample::ChipPillar => (800.0, 0.05, 6.0),
        Sample::FlatChip => (300.0, 2.0, 3.0),
    };
    for ti in 0..t {
        // slow scan drift: beam position moves smoothly with time
        let phase = ti as f64 * 0.02;
        let cy = h as f64 / 2.0 + 1.5 * (phase * 2.0).sin();
        let cx = w as f64 / 2.0 + 1.5 * (phase * 3.1).cos();
        let intensity_scale = 1.0 + 0.1 * (phase * 5.0).sin();
        for y in 0..h {
            for x in 0..w {
                let dy = y as f64 - cy;
                let dx = x as f64 - cx;
                let r = (dy * dy + dx * dx).sqrt() / (h.min(w) as f64 / ring_scale);
                // Airy-like ringed falloff modulated by the sample speckle
                let airy = (-1.2 * r).exp() * (1.0 + 0.5 * (r * 9.0).cos());
                let lambda =
                    (peak * airy * speckle[y * w + x] * intensity_scale + bg).max(0.0);
                out.push(rng.poisson(lambda) as f32);
            }
        }
    }
    Field::f32(sample.name(), &[t, h, w], out).expect("valid field")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temporal_vs_spatial_corr(f: &Field) -> (f64, f64) {
        let dims = f.shape.dims();
        let (t, h, w) = (dims[0], dims[1], dims[2]);
        let v = f.values.to_f64_vec();
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / v.len() as f64;
        let mut ct = 0.0;
        let mut cs = 0.0;
        let mut nt = 0usize;
        let mut ns = 0usize;
        for ti in 0..t - 1 {
            for y in 0..h {
                for x in 0..w {
                    let a = v[(ti * h + y) * w + x] - mean;
                    let b = v[((ti + 1) * h + y) * w + x] - mean;
                    ct += a * b;
                    nt += 1;
                    if x + 1 < w {
                        let c = v[(ti * h + y) * w + x + 1] - mean;
                        cs += a * c;
                        ns += 1;
                    }
                }
            }
        }
        (ct / nt as f64 / var, cs / ns as f64 / var)
    }

    #[test]
    fn temporal_correlation_dominates() {
        // the property §5.2 builds the adaptive pipeline on
        for sample in [Sample::ChipPillar, Sample::FlatChip] {
            let f = diffraction_stack(sample, 24, 24, 24, 5);
            let (ct, cs) = temporal_vs_spatial_corr(&f);
            assert!(
                ct > cs + 0.05,
                "{}: temporal {ct:.3} should exceed spatial {cs:.3}",
                f.name
            );
        }
    }

    #[test]
    fn counts_are_integer_valued() {
        let f = diffraction_stack(Sample::ChipPillar, 4, 16, 16, 6);
        let v = f.values.to_f64_vec();
        assert!(v.iter().all(|x| x.fract() == 0.0 && *x >= 0.0));
    }
}
