//! GAMESS ERI-like stream generator (paper §4 substitution).
//!
//! Two-electron repulsion integrals are computed shell-quartet by
//! shell-quartet; within a quartet the integral values follow a common
//! angular pattern scaled by a distance/exponent-dependent factor, which is
//! exactly what SZ-Pastri exploits. The generator reproduces:
//!   * a periodic base pattern per field (different per ERI class),
//!   * per-repetition exponential scaling across many decades,
//!   * a heavy unpredictable tail (~20% pattern-breaking values, the
//!     Fig. 3 "data" histogram tail),
//!   * double precision storage (ERI data is f64).

use crate::data::Field;
use crate::util::rng::Pcg32;

/// ERI field flavors mirroring the paper's three GAMESS fields.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EriClass {
    /// (ff|ff): high angular momentum — long period, oscillatory pattern.
    FfFf,
    /// (ff|dd): mixed — medium period.
    FfDd,
    /// (dd|dd): lower angular momentum — short period, smoother decay.
    DdDd,
}

impl EriClass {
    /// Field name as in Table 1 / Fig. 4.
    pub fn name(self) -> &'static str {
        match self {
            EriClass::FfFf => "ff|ff",
            EriClass::FfDd => "ff|dd",
            EriClass::DdDd => "dd|dd",
        }
    }

    fn period(self) -> usize {
        match self {
            EriClass::FfFf => 49 * 4, // (2*3+1)^2 * shells
            EriClass::FfDd => 35 * 4,
            EriClass::DdDd => 25 * 4,
        }
    }

    fn oscillation(self) -> f64 {
        match self {
            EriClass::FfFf => 17.0,
            EriClass::FfDd => 11.0,
            EriClass::DdDd => 7.0,
        }
    }
}

/// Generate one ERI-like field of `n` doubles.
pub fn eri_field(class: EriClass, n: usize, seed: u64) -> Field {
    let mut rng = Pcg32::new(seed, class as u64 + 100);
    let p = class.period();
    // base angular pattern: oscillation under exponential envelope + jitter
    let pattern: Vec<f64> = (0..p)
        .map(|i| {
            let t = i as f64 / p as f64;
            (t * class.oscillation()).sin() * (-3.5 * t).exp()
                + 0.02 * rng.normal()
        })
        .collect();
    let mut out = Vec::with_capacity(n);
    let mut scale = 1.0f64;
    for i in 0..n {
        if i % p == 0 {
            // quartet scale: log-uniform over ~6 decades (screening decay)
            scale = 10f64.powf(rng.uniform(-7.0, -1.0));
        }
        let base = scale * pattern[i % p];
        // ~20% unpredictable tail: values that break the pattern (different
        // primitive contractions), matching the Fig. 3 characterization.
        // In-pattern noise is kept near the scientists' 1e-10 requirement
        // relative to the local scale, so predictable points stay within a
        // few quantization bins (as in the paper's Fig. 3 histogram).
        let v = if rng.below(5) == 0 {
            base * rng.uniform(0.2, 5.0) + scale * 0.1 * rng.normal()
        } else {
            base + scale * 3e-7 * rng.normal()
        };
        out.push(v);
    }
    Field::f64(class.name(), &[n], out).expect("valid field")
}

/// The three-field GAMESS dataset used by Table 1 / Figs. 3-4.
pub fn gamess_dataset(n_per_field: usize, seed: u64) -> Vec<Field> {
    vec![
        eri_field(EriClass::FfFf, n_per_field, seed),
        eri_field(EriClass::FfDd, n_per_field, seed),
        eri_field(EriClass::DdDd, n_per_field, seed),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::PastriCompressor;

    #[test]
    fn fields_have_expected_shape_and_range() {
        for class in [EriClass::FfFf, EriClass::FfDd, EriClass::DdDd] {
            let f = eri_field(class, 50_000, 3);
            assert_eq!(f.len(), 50_000);
            let (lo, hi) = f.value_range();
            assert!(hi > 0.0 && lo < 0.0, "{}: range ({lo}, {hi})", f.name);
            assert!(hi < 1.0, "scales should stay ≤ ~0.1");
        }
    }

    #[test]
    fn period_is_detectable() {
        let f = eri_field(EriClass::DdDd, 40_000, 9);
        let data = f.values.to_f64_vec();
        let p = PastriCompressor::detect_period(&data);
        let truth = EriClass::DdDd.period();
        assert!(
            p == truth || p % truth == 0 || (truth % p == 0 && p >= 8),
            "detected {p}, truth {truth}"
        );
    }
}
