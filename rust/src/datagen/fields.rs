//! Reduced-size stand-ins for the Table 3 survey applications.
//!
//! Rate-distortion *shape* (Figs. 7-8) is governed by smoothness and
//! correlation structure, which each generator reproduces for its domain:
//! cosmology fields are clumpy with huge dynamic range, climate fields are
//! smooth with fronts and latitudinal trends, turbulence is multi-scale
//! smooth, seismic wavefields are oscillatory wavefronts, QMC orbitals are
//! smooth 4-D envelopes. Dimensions are scaled down ~one order per axis
//! from Table 3 to keep benches tractable.

use super::Dataset;
use crate::data::Field;
use crate::util::rng::Pcg32;

/// Sum of random Fourier modes over `dims`, with per-mode frequency range
/// and amplitude decay `spectrum(k) = k^-slope` — the all-purpose smooth
/// field. `octaves` controls multi-scale content.
fn spectral_field(
    rng: &mut Pcg32,
    dims: &[usize],
    octaves: usize,
    slope: f64,
    modes_per_octave: usize,
) -> Vec<f32> {
    let n: usize = dims.iter().product();
    let nd = dims.len();
    struct Mode {
        amp: f64,
        freq: Vec<f64>,
        phase: f64,
    }
    let mut modes = Vec::new();
    for o in 0..octaves {
        let base = 2f64.powi(o as i32);
        for _ in 0..modes_per_octave {
            let freq: Vec<f64> = (0..nd).map(|_| rng.uniform(0.5, 1.0) * base).collect();
            modes.push(Mode {
                amp: base.powf(-slope) * rng.uniform(0.5, 1.5),
                freq,
                phase: rng.uniform(0.0, std::f64::consts::TAU),
            });
        }
    }
    let mut out = vec![0f32; n];
    let mut idx = vec![0usize; nd];
    for v in out.iter_mut() {
        let mut val = 0.0;
        for m in &modes {
            let arg: f64 = idx
                .iter()
                .zip(dims)
                .zip(&m.freq)
                .map(|((&i, &d), &f)| f * i as f64 / d as f64 * std::f64::consts::TAU)
                .sum::<f64>()
                + m.phase;
            val += m.amp * arg.sin();
        }
        *v = val as f32;
        for d in (0..nd).rev() {
            idx[d] += 1;
            if idx[d] < dims[d] {
                break;
            }
            idx[d] = 0;
        }
    }
    out
}

/// HACC-like cosmology particle-grid field: clumpy log-normal density plus
/// broad velocity fields with huge dynamic range.
pub fn hacc(seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed, 301);
    let dims = [28usize, 96, 86]; // ~1/10 per axis of 280×953×867
    let smooth = spectral_field(&mut rng, &dims, 4, 1.2, 4);
    // log-normal density: exponentiate a correlated Gaussian field
    let density: Vec<f32> = smooth.iter().map(|&x| (1.8 * x as f64).exp() as f32).collect();
    let vx = spectral_field(&mut rng, &dims, 3, 1.5, 4)
        .iter()
        .map(|&x| x * 300.0)
        .collect();
    let vy = spectral_field(&mut rng, &dims, 3, 1.5, 4)
        .iter()
        .map(|&x| x * 300.0)
        .collect();
    Dataset {
        name: "hacc",
        domain: "Cosmology",
        fields: vec![
            Field::f32("rho", &dims, density).unwrap(),
            Field::f32("vx", &dims, vx).unwrap(),
            Field::f32("vy", &dims, vy).unwrap(),
        ],
        notes: "log-normal clumpy density + broadband velocities; rough \
                small-scale structure like HACC particle-deposited grids",
    }
}

/// ATM-like 2-D climate field: smooth large-scale flow + latitudinal trend.
pub fn atm(seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed, 302);
    let dims = [360usize, 720]; // 1/5 of 1800×3600
    let mut base = spectral_field(&mut rng, &dims, 3, 1.8, 5);
    for (i, v) in base.iter_mut().enumerate() {
        let lat = (i / dims[1]) as f64 / dims[0] as f64; // 0..1
        // equator-to-pole trend dominates, as in temperature fields
        *v = (*v as f64 * 4.0 + 40.0 * (std::f64::consts::PI * lat).sin() - 10.0) as f32;
    }
    let humidity = spectral_field(&mut rng, &dims, 4, 1.3, 5)
        .iter()
        .map(|&x| (x * 0.2 + 0.5).clamp(0.0, 1.0))
        .collect();
    Dataset {
        name: "atm",
        domain: "Climate",
        fields: vec![
            Field::f32("temperature", &dims, base).unwrap(),
            Field::f32("humidity", &dims, humidity).unwrap(),
        ],
        notes: "smooth synoptic-scale modes + latitudinal trend (T) and \
                clamped moisture-like field",
    }
}

/// Hurricane-WRF-like 3-D field: vortex + fronts.
pub fn hurricane(seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed, 303);
    let dims = [25usize, 125, 125]; // 1/4 of 100×500×500
    let mut wind = spectral_field(&mut rng, &dims, 4, 1.4, 4);
    let (cz, cy, cx) = (dims[0] as f64 / 2.0, dims[1] as f64 / 2.0, dims[2] as f64 / 2.0);
    let mut i = 0usize;
    let mut gust = 0.0f64;
    for z in 0..dims[0] {
        for y in 0..dims[1] {
            for x in 0..dims[2] {
                let dy = y as f64 - cy;
                let dx = x as f64 - cx;
                let r = (dy * dy + dx * dx).sqrt() + 1.0;
                // Rankine-like vortex with height decay
                let vortex = 60.0 * (r / 15.0).min(15.0 / r) * (-((z as f64 - cz).abs()) / 12.0).exp();
                // short-correlation gust texture (see scale_letkf note)
                gust = 0.65 * gust + 0.35 * rng.normal();
                wind[i] = (wind[i] as f64 * 3.0 + vortex + 0.8 * gust) as f32;
                i += 1;
            }
        }
    }
    Dataset {
        name: "hurricane",
        domain: "Climate",
        fields: vec![Field::f32("wind", &dims, wind).unwrap()],
        notes: "Rankine vortex embedded in broadband flow; sharp radial \
                gradients like Hurricane-WRF wind fields",
    }
}

/// NYX-like cosmology hydro field: baryon density (log-normal, steeper).
pub fn nyx(seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed, 304);
    let dims = [64usize, 64, 64]; // 1/8 of 512³
    let smooth = spectral_field(&mut rng, &dims, 5, 1.0, 4);
    let density: Vec<f32> =
        smooth.iter().map(|&x| (2.4 * x as f64).exp() as f32).collect();
    let temp: Vec<f32> = spectral_field(&mut rng, &dims, 4, 1.5, 4)
        .iter()
        .map(|&x| ((x as f64 * 0.8 + 4.0) * 1e4) as f32)
        .collect();
    Dataset {
        name: "nyx",
        domain: "Cosmology",
        fields: vec![
            Field::f32("baryon_density", &dims, density).unwrap(),
            Field::f32("temperature", &dims, temp).unwrap(),
        ],
        notes: "steeper log-normal density (shock-heated baryons) + smooth \
                temperature; NYX AMR-grid-like statistics",
    }
}

/// SCALE-LETKF-like 3-D NWP ensemble field.
pub fn scale_letkf(seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed, 305);
    let dims = [30usize, 150, 150]; // ~1/8 of 98×1200×1200
    let mut qv = spectral_field(&mut rng, &dims, 5, 1.1, 4);
    // moisture: non-negative with sharp cloud boundaries (rectified field)
    // plus short-correlation AR(1) microstructure (turbulent mixing) — the
    // texture regime where Lorenzo's 1-step prediction beats the dyadic
    // interpolation stencil at tight bounds (Fig. 7 Scale behaviour)
    let mut ar = 0.0f64;
    for v in qv.iter_mut() {
        ar = 0.7 * ar + 0.3 * rng.normal();
        let cloudy = (*v > 0.35) as u8 as f64;
        *v = (((*v as f64 - 0.4).max(0.0) + 0.15 * ar.abs() * cloudy) * 1e-3) as f32;
    }
    Dataset {
        name: "scale-letkf",
        domain: "Climate",
        fields: vec![Field::f32("qv", &dims, qv).unwrap()],
        notes: "rectified moisture with cloud edges — hard for regression, \
                good for Lorenzo at tight bounds (the Fig. 7 Scale case)",
    }
}

/// QMCPack-like 4-D orbital batch.
pub fn qmcpack(seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed, 306);
    let dims = [48usize, 29, 35, 35]; // ~1/4 of 288×115×69×69
    let n: usize = dims.iter().product();
    let base = spectral_field(&mut rng, &dims, 3, 1.6, 3);
    let mut orbitals = vec![0f32; n];
    let per_orbital: usize = dims[1] * dims[2] * dims[3];
    for (i, v) in orbitals.iter_mut().enumerate() {
        let orb = i / per_orbital;
        let r = (i % per_orbital) as f64 / per_orbital as f64;
        // orbital envelope decays with a per-orbital rate
        let envelope = (-(2.0 + (orb % 7) as f64) * r).exp();
        *v = base[i] * envelope as f32;
    }
    Dataset {
        name: "qmcpack",
        domain: "Quantum Structure",
        fields: vec![Field::f32("orbitals", &dims, orbitals).unwrap()],
        notes: "smooth 4-D spline-like orbitals with per-orbital decay \
                envelopes",
    }
}

/// RTM-like seismic wavefield snapshot.
pub fn rtm(seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed, 307);
    let dims = [90usize, 90, 47]; // 1/5 of 449×449×235
    let n: usize = dims.iter().product();
    let mut wave = vec![0f32; n];
    // expanding spherical wavefronts from a few sources over layered media
    let sources: Vec<(f64, f64, f64, f64)> = (0..4)
        .map(|_| {
            (
                rng.uniform(0.0, dims[0] as f64),
                rng.uniform(0.0, dims[1] as f64),
                rng.uniform(0.0, dims[2] as f64 / 3.0),
                rng.uniform(15.0, 40.0), // wavefront radius
            )
        })
        .collect();
    let mut i = 0usize;
    for z in 0..dims[0] {
        for y in 0..dims[1] {
            for x in 0..dims[2] {
                let mut v = 0.0f64;
                for &(sz, sy, sx, r0) in &sources {
                    let dz = z as f64 - sz;
                    let dy = y as f64 - sy;
                    let dx = x as f64 - sx;
                    let r = (dz * dz + dy * dy + dx * dx).sqrt();
                    // Ricker-like wavelet on the front
                    let u = (r - r0) / 4.0;
                    v += (1.0 - 2.0 * u * u) * (-u * u).exp() / (1.0 + r * 0.05);
                }
                // layered background impedance
                v += 0.05 * ((z as f64) * 0.7).sin();
                wave[i] = v as f32;
                i += 1;
            }
        }
    }
    Dataset {
        name: "rtm",
        domain: "Seismic Wave",
        fields: vec![Field::f32("pressure", &dims, wave).unwrap()],
        notes: "Ricker wavefronts over layered media — oscillatory, locally \
                smooth, like reverse-time-migration snapshots",
    }
}

/// Miranda-like turbulence field.
pub fn miranda(seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed, 308);
    let dims = [64usize, 96, 96]; // 1/4 of 256×384×384
    let density = spectral_field(&mut rng, &dims, 5, 5.0 / 3.0, 6); // Kolmogorov-ish
    let viscosity: Vec<f32> = spectral_field(&mut rng, &dims, 4, 2.0, 5)
        .iter()
        .map(|&x| x * 0.1 + 1.0)
        .collect();
    Dataset {
        name: "miranda",
        domain: "Turbulence",
        fields: vec![
            Field::f32("density", &dims, density).unwrap(),
            Field::f32("viscosity", &dims, viscosity).unwrap(),
        ],
        notes: "k^-5/3 spectral slope, very smooth at fine scales — the \
                regime where interpolation dominates (Fig. 7 Miranda)",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spectral_field_is_smooth() {
        let mut rng = Pcg32::seeded(1);
        let dims = [32usize, 32];
        let f = spectral_field(&mut rng, &dims, 3, 1.5, 4);
        // mean |gradient| much smaller than value range
        let (lo, hi) = f
            .iter()
            .fold((f32::MAX, f32::MIN), |(l, h), &x| (l.min(x), h.max(x)));
        let range = (hi - lo) as f64;
        let mut grad = 0.0;
        let mut cnt = 0;
        for y in 0..32 {
            for x in 1..32 {
                grad += (f[y * 32 + x] - f[y * 32 + x - 1]).abs() as f64;
                cnt += 1;
            }
        }
        assert!(grad / cnt as f64 <= 0.35 * range);
    }

    #[test]
    fn miranda_smoother_than_hacc() {
        // The property that drives the Fig. 7 ordering: mean |first
        // difference| normalized by the mean absolute deviation. A
        // range-normalized metric would be fooled by hacc's rare density
        // peaks inflating the range.
        let roughness = |f: &Field| {
            let v = f.values.to_f64_vec();
            let mean = v.iter().sum::<f64>() / v.len() as f64;
            let mad =
                v.iter().map(|x| (x - mean).abs()).sum::<f64>() / v.len() as f64;
            let g: f64 =
                v.windows(2).map(|w| (w[1] - w[0]).abs()).sum::<f64>() / (v.len() - 1) as f64;
            g / mad.max(1e-30)
        };
        let m = miranda(3);
        let h = hacc(3);
        assert!(
            roughness(&m.fields[0]) < roughness(&h.fields[0]),
            "miranda {} vs hacc {}",
            roughness(&m.fields[0]),
            roughness(&h.fields[0])
        );
    }
}
