//! Owned multidimensional field of scalar data — the unit the coordinator
//! streams and a pipeline compresses.

use super::shape::Shape;
use crate::error::{Result, SzError};

/// Type-erased field values. The framework is generic over [`super::Scalar`];
/// `FieldValues` is the boundary type used by CLI/coordinator/datagen.
#[derive(Clone, Debug, PartialEq)]
pub enum FieldValues {
    /// Single-precision floats.
    F32(Vec<f32>),
    /// Double-precision floats.
    F64(Vec<f64>),
    /// 32-bit signed integers (e.g. detector counts).
    I32(Vec<i32>),
}

impl FieldValues {
    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            FieldValues::F32(v) => v.len(),
            FieldValues::F64(v) => v.len(),
            FieldValues::I32(v) => v.len(),
        }
    }

    /// True if no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes of the native representation.
    pub fn nbytes(&self) -> usize {
        match self {
            FieldValues::F32(v) => v.len() * 4,
            FieldValues::F64(v) => v.len() * 8,
            FieldValues::I32(v) => v.len() * 4,
        }
    }

    /// Datatype tag for stream headers.
    pub fn dtype(&self) -> &'static str {
        match self {
            FieldValues::F32(_) => "f32",
            FieldValues::F64(_) => "f64",
            FieldValues::I32(_) => "i32",
        }
    }

    /// View the values as f64 (copying). Used by metrics.
    pub fn to_f64_vec(&self) -> Vec<f64> {
        match self {
            FieldValues::F32(v) => v.iter().map(|&x| x as f64).collect(),
            FieldValues::F64(v) => v.clone(),
            FieldValues::I32(v) => v.iter().map(|&x| x as f64).collect(),
        }
    }

    /// Serialize the values as flat little-endian bytes — the raw on-disk
    /// and on-wire layout shared by `sz3 decompress`/`extract` output
    /// files and the HTTP server's region responses.
    pub fn to_le_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.nbytes());
        match self {
            FieldValues::F32(v) => {
                v.iter().for_each(|x| out.extend_from_slice(&x.to_le_bytes()))
            }
            FieldValues::F64(v) => {
                v.iter().for_each(|x| out.extend_from_slice(&x.to_le_bytes()))
            }
            FieldValues::I32(v) => {
                v.iter().for_each(|x| out.extend_from_slice(&x.to_le_bytes()))
            }
        }
        out
    }

    /// Concatenate same-dtype value buffers in order (the chunk-reassembly
    /// path shared by `coordinator::reassemble` and the container format).
    pub fn concat<'a, I>(parts: I) -> Result<FieldValues>
    where
        I: IntoIterator<Item = &'a FieldValues>,
    {
        let mut it = parts.into_iter();
        let first = it
            .next()
            .ok_or_else(|| SzError::config("no values to concatenate"))?;
        let mut out = first.clone();
        for p in it {
            match (&mut out, p) {
                (FieldValues::F32(v), FieldValues::F32(x)) => v.extend_from_slice(x),
                (FieldValues::F64(v), FieldValues::F64(x)) => v.extend_from_slice(x),
                (FieldValues::I32(v), FieldValues::I32(x)) => v.extend_from_slice(x),
                _ => return Err(SzError::corrupt("mixed chunk dtypes")),
            }
        }
        Ok(out)
    }
}

/// A named multidimensional array of scalars.
#[derive(Clone, Debug)]
pub struct Field {
    /// Field name (e.g. `"ff|ff"`, `"velocity_x"`).
    pub name: String,
    /// Shape, slowest-varying axis first.
    pub shape: Shape,
    /// Values in row-major order.
    pub values: FieldValues,
}

impl Field {
    /// Build a field, validating shape/value agreement.
    pub fn new(name: impl Into<String>, dims: &[usize], values: FieldValues) -> Result<Self> {
        let shape = Shape::new(dims)?;
        if shape.len() != values.len() {
            return Err(SzError::Shape(format!(
                "shape {:?} has {} elems but {} values given",
                dims,
                shape.len(),
                values.len()
            )));
        }
        Ok(Field { name: name.into(), shape, values })
    }

    /// Convenience f32 constructor.
    pub fn f32(name: impl Into<String>, dims: &[usize], values: Vec<f32>) -> Result<Self> {
        Self::new(name, dims, FieldValues::F32(values))
    }

    /// Convenience f64 constructor.
    pub fn f64(name: impl Into<String>, dims: &[usize], values: Vec<f64>) -> Result<Self> {
        Self::new(name, dims, FieldValues::F64(values))
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// True if empty (cannot happen for validated fields).
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Native size in bytes (the numerator of compression ratio).
    pub fn nbytes(&self) -> usize {
        self.values.nbytes()
    }

    /// (min, max) of the data, in f64.
    pub fn value_range(&self) -> (f64, f64) {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        match &self.values {
            FieldValues::F32(v) => {
                for &x in v {
                    lo = lo.min(x as f64);
                    hi = hi.max(x as f64);
                }
            }
            FieldValues::F64(v) => {
                for &x in v {
                    lo = lo.min(x);
                    hi = hi.max(x);
                }
            }
            FieldValues::I32(v) => {
                for &x in v {
                    lo = lo.min(x as f64);
                    hi = hi.max(x as f64);
                }
            }
        }
        if lo > hi {
            (0.0, 0.0)
        } else {
            (lo, hi)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_range() {
        let f = Field::f32("t", &[2, 3], vec![1.0, -2.0, 3.0, 0.5, 0.0, 9.0]).unwrap();
        assert_eq!(f.len(), 6);
        assert_eq!(f.nbytes(), 24);
        assert_eq!(f.value_range(), (-2.0, 9.0));
    }

    #[test]
    fn shape_value_mismatch() {
        assert!(Field::f32("t", &[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn le_bytes_roundtrip_every_dtype() {
        let f32s = FieldValues::F32(vec![1.5, -2.25]);
        assert_eq!(
            f32s.to_le_bytes(),
            [1.5f32.to_le_bytes(), (-2.25f32).to_le_bytes()].concat()
        );
        let i32s = FieldValues::I32(vec![7, -9]);
        assert_eq!(
            i32s.to_le_bytes(),
            [7i32.to_le_bytes(), (-9i32).to_le_bytes()].concat()
        );
        let f64s = FieldValues::F64(vec![3.0]);
        assert_eq!(f64s.to_le_bytes().len(), f64s.nbytes());
    }
}
