//! Shape and stride arithmetic for N-dimensional row-major arrays.

use crate::error::{Result, SzError};

/// Maximum dimensionality supported (matches the paper's 1D–4D, Table 2).
pub const MAX_DIMS: usize = 4;

/// Row-major array shape with precomputed strides.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Shape {
    dims: Vec<usize>,
    strides: Vec<usize>,
}

impl Shape {
    /// Build a shape; dims must be non-empty, each ≥ 1, ≤ [`MAX_DIMS`] axes.
    pub fn new(dims: &[usize]) -> Result<Self> {
        if dims.is_empty() || dims.len() > MAX_DIMS {
            return Err(SzError::Shape(format!(
                "got {} dims, supported 1..={MAX_DIMS}",
                dims.len()
            )));
        }
        if dims.iter().any(|&d| d == 0) {
            return Err(SzError::Shape("zero-length dimension".into()));
        }
        let mut strides = vec![1usize; dims.len()];
        for i in (0..dims.len() - 1).rev() {
            strides[i] = strides[i + 1].checked_mul(dims[i + 1]).ok_or_else(|| {
                SzError::Shape("shape element count overflows usize".into())
            })?;
        }
        // the full product must fit as well: `len()` and every buffer
        // sizing downstream rely on it being representable
        strides
            .first()
            .copied()
            .unwrap_or(1)
            .checked_mul(dims.first().copied().unwrap_or(1))
            .ok_or_else(|| SzError::Shape("shape element count overflows usize".into()))?;
        Ok(Shape { dims: dims.to_vec(), strides })
    }

    /// Dimensions, slowest-varying first.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Row-major strides.
    pub fn strides(&self) -> &[usize] {
        &self.strides
    }

    /// Number of axes.
    pub fn ndim(&self) -> usize {
        self.dims.len()
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    /// True for a degenerate empty shape (cannot happen post-`new`).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Flat offset of a multi-index (debug-checked).
    #[inline]
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.dims.len());
        idx.iter().zip(self.strides.iter()).map(|(&i, &s)| i * s).sum()
    }

    /// Flat offset of `idx` shifted by `off`; `None` if out of bounds.
    #[inline]
    pub fn offset_shifted(&self, idx: &[usize], off: &[isize]) -> Option<usize> {
        let mut flat = 0usize;
        for d in 0..self.dims.len() {
            let i = idx[d] as isize + off[d];
            if i < 0 || i >= self.dims[d] as isize {
                return None;
            }
            flat += i as usize * self.strides[d];
        }
        Some(flat)
    }

    /// Increment a multi-index in row-major order. Returns false on wrap.
    #[inline]
    pub fn advance(&self, idx: &mut [usize]) -> bool {
        for d in (0..self.dims.len()).rev() {
            idx[d] += 1;
            if idx[d] < self.dims[d] {
                return true;
            }
            idx[d] = 0;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        let s = Shape::new(&[2, 3, 4]).unwrap();
        assert_eq!(s.strides(), &[12, 4, 1]);
        assert_eq!(s.len(), 24);
        assert_eq!(s.offset(&[1, 2, 3]), 23);
    }

    #[test]
    fn rejects_bad_shapes() {
        assert!(Shape::new(&[]).is_err());
        assert!(Shape::new(&[1, 2, 3, 4, 5]).is_err());
        assert!(Shape::new(&[3, 0]).is_err());
    }

    #[test]
    fn shifted_bounds() {
        let s = Shape::new(&[2, 2]).unwrap();
        assert_eq!(s.offset_shifted(&[1, 1], &[-1, -1]), Some(0));
        assert_eq!(s.offset_shifted(&[0, 0], &[-1, 0]), None);
        assert_eq!(s.offset_shifted(&[1, 1], &[1, 0]), None);
    }

    #[test]
    fn advance_covers_all() {
        let s = Shape::new(&[2, 3]).unwrap();
        let mut idx = vec![0, 0];
        let mut count = 1;
        while s.advance(&mut idx) {
            count += 1;
        }
        assert_eq!(count, 6);
        assert_eq!(idx, vec![0, 0]);
    }
}
