//! Data substrate: datatype abstraction, field container, shape/stride math
//! and the multidimensional iterator (§6.1.2 of the paper).
//!
//! The paper's SZ2 comparison point is a codebase with >120 functions
//! specialized per datatype × dimensionality. SZ3 (and this port) instead
//! use a single generic implementation: the [`Scalar`] trait abstracts the
//! element type and [`cursor::NdCursor`] abstracts the dimensionality.

pub mod cursor;
pub mod field;
pub mod shape;

pub use cursor::NdCursor;
pub use field::{Field, FieldValues};
pub use shape::Shape;

use crate::byteio::{ByteReader, ByteWriter};
use crate::error::Result;

/// Datatype abstraction: the element types a pipeline can compress.
///
/// Mirrors the paper's `template<class T>` datatype abstraction. All
/// arithmetic used by predictors/quantizers happens in f64 to make the
/// error-bound guarantee independent of the storage type.
pub trait Scalar: Copy + Send + Sync + PartialOrd + std::fmt::Debug + 'static {
    /// Canonical name, stored in stream headers.
    const NAME: &'static str;
    /// Size in bytes of the storage representation.
    const SIZE: usize;
    /// Convert to f64 for arithmetic.
    fn to_f64(self) -> f64;
    /// Convert from f64 (rounding for integer types).
    fn from_f64(v: f64) -> Self;
    /// Additive identity.
    fn zero() -> Self;
    /// Serialize one value.
    fn write(self, w: &mut ByteWriter);
    /// Deserialize one value.
    fn read(r: &mut ByteReader) -> Result<Self>;
}

impl Scalar for f32 {
    const NAME: &'static str = "f32";
    const SIZE: usize = 4;
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline]
    fn from_f64(v: f64) -> Self {
        v as f32
    }
    #[inline]
    fn zero() -> Self {
        0.0
    }
    fn write(self, w: &mut ByteWriter) {
        w.put_f32(self)
    }
    fn read(r: &mut ByteReader) -> Result<Self> {
        r.get_f32()
    }
}

impl Scalar for f64 {
    const NAME: &'static str = "f64";
    const SIZE: usize = 8;
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline]
    fn from_f64(v: f64) -> Self {
        v
    }
    #[inline]
    fn zero() -> Self {
        0.0
    }
    fn write(self, w: &mut ByteWriter) {
        w.put_f64(self)
    }
    fn read(r: &mut ByteReader) -> Result<Self> {
        r.get_f64()
    }
}

impl Scalar for i32 {
    const NAME: &'static str = "i32";
    const SIZE: usize = 4;
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline]
    fn from_f64(v: f64) -> Self {
        v.round() as i32
    }
    #[inline]
    fn zero() -> Self {
        0
    }
    fn write(self, w: &mut ByteWriter) {
        w.put_i32(self)
    }
    fn read(r: &mut ByteReader) -> Result<Self> {
        r.get_i32()
    }
}
