//! The multidimensional iterator (paper §6.1.2).
//!
//! `NdCursor` walks an N-d buffer in row-major order and exposes neighbor
//! access with boundary handling — `neighbor(&[-1, -1, -1])` is the paper's
//! `iterator.move(-1,-1,-1)`. Out-of-range neighbors read as zero, which is
//! exactly the Lorenzo boundary convention used by SZ.
//!
//! During compression the underlying buffer is progressively overwritten
//! with *decompressed* values, so predictors that read neighbors see the
//! same values the decompressor will see — the invariant that makes
//! error-bounded prediction correct.

use super::shape::{Shape, MAX_DIMS};
use super::Scalar;

/// Row-major cursor over a mutable scalar buffer.
pub struct NdCursor<'a, T: Scalar> {
    data: &'a mut [T],
    shape: &'a Shape,
    idx: [usize; MAX_DIMS],
    flat: usize,
}

impl<'a, T: Scalar> NdCursor<'a, T> {
    /// Cursor at the origin of `data` shaped by `shape`.
    pub fn new(data: &'a mut [T], shape: &'a Shape) -> Self {
        debug_assert_eq!(data.len(), shape.len());
        NdCursor { data, shape, idx: [0; MAX_DIMS], flat: 0 }
    }

    /// Number of axes.
    #[inline]
    pub fn ndim(&self) -> usize {
        self.shape.ndim()
    }

    /// Current multi-index.
    #[inline]
    pub fn index(&self) -> &[usize] {
        &self.idx[..self.shape.ndim()]
    }

    /// Current flat offset.
    #[inline]
    pub fn flat(&self) -> usize {
        self.flat
    }

    /// Value at the cursor.
    #[inline]
    pub fn value(&self) -> T {
        self.data[self.flat]
    }

    /// Overwrite the value at the cursor (with the decompressed value).
    #[inline]
    pub fn set(&mut self, v: T) {
        self.data[self.flat] = v;
    }

    /// Value at `idx + off` (one `off` entry per axis); zero outside bounds.
    #[inline]
    pub fn neighbor(&self, off: &[isize]) -> T {
        debug_assert_eq!(off.len(), self.shape.ndim());
        match self.shape.offset_shifted(self.index(), off) {
            Some(f) => self.data[f],
            None => T::zero(),
        }
    }

    /// f64 view of [`Self::neighbor`] — predictors compute in f64.
    #[inline]
    pub fn neighbor_f64(&self, off: &[isize]) -> f64 {
        self.neighbor(off).to_f64()
    }

    /// True if the point at `idx + off` exists (all axes in range).
    #[inline]
    pub fn in_bounds(&self, off: &[isize]) -> bool {
        self.shape.offset_shifted(self.index(), off).is_some()
    }

    /// Advance one position in row-major order; false after the last point.
    #[inline]
    pub fn advance(&mut self) -> bool {
        let nd = self.shape.ndim();
        let dims = self.shape.dims();
        // Fast path: bump the innermost axis.
        self.idx[nd - 1] += 1;
        self.flat += 1;
        if self.idx[nd - 1] < dims[nd - 1] {
            return true;
        }
        self.idx[nd - 1] = 0;
        for d in (0..nd - 1).rev() {
            self.idx[d] += 1;
            if self.idx[d] < dims[d] {
                return self.flat < self.shape.len();
            }
            self.idx[d] = 0;
        }
        false
    }

    /// Jump to an absolute multi-index.
    pub fn seek(&mut self, idx: &[usize]) {
        debug_assert_eq!(idx.len(), self.shape.ndim());
        self.idx[..idx.len()].copy_from_slice(idx);
        self.flat = self.shape.offset(idx);
    }

    /// Relative move by per-axis deltas (the paper's `iterator.move(..)`).
    /// Debug-asserts the target is in bounds.
    pub fn move_by(&mut self, off: &[isize]) {
        let target = self
            .shape
            .offset_shifted(self.index(), off)
            .expect("move_by out of bounds");
        for (d, &o) in off.iter().enumerate() {
            self.idx[d] = (self.idx[d] as isize + o) as usize;
        }
        self.flat = target;
    }

    /// Immutable access to the whole underlying buffer.
    pub fn buffer(&self) -> &[T] {
        self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Result;

    #[test]
    fn walk_and_neighbors_2d() -> Result<()> {
        let shape = Shape::new(&[2, 3])?;
        let mut data: Vec<f32> = (0..6).map(|x| x as f32).collect();
        let mut c = NdCursor::new(&mut data, &shape);
        // origin: all backward neighbors are zero-padded
        assert_eq!(c.neighbor(&[-1, 0]), 0.0);
        assert_eq!(c.neighbor(&[0, -1]), 0.0);
        assert!(c.advance()); // (0,1)
        assert_eq!(c.value(), 1.0);
        assert_eq!(c.neighbor(&[0, -1]), 0.0); // value at (0,0) = 0.0
        c.seek(&[1, 2]);
        assert_eq!(c.value(), 5.0);
        assert_eq!(c.neighbor(&[-1, 0]), 2.0);
        assert_eq!(c.neighbor(&[-1, -1]), 1.0);
        assert_eq!(c.neighbor(&[0, -1]), 4.0);
        Ok(())
    }

    #[test]
    fn advance_visits_every_point_once() -> Result<()> {
        let shape = Shape::new(&[3, 2, 4])?;
        let mut data = vec![0f32; 24];
        let mut c = NdCursor::new(&mut data, &shape);
        let mut visited = vec![false; 24];
        loop {
            assert!(!visited[c.flat()]);
            visited[c.flat()] = true;
            if !c.advance() {
                break;
            }
        }
        assert!(visited.iter().all(|&v| v));
        Ok(())
    }

    #[test]
    fn move_by_matches_paper_example() -> Result<()> {
        let shape = Shape::new(&[3, 3, 3])?;
        let mut data: Vec<f32> = (0..27).map(|x| x as f32).collect();
        let mut c = NdCursor::new(&mut data, &shape);
        c.seek(&[1, 1, 1]);
        c.move_by(&[-1, -1, -1]); // upper-left neighbor, as in §6.1.2
        assert_eq!(c.value(), 0.0);
        assert_eq!(c.index(), &[0, 0, 0]);
        Ok(())
    }

    #[test]
    fn set_is_visible_to_neighbor_reads() -> Result<()> {
        let shape = Shape::new(&[1, 4])?;
        let mut data = vec![1f32, 2.0, 3.0, 4.0];
        let mut c = NdCursor::new(&mut data, &shape);
        c.set(10.0);
        c.advance();
        assert_eq!(c.neighbor(&[0, -1]), 10.0);
        Ok(())
    }
}
