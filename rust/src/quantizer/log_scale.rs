//! Log-scale quantizer (paper §3.2, NUMARCK-style [35]): bin widths grow
//! geometrically away from the zero-residual bin, up to the linear cap of
//! `2 * eb`. Small residuals land in narrower bins, producing a more
//! centralized error distribution; no bin ever exceeds `2 * eb`, so the
//! absolute error bound is still respected everywhere.

use super::{Quantizer, UNPREDICTABLE};
use crate::byteio::{ByteReader, ByteWriter};
use crate::data::Scalar;
use crate::error::{Result, SzError};

/// Largest radius accepted from a stream: the bin tables are O(radius)
/// heap and CPU to rebuild, so an attacker-chosen radius must not be able
/// to request gigabytes. 2^22 is far beyond any useful alphabet (the
/// grammar's default is 2^15) while keeping the tables under 70 MB.
const MAX_WIRE_RADIUS: u32 = 1 << 22;

/// Geometric-then-linear binned quantizer.
pub struct LogScaleQuantizer<T: Scalar> {
    eb: f64,
    /// Width of the central bin relative to `2*eb` (0 < alpha <= 1).
    alpha: f64,
    /// Geometric growth per bin (> 1).
    gamma: f64,
    radius: u32,
    /// Bin boundaries for positive residuals: bin k covers
    /// [bounds[k], bounds[k+1]), k in 0..radius-1. bounds[0] = half central.
    bounds: Vec<f64>,
    centers: Vec<f64>,
    unpred: Vec<T>,
    replay: usize,
}

impl<T: Scalar> LogScaleQuantizer<T> {
    /// New quantizer with default shape parameters (alpha=0.25, gamma=1.5).
    pub fn new(eb: f64, radius: u32) -> Self {
        Self::with_shape(eb, radius, 0.25, 1.5)
    }

    /// Fully parameterized constructor.
    pub fn with_shape(eb: f64, radius: u32, alpha: f64, gamma: f64) -> Self {
        assert!(eb > 0.0 && alpha > 0.0 && alpha <= 1.0 && gamma > 1.0);
        let mut q = LogScaleQuantizer {
            eb,
            alpha,
            gamma,
            radius: radius.max(2),
            bounds: Vec::new(),
            centers: Vec::new(),
            unpred: Vec::new(),
            replay: 0,
        };
        q.rebuild_tables();
        q
    }

    fn rebuild_tables(&mut self) {
        let r = self.radius as usize;
        let cap = 2.0 * self.eb;
        let mut bounds = Vec::with_capacity(r + 1);
        let mut centers = Vec::with_capacity(r);
        // central bin is symmetric around 0 with half-width alpha*eb
        let mut lo = self.alpha * self.eb;
        bounds.push(lo);
        let mut width = self.alpha * cap;
        for _ in 0..r {
            width = (width * self.gamma).min(cap);
            let hi = lo + width;
            centers.push(0.5 * (lo + hi));
            bounds.push(hi);
            lo = hi;
        }
        self.bounds = bounds;
        self.centers = centers;
    }

    /// Find the positive-side bin for |diff|; None if beyond the last bin.
    #[inline]
    fn find_bin(&self, mag: f64) -> Option<usize> {
        let last = self.bounds.last().copied().unwrap_or(0.0);
        if mag >= last {
            return None;
        }
        // first boundary strictly above |diff| (bin 0 = central)
        let lo = self.bounds.partition_point(|&b| mag >= b);
        // The outermost bin (lo == radius) is rejected so the signed index
        // never reaches -radius, which would collide with UNPREDICTABLE
        // (index 0).
        if lo >= self.radius as usize {
            None
        } else {
            Some(lo)
        }
    }

    fn index_to_residual(&self, index: u32) -> f64 {
        let r = self.radius as i64;
        let k = i64::from(index) - r; // signed bin, 0 = central
        match k.cmp(&0) {
            std::cmp::Ordering::Equal => 0.0,
            std::cmp::Ordering::Greater => {
                self.centers.get((k - 1) as usize).copied().unwrap_or(0.0)
            }
            std::cmp::Ordering::Less => {
                -self.centers.get((-k - 1) as usize).copied().unwrap_or(0.0)
            }
        }
    }
}

impl<T: Scalar> Quantizer<T> for LogScaleQuantizer<T> {
    fn name(&self) -> &'static str {
        "log_scale"
    }

    #[inline]
    fn quantize(&mut self, data: T, pred: f64) -> (u32, T) {
        let diff = data.to_f64() - pred;
        let mag = diff.abs();
        if let Some(bin) = self.find_bin(mag) {
            let k = bin as i64; // 0 = central
            let signed = if diff < 0.0 { -k } else { k };
            let index = (signed + self.radius as i64) as u32;
            let rec = T::from_f64(pred + self.index_to_residual(index));
            if (rec.to_f64() - data.to_f64()).abs() <= self.eb {
                return (index, rec);
            }
        }
        self.unpred.push(data);
        (UNPREDICTABLE, data)
    }

    #[inline]
    fn recover(&mut self, pred: f64, index: u32) -> T {
        if index == UNPREDICTABLE {
            // corrupt streams may request more unpredictables than stored;
            // degrade to zero rather than panic (decode already yields junk)
            let v = self.unpred.get(self.replay).copied().unwrap_or_else(T::zero);
            self.replay += 1;
            v
        } else {
            T::from_f64(pred + self.index_to_residual(index))
        }
    }

    fn index_range(&self) -> u32 {
        2 * self.radius
    }

    fn save(&self, w: &mut ByteWriter) -> Result<()> {
        w.put_f64(self.eb);
        w.put_f64(self.alpha);
        w.put_f64(self.gamma);
        w.put_u32(self.radius);
        w.put_varint(self.unpred.len() as u64);
        for &v in &self.unpred {
            v.write(w);
        }
        Ok(())
    }

    fn load(&mut self, r: &mut ByteReader) -> Result<()> {
        self.eb = r.get_f64()?;
        self.alpha = r.get_f64()?;
        self.gamma = r.get_f64()?;
        self.radius = r.get_u32()?;
        if self.eb <= 0.0
            || !self.eb.is_finite()
            || !(0.0..=1.0).contains(&self.alpha)
            || self.alpha == 0.0
            || self.gamma <= 1.0
            || !self.gamma.is_finite()
        {
            return Err(SzError::corrupt("log_scale quantizer: bad params"));
        }
        // The bin tables are O(radius) heap + CPU; an attacker-supplied
        // radius of u32::MAX would burn gigabytes before the first data
        // byte is read. Legitimate radii are in the grammar's range.
        if !(2..=MAX_WIRE_RADIUS).contains(&self.radius) {
            return Err(SzError::corrupt("log_scale quantizer: radius out of range"));
        }
        self.rebuild_tables();
        let n64 = r.get_varint()?;
        let cap = (r.remaining() / T::SIZE) as u64;
        if n64 > cap {
            return Err(SzError::corrupt(
                "log_scale quantizer: unpredictable count exceeds payload",
            ));
        }
        let n = usize::try_from(n64)
            .map_err(|_| SzError::corrupt("log_scale quantizer: count overflows usize"))?;
        self.unpred.clear();
        self.unpred.reserve(n);
        for _ in 0..n {
            self.unpred.push(T::read(r)?);
        }
        self.replay = 0;
        Ok(())
    }

    fn reset(&mut self) {
        self.unpred.clear();
        self.replay = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quantizer::test_support::roundtrip_check;
    use crate::util::prop;

    #[test]
    fn bin_widths_capped_at_2eb() {
        let q = LogScaleQuantizer::<f64>::new(0.5, 64);
        for k in 1..q.bounds.len() {
            let w = q.bounds[k] - q.bounds[k - 1];
            assert!(w <= 2.0 * 0.5 + 1e-12, "bin {k} width {w}");
        }
    }

    #[test]
    fn small_residuals_get_smaller_error() {
        let mut q = LogScaleQuantizer::<f64>::new(1.0, 64);
        // residual 0.3 with eb=1.0: central/early bins -> error well under eb
        let (_, rec) = q.quantize(10.3, 10.0);
        assert!((rec - 10.3).abs() < 0.5);
    }

    #[test]
    fn prop_error_bound_holds() {
        prop::cases(80, 0x10c, |rng| {
            let eb = 10f64.powf(rng.uniform(-6.0, 1.0));
            let n = rng.below(400) + 1;
            let data: Vec<f64> = (0..n).map(|_| rng.uniform(-50.0, 50.0)).collect();
            let preds: Vec<f64> =
                data.iter().map(|&d| d + rng.normal() * eb * 5.0).collect();
            let bounds = vec![eb; n];
            let mut q = LogScaleQuantizer::<f64>::new(eb, 128);
            roundtrip_check(&mut q, &data, &preds, &bounds);
        });
    }

    #[test]
    fn more_centralized_than_linear() {
        // With the same radius, log-scale should produce smaller mean |error|
        // on small residuals than linear's uniform bins.
        use crate::quantizer::LinearQuantizer;
        use crate::util::rng::Pcg32;
        let eb = 1.0;
        let mut rng = Pcg32::seeded(15);
        let mut sum_log = 0.0;
        let mut sum_lin = 0.0;
        let mut qlog = LogScaleQuantizer::<f64>::new(eb, 128);
        let mut qlin = LinearQuantizer::<f64>::with_radius(eb, 128);
        for _ in 0..2000 {
            let pred = rng.uniform(-10.0, 10.0);
            let d = pred + rng.normal() * 0.3; // small residuals
            let (_, r1) = qlog.quantize(d, pred);
            let (_, r2) = qlin.quantize(d, pred);
            sum_log += (r1 - d).abs();
            sum_lin += (r2 - d).abs();
        }
        assert!(sum_log < sum_lin, "log {sum_log} vs lin {sum_lin}");
    }
}
