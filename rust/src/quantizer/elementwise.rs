//! Element-wise quantizer (paper §3.2, cpSZ [21]): a per-point error bound,
//! enabling feature-preserving compression — points near critical features
//! get tight bounds, smooth regions get relaxed ones.
//!
//! Bounds are described by a [`BoundsMap`]: a piecewise-constant map from
//! flat index ranges to bounds. The map is serialized with the stream so
//! compressor and decompressor walk identical bounds.

use super::{Quantizer, UNPREDICTABLE};
use crate::byteio::{ByteReader, ByteWriter};
use crate::data::Scalar;
use crate::error::{Result, SzError};

/// Largest total point coverage accepted from a serialized bounds map —
/// matches the pipeline layer's header element cap, so any legitimate
/// field fits while `len()` can never overflow on hostile run lengths.
const MAX_COVERED_POINTS: u64 = 1 << 40;

/// Piecewise-constant per-point error bounds over flat indices.
#[derive(Clone, Debug, PartialEq)]
pub struct BoundsMap {
    /// (run_length, bound) segments covering the field in order.
    pub segments: Vec<(usize, f64)>,
}

impl BoundsMap {
    /// Uniform bound over `n` points.
    pub fn uniform(n: usize, eb: f64) -> Self {
        BoundsMap { segments: vec![(n, eb)] }
    }

    /// Total points covered.
    pub fn len(&self) -> usize {
        self.segments.iter().map(|&(n, _)| n).sum()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.segments.is_empty()
    }

    /// Smallest bound in the map (used for alphabet sizing).
    pub fn min_bound(&self) -> f64 {
        self.segments.iter().map(|&(_, b)| b).fold(f64::INFINITY, f64::min)
    }

    fn save(&self, w: &mut ByteWriter) {
        w.put_varint(self.segments.len() as u64);
        for &(n, b) in &self.segments {
            w.put_varint(n as u64);
            w.put_f64(b);
        }
    }

    fn load(r: &mut ByteReader) -> Result<Self> {
        // Each serialized segment is at least 9 bytes (varint run length +
        // f64 bound), so the remaining payload caps the segment count —
        // reject hostile counts before sizing the allocation by them.
        let k64 = r.get_varint()?;
        let cap = (r.remaining() / 9) as u64;
        if k64 > cap {
            return Err(SzError::corrupt("elementwise: segment count exceeds payload"));
        }
        let k = usize::try_from(k64)
            .map_err(|_| SzError::corrupt("elementwise: segment count overflows usize"))?;
        let mut segments = Vec::with_capacity(k);
        let mut covered = 0u64;
        for _ in 0..k {
            let n64 = r.get_varint()?;
            let b = r.get_f64()?;
            if b <= 0.0 || !b.is_finite() {
                return Err(SzError::corrupt("elementwise: non-positive bound"));
            }
            covered = covered
                .checked_add(n64)
                .filter(|&c| c <= MAX_COVERED_POINTS)
                .ok_or_else(|| SzError::corrupt("elementwise: bounds map covers too many points"))?;
            let n = usize::try_from(n64)
                .map_err(|_| SzError::corrupt("elementwise: run length overflows usize"))?;
            segments.push((n, b));
        }
        Ok(BoundsMap { segments })
    }
}

/// Walks a [`BoundsMap`] while quantizing point-by-point.
pub struct ElementwiseQuantizer<T: Scalar> {
    map: BoundsMap,
    seg: usize,
    seg_pos: usize,
    radius: u32,
    unpred: Vec<T>,
    replay: usize,
}

impl<T: Scalar> ElementwiseQuantizer<T> {
    /// New quantizer over `map` with index radius `radius`.
    pub fn new(map: BoundsMap, radius: u32) -> Self {
        assert!(!map.is_empty(), "bounds map must be non-empty");
        ElementwiseQuantizer {
            map,
            seg: 0,
            seg_pos: 0,
            radius: radius.max(1),
            unpred: Vec::new(),
            replay: 0,
        }
    }

    /// Current point's bound, advancing the walk.
    #[inline]
    fn next_bound(&mut self) -> f64 {
        // Clamp at the last segment if walked past the declared coverage.
        let at = self.seg.min(self.map.segments.len().saturating_sub(1));
        let Some(&(len, b)) = self.map.segments.get(at) else {
            return f64::INFINITY; // unreachable: the map is never empty
        };
        self.seg_pos += 1;
        if self.seg_pos >= len && self.seg + 1 < self.map.segments.len() {
            self.seg += 1;
            self.seg_pos = 0;
        }
        b
    }

    fn rewind(&mut self) {
        self.seg = 0;
        self.seg_pos = 0;
    }
}

impl<T: Scalar> Quantizer<T> for ElementwiseQuantizer<T> {
    fn name(&self) -> &'static str {
        "elementwise"
    }

    #[inline]
    fn quantize(&mut self, data: T, pred: f64) -> (u32, T) {
        let eb = self.next_bound();
        let diff = data.to_f64() - pred;
        let q = (diff / (2.0 * eb)).round();
        if q.abs() < self.radius as f64 {
            let rec = T::from_f64(pred + q * 2.0 * eb);
            if (rec.to_f64() - data.to_f64()).abs() <= eb {
                return ((q as i64 + self.radius as i64) as u32, rec);
            }
        }
        self.unpred.push(data);
        (UNPREDICTABLE, data)
    }

    #[inline]
    fn recover(&mut self, pred: f64, index: u32) -> T {
        let eb = self.next_bound();
        if index == UNPREDICTABLE {
            // corrupt streams may request more unpredictables than stored;
            // degrade to zero rather than panic (decode already yields junk)
            let v = self.unpred.get(self.replay).copied().unwrap_or_else(T::zero);
            self.replay += 1;
            v
        } else {
            let q = index as i64 - self.radius as i64;
            T::from_f64(pred + q as f64 * 2.0 * eb)
        }
    }

    fn index_range(&self) -> u32 {
        2 * self.radius
    }

    fn save(&self, w: &mut ByteWriter) -> Result<()> {
        self.map.save(w);
        w.put_u32(self.radius);
        w.put_varint(self.unpred.len() as u64);
        for &v in &self.unpred {
            v.write(w);
        }
        Ok(())
    }

    fn load(&mut self, r: &mut ByteReader) -> Result<()> {
        let map = BoundsMap::load(r)?;
        if map.is_empty() {
            return Err(SzError::corrupt("elementwise: empty bounds map"));
        }
        self.map = map;
        self.radius = r.get_u32()?;
        if self.radius == 0 {
            return Err(SzError::corrupt("elementwise: zero radius"));
        }
        let n64 = r.get_varint()?;
        let cap = (r.remaining() / T::SIZE) as u64;
        if n64 > cap {
            return Err(SzError::corrupt(
                "elementwise: unpredictable count exceeds payload",
            ));
        }
        let n = usize::try_from(n64)
            .map_err(|_| SzError::corrupt("elementwise: count overflows usize"))?;
        self.unpred.clear();
        self.unpred.reserve(n);
        for _ in 0..n {
            self.unpred.push(T::read(r)?);
        }
        self.replay = 0;
        self.rewind();
        Ok(())
    }

    fn reset(&mut self) {
        self.unpred.clear();
        self.replay = 0;
        self.rewind();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quantizer::test_support::roundtrip_check;
    use crate::util::prop;

    #[test]
    fn per_segment_bounds_respected() {
        let map = BoundsMap { segments: vec![(10, 1e-6), (10, 1.0)] };
        let data: Vec<f64> = (0..20).map(|i| i as f64 * 0.1).collect();
        let preds: Vec<f64> = data.iter().map(|&d| d + 0.4).collect();
        let bounds: Vec<f64> =
            (0..20).map(|i| if i < 10 { 1e-6 } else { 1.0 }).collect();
        let mut q = ElementwiseQuantizer::<f64>::new(map, 512);
        roundtrip_check(&mut q, &data, &preds, &bounds);
    }

    #[test]
    fn prop_random_segment_maps() {
        prop::cases(60, 0xe1e, |rng| {
            let nseg = rng.below(6) + 1;
            let mut segments = Vec::new();
            let mut bounds = Vec::new();
            for _ in 0..nseg {
                let len = rng.below(50) + 1;
                let eb = 10f64.powf(rng.uniform(-6.0, 0.5));
                segments.push((len, eb));
                bounds.extend(std::iter::repeat(eb).take(len));
            }
            let n = bounds.len();
            let data: Vec<f64> = (0..n).map(|_| rng.uniform(-10.0, 10.0)).collect();
            let preds: Vec<f64> =
                data.iter().map(|&d| d + rng.normal() * 0.5).collect();
            let mut q = ElementwiseQuantizer::<f64>::new(
                BoundsMap { segments },
                1024,
            );
            roundtrip_check(&mut q, &data, &preds, &bounds);
        });
    }

    #[test]
    fn uniform_map_helpers() {
        let m = BoundsMap::uniform(100, 0.5);
        assert_eq!(m.len(), 100);
        assert_eq!(m.min_bound(), 0.5);
    }
}
