//! Linear-scaling quantizer (paper §3.2): equal-sized bins of width
//! `2 * eb`; the residual maps to the index of the containing bin. This is
//! the quantizer of SZ1.4/SZ2 and the default in most pipelines.

use super::{Quantizer, UNPREDICTABLE};
use crate::byteio::{ByteReader, ByteWriter};
use crate::data::Scalar;
use crate::error::{Result, SzError};
use crate::util::simd;

// The SIMD kernel hardcodes its escape code; keep the two in lockstep.
const _: () = assert!(simd::ESCAPE == UNPREDICTABLE);

/// Linear-scaling quantizer with absolute error bound `eb`.
pub struct LinearQuantizer<T: Scalar> {
    eb: f64,
    radius: u32,
    /// Exactly-stored unpredictable values (compression side appends,
    /// decompression side replays).
    unpred: Vec<T>,
    replay: usize,
}

impl<T: Scalar> LinearQuantizer<T> {
    /// Default index radius (2^15 bins each side), as in SZ2.
    pub const DEFAULT_RADIUS: u32 = 32768;

    /// New quantizer with error bound `eb` and default radius.
    pub fn new(eb: f64) -> Self {
        Self::with_radius(eb, Self::DEFAULT_RADIUS)
    }

    /// New quantizer with explicit radius (`index_range = 2 * radius`).
    pub fn with_radius(eb: f64, radius: u32) -> Self {
        assert!(eb > 0.0, "error bound must be positive");
        LinearQuantizer { eb, radius: radius.max(1), unpred: Vec::new(), replay: 0 }
    }

    /// The configured error bound.
    pub fn eb(&self) -> f64 {
        self.eb
    }

    /// Number of values stored as unpredictable so far.
    pub fn unpredictable_count(&self) -> usize {
        self.unpred.len()
    }

    /// Bulk-quantize a row of values against precomputed predictions via
    /// the runtime-dispatched SIMD kernel. Bit-identical to calling
    /// [`Quantizer::quantize`] once per element: recovered values
    /// overwrite `values`, bin codes land in `codes`, and out-of-range
    /// inputs (left untouched in `values`) are appended to the
    /// unpredictable store in order.
    pub fn quantize_row(&mut self, values: &mut [T], preds: &[f64], codes: &mut [u32]) {
        debug_assert_eq!(values.len(), preds.len());
        debug_assert_eq!(values.len(), codes.len());
        let escapes = simd::linear_quantize(values, preds, self.eb, self.radius, codes);
        if escapes > 0 {
            self.unpred.reserve(escapes);
            for (&v, &c) in values.iter().zip(codes.iter()) {
                if c == UNPREDICTABLE {
                    self.unpred.push(v);
                }
            }
        }
    }
}

impl<T: Scalar> Quantizer<T> for LinearQuantizer<T> {
    fn name(&self) -> &'static str {
        "linear"
    }

    #[inline]
    fn quantize(&mut self, data: T, pred: f64) -> (u32, T) {
        let diff = data.to_f64() - pred;
        let q = (diff / (2.0 * self.eb)).round();
        if q.abs() < self.radius as f64 {
            let decomp = pred + q * 2.0 * self.eb;
            // Floating-point safety net: verify the bound holds on the value
            // the decompressor will actually materialize (including any
            // rounding from the f64 -> T conversion); otherwise store exactly.
            let rec = T::from_f64(decomp);
            if (rec.to_f64() - data.to_f64()).abs() <= self.eb {
                return ((q as i64 + self.radius as i64) as u32, rec);
            }
        }
        self.unpred.push(data);
        (UNPREDICTABLE, data)
    }

    #[inline]
    fn recover(&mut self, pred: f64, index: u32) -> T {
        if index == UNPREDICTABLE {
            // corrupt streams may request more unpredictables than stored;
            // degrade to zero rather than panic (decode already yields junk)
            let v = self.unpred.get(self.replay).copied().unwrap_or_else(T::zero);
            self.replay += 1;
            v
        } else {
            let q = index as i64 - self.radius as i64;
            T::from_f64(pred + q as f64 * 2.0 * self.eb)
        }
    }

    fn index_range(&self) -> u32 {
        2 * self.radius
    }

    fn save(&self, w: &mut ByteWriter) -> Result<()> {
        w.put_f64(self.eb);
        w.put_u32(self.radius);
        w.put_varint(self.unpred.len() as u64);
        for &v in &self.unpred {
            v.write(w);
        }
        Ok(())
    }

    fn load(&mut self, r: &mut ByteReader) -> Result<()> {
        self.eb = r.get_f64()?;
        self.radius = r.get_u32()?;
        if self.eb <= 0.0 || !self.eb.is_finite() || self.radius == 0 {
            return Err(SzError::corrupt("linear quantizer: bad params"));
        }
        // The count is attacker-controlled: cap it by the bytes actually
        // left in the stream before reserving, so a hostile varint errors
        // instead of aborting on a doomed multi-exabyte allocation.
        let n64 = r.get_varint()?;
        let cap = (r.remaining() / T::SIZE) as u64;
        if n64 > cap {
            return Err(SzError::corrupt(
                "linear quantizer: unpredictable count exceeds payload",
            ));
        }
        let n = usize::try_from(n64)
            .map_err(|_| SzError::corrupt("linear quantizer: count overflows usize"))?;
        self.unpred.clear();
        self.unpred.reserve(n);
        for _ in 0..n {
            self.unpred.push(T::read(r)?);
        }
        self.replay = 0;
        Ok(())
    }

    fn reset(&mut self) {
        self.unpred.clear();
        self.replay = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quantizer::test_support::roundtrip_check;
    use crate::util::prop;

    #[test]
    fn exact_on_zero_residual() {
        let mut q = LinearQuantizer::<f32>::new(0.1);
        let (idx, rec) = q.quantize(5.0, 5.0);
        assert_eq!(idx, LinearQuantizer::<f32>::DEFAULT_RADIUS);
        assert!((rec - 5.0).abs() <= 0.1);
    }

    #[test]
    fn far_residual_is_unpredictable_and_exact() {
        let mut q = LinearQuantizer::<f32>::with_radius(1e-6, 8);
        let (idx, rec) = q.quantize(1000.0, 0.0);
        assert_eq!(idx, UNPREDICTABLE);
        assert_eq!(rec, 1000.0);
        assert_eq!(q.unpredictable_count(), 1);
    }

    #[test]
    fn integer_data_half_eb_is_lossless() {
        // The APS trick: eb = 0.5 (bin width 1) on integer-valued data
        // recovers exactly.
        let mut q = LinearQuantizer::<f32>::new(0.5);
        for (d, p) in [(7.0f32, 3.0f64), (0.0, 2.0), (-12.0, -5.0), (100.0, 98.0)] {
            let (_, rec) = q.quantize(d, p);
            assert_eq!(rec, d);
        }
    }

    #[test]
    fn prop_error_bound_holds() {
        prop::cases(100, 0x11a, |rng| {
            let eb = 10f64.powf(rng.uniform(-8.0, 1.0));
            let n = rng.below(500) + 1;
            let data: Vec<f64> = (0..n).map(|_| rng.uniform(-1e3, 1e3)).collect();
            let preds: Vec<f64> = data
                .iter()
                .map(|&d| d + rng.normal() * eb * 10.0_f64.powf(rng.uniform(-1.0, 3.0)))
                .collect();
            let bounds = vec![eb; n];
            let mut q = LinearQuantizer::<f64>::with_radius(eb, 256);
            roundtrip_check(&mut q, &data, &preds, &bounds);
        });
    }

    #[test]
    fn quantize_row_matches_pointwise_including_unpred_order() {
        prop::cases(40, 0x11c, |rng| {
            let eb = 10f64.powf(rng.uniform(-5.0, 0.0));
            let n = rng.below(300) + 1;
            let data: Vec<f64> = (0..n).map(|_| rng.uniform(-100.0, 100.0)).collect();
            // a mix of good and wildly wrong predictions => some escapes
            let preds: Vec<f64> = data
                .iter()
                .map(|&d| if rng.below(5) == 0 { d + 1e6 } else { d + rng.normal() * eb })
                .collect();
            let mut point = LinearQuantizer::<f64>::with_radius(eb, 64);
            let mut want_codes = Vec::new();
            let mut want_vals = Vec::new();
            for (&d, &p) in data.iter().zip(&preds) {
                let (c, rec) = point.quantize(d, p);
                want_codes.push(c);
                want_vals.push(rec.to_bits());
            }
            let mut bulk = LinearQuantizer::<f64>::with_radius(eb, 64);
            let mut values = data.clone();
            let mut codes = vec![0u32; n];
            bulk.quantize_row(&mut values, &preds, &mut codes);
            assert_eq!(codes, want_codes);
            let got: Vec<u64> = values.iter().map(|x| x.to_bits()).collect();
            assert_eq!(got, want_vals);
            assert_eq!(bulk.unpred, point.unpred, "escape replay order must match");
        });
    }

    #[test]
    fn prop_f32_storage_error_bound() {
        prop::cases(50, 0x11b, |rng| {
            let eb = 10f64.powf(rng.uniform(-4.0, 0.0));
            let n = rng.below(300) + 1;
            let data: Vec<f32> = (0..n).map(|_| rng.uniform(-100.0, 100.0) as f32).collect();
            let preds: Vec<f64> =
                data.iter().map(|&d| d as f64 + rng.normal() * eb * 3.0).collect();
            // The safety check validates the bound on the materialized f32,
            // so the exact bound must hold even with f32 storage rounding.
            let bounds = vec![eb; n];
            let mut q = LinearQuantizer::<f32>::new(eb);
            roundtrip_check(&mut q, &data, &preds, &bounds);
        });
    }
}
