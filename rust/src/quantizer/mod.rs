//! Quantizer stage (paper §3.2, Appendix A.3) — the *only* module that
//! introduces error, hence the module that owns the error-bound guarantee.
//!
//! Contract: for every point, `|recovered - original| <= bound(point)`.
//! Values that cannot be represented within the index range are
//! "unpredictable" (index 0) and are reproduced from a side store.
//!
//! Instances: [`linear::LinearQuantizer`] (SZ's linear-scaling quantizer),
//! [`log_scale::LogScaleQuantizer`] (centralized error distribution),
//! [`elementwise::ElementwiseQuantizer`] (per-point bounds, cpSZ-style) and
//! [`unpred_aware::UnpredAwareQuantizer`] (bitplane-coded unpredictables,
//! the SZ3-Pastri contribution of paper §4.2).

pub mod elementwise;
pub mod linear;
pub mod log_scale;
pub mod unpred_aware;

pub use elementwise::{BoundsMap, ElementwiseQuantizer};
pub use linear::LinearQuantizer;
pub use log_scale::LogScaleQuantizer;
pub use unpred_aware::UnpredAwareQuantizer;

use crate::byteio::{ByteReader, ByteWriter};
use crate::data::Scalar;
use crate::error::Result;

/// Index reserved for unpredictable points.
pub const UNPREDICTABLE: u32 = 0;

/// Error-controlled quantizer over prediction residuals.
///
/// Stateful within one field: the unpredictable store accumulates during
/// compression (`quantize`) and is replayed in the same order during
/// decompression (`recover`). `save`/`load` persist the store plus the
/// quantizer parameters, mirroring the paper's interface.
pub trait Quantizer<T: Scalar>: Send {
    /// Instance name for configs and stream headers.
    fn name(&self) -> &'static str;

    /// Quantize `data` against prediction `pred` (f64 domain). Returns the
    /// quantization index and the recovered value the decompressor will see
    /// (which the caller writes back so later predictions are consistent).
    fn quantize(&mut self, data: T, pred: f64) -> (u32, T);

    /// Recover the value for `index` given prediction `pred`.
    fn recover(&mut self, pred: f64, index: u32) -> T;

    /// Number of representable indices (encoder alphabet hint), 2*radius.
    fn index_range(&self) -> u32;

    /// Persist parameters + unpredictable store.
    fn save(&self, w: &mut ByteWriter) -> Result<()>;

    /// Restore parameters + unpredictable store (resets replay position).
    fn load(&mut self, r: &mut ByteReader) -> Result<()>;

    /// Clear per-field state (call between fields).
    fn reset(&mut self);
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::byteio::{ByteReader, ByteWriter};

    /// Drive a quantizer through compress + save + load + recover over a
    /// (data, pred) sequence and assert the per-point error bound `bounds`.
    pub fn roundtrip_check<T: Scalar, Q: Quantizer<T>>(
        q: &mut Q,
        data: &[T],
        preds: &[f64],
        bounds: &[f64],
    ) {
        assert_eq!(data.len(), preds.len());
        q.reset();
        let mut indices = Vec::with_capacity(data.len());
        let mut recovered_c = Vec::with_capacity(data.len());
        for (&d, &p) in data.iter().zip(preds) {
            let (idx, rec) = q.quantize(d, p);
            indices.push(idx);
            recovered_c.push(rec);
        }
        let mut w = ByteWriter::new();
        q.save(&mut w).unwrap();
        let buf = w.finish();
        let mut r = ByteReader::new(&buf);
        q.load(&mut r).unwrap();
        for (i, (&p, &idx)) in preds.iter().zip(indices.iter()).enumerate() {
            let rec = q.recover(p, idx);
            assert_eq!(
                rec.to_f64(),
                recovered_c[i].to_f64(),
                "{}: compress/decompress recovery diverged at {i}",
                q.name()
            );
            let err = (rec.to_f64() - data[i].to_f64()).abs();
            assert!(
                err <= bounds[i] * (1.0 + 1e-12),
                "{}: error {err} > bound {} at {i} (data {:?} pred {p})",
                q.name(),
                bounds[i],
                data[i]
            );
        }
    }
}
