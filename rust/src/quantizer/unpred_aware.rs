//! Unpred-aware quantizer — the SZ3-Pastri contribution (paper §4.2).
//!
//! Predictable points behave exactly like the linear-scaling quantizer. The
//! difference is the treatment of *unpredictable* points: instead of storing
//! them verbatim (SZ-Pastri's truncation), the prediction difference has its
//! exponent aligned to the error bound (`q = round(diff / eb)`, so
//! `|recovered - original| <= eb/2`) and the resulting integers are recorded
//! **bitplane-major**, most-significant plane first. Because most
//! unpredictable magnitudes are small, high planes are runs of zeros — not
//! smaller at this stage, but highly compressible by the lossless stage,
//! which is where the paper's Table 1 gains come from.

use super::{Quantizer, UNPREDICTABLE};
use crate::bitio::{BitReader, BitWriter};
use crate::byteio::{ByteReader, ByteWriter};
use crate::data::Scalar;
use crate::error::{Result, SzError};

/// Largest exponent-aligned magnitude stored in bitplanes; larger residuals
/// (or values whose storage-type rounding would break the bound) escape to
/// exact storage.
const MAG_CAP: f64 = (1u64 << 50) as f64;

struct UnpredRecord<T> {
    /// None => bitplane-coded (sign, magnitude); Some => exact escape.
    exact: Option<T>,
    sign: bool,
    mag: u64,
}

/// Linear quantizer with bitplane-coded unpredictable storage.
pub struct UnpredAwareQuantizer<T: Scalar> {
    eb: f64,
    radius: u32,
    /// `true` (default): bitplane/plane-major storage (SZ3-Pastri).
    /// `false`: value-major storage — equivalent in size before lossless,
    /// mimicking SZ-Pastri's truncation layout (the Table 1 ablation).
    pub plane_major: bool,
    records: Vec<UnpredRecord<T>>,
    replay: usize,
}

impl<T: Scalar> UnpredAwareQuantizer<T> {
    /// New quantizer with error bound `eb` and index radius `radius`.
    pub fn new(eb: f64, radius: u32) -> Self {
        assert!(eb > 0.0);
        UnpredAwareQuantizer {
            eb,
            radius: radius.max(1),
            plane_major: true,
            records: Vec::new(),
            replay: 0,
        }
    }

    /// Value-major (truncation-layout) variant.
    pub fn value_major(eb: f64, radius: u32) -> Self {
        UnpredAwareQuantizer { plane_major: false, ..Self::new(eb, radius) }
    }

    /// Number of unpredictable points so far.
    pub fn unpredictable_count(&self) -> usize {
        self.records.len()
    }

    fn record_value(&self, rec: &UnpredRecord<T>, pred: f64) -> T {
        match rec.exact {
            Some(v) => v,
            None => {
                let diff = rec.mag as f64 * self.eb;
                T::from_f64(if rec.sign { pred - diff } else { pred + diff })
            }
        }
    }
}

impl<T: Scalar> Quantizer<T> for UnpredAwareQuantizer<T> {
    fn name(&self) -> &'static str {
        "unpred_aware"
    }

    #[inline]
    fn quantize(&mut self, data: T, pred: f64) -> (u32, T) {
        let diff = data.to_f64() - pred;
        let q = (diff / (2.0 * self.eb)).round();
        if q.abs() < self.radius as f64 {
            let rec = T::from_f64(pred + q * 2.0 * self.eb);
            if (rec.to_f64() - data.to_f64()).abs() <= self.eb {
                return ((q as i64 + self.radius as i64) as u32, rec);
            }
        }
        // Unpredictable: exponent-aligned integer, bitplane-stored.
        let qm = (diff / self.eb).round();
        let record = if qm.abs() < MAG_CAP {
            UnpredRecord { exact: None, sign: qm < 0.0, mag: qm.abs() as u64 }
        } else {
            UnpredRecord { exact: Some(data), sign: false, mag: 0 }
        };
        let rec = self.record_value(&record, pred);
        let record = if (rec.to_f64() - data.to_f64()).abs() <= self.eb {
            record
        } else {
            // storage-type rounding broke the bound: escape to exact
            UnpredRecord { exact: Some(data), sign: false, mag: 0 }
        };
        let rec = self.record_value(&record, pred);
        self.records.push(record);
        (UNPREDICTABLE, rec)
    }

    #[inline]
    fn recover(&mut self, pred: f64, index: u32) -> T {
        if index == UNPREDICTABLE {
            // corrupt streams may overrun the store; degrade to pred
            let Some(rec) = self.records.get(self.replay) else {
                self.replay += 1;
                return T::from_f64(pred);
            };
            self.replay += 1;
            self.record_value(rec, pred)
        } else {
            let q = index as i64 - self.radius as i64;
            T::from_f64(pred + q as f64 * 2.0 * self.eb)
        }
    }

    fn index_range(&self) -> u32 {
        2 * self.radius
    }

    fn save(&self, w: &mut ByteWriter) -> Result<()> {
        w.put_f64(self.eb);
        w.put_u32(self.radius);
        let n = self.records.len();
        w.put_varint(n as u64);
        if n == 0 {
            return Ok(());
        }
        // escape plane + sign plane
        let mut bw = BitWriter::with_capacity(n / 4 + 1);
        for r in &self.records {
            bw.put_bit(r.exact.is_some() as u32);
        }
        for r in &self.records {
            bw.put_bit(r.sign as u32);
        }
        // magnitudes: either bitplane-major (MSB plane first — the embedded
        // encoding of §4.2) or value-major (truncation layout). Same size,
        // very different compressibility downstream.
        let max_mag = self.records.iter().map(|r| r.mag).max().unwrap_or(0);
        let nbits = 64 - max_mag.leading_zeros();
        w.put_u8(nbits as u8);
        w.put_u8(self.plane_major as u8);
        if self.plane_major {
            for plane in (0..nbits).rev() {
                for r in &self.records {
                    bw.put_bit(((r.mag >> plane) & 1) as u32);
                }
            }
        } else {
            for r in &self.records {
                bw.put_bits(r.mag, nbits);
            }
        }
        w.put_block(&bw.finish());
        // exact escapes, in order
        for r in &self.records {
            if let Some(v) = r.exact {
                v.write(w);
            }
        }
        Ok(())
    }

    fn load(&mut self, r: &mut ByteReader) -> Result<()> {
        self.eb = r.get_f64()?;
        self.radius = r.get_u32()?;
        if self.eb <= 0.0 || !self.eb.is_finite() || self.radius == 0 {
            return Err(SzError::corrupt("unpred_aware: bad params"));
        }
        let n64 = r.get_varint()?;
        self.records.clear();
        self.replay = 0;
        if n64 == 0 {
            return Ok(());
        }
        let nbits = u64::from(r.get_u8()?);
        let plane_major = r.get_u8()? == 1;
        self.plane_major = plane_major;
        // `planes` is a length-checked block, so its size is bounded by the
        // bytes actually present. Every record needs an escape bit, a sign
        // bit and `nbits` magnitude bits — reject counts the block cannot
        // hold *before* sizing any allocation by the hostile count.
        let planes = r.get_block()?;
        let have_bits = (planes.len() as u64).saturating_mul(8);
        let need_bits = n64.checked_mul(nbits.saturating_add(2));
        if need_bits.map(|need| need > have_bits).unwrap_or(true) {
            return Err(SzError::corrupt(
                "unpred_aware: record count exceeds bitplane payload",
            ));
        }
        let n = usize::try_from(n64)
            .map_err(|_| SzError::corrupt("unpred_aware: count overflows usize"))?;
        let mut br = BitReader::new(planes);
        let mut escapes = Vec::with_capacity(n);
        for _ in 0..n {
            escapes.push(br.get_bit()? == 1);
        }
        let mut signs = Vec::with_capacity(n);
        for _ in 0..n {
            signs.push(br.get_bit()? == 1);
        }
        let mut mags = vec![0u64; n];
        if plane_major {
            for _ in 0..nbits {
                for m in mags.iter_mut() {
                    *m = (*m << 1) | u64::from(br.get_bit()?);
                }
            }
        } else {
            let w = nbits as u32;
            for m in mags.iter_mut() {
                *m = br.get_bits(w)?;
            }
        }
        let mut records = Vec::with_capacity(n);
        for (&esc, (&sign, &mag)) in escapes.iter().zip(signs.iter().zip(mags.iter())) {
            let exact = if esc { Some(T::read(r)?) } else { None };
            records.push(UnpredRecord { exact, sign, mag });
        }
        self.records = records;
        Ok(())
    }

    fn reset(&mut self) {
        self.records.clear();
        self.replay = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quantizer::test_support::roundtrip_check;
    use crate::util::prop;

    #[test]
    fn unpredictables_respect_half_eb() {
        let eb = 1e-3;
        let mut q = UnpredAwareQuantizer::<f64>::new(eb, 4); // tiny radius
        let (idx, rec) = q.quantize(100.0, 0.0); // far out of range
        assert_eq!(idx, UNPREDICTABLE);
        assert!((rec - 100.0).abs() <= eb / 2.0 + 1e-15);
    }

    #[test]
    fn bitplane_store_smaller_after_lossless_than_truncation() {
        // The §4.2 claim: bitplane order doesn't shrink the raw size but
        // makes it far more compressible. Compare zstd(bitplanes) against
        // zstd(exact f64 storage) for small-magnitude unpredictables.
        use crate::lossless::{Lossless, ZstdLossless};
        use crate::util::rng::Pcg32;
        let eb = 1e-6;
        let mut rng = Pcg32::seeded(77);
        let mut q = UnpredAwareQuantizer::<f64>::new(eb, 2);
        let mut exact_bytes = ByteWriter::new();
        for _ in 0..4000 {
            let pred = 0.0;
            let d = rng.normal() * 40.0 * eb; // unpredictable at radius 2
            q.quantize(d, pred);
            exact_bytes.put_f64(d);
        }
        let mut w = ByteWriter::new();
        q.save(&mut w).unwrap();
        let z = ZstdLossless::default();
        let bp = z.compress(&w.finish()).unwrap().len();
        let ex = z.compress(&exact_bytes.finish()).unwrap().len();
        assert!(bp * 2 < ex, "bitplane {bp} not much smaller than exact {ex}");
    }

    #[test]
    fn prop_error_bound_holds_mixed() {
        prop::cases(60, 0x0b1, |rng| {
            let eb = 10f64.powf(rng.uniform(-8.0, 0.0));
            let n = rng.below(400) + 1;
            let data: Vec<f64> = (0..n).map(|_| rng.uniform(-100.0, 100.0)).collect();
            // predictions mostly good, sometimes terrible => mixed streams
            let preds: Vec<f64> = data
                .iter()
                .map(|&d| {
                    if rng.below(4) == 0 {
                        rng.uniform(-100.0, 100.0)
                    } else {
                        d + rng.normal() * eb
                    }
                })
                .collect();
            let bounds = vec![eb; n];
            let mut q = UnpredAwareQuantizer::<f64>::new(eb, 64);
            roundtrip_check(&mut q, &data, &preds, &bounds);
        });
    }

    #[test]
    fn prop_f32_and_huge_magnitudes() {
        prop::cases(30, 0x0b2, |rng| {
            let eb = 1e-12; // force MAG_CAP escapes
            let n = rng.below(100) + 1;
            let data: Vec<f32> = (0..n).map(|_| rng.uniform(-1e6, 1e6) as f32).collect();
            let preds: Vec<f64> = (0..n).map(|_| rng.uniform(-1e6, 1e6)).collect();
            let bounds = vec![eb; n];
            let mut q = UnpredAwareQuantizer::<f32>::new(eb, 16);
            roundtrip_check(&mut q, &data, &preds, &bounds);
        });
    }
}
