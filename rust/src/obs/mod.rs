//! Process-wide observability: a dependency-free metrics registry and a
//! span tracer ([`trace`]) instrumented through the compression hot
//! layers.
//!
//! Every metric is a `static` lock-free cell ([`Counter`], [`Gauge`],
//! [`Histogram`] — the latter generalizes the log₂-bucket accumulator
//! that `server/stats.rs` pioneered): recording is a handful of relaxed
//! atomic adds with no allocation, no locking and no string lookup, so
//! the instrumentation is compiled in unconditionally (no feature gate)
//! and stays on in production. The catalog is fixed at compile time;
//! dynamic dimensions (pipeline specs, artifact ids) fold into small
//! static label sets (predictor family, endpoint class) so the hot path
//! never formats or hashes a label.
//!
//! Consumers:
//! * `GET /metricsz` renders the whole registry in Prometheus text
//!   exposition format ([`render_prometheus`]).
//! * `sz3 compress/extract --stats` prints the per-stage wall-time /
//!   bytes / throughput table ([`stage_table`], [`reader_table`]).
//! * `sz3 ... --trace FILE` dumps the span ring buffer as Chrome
//!   `trace_event` JSON ([`trace`]).
//!
//! The metric catalog is documented in `docs/OBSERVABILITY.md`; this
//! module is part of the `sz3 audit` trust map, so everything here is
//! panic-free and uses checked indexing only.

pub mod trace;

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Histogram bucket span: bucket *i* covers `[2^i, 2^(i+1))` µs (bucket 0
/// also absorbs 0–1 µs), so bucket 25 tops out at ~67 s.
pub const N_BUCKETS: usize = 26;

/// Monotonically increasing event count — relaxed atomic adds only.
#[derive(Debug)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    /// A zeroed counter (const, so counters can live in statics).
    pub const fn new() -> Counter {
        Counter { v: AtomicU64::new(0) }
    }

    /// Add `n` to the counter.
    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    /// Add 1 to the counter.
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

impl Default for Counter {
    fn default() -> Counter {
        Counter::new()
    }
}

/// A last-write-wins instantaneous value (bytes resident, entries live).
#[derive(Debug)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    /// A zeroed gauge.
    pub const fn new() -> Gauge {
        Gauge { v: AtomicU64::new(0) }
    }

    /// Overwrite the gauge.
    #[inline]
    pub fn set(&self, n: u64) {
        self.v.store(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge::new()
    }
}

/// Log₂-bucketed latency histogram: 26 fixed `u64` microsecond buckets
/// plus count / max, all relaxed atomics — safe to hammer from every
/// worker thread with no allocation or locking. The running **sum is
/// kept in nanoseconds** so sub-microsecond observations (ingest stage
/// slices) still accumulate instead of truncating to zero; rendered
/// sums stay seconds-normalized.
#[derive(Debug)]
pub struct Histogram {
    n: AtomicU64,
    sum_ns: AtomicU64,
    max_us: AtomicU64,
    buckets: [AtomicU64; N_BUCKETS],
}

/// Point-in-time copy of a [`Histogram`], for rendering and quantiles.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HistSnapshot {
    /// Observations recorded.
    pub n: u64,
    /// Sum of all observations, nanoseconds.
    pub sum_ns: u64,
    /// Largest observation, microseconds.
    pub max_us: u64,
    /// Per-bucket observation counts.
    pub buckets: [u64; N_BUCKETS],
}

/// Bucket slot for a microsecond value.
#[inline]
fn bucket_of(us: u64) -> usize {
    if us <= 1 {
        0
    } else {
        ((63 - us.leading_zeros()) as usize).min(N_BUCKETS - 1)
    }
}

/// Inclusive lower bound (µs) of bucket `slot`.
fn bucket_lo_us(slot: usize) -> u64 {
    if slot == 0 {
        0
    } else {
        1u64 << slot.min(N_BUCKETS)
    }
}

/// Exclusive upper bound (µs) of bucket `slot`.
pub fn bucket_hi_us(slot: usize) -> u64 {
    1u64 << (slot.min(N_BUCKETS - 1) + 1)
}

impl Histogram {
    /// A zeroed histogram (const, so histograms can live in statics).
    pub const fn new() -> Histogram {
        const Z: AtomicU64 = AtomicU64::new(0);
        Histogram {
            n: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
            buckets: [Z; N_BUCKETS],
        }
    }

    #[inline]
    fn record(&self, us: u64, ns: u64) {
        self.n.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
        if let Some(b) = self.buckets.get(bucket_of(us)) {
            b.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record one observation of `us` microseconds.
    #[inline]
    pub fn observe_us(&self, us: u64) {
        self.record(us, us.saturating_mul(1000));
    }

    /// Record one observation of a duration — the sum keeps full
    /// nanosecond precision, the bucket is placed by microsecond.
    #[inline]
    pub fn observe(&self, elapsed: Duration) {
        let ns = u64::try_from(elapsed.as_nanos()).unwrap_or(u64::MAX);
        self.record(ns / 1000, ns);
    }

    /// Record the time elapsed since `start`.
    #[inline]
    pub fn observe_since(&self, start: Instant) {
        self.observe(start.elapsed());
    }

    /// Copy the distribution. Counters advance concurrently, so a
    /// snapshot taken under traffic is approximate — fine for
    /// observability.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut s = HistSnapshot {
            n: self.n.load(Ordering::Relaxed),
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
            max_us: self.max_us.load(Ordering::Relaxed),
            ..HistSnapshot::default()
        };
        for (slot, b) in s.buckets.iter_mut().zip(self.buckets.iter()) {
            *slot = b.load(Ordering::Relaxed);
        }
        s
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl HistSnapshot {
    /// Estimated quantile `q` (0..=1) in microseconds, **linearly
    /// interpolated within the winning bucket** — the bucket holding the
    /// target rank is located, then the rank's position inside that
    /// bucket's count interpolates between the bucket's bounds. Exact
    /// when a bucket's samples are uniform; always within the bucket
    /// (the former upper-bound estimate was conservative to 2×).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total: u64 = self.buckets.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = (total as f64) * q.clamp(0.0, 1.0);
        let mut cum = 0u64;
        for (slot, &c) in self.buckets.iter().enumerate() {
            let reach = cum.saturating_add(c);
            if c > 0 && (reach as f64) >= target {
                let lo = bucket_lo_us(slot) as f64;
                let hi = bucket_hi_us(slot) as f64;
                let frac = ((target - cum as f64) / c as f64).clamp(0.0, 1.0);
                return (lo + (hi - lo) * frac) as u64;
            }
            cum = reach;
        }
        self.max_us
    }

    /// Mean observation in microseconds (0 when empty). Computed from
    /// the nanosecond sum, so sub-µs populations round to 0 only after
    /// averaging, not per sample.
    pub fn mean_us(&self) -> u64 {
        if self.n == 0 {
            0
        } else {
            self.sum_ns / self.n / 1000
        }
    }
}

/// Nanoseconds elapsed since `start`, saturating.
#[inline]
pub fn elapsed_ns(start: Instant) -> u64 {
    u64::try_from(start.elapsed().as_nanos()).unwrap_or(u64::MAX)
}

// ---------------------------------------------------------------------------
// Pipeline stage accounting
// ---------------------------------------------------------------------------

/// Wall time + byte flow accumulator for one pipeline stage.
#[derive(Debug)]
pub struct StageMetrics {
    ns: Counter,
    b_in: Counter,
    b_out: Counter,
    calls: Counter,
}

impl StageMetrics {
    /// A zeroed stage accumulator.
    pub const fn new() -> StageMetrics {
        StageMetrics {
            ns: Counter::new(),
            b_in: Counter::new(),
            b_out: Counter::new(),
            calls: Counter::new(),
        }
    }

    /// Record one stage execution: wall time since `start`, bytes
    /// consumed and bytes produced.
    #[inline]
    pub fn record(&self, start: Instant, bytes_in: u64, bytes_out: u64) {
        self.ns.add(elapsed_ns(start));
        self.b_in.add(bytes_in);
        self.b_out.add(bytes_out);
        self.calls.inc();
    }

    /// Cumulative stage wall time.
    pub fn total(&self) -> Duration {
        Duration::from_nanos(self.ns.get())
    }

    /// Cumulative bytes in.
    pub fn bytes_in(&self) -> u64 {
        self.b_in.get()
    }

    /// Cumulative bytes out.
    pub fn bytes_out(&self) -> u64 {
        self.b_out.get()
    }

    /// Executions recorded.
    pub fn calls(&self) -> u64 {
        self.calls.get()
    }
}

/// Stage labels, index-aligned with [`STAGE`]. The first five are the
/// compression direction (paper §3.2 module order), the last four the
/// decompression direction.
pub const STAGE_NAMES: [&str; 9] = [
    "preprocess",
    "analyze",
    "predict",
    "encode",
    "lossless",
    "unlossless",
    "decode",
    "reconstruct",
    "postprocess",
];

/// Stage slot: preprocessor transform (log / linearize) on compress.
pub const ST_PREPROCESS: usize = 0;
/// Stage slot: block analysis (regression fit + error estimation).
pub const ST_ANALYZE: usize = 1;
/// Stage slot: prediction + quantization sweep on compress.
pub const ST_PREDICT: usize = 2;
/// Stage slot: entropy coding of quantization indices.
pub const ST_ENCODE: usize = 3;
/// Stage slot: lossless backend, compress direction.
pub const ST_LOSSLESS: usize = 4;
/// Stage slot: lossless backend, decompress direction.
pub const ST_UNLOSSLESS: usize = 5;
/// Stage slot: entropy decoding of quantization indices.
pub const ST_DECODE: usize = 6;
/// Stage slot: prediction + reconstruction sweep on decompress.
pub const ST_RECONSTRUCT: usize = 7;
/// Stage slot: preprocessor inverse (exp / de-linearize) on decompress.
pub const ST_POSTPROCESS: usize = 8;

/// The stage slots of the compression direction, in execution order.
pub const COMPRESS_STAGES: [usize; 5] =
    [ST_PREPROCESS, ST_ANALYZE, ST_PREDICT, ST_ENCODE, ST_LOSSLESS];

/// The stage slots of the decompression direction, in execution order.
pub const DECOMPRESS_STAGES: [usize; 4] =
    [ST_UNLOSSLESS, ST_DECODE, ST_RECONSTRUCT, ST_POSTPROCESS];

const STAGE_INIT: StageMetrics = StageMetrics::new();
/// Per-stage accumulators, indexed by the `ST_*` constants.
pub static STAGE: [StageMetrics; 9] = [STAGE_INIT; 9];

static NULL_STAGE: StageMetrics = StageMetrics::new();

/// The accumulator for stage `slot` (out-of-range slots return an inert
/// accumulator rather than panicking).
#[inline]
pub fn stage(slot: usize) -> &'static StageMetrics {
    STAGE.get(slot).unwrap_or(&NULL_STAGE)
}

// ---------------------------------------------------------------------------
// Static metric catalog
// ---------------------------------------------------------------------------

/// Chunks emitted by the coordinator's planner.
pub static CHUNKS_PLANNED: Counter = Counter::new();
/// Cumulative chunk-planning wall time, nanoseconds.
pub static CHUNK_PLAN_NS: Counter = Counter::new();
/// Per-chunk compression wall time (worker-side, selection included).
pub static CHUNK_COMPRESS_US: Histogram = Histogram::new();
/// Uncompressed bytes entering per-chunk compression.
pub static CHUNK_BYTES_IN: Counter = Counter::new();
/// Compressed bytes leaving per-chunk compression.
pub static CHUNK_BYTES_OUT: Counter = Counter::new();

/// Predictor-family labels for the adaptive selector's win counters,
/// index-aligned with [`SELECTOR_WINS`]. Dynamic pipeline specs fold
/// into their family so recording stays allocation-free.
pub const SELECTOR_FAMILIES: [&str; 9] = [
    "block", "interp", "point", "truncation", "szx", "transform", "pastri",
    "aps", "other",
];

const COUNTER_INIT: Counter = Counter::new();
/// Adaptive-selector wins per predictor family.
pub static SELECTOR_WINS: [Counter; 9] = [COUNTER_INIT; 9];
/// Candidate pipelines scored by the adaptive selector.
pub static SELECTOR_CANDIDATES: Counter = Counter::new();
/// Per-chunk adaptive selection wall time.
pub static SELECTOR_US: Histogram = Histogram::new();
/// Times the unpredictability override forced the truncation pipeline.
pub static SELECTOR_OVERRIDES: Counter = Counter::new();

/// Family slot for a predictor-family name (unknown → `"other"`).
pub fn selector_family_slot(family: &str) -> usize {
    SELECTOR_FAMILIES
        .iter()
        .position(|f| *f == family)
        .unwrap_or(SELECTOR_FAMILIES.len() - 1)
}

/// Count one adaptive-selector win for `family`.
pub fn selector_win(family: &str) {
    if let Some(c) = SELECTOR_WINS.get(selector_family_slot(family)) {
        c.inc();
    }
}

/// Series chunks stored direct (delta lost or disabled).
pub static SERIES_DIRECT_CHUNKS: Counter = Counter::new();
/// Series chunks stored as snapshot residuals (delta won).
pub static SERIES_DELTA_CHUNKS: Counter = Counter::new();
/// Payload bytes saved by delta mode vs storing every chunk direct.
pub static SERIES_BYTES_SAVED: Counter = Counter::new();

/// Reader chunk-fetch wall time (source I/O).
pub static READER_FETCH_US: Histogram = Histogram::new();
/// Reader per-chunk CRC-32 verification wall time.
pub static READER_CRC_US: Histogram = Histogram::new();
/// Reader per-chunk pipeline decode wall time.
pub static READER_DECODE_US: Histogram = Histogram::new();

/// Decoded-chunk cache hits.
pub static CACHE_HITS: Counter = Counter::new();
/// Decoded-chunk cache misses.
pub static CACHE_MISSES: Counter = Counter::new();
/// Entries evicted to make room.
pub static CACHE_EVICTIONS: Counter = Counter::new();
/// Entries inserted.
pub static CACHE_INSERTS: Counter = Counter::new();
/// Entries rejected as larger than the whole budget.
pub static CACHE_REJECTS: Counter = Counter::new();
/// Bytes currently resident in the cache.
pub static CACHE_BYTES: Gauge = Gauge::new();
/// Entries currently resident in the cache.
pub static CACHE_ENTRIES: Gauge = Gauge::new();

/// Endpoint-class labels for the HTTP metrics, index-aligned with
/// [`HTTP_REQUESTS`] / [`HTTP_US`] / [`HTTP_RESP_BYTES`]. The server's
/// per-instance `/statsz` accounting uses the same label set. `"other"`
/// must stay last — it is the fold target for unknown labels.
pub const HTTP_ENDPOINTS: [&str; 11] = [
    "list", "meta", "roi", "raw", "healthz", "statsz", "metricsz", "ingest",
    "delete", "rescan", "other",
];

/// Requests served per endpoint class.
pub static HTTP_REQUESTS: [Counter; 11] = [COUNTER_INIT; 11];
const HIST_INIT: Histogram = Histogram::new();
/// Request handling latency per endpoint class.
pub static HTTP_US: [Histogram; 11] = [HIST_INIT; 11];
/// Response body bytes per endpoint class.
pub static HTTP_RESP_BYTES: [Counter; 11] = [COUNTER_INIT; 11];

// ---------------------------------------------------------------------------
// Ingest / registry metrics (the server write path)
// ---------------------------------------------------------------------------

/// Raw request-body bytes accepted into the ingest pipeline.
pub static INGEST_BYTES: Counter = Counter::new();
/// Artifacts created by `PUT` (id previously unknown).
pub static INGEST_CREATED: Counter = Counter::new();
/// Artifacts atomically replaced by `PUT` (id already live).
pub static INGEST_REPLACED: Counter = Counter::new();
/// Ingest attempts that failed after admission (bad params, compression
/// or I/O error) — partial temp files are cleaned up on this path.
pub static INGEST_FAILED: Counter = Counter::new();
/// Ingest attempts rejected with `429` because every ingest slot was busy.
pub static INGEST_REJECTED_BUSY: Counter = Counter::new();
/// End-to-end ingest wall time (body parse through registry publish).
pub static INGEST_SECONDS: Histogram = Histogram::new();
/// Artifacts removed via `DELETE`.
pub static ARTIFACTS_DELETED: Counter = Counter::new();
/// Directory rescans served via `POST /v1/admin/rescan`.
pub static RESCANS: Counter = Counter::new();
/// Registry epoch — bumped on every publish/delete/rescan swap.
pub static REGISTRY_GENERATION: Gauge = Gauge::new();
/// Artifacts live in the current registry snapshot.
pub static REGISTRY_ARTIFACTS: Gauge = Gauge::new();

/// Endpoint slot for a handler label (unknown → `"other"`).
pub fn http_slot(label: &str) -> usize {
    HTTP_ENDPOINTS
        .iter()
        .position(|e| *e == label)
        .unwrap_or(HTTP_ENDPOINTS.len() - 1)
}

/// Record one served request against endpoint slot `slot`.
pub fn http_record(slot: usize, elapsed: Duration, resp_bytes: u64) {
    if let Some(c) = HTTP_REQUESTS.get(slot) {
        c.inc();
    }
    if let Some(h) = HTTP_US.get(slot) {
        h.observe(elapsed);
    }
    if let Some(c) = HTTP_RESP_BYTES.get(slot) {
        c.add(resp_bytes);
    }
}

/// Trace events overwritten because the ring buffer was full.
pub static TRACE_DROPPED: Counter = Counter::new();

// ---------------------------------------------------------------------------
// Prometheus text exposition
// ---------------------------------------------------------------------------

fn head(out: &mut String, name: &str, kind: &str, help: &str) {
    out.push_str("# HELP ");
    out.push_str(name);
    out.push(' ');
    out.push_str(help);
    out.push_str("\n# TYPE ");
    out.push_str(name);
    out.push(' ');
    out.push_str(kind);
    out.push('\n');
}

fn sample(out: &mut String, name: &str, label: Option<(&str, &str)>, value: &str) {
    out.push_str(name);
    if let Some((k, v)) = label {
        out.push('{');
        out.push_str(k);
        out.push_str("=\"");
        out.push_str(v);
        out.push_str("\"}");
    }
    out.push(' ');
    out.push_str(value);
    out.push('\n');
}

fn seconds(ns: u64) -> String {
    format!("{:.9}", ns as f64 / 1e9)
}

/// One labeled counter family.
fn counter_family(
    out: &mut String,
    name: &str,
    help: &str,
    label_key: &str,
    cells: &[(&str, u64)],
) {
    head(out, name, "counter", help);
    for (lv, v) in cells {
        sample(out, name, Some((label_key, lv)), &v.to_string());
    }
}

/// One unlabeled counter.
fn counter_single(out: &mut String, name: &str, help: &str, v: u64) {
    head(out, name, "counter", help);
    sample(out, name, None, &v.to_string());
}

/// One unlabeled gauge.
fn gauge_single(out: &mut String, name: &str, help: &str, v: u64) {
    head(out, name, "gauge", help);
    sample(out, name, None, &v.to_string());
}

/// Emit the `_bucket`/`_sum`/`_count` series of one histogram, with an
/// optional extra label. Bounds are rendered in seconds per convention.
fn hist_series(out: &mut String, name: &str, label: Option<(&str, &str)>, s: &HistSnapshot) {
    let bucket_name = format!("{name}_bucket");
    let mut cum = 0u64;
    for (slot, c) in s.buckets.iter().enumerate() {
        cum = cum.saturating_add(*c);
        let le = format!("{:.6}", bucket_hi_us(slot) as f64 / 1e6);
        out.push_str(&bucket_name);
        out.push('{');
        if let Some((k, v)) = label {
            out.push_str(k);
            out.push_str("=\"");
            out.push_str(v);
            out.push_str("\",");
        }
        out.push_str("le=\"");
        out.push_str(&le);
        out.push_str("\"} ");
        out.push_str(&cum.to_string());
        out.push('\n');
    }
    out.push_str(&bucket_name);
    out.push('{');
    if let Some((k, v)) = label {
        out.push_str(k);
        out.push_str("=\"");
        out.push_str(v);
        out.push_str("\",");
    }
    out.push_str("le=\"+Inf\"} ");
    out.push_str(&s.n.to_string());
    out.push('\n');
    sample(
        out,
        &format!("{name}_sum"),
        label,
        // the running sum is nanoseconds; exposition stays seconds
        &format!("{:.9}", s.sum_ns as f64 / 1e9),
    );
    sample(out, &format!("{name}_count"), label, &s.n.to_string());
}

/// One unlabeled histogram family.
fn hist_single(out: &mut String, name: &str, help: &str, h: &Histogram) {
    head(out, name, "histogram", help);
    hist_series(out, name, None, &h.snapshot());
}

/// Render the entire registry in Prometheus text exposition format
/// (version 0.0.4) — the body of `GET /metricsz`.
pub fn render_prometheus() -> String {
    let mut out = String::with_capacity(16 * 1024);

    let stage_cells = |f: &dyn Fn(&StageMetrics) -> u64| -> Vec<(&'static str, u64)> {
        STAGE_NAMES.iter().zip(STAGE.iter()).map(|(n, s)| (*n, f(s))).collect()
    };
    head(
        &mut out,
        "sz3_stage_seconds_total",
        "counter",
        "Cumulative wall time per pipeline stage.",
    );
    for (n, s) in STAGE_NAMES.iter().zip(STAGE.iter()) {
        sample(
            &mut out,
            "sz3_stage_seconds_total",
            Some(("stage", n)),
            &seconds(s.ns.get()),
        );
    }
    counter_family(
        &mut out,
        "sz3_stage_bytes_in_total",
        "Bytes consumed per pipeline stage.",
        "stage",
        &stage_cells(&|s| s.bytes_in()),
    );
    counter_family(
        &mut out,
        "sz3_stage_bytes_out_total",
        "Bytes produced per pipeline stage.",
        "stage",
        &stage_cells(&|s| s.bytes_out()),
    );
    counter_family(
        &mut out,
        "sz3_stage_calls_total",
        "Stage executions.",
        "stage",
        &stage_cells(&|s| s.calls()),
    );

    counter_single(
        &mut out,
        "sz3_chunks_planned_total",
        "Chunks emitted by the coordinator planner.",
        CHUNKS_PLANNED.get(),
    );
    head(
        &mut out,
        "sz3_chunk_plan_seconds_total",
        "counter",
        "Cumulative chunk-planning wall time.",
    );
    sample(&mut out, "sz3_chunk_plan_seconds_total", None, &seconds(CHUNK_PLAN_NS.get()));
    hist_single(
        &mut out,
        "sz3_chunk_compress_seconds",
        "Per-chunk compression wall time (selection included).",
        &CHUNK_COMPRESS_US,
    );
    counter_single(
        &mut out,
        "sz3_chunk_bytes_in_total",
        "Uncompressed bytes entering per-chunk compression.",
        CHUNK_BYTES_IN.get(),
    );
    counter_single(
        &mut out,
        "sz3_chunk_bytes_out_total",
        "Compressed bytes produced by per-chunk compression.",
        CHUNK_BYTES_OUT.get(),
    );

    let win_cells: Vec<(&'static str, u64)> = SELECTOR_FAMILIES
        .iter()
        .zip(SELECTOR_WINS.iter())
        .map(|(f, c)| (*f, c.get()))
        .collect();
    counter_family(
        &mut out,
        "sz3_selector_wins_total",
        "Adaptive-selector wins per predictor family.",
        "family",
        &win_cells,
    );
    counter_single(
        &mut out,
        "sz3_selector_candidates_total",
        "Candidate pipelines scored by the adaptive selector.",
        SELECTOR_CANDIDATES.get(),
    );
    hist_single(
        &mut out,
        "sz3_selector_seconds",
        "Per-chunk adaptive selection wall time.",
        &SELECTOR_US,
    );
    counter_single(
        &mut out,
        "sz3_selector_truncation_overrides_total",
        "Times the unpredictability override forced truncation.",
        SELECTOR_OVERRIDES.get(),
    );

    counter_family(
        &mut out,
        "sz3_series_chunks_total",
        "Series chunks by chosen representation.",
        "mode",
        &[
            ("direct", SERIES_DIRECT_CHUNKS.get()),
            ("delta", SERIES_DELTA_CHUNKS.get()),
        ],
    );
    counter_single(
        &mut out,
        "sz3_series_bytes_saved_total",
        "Payload bytes saved by snapshot delta mode.",
        SERIES_BYTES_SAVED.get(),
    );

    hist_single(
        &mut out,
        "sz3_reader_fetch_seconds",
        "Reader chunk-fetch (source I/O) wall time.",
        &READER_FETCH_US,
    );
    hist_single(
        &mut out,
        "sz3_reader_crc_seconds",
        "Reader per-chunk CRC-32 verification wall time.",
        &READER_CRC_US,
    );
    hist_single(
        &mut out,
        "sz3_reader_decode_seconds",
        "Reader per-chunk pipeline decode wall time.",
        &READER_DECODE_US,
    );

    counter_single(&mut out, "sz3_cache_hits_total", "Decoded-chunk cache hits.", CACHE_HITS.get());
    counter_single(
        &mut out,
        "sz3_cache_misses_total",
        "Decoded-chunk cache misses.",
        CACHE_MISSES.get(),
    );
    counter_single(
        &mut out,
        "sz3_cache_evictions_total",
        "Cache entries evicted to make room.",
        CACHE_EVICTIONS.get(),
    );
    counter_single(
        &mut out,
        "sz3_cache_inserts_total",
        "Cache entries inserted.",
        CACHE_INSERTS.get(),
    );
    counter_single(
        &mut out,
        "sz3_cache_rejects_total",
        "Cache entries rejected as larger than the budget.",
        CACHE_REJECTS.get(),
    );
    gauge_single(&mut out, "sz3_cache_bytes", "Bytes resident in the cache.", CACHE_BYTES.get());
    gauge_single(
        &mut out,
        "sz3_cache_entries",
        "Entries resident in the cache.",
        CACHE_ENTRIES.get(),
    );

    let req_cells: Vec<(&'static str, u64)> = HTTP_ENDPOINTS
        .iter()
        .zip(HTTP_REQUESTS.iter())
        .map(|(e, c)| (*e, c.get()))
        .collect();
    counter_family(
        &mut out,
        "sz3_http_requests_total",
        "Requests served per endpoint class.",
        "endpoint",
        &req_cells,
    );
    head(
        &mut out,
        "sz3_http_request_seconds",
        "histogram",
        "Request handling latency per endpoint class.",
    );
    for (e, h) in HTTP_ENDPOINTS.iter().zip(HTTP_US.iter()) {
        hist_series(&mut out, "sz3_http_request_seconds", Some(("endpoint", e)), &h.snapshot());
    }
    let byte_cells: Vec<(&'static str, u64)> = HTTP_ENDPOINTS
        .iter()
        .zip(HTTP_RESP_BYTES.iter())
        .map(|(e, c)| (*e, c.get()))
        .collect();
    counter_family(
        &mut out,
        "sz3_http_response_bytes_total",
        "Response body bytes per endpoint class.",
        "endpoint",
        &byte_cells,
    );

    counter_single(
        &mut out,
        "sz3_ingest_bytes_total",
        "Raw request-body bytes accepted into the ingest pipeline.",
        INGEST_BYTES.get(),
    );
    counter_family(
        &mut out,
        "sz3_ingest_artifacts_total",
        "Ingest outcomes by kind.",
        "outcome",
        &[
            ("created", INGEST_CREATED.get()),
            ("replaced", INGEST_REPLACED.get()),
            ("failed", INGEST_FAILED.get()),
            ("rejected_busy", INGEST_REJECTED_BUSY.get()),
        ],
    );
    hist_single(
        &mut out,
        "sz3_ingest_seconds",
        "End-to-end ingest wall time (body parse through registry publish).",
        &INGEST_SECONDS,
    );
    counter_single(
        &mut out,
        "sz3_artifacts_deleted_total",
        "Artifacts removed via DELETE.",
        ARTIFACTS_DELETED.get(),
    );
    counter_single(
        &mut out,
        "sz3_rescans_total",
        "Directory rescans served via POST /v1/admin/rescan.",
        RESCANS.get(),
    );
    gauge_single(
        &mut out,
        "sz3_registry_generation",
        "Registry epoch, bumped on every publish/delete/rescan swap.",
        REGISTRY_GENERATION.get(),
    );
    gauge_single(
        &mut out,
        "sz3_registry_artifacts",
        "Artifacts live in the current registry snapshot.",
        REGISTRY_ARTIFACTS.get(),
    );

    counter_single(
        &mut out,
        "sz3_trace_events_dropped_total",
        "Trace events overwritten because the ring buffer was full.",
        TRACE_DROPPED.get(),
    );
    out
}

// ---------------------------------------------------------------------------
// CLI --stats tables
// ---------------------------------------------------------------------------

fn human_bytes(b: u64) -> String {
    if b >= 1_000_000_000 {
        format!("{:.2} GB", b as f64 / 1e9)
    } else if b >= 1_000_000 {
        format!("{:.2} MB", b as f64 / 1e6)
    } else if b >= 1_000 {
        format!("{:.1} kB", b as f64 / 1e3)
    } else {
        format!("{b} B")
    }
}

fn human_time(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.0} µs", s * 1e6)
    }
}

/// Render the per-stage breakdown table behind `sz3 compress/extract
/// --stats`: one row per instrumented stage with wall-time share, byte
/// flow and throughput over the stage's input, then a residual `other`
/// row so the rows always sum to the measured wall clock.
pub fn stage_table(slots: &[usize], wall: Duration) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<12} {:>10} {:>7} {:>10} {:>10} {:>9}\n",
        "stage", "time", "%wall", "bytes in", "bytes out", "MB/s"
    ));
    let wall_s = wall.as_secs_f64().max(1e-12);
    let mut accounted = Duration::ZERO;
    for &slot in slots {
        let s = stage(slot);
        if s.calls() == 0 {
            continue;
        }
        let t = s.total();
        accounted = accounted.saturating_add(t);
        let mbs = s.bytes_in() as f64 / 1e6 / t.as_secs_f64().max(1e-12);
        out.push_str(&format!(
            "{:<12} {:>10} {:>6.1}% {:>10} {:>10} {:>9.1}\n",
            STAGE_NAMES.get(slot).copied().unwrap_or("?"),
            human_time(t),
            100.0 * t.as_secs_f64() / wall_s,
            human_bytes(s.bytes_in()),
            human_bytes(s.bytes_out()),
            mbs,
        ));
    }
    let other = wall.saturating_sub(accounted);
    out.push_str(&format!(
        "{:<12} {:>10} {:>6.1}%\n",
        "other",
        human_time(other),
        100.0 * other.as_secs_f64() / wall_s,
    ));
    out.push_str(&format!("{:<12} {:>10} {:>6.1}%\n", "wall", human_time(wall), 100.0));
    out
}

/// Render the reader-side breakdown behind `sz3 extract --stats`:
/// fetch / CRC / decode time plus cache behavior.
pub fn reader_table() -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<12} {:>8} {:>10} {:>10} {:>10}\n",
        "reader", "calls", "total", "mean", "p99"
    ));
    for (name, h) in [
        ("fetch", &READER_FETCH_US),
        ("crc", &READER_CRC_US),
        ("decode", &READER_DECODE_US),
    ] {
        let s = h.snapshot();
        if s.n == 0 {
            continue;
        }
        out.push_str(&format!(
            "{:<12} {:>8} {:>10} {:>9}µs {:>9}µs\n",
            name,
            s.n,
            human_time(Duration::from_nanos(s.sum_ns)),
            s.mean_us(),
            s.quantile_us(0.99),
        ));
    }
    out.push_str(&format!(
        "cache        hits {} misses {} evictions {} resident {}\n",
        CACHE_HITS.get(),
        CACHE_MISSES.get(),
        CACHE_EVICTIONS.get(),
        human_bytes(CACHE_BYTES.get()),
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counter_and_histogram_survive_concurrent_hammer_exactly() {
        // N threads × M ops: totals must be exact (no lost updates), and
        // the histogram's bucket sum must equal its observation count.
        let c = Arc::new(Counter::new());
        let h = Arc::new(Histogram::new());
        let threads = 8usize;
        let per = 10_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let c = Arc::clone(&c);
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..per {
                        c.add(2);
                        h.observe_us((t as u64) * 131 + i % 4096);
                    }
                })
            })
            .collect();
        for j in handles {
            j.join().expect("hammer thread panicked");
        }
        let expected = threads as u64 * per;
        assert_eq!(c.get(), expected * 2);
        let s = h.snapshot();
        assert_eq!(s.n, expected);
        assert_eq!(s.buckets.iter().sum::<u64>(), expected);
        assert!(s.max_us >= 4095 && s.max_us <= 7 * 131 + 4095);
    }

    #[test]
    fn bucket_of_matches_log2_bounds() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(127), 6);
        assert_eq!(bucket_of(128), 7);
        assert_eq!(bucket_of(u64::MAX), N_BUCKETS - 1);
        for slot in 0..N_BUCKETS {
            assert!(bucket_lo_us(slot) < bucket_hi_us(slot));
            if slot > 0 {
                assert_eq!(bucket_lo_us(slot), bucket_hi_us(slot - 1));
            }
        }
    }

    #[test]
    fn quantiles_interpolate_within_the_winning_bucket() {
        let h = Histogram::new();
        // 100 samples at 100µs → bucket [64,128); one outlier at 50ms
        for _ in 0..100 {
            h.observe_us(100);
        }
        h.observe_us(50_000);
        let s = h.snapshot();
        // p50: target rank 50.5 of 101, all inside [64,128) → interpolated
        // strictly inside the bucket, not the old 128µs upper bound
        let p50 = s.quantile_us(0.50);
        assert!((64..128).contains(&p50), "p50 {p50} must interpolate inside [64,128)");
        // p99: rank 99.99 of 101 still inside the fast bucket
        let p99 = s.quantile_us(0.99);
        assert!((64..=128).contains(&p99), "p99 {p99}");
        // p100 reaches the outlier's bucket
        assert!(s.quantile_us(1.0) >= 32_768);
        assert_eq!(s.max_us, 50_000);
        // degenerate cases
        assert_eq!(HistSnapshot::default().quantile_us(0.5), 0);
    }

    #[test]
    fn prometheus_exposition_is_well_formed() {
        // touch a few metrics so non-zero samples render too
        stage(ST_ENCODE).record(std::time::Instant::now(), 1024, 256);
        CHUNK_COMPRESS_US.observe_us(500);
        selector_win("interp");
        selector_win("not-a-family");
        http_record(http_slot("roi"), Duration::from_micros(250), 4096);
        let text = render_prometheus();
        let mut families = 0usize;
        let mut seen_type_for = Vec::new();
        for line in text.lines() {
            assert!(!line.ends_with(' '), "trailing space: {line:?}");
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                families += 1;
                let mut it = rest.split(' ');
                let name = it.next().unwrap_or("");
                let kind = it.next().unwrap_or("");
                assert!(
                    matches!(kind, "counter" | "gauge" | "histogram"),
                    "bad TYPE: {line}"
                );
                seen_type_for.push(name.to_string());
            } else if !line.starts_with('#') && !line.is_empty() {
                // sample line: name[{labels}] value
                let (series, value) =
                    line.rsplit_once(' ').expect("sample line has a value");
                assert!(value.parse::<f64>().is_ok(), "bad value in: {line}");
                let name = series.split('{').next().unwrap_or("");
                assert!(
                    seen_type_for.iter().map(|f| f.as_str()).any(|f| name == f
                        || name == format!("{f}_bucket")
                        || name == format!("{f}_sum")
                        || name == format!("{f}_count")),
                    "sample before its TYPE: {line}"
                );
            }
        }
        assert!(families >= 15, "need ≥15 metric families, got {families}");
        // the acceptance-bar families are all present
        for fam in [
            "sz3_stage_seconds_total",
            "sz3_selector_wins_total",
            "sz3_cache_hits_total",
            "sz3_reader_decode_seconds",
            "sz3_http_request_seconds",
        ] {
            assert!(text.contains(&format!("# TYPE {fam} ")), "missing {fam}");
        }
        // histogram buckets are cumulative and end at +Inf == count
        let roi_buckets: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("sz3_http_request_seconds_bucket{endpoint=\"roi\""))
            .map(|l| l.rsplit_once(' ').and_then(|(_, v)| v.parse().ok()).unwrap_or(0))
            .collect();
        assert_eq!(roi_buckets.len(), N_BUCKETS + 1);
        assert!(roi_buckets.windows(2).all(|w| w[0] <= w[1]), "non-cumulative buckets");
    }

    #[test]
    fn histogram_sums_accumulate_ns_and_render_seconds() {
        let h = Histogram::new();
        // sub-µs durations must accumulate instead of truncating to zero
        h.observe(Duration::from_nanos(400));
        h.observe(Duration::from_nanos(600));
        // the whole-µs entry point scales to ns
        h.observe_us(1);
        let s = h.snapshot();
        assert_eq!(s.n, 3);
        assert_eq!(s.sum_ns, 400 + 600 + 1_000);
        // exposition `_sum` stays seconds-normalized: 2000 ns = 2e-6 s
        let mut out = String::new();
        hist_series(&mut out, "t_seconds", None, &s);
        assert!(out.contains("t_seconds_sum 0.000002000"), "sum line: {out}");
        assert!(out.contains("t_seconds_count 3"), "count line: {out}");
        // mean truncates to µs only after averaging in ns
        assert_eq!(s.mean_us(), 0);
        let h2 = Histogram::new();
        for _ in 0..4 {
            h2.observe(Duration::from_micros(3));
        }
        assert_eq!(h2.snapshot().mean_us(), 3);
    }

    #[test]
    fn stage_table_accounts_for_wall_time() {
        let wall = Duration::from_millis(100);
        let t = stage_table(&COMPRESS_STAGES, wall);
        assert!(t.contains("wall"));
        assert!(t.contains("other"));
        assert!(t.lines().count() >= 3);
        let rt = reader_table();
        assert!(rt.contains("cache"));
    }
}
