//! Span-based tracer with a ring-buffer sink and Chrome `trace_event`
//! JSON export.
//!
//! [`Span::enter`] opens an RAII span; dropping it records one complete
//! (`"ph":"X"`) event into a fixed-capacity ring buffer. Disabled (the
//! default) a span is one relaxed atomic load — no clock read, no
//! allocation, no lock. Enabled, recording is a clock read plus one
//! short mutex push of a `Copy` event (names and arg keys are
//! `&'static str`, so the hot path still never allocates); when the
//! ring wraps, the oldest event is overwritten and
//! [`super::TRACE_DROPPED`] counts the loss.
//!
//! [`dump_json`] renders the buffer in Chrome's `trace_event` format
//! (JSON object with a `traceEvents` array of duration-complete events),
//! which `chrome://tracing` and [Perfetto](https://ui.perfetto.dev) open
//! directly — `sz3 compress --trace out.json` end to end. See
//! `docs/OBSERVABILITY.md` for the workflow.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

/// Maximum key/value args carried per span (fixed so events stay `Copy`).
pub const MAX_ARGS: usize = 2;

/// One recorded complete event.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Span name (static — e.g. `"chunk"`, `"select"`).
    pub name: &'static str,
    /// Category (static — the subsystem, e.g. `"coordinator"`).
    pub cat: &'static str,
    /// Start, microseconds since the sink was enabled.
    pub ts_us: u64,
    /// Duration, microseconds.
    pub dur_us: u64,
    /// Recording thread (small dense ids, first-use order).
    pub tid: u64,
    /// Numeric args attached via [`Span::arg`].
    pub args: [(&'static str, u64); MAX_ARGS],
    /// How many of `args` are set.
    pub n_args: u8,
}

struct Sink {
    events: Vec<Event>,
    /// Next write slot once `events` reached capacity.
    write: usize,
    capacity: usize,
    start: Instant,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<Sink>> = Mutex::new(None);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

fn sink_guard() -> MutexGuard<'static, Option<Sink>> {
    match SINK.lock() {
        Ok(g) => g,
        // a panicking span holder cannot corrupt a Vec of Copy events
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Start tracing into a fresh ring buffer of `capacity` events
/// (clamped to at least 16). Replaces any previous buffer.
pub fn enable(capacity: usize) {
    let capacity = capacity.max(16);
    let mut g = sink_guard();
    *g = Some(Sink {
        events: Vec::with_capacity(capacity),
        write: 0,
        capacity,
        start: Instant::now(),
    });
    ENABLED.store(true, Ordering::Release);
}

/// Stop tracing and drop the buffer.
pub fn disable() {
    ENABLED.store(false, Ordering::Release);
    let mut g = sink_guard();
    *g = None;
}

/// True while a sink is installed.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Events currently buffered (oldest first).
pub fn events() -> Vec<Event> {
    let g = sink_guard();
    match g.as_ref() {
        Some(s) => {
            if s.events.len() < s.capacity {
                s.events.clone()
            } else {
                // ring wrapped: [write..] is the oldest run
                let mut out = Vec::with_capacity(s.events.len());
                out.extend_from_slice(s.events.get(s.write..).unwrap_or(&[]));
                out.extend_from_slice(s.events.get(..s.write).unwrap_or(&[]));
                out
            }
        }
        None => Vec::new(),
    }
}

fn push(s: &mut Sink, event: Event) {
    if s.events.len() < s.capacity {
        s.events.push(event);
    } else {
        if let Some(slot) = s.events.get_mut(s.write) {
            *slot = event;
        }
        s.write = (s.write + 1) % s.capacity.max(1);
        super::TRACE_DROPPED.inc();
    }
}

/// An RAII span: times the enclosing scope and records one complete
/// event on drop (when tracing is enabled).
pub struct Span {
    name: &'static str,
    cat: &'static str,
    start: Option<Instant>,
    args: [(&'static str, u64); MAX_ARGS],
    n_args: u8,
}

impl Span {
    /// Open a span named `name` in category `cat`. When tracing is
    /// disabled this is a single relaxed load and the span is inert.
    #[inline]
    pub fn enter(name: &'static str, cat: &'static str) -> Span {
        let start = if ENABLED.load(Ordering::Relaxed) {
            Some(Instant::now())
        } else {
            None
        };
        Span { name, cat, start, args: [("", 0); MAX_ARGS], n_args: 0 }
    }

    /// Attach a numeric argument (first [`MAX_ARGS`] stick).
    #[inline]
    pub fn arg(mut self, key: &'static str, value: u64) -> Span {
        self.set_arg(key, value);
        self
    }

    /// Attach a numeric argument in place (for spans held in a binding).
    #[inline]
    pub fn set_arg(&mut self, key: &'static str, value: u64) {
        let n = usize::from(self.n_args);
        if let Some(slot) = self.args.get_mut(n) {
            *slot = (key, value);
            self.n_args = self.n_args.saturating_add(1);
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(t0) = self.start else { return };
        let dur_us = u64::try_from(t0.elapsed().as_micros()).unwrap_or(u64::MAX);
        // ts is computed against the sink's epoch under the same lock
        // that pushes the event (duration_since saturates to zero for a
        // span opened before the sink was (re-)enabled)
        let mut g = sink_guard();
        let Some(s) = g.as_mut() else { return };
        let ts_us = u64::try_from(t0.duration_since(s.start).as_micros()).unwrap_or(0);
        let event = Event {
            name: self.name,
            cat: self.cat,
            ts_us,
            dur_us,
            tid: TID.with(|t| *t),
            args: self.args,
            n_args: self.n_args,
        };
        push(s, event);
    }
}

/// Render the buffered events as Chrome `trace_event` JSON — an object
/// with a `traceEvents` array of `"ph":"X"` (duration-complete) events,
/// loadable in `chrome://tracing` and Perfetto. Returns `None` when
/// tracing was never enabled.
pub fn dump_json() -> Option<String> {
    if !enabled() {
        return None;
    }
    let evs = events();
    let pid = std::process::id();
    let mut out = String::with_capacity(evs.len() * 96 + 64);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    for (i, e) in evs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
             \"pid\":{},\"tid\":{}",
            e.name, e.cat, e.ts_us, e.dur_us, pid, e.tid
        ));
        if e.n_args > 0 {
            out.push_str(",\"args\":{");
            for (j, (k, v)) in e.args.iter().take(usize::from(e.n_args)).enumerate() {
                if j > 0 {
                    out.push(',');
                }
                out.push_str(&format!("\"{k}\":{v}"));
            }
            out.push('}');
        }
        out.push_str("}}");
    }
    out.push_str("]}\n");
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serialize tests that flip the global sink.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn locked() -> MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = locked();
        disable();
        {
            let _s = Span::enter("noop", "test").arg("k", 1);
        }
        assert!(dump_json().is_none());
        assert!(events().is_empty());
    }

    #[test]
    fn spans_record_and_dump_valid_chrome_json() {
        let _g = locked();
        enable(64);
        {
            let _outer = Span::enter("outer", "test").arg("bytes", 1234);
            let _inner = Span::enter("inner", "test");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let json = dump_json().expect("enabled sink dumps");
        disable();
        // valid JSON by the crate's own parser
        let parsed = crate::config::Json::parse(&json).expect("trace JSON parses");
        let evs = parsed
            .get("traceEvents")
            .and_then(|v| v.as_arr())
            .expect("traceEvents array");
        assert_eq!(evs.len(), 2, "{json}");
        let mut begins = 0i64;
        let mut ends = 0i64;
        for e in evs {
            let ph = e.get("ph").and_then(|p| p.as_str()).expect("ph");
            match ph {
                "B" => begins += 1,
                "E" => ends += 1,
                "X" => {
                    // complete events are self-balanced but must carry a
                    // duration and a timestamp
                    assert!(e.get("dur").and_then(|d| d.as_f64()).is_some());
                    assert!(e.get("ts").and_then(|d| d.as_f64()).is_some());
                }
                other => panic!("unexpected phase {other}"),
            }
            for key in ["name", "cat", "pid", "tid"] {
                assert!(e.get(key).is_some(), "event missing {key}");
            }
        }
        assert_eq!(begins, ends, "begin/end events must balance");
        // the inner span closed first and slept ≥2ms
        let inner = evs
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("inner"))
            .expect("inner event");
        assert!(inner.get("dur").and_then(|d| d.as_f64()).unwrap_or(0.0) >= 2_000.0);
        // args survived on the outer span
        let outer = evs
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("outer"))
            .expect("outer event");
        let bytes = outer
            .get("args")
            .and_then(|a| a.get("bytes"))
            .and_then(|b| b.as_f64());
        assert_eq!(bytes, Some(1234.0));
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let _g = locked();
        let dropped_before = crate::obs::TRACE_DROPPED.get();
        enable(16);
        for _ in 0..40 {
            let _s = Span::enter("tick", "test");
        }
        let evs = events();
        assert_eq!(evs.len(), 16, "ring keeps exactly its capacity");
        disable();
        assert_eq!(crate::obs::TRACE_DROPPED.get() - dropped_before, 24);
    }
}
