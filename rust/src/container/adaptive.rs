//! Per-chunk adaptive pipeline selection (the paper's best-fit predictor
//! criterion, §3 contribution 2, lifted from block level to chunk level —
//! cf. Tao et al., "Optimizing Lossy Compression Rate-Distortion from
//! Automatic Online Selection between SZ and ZFP").
//!
//! The selector samples full analysis blocks from a chunk, reuses
//! [`BlockAnalyzer`] (native or PJRT) for the Lorenzo/regression error
//! estimates, adds cheap first/second-difference estimates for the 1-D and
//! interpolation predictors, and maps each candidate registry pipeline to
//! a predicted-residual proxy. The winner is recorded per chunk in the
//! container index so decompression dispatches without re-analysis.
//!
//! Truncation is not prediction-based: it is selected only when every
//! predictor's estimated residual stays above a fixed fraction of the
//! chunk's value range (prediction would save < ~3 bits/element over raw
//! bit truncation, so the cheaper pipeline wins at equal quality).
//! Symmetrically, a chunk whose whole value range fits inside the error
//! bound is handed to the `constblock` (SZx-style) family when it is a
//! candidate: every scan block collapses to one stored mean, so the fast
//! path wins at any quality.

use crate::data::{Field, FieldValues};
use crate::error::{Result, SzError};
use crate::obs;
use crate::pipeline::analysis::{BlockAnalyzer, NativeAnalyzer};
use crate::pipeline::block::block_side;
use crate::pipeline::spec::{self, PipelineSpec, PreSpec, PredSpec};
use crate::pipeline::CompressConf;
use crate::predictor::LorenzoPredictor;
use std::sync::Arc;

/// Predictor-error estimates measured on a chunk sample.
#[derive(Clone, Copy, Debug, Default)]
pub struct ChunkSignals {
    /// Mean |Lorenzo residual| over sampled full blocks.
    pub lorenzo_err: f64,
    /// Mean |regression residual| over sampled full blocks.
    pub regression_err: f64,
    /// Mean |first difference| along the innermost axis (1-D Lorenzo proxy).
    pub first_diff_err: f64,
    /// Mean |second difference| along the innermost axis (interpolation
    /// residual proxy: midpoint interpolation error ≈ half the curvature).
    pub curvature_err: f64,
    /// Chunk value range (max - min).
    pub range: f64,
    /// Absolute error bound resolved for this chunk.
    pub eb: f64,
}

/// Outcome of selecting a pipeline for one chunk.
#[derive(Clone, Debug)]
pub struct Selection {
    /// Winning pipeline as a canonical spec string (what the chunk index
    /// records and [`crate::pipeline::build`] reconstructs).
    pub pipeline: String,
    /// The signals the decision was based on.
    pub signals: ChunkSignals,
}

/// Chunk-granularity best-fit pipeline selector. Candidates are pipeline
/// *specs* (raw compositions or registry aliases — anything
/// [`crate::pipeline::build`] accepts), so the search space is the whole
/// spec grammar, not a closed name list; the residual proxy keys on each
/// candidate's predictor family.
pub struct AdaptiveChunkSelector {
    /// Canonical spec of each candidate, parallel to `specs`.
    names: Vec<String>,
    specs: Vec<PipelineSpec>,
    analyzer: Arc<dyn BlockAnalyzer>,
    /// Cap on sampled analysis blocks per chunk (keeps selection overhead
    /// a small fraction of compression time on large chunks).
    pub max_blocks: usize,
}

/// Prediction beats truncation only when its estimated residual is below
/// this fraction of the value range (≈ 2.7 bits/element of headroom).
const UNPREDICTABLE_FRACTION: f64 = 0.15;

impl AdaptiveChunkSelector {
    /// Default candidate set: the three fixed pipelines the paper composes
    /// plus the linearized 1-D path and the SZx-style constant-block fast
    /// family.
    pub const DEFAULT_CANDIDATES: &'static [&'static str] =
        &["sz3-lr", "sz3-interp", "lorenzo-1d", "sz3-truncation", "szx"];

    /// Selector over the default candidates with native analysis.
    pub fn new() -> Self {
        Self::from_names(Self::DEFAULT_CANDIDATES.iter().map(|s| s.to_string()))
            .expect("default candidates are registered")
    }

    /// Selector over explicit candidates — registry aliases or raw
    /// pipeline specs; every entry is parsed and validated up front and
    /// held in canonical form.
    pub fn from_names<I: IntoIterator<Item = String>>(names: I) -> Result<Self> {
        let raw: Vec<String> = names.into_iter().collect();
        if raw.is_empty() {
            return Err(SzError::config("adaptive selection needs ≥ 1 candidate"));
        }
        let mut specs = Vec::with_capacity(raw.len());
        let mut canon = Vec::with_capacity(raw.len());
        for name in &raw {
            let s = spec::resolve(name).map_err(|e| {
                SzError::config(format!("candidate pipeline '{name}': {e}"))
            })?;
            canon.push(s.canonical());
            specs.push(s);
        }
        Ok(AdaptiveChunkSelector {
            names: canon,
            specs,
            analyzer: Arc::new(NativeAnalyzer),
            max_blocks: 256,
        })
    }

    /// Replace the analysis backend (e.g. with the PJRT engine).
    pub fn with_analyzer(mut self, a: Arc<dyn BlockAnalyzer>) -> Self {
        self.analyzer = a;
        self
    }

    /// The candidates as canonical spec strings.
    pub fn candidates(&self) -> &[String] {
        &self.names
    }

    /// Measure predictor-error signals on a sample of `field`.
    pub fn signals(&self, field: &Field, conf: &CompressConf) -> Result<ChunkSignals> {
        let (lo, hi) = field.value_range();
        let range = hi - lo;
        // one O(n) scan serves both the range signal and the Rel bound
        let eb = conf.bound.to_abs_with_range(|| (lo, hi))?;
        // copy only the sampled rows out (not the whole chunk): selection
        // runs on the compression hot path, and a full f64 materialization
        // of a 2^21-element chunk would dwarf the max_blocks cap
        let push_range = |out: &mut Vec<f64>, start: usize, len: usize| match &field.values {
            FieldValues::F32(v) => {
                out.extend(v[start..start + len].iter().map(|&x| x as f64))
            }
            FieldValues::F64(v) => out.extend_from_slice(&v[start..start + len]),
            FieldValues::I32(v) => {
                out.extend(v[start..start + len].iter().map(|&x| x as f64))
            }
        };
        let dims = field.shape.dims();
        let nd = dims.len();
        let side = block_side(nd);
        let strides = field.shape.strides();

        let mut signals = ChunkSignals { range, eb, ..Default::default() };
        // Analysis blocks shrink to the chunk: coordinator shards are often
        // only a few rows deep along the slow axis, and demanding a full
        // `side`-cube there would push every such chunk onto a degenerate
        // path that never runs the BlockAnalyzer.
        let bdims: Vec<usize> = dims.iter().map(|&d| side.min(d)).collect();
        if field.len() < 4 {
            // too small for any fit: flat first/second differences double
            // as the Lorenzo and regression proxies
            let mut vals = Vec::with_capacity(field.len());
            push_range(&mut vals, 0, field.len());
            let (fd, cv) = diff_errors(&vals);
            signals.first_diff_err = fd;
            signals.curvature_err = cv;
            signals.lorenzo_err = fd;
            signals.regression_err = fd.max(cv);
            return Ok(signals);
        }

        // evenly subsample the block grid up to max_blocks
        let blocks_per_dim: Vec<usize> =
            dims.iter().zip(&bdims).map(|(&d, &b)| d / b).collect();
        let total_full: usize = blocks_per_dim.iter().product();
        let take = total_full.min(self.max_blocks.max(1));
        let step = total_full as f64 / take as f64;
        let block_len: usize = bdims.iter().product();
        let inner = bdims[nd - 1];
        let mut buf: Vec<f64> = Vec::with_capacity(take * block_len);
        for k in 0..take {
            let flat_block = (k as f64 * step) as usize;
            // decode the block grid index, then the element origin
            let mut rem = flat_block;
            let mut origin = vec![0usize; nd];
            for d in (0..nd).rev() {
                origin[d] = (rem % blocks_per_dim[d]) * bdims[d];
                rem /= blocks_per_dim[d];
            }
            // extract the block row-major; the innermost axis is contiguous
            let base: usize = origin.iter().zip(strides).map(|(&o, &s)| o * s).sum();
            let outer: usize = block_len / inner;
            let mut lidx = vec![0usize; nd.saturating_sub(1)];
            for _ in 0..outer {
                let off: usize = lidx
                    .iter()
                    .zip(strides.iter())
                    .map(|(&l, &s)| l * s)
                    .sum();
                push_range(&mut buf, base + off, inner);
                for d in (0..lidx.len()).rev() {
                    lidx[d] += 1;
                    if lidx[d] < bdims[d] {
                        break;
                    }
                    lidx[d] = 0;
                }
            }
        }
        // diff-based proxies over the sampled contiguous rows
        let mut fd_sum = 0.0;
        let mut fd_n = 0usize;
        let mut cv_sum = 0.0;
        let mut cv_n = 0usize;
        for row in buf.chunks_exact(inner.max(1)) {
            for w in row.windows(2) {
                fd_sum += (w[1] - w[0]).abs();
                fd_n += 1;
            }
            for w in row.windows(3) {
                cv_sum += (w[2] - 2.0 * w[1] + w[0]).abs();
                cv_n += 1;
            }
        }
        signals.first_diff_err = fd_sum / fd_n.max(1) as f64;
        signals.curvature_err = if cv_n > 0 {
            cv_sum / cv_n as f64
        } else {
            signals.first_diff_err
        };

        // size-1 axes carry no variance (the regression fit's denominator
        // would vanish); squeezing them out leaves the same row-major
        // buffer, so the analyzer sees an equivalent lower-rank block
        let analysis_dims: Vec<usize> =
            bdims.iter().copied().filter(|&b| b >= 2).collect();
        if analysis_dims.is_empty() {
            signals.lorenzo_err = signals.first_diff_err;
            signals.regression_err = signals.first_diff_err.max(signals.curvature_err);
            return Ok(signals);
        }
        let analyses = self.analyzer.analyze_batch(&buf, &analysis_dims)?;
        let n = analyses.len().max(1) as f64;
        signals.lorenzo_err = analyses.iter().map(|a| a.lorenzo_err).sum::<f64>() / n;
        signals.regression_err =
            analyses.iter().map(|a| a.regression_err).sum::<f64>() / n;
        Ok(signals)
    }

    /// Stable metric label for a spec's predictor family (the
    /// [`obs::SELECTOR_FAMILIES`] vocabulary).
    fn family_label(s: &PipelineSpec) -> &'static str {
        match s.pred {
            PredSpec::Block { .. } => "block",
            PredSpec::Interp(_) => "interp",
            PredSpec::Lorenzo(_) | PredSpec::Zero => "point",
            PredSpec::Truncation { .. } => "truncation",
            PredSpec::ConstBlock { .. } => "szx",
            PredSpec::Pastri { .. } => "pastri",
            PredSpec::Aps { .. } => "aps",
        }
    }

    /// Pick the best-fit candidate for `field` under `conf`.
    pub fn select(&self, field: &Field, conf: &CompressConf) -> Result<Selection> {
        let t_select = std::time::Instant::now();
        let _span = obs::trace::Span::enter("select", "selector");
        obs::SELECTOR_CANDIDATES.add(self.specs.len() as u64);
        let signals = self.signals(field, conf)?;
        let nd = field.shape.ndim();
        let noise = LorenzoPredictor::noise_factor(nd) * signals.eb;
        let noise_1d = LorenzoPredictor::noise_factor(1) * signals.eb;
        // estimated mean |residual| if the chunk ran through each candidate,
        // keyed on the spec's predictor family — any composition over a
        // modeled predictor participates, however its later stages differ
        let proxy = |s: &PipelineSpec| -> Option<f64> {
            match s.pred {
                PredSpec::Block { .. } => {
                    Some((signals.lorenzo_err + noise).min(signals.regression_err))
                }
                // the first-difference model describes a *linearized* scan
                // (the lorenzo-1d shape); an N-d order-1 Lorenzo without
                // the linearize prefix predicts from multi-axis neighbors,
                // which this signal does not estimate
                PredSpec::Lorenzo(1) if s.pre == PreSpec::Linearize => {
                    Some(signals.first_diff_err + noise_1d)
                }
                PredSpec::Interp(_) => Some(0.5 * signals.curvature_err),
                // no residual model (non-linearized point lorenzo, zero,
                // pastri, aps, truncation)
                _ => None,
            }
        };
        let mut best: Option<(usize, f64)> = None;
        for (i, s) in self.specs.iter().enumerate() {
            if let Some(e) = proxy(s) {
                if best.map(|(_, b)| e < b).unwrap_or(true) {
                    best = Some((i, e));
                }
            }
        }
        let truncation = self
            .specs
            .iter()
            .position(|s| matches!(s.pred, PredSpec::Truncation { .. }));
        let constblock = self
            .specs
            .iter()
            .position(|s| matches!(s.pred, PredSpec::ConstBlock { .. }));
        // near-constant chunk: the whole value range fits inside one
        // representative ± eb, so every constblock scan block collapses to
        // a single stored mean — no predictor can beat that
        if signals.range <= 2.0 * signals.eb {
            if let Some(c) = constblock {
                obs::SELECTOR_OVERRIDES.inc();
                obs::selector_win(Self::family_label(&self.specs[c]));
                obs::SELECTOR_US.observe_since(t_select);
                return Ok(Selection { pipeline: self.names[c].clone(), signals });
            }
        }
        let winner = match (best, truncation) {
            // unpredictable data: every predictor leaves residuals near the
            // raw value range, so prediction buys almost nothing over plain
            // bit truncation — take the cheaper pipeline if it is a candidate
            (Some((_, e)), Some(t)) if e > UNPREDICTABLE_FRACTION * signals.range => {
                obs::SELECTOR_OVERRIDES.inc();
                t
            }
            (Some((i, _)), _) => i,
            // no candidate has a residual model: keep the user's first choice
            (None, _) => 0,
        };
        if let Some(s) = self.specs.get(winner) {
            obs::selector_win(Self::family_label(s));
        }
        obs::SELECTOR_US.observe_since(t_select);
        Ok(Selection { pipeline: self.names[winner].clone(), signals })
    }
}

impl Default for AdaptiveChunkSelector {
    fn default() -> Self {
        Self::new()
    }
}

/// Mean |first difference| and |second difference| of a flat sequence.
fn diff_errors(vals: &[f64]) -> (f64, f64) {
    let mut fd = 0.0;
    for w in vals.windows(2) {
        fd += (w[1] - w[0]).abs();
    }
    let mut cv = 0.0;
    for w in vals.windows(3) {
        cv += (w[2] - 2.0 * w[1] + w[0]).abs();
    }
    let fd = fd / (vals.len().saturating_sub(1)).max(1) as f64;
    let cv = if vals.len() >= 3 {
        cv / (vals.len() - 2) as f64
    } else {
        fd
    };
    (fd, cv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::ErrorBound;
    use crate::util::rng::Pcg32;

    fn conf() -> CompressConf {
        CompressConf::new(ErrorBound::Abs(0.5))
    }

    /// Canonical spec of a registry alias, for selection assertions.
    fn canon(alias: &str) -> String {
        spec::canonical(alias).unwrap()
    }

    #[test]
    fn unknown_candidate_rejected() {
        assert!(AdaptiveChunkSelector::from_names(vec!["nope".to_string()]).is_err());
        assert!(AdaptiveChunkSelector::from_names(Vec::<String>::new()).is_err());
        // malformed raw specs are rejected with the same path
        assert!(AdaptiveChunkSelector::from_names(vec![
            "lorenzo/linear/huffman".to_string()
        ])
        .is_err());
    }

    #[test]
    fn raw_spec_candidates_enter_the_search_space() {
        // a non-registry composition participates in selection and its
        // canonical spec is what the selection reports
        let mut rng = Pcg32::seeded(25);
        let dims = [16usize, 24, 24];
        let vals = crate::util::prop::smooth_field(&mut rng, &dims);
        let f = Field::f32("smooth", &dims, vals).unwrap();
        let sel = AdaptiveChunkSelector::from_names(
            ["block(lorenzo+regression)/linear/huffman/lzhuf", "truncation/rle"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let s = sel.select(&f, &CompressConf::new(ErrorBound::Abs(1e-3))).unwrap();
        assert_eq!(s.pipeline, "block(lorenzo+regression)/linear/huffman/lzhuf");
        assert!(crate::pipeline::build(&s.pipeline).is_ok());
        // noise routes to the truncation-family candidate, whatever its
        // lossless stage
        let noisy: Vec<f32> =
            (0..16 * 24 * 24).map(|_| rng.uniform(-1000.0, 1000.0) as f32).collect();
        let f = Field::f32("noise", &dims, noisy).unwrap();
        let s = sel.select(&f, &conf()).unwrap();
        assert_eq!(s.pipeline, "truncation/rle");
    }

    #[test]
    fn white_noise_selects_truncation() {
        let mut rng = Pcg32::seeded(21);
        let dims = [16usize, 24, 24];
        let vals: Vec<f32> =
            (0..16 * 24 * 24).map(|_| rng.uniform(-1000.0, 1000.0) as f32).collect();
        let f = Field::f32("noise", &dims, vals).unwrap();
        let sel = AdaptiveChunkSelector::new();
        let s = sel.select(&f, &conf()).unwrap();
        assert_eq!(s.pipeline, canon("sz3-truncation"), "signals: {:?}", s.signals);
    }

    #[test]
    fn smooth_data_selects_a_predictor() {
        let mut rng = Pcg32::seeded(22);
        let dims = [16usize, 24, 24];
        let vals = crate::util::prop::smooth_field(&mut rng, &dims);
        let f = Field::f32("smooth", &dims, vals).unwrap();
        let sel = AdaptiveChunkSelector::new();
        let s = sel.select(&f, &CompressConf::new(ErrorBound::Abs(1e-3))).unwrap();
        assert_ne!(s.pipeline, canon("sz3-truncation"), "signals: {:?}", s.signals);
    }

    #[test]
    fn constant_chunk_selects_the_constblock_fast_path() {
        let f = Field::f32("flat", &[8, 12, 12], vec![3.5; 8 * 12 * 12]).unwrap();
        let sel = AdaptiveChunkSelector::new();
        let s = sel.select(&f, &CompressConf::new(ErrorBound::Rel(1e-3))).unwrap();
        assert_eq!(s.pipeline, canon("szx"), "signals: {:?}", s.signals);
        assert!(crate::pipeline::build(&s.pipeline).is_ok());
    }

    #[test]
    fn constant_chunk_stays_prediction_based_without_constblock() {
        // when the fast family is not a candidate, a flat chunk must not
        // fall through to truncation (prediction nails it exactly)
        let f = Field::f32("flat", &[8, 12, 12], vec![3.5; 8 * 12 * 12]).unwrap();
        let sel = AdaptiveChunkSelector::from_names(
            ["sz3-lr", "sz3-interp", "sz3-truncation"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let s = sel.select(&f, &CompressConf::new(ErrorBound::Rel(1e-3))).unwrap();
        assert_ne!(s.pipeline, canon("sz3-truncation"));
    }

    #[test]
    fn thin_chunks_still_use_block_analysis() {
        // coordinator shards are often only a few rows deep (< block side
        // along the slow axis); selection must not degrade to the flat-diff
        // fallback there — noise must still route to truncation and smooth
        // data to a predictor
        let mut rng = Pcg32::seeded(24);
        let dims = [2usize, 64, 64];
        let noisy: Vec<f32> =
            (0..2 * 64 * 64).map(|_| rng.uniform(-1000.0, 1000.0) as f32).collect();
        let sel = AdaptiveChunkSelector::new();
        let f = Field::f32("thin-noise", &dims, noisy).unwrap();
        let s = sel.select(&f, &conf()).unwrap();
        assert_eq!(s.pipeline, canon("sz3-truncation"), "signals: {:?}", s.signals);
        let smooth = crate::util::prop::smooth_field(&mut rng, &dims);
        let f = Field::f32("thin-smooth", &dims, smooth).unwrap();
        let s = sel.select(&f, &CompressConf::new(ErrorBound::Abs(1e-3))).unwrap();
        assert_ne!(s.pipeline, canon("sz3-truncation"), "signals: {:?}", s.signals);
    }

    #[test]
    fn tiny_chunk_does_not_panic() {
        let f = Field::f32("tiny", &[3], vec![1.0, 2.0, 3.0]).unwrap();
        let sel = AdaptiveChunkSelector::new();
        let s = sel.select(&f, &conf()).unwrap();
        assert!(crate::pipeline::build(&s.pipeline).is_ok());
    }

    #[test]
    fn truncation_needs_to_be_a_candidate() {
        let mut rng = Pcg32::seeded(23);
        let dims = [16usize, 24, 24];
        let vals: Vec<f32> =
            (0..16 * 24 * 24).map(|_| rng.uniform(-1000.0, 1000.0) as f32).collect();
        let f = Field::f32("noise", &dims, vals).unwrap();
        let sel = AdaptiveChunkSelector::from_names(
            ["sz3-lr", "sz3-interp"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let s = sel.select(&f, &conf()).unwrap();
        assert!(
            s.pipeline == canon("sz3-lr") || s.pipeline == canon("sz3-interp"),
            "{}",
            s.pipeline
        );
    }
}
