//! Per-chunk adaptive pipeline selection (the paper's best-fit predictor
//! criterion, §3 contribution 2, lifted from block level to chunk level —
//! cf. Tao et al., "Optimizing Lossy Compression Rate-Distortion from
//! Automatic Online Selection between SZ and ZFP").
//!
//! The selector samples full analysis blocks from a chunk, reuses
//! [`BlockAnalyzer`] (native or PJRT) for the Lorenzo/regression error
//! estimates, adds cheap first/second-difference estimates for the 1-D and
//! interpolation predictors, and maps each candidate registry pipeline to
//! a predicted-residual proxy. The winner is recorded per chunk in the
//! container index so decompression dispatches without re-analysis.
//!
//! Truncation is not prediction-based: it is selected only when every
//! predictor's estimated residual stays above a fixed fraction of the
//! chunk's value range (prediction would save < ~3 bits/element over raw
//! bit truncation, so the cheaper pipeline wins at equal quality).
//! Symmetrically, a chunk whose whole value range fits inside the error
//! bound is handed to the `constblock` (SZx-style) family when it is a
//! candidate: every scan block collapses to one stored mean, so the fast
//! path wins at any quality.
//!
//! # Measured mode
//!
//! The proxy above predicts *residuals*, not bytes — two families with
//! equal residual can differ 2× in encoded size. [`SelectionMode::Measured`]
//! ([`AdaptiveChunkSelector::with_measured`]) instead compresses a
//! stratified ~1/16 sample of the chunk through **every** candidate and
//! scores the measured (bytes, max-error) pairs, disqualifying any
//! candidate whose sample reconstruction violates the bound. Scoring
//! honors an [`OptimizeTarget`]: `Ratio` takes the fewest sample bytes,
//! `Speed` the cheapest family by the one-shot ns/byte microbenchmark
//! cost table (measured once per process, see [`family_cost_ns_per_byte`]),
//! and `Balanced` the best bytes × √time product. When no candidate
//! qualifies on the sample, selection falls back to the proxy path.

use crate::data::{Field, FieldValues};
use crate::error::{Result, SzError};
use crate::obs;
use crate::pipeline::analysis::{BlockAnalyzer, NativeAnalyzer};
use crate::pipeline::block::block_side;
use crate::pipeline::spec::{self, PipelineSpec, PreSpec, PredSpec};
use crate::pipeline::{CompressConf, ErrorBound};
use crate::predictor::LorenzoPredictor;
use std::sync::{Arc, OnceLock};

/// Predictor-error estimates measured on a chunk sample.
#[derive(Clone, Copy, Debug, Default)]
pub struct ChunkSignals {
    /// Mean |Lorenzo residual| over sampled full blocks.
    pub lorenzo_err: f64,
    /// Mean |regression residual| over sampled full blocks.
    pub regression_err: f64,
    /// Mean |first difference| along the innermost axis (1-D Lorenzo proxy).
    pub first_diff_err: f64,
    /// Mean |second difference| along the innermost axis (interpolation
    /// residual proxy: midpoint interpolation error ≈ half the curvature).
    pub curvature_err: f64,
    /// Chunk value range (max - min).
    pub range: f64,
    /// Absolute error bound resolved for this chunk.
    pub eb: f64,
}

/// How the selector scores candidates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectionMode {
    /// Residual-proxy scoring from [`ChunkSignals`] (cheap, model-based).
    Proxy,
    /// Compress a stratified chunk sample through every candidate and
    /// score measured (bytes, max-error) pairs.
    Measured,
}

/// What measured selection optimizes for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizeTarget {
    /// Fewest sample bytes (best compression ratio).
    Ratio,
    /// Cheapest family by the ns/byte microbenchmark cost table.
    Speed,
    /// Best bytes × √time product.
    Balanced,
}

impl OptimizeTarget {
    /// Parse a config/CLI token (`ratio` | `speed` | `balanced`).
    pub fn from_name(name: &str) -> Result<OptimizeTarget> {
        match name {
            "ratio" => Ok(OptimizeTarget::Ratio),
            "speed" => Ok(OptimizeTarget::Speed),
            "balanced" => Ok(OptimizeTarget::Balanced),
            other => Err(SzError::config(format!(
                "unknown optimize target '{other}' (known: ratio, speed, \
                 balanced)"
            ))),
        }
    }
}

/// Outcome of selecting a pipeline for one chunk.
#[derive(Clone, Debug)]
pub struct Selection {
    /// Winning pipeline as a canonical spec string (what the chunk index
    /// records and [`crate::pipeline::build`] reconstructs).
    pub pipeline: String,
    /// The signals the decision was based on.
    pub signals: ChunkSignals,
}

/// Chunk-granularity best-fit pipeline selector. Candidates are pipeline
/// *specs* (raw compositions or registry aliases — anything
/// [`crate::pipeline::build`] accepts), so the search space is the whole
/// spec grammar, not a closed name list; the residual proxy keys on each
/// candidate's predictor family.
pub struct AdaptiveChunkSelector {
    /// Canonical spec of each candidate, parallel to `specs`.
    names: Vec<String>,
    specs: Vec<PipelineSpec>,
    analyzer: Arc<dyn BlockAnalyzer>,
    /// Cap on sampled analysis blocks per chunk (keeps selection overhead
    /// a small fraction of compression time on large chunks).
    pub max_blocks: usize,
    /// Proxy (default) or measured scoring.
    pub mode: SelectionMode,
    /// Objective for measured scoring.
    pub optimize: OptimizeTarget,
}

/// Prediction beats truncation only when its estimated residual is below
/// this fraction of the value range (≈ 2.7 bits/element of headroom).
const UNPREDICTABLE_FRACTION: f64 = 0.15;

impl AdaptiveChunkSelector {
    /// Default candidate set: the three fixed pipelines the paper composes
    /// plus the linearized 1-D path, the SZx-style constant-block fast
    /// family, and the ZFP-style transform family.
    pub const DEFAULT_CANDIDATES: &'static [&'static str] = &[
        "sz3-lr", "sz3-interp", "lorenzo-1d", "sz3-truncation", "szx", "zfp-like",
    ];

    /// Selector over the default candidates with native analysis.
    pub fn new() -> Self {
        Self::from_names(Self::DEFAULT_CANDIDATES.iter().map(|s| s.to_string()))
            .expect("default candidates are registered")
    }

    /// Selector over explicit candidates — registry aliases or raw
    /// pipeline specs; every entry is parsed and validated up front and
    /// held in canonical form.
    pub fn from_names<I: IntoIterator<Item = String>>(names: I) -> Result<Self> {
        let raw: Vec<String> = names.into_iter().collect();
        if raw.is_empty() {
            return Err(SzError::config("adaptive selection needs ≥ 1 candidate"));
        }
        let mut specs = Vec::with_capacity(raw.len());
        let mut canon = Vec::with_capacity(raw.len());
        for name in &raw {
            let s = spec::resolve(name).map_err(|e| {
                SzError::config(format!("candidate pipeline '{name}': {e}"))
            })?;
            canon.push(s.canonical());
            specs.push(s);
        }
        Ok(AdaptiveChunkSelector {
            names: canon,
            specs,
            analyzer: Arc::new(NativeAnalyzer),
            max_blocks: 256,
            mode: SelectionMode::Proxy,
            optimize: OptimizeTarget::Ratio,
        })
    }

    /// Replace the analysis backend (e.g. with the PJRT engine).
    pub fn with_analyzer(mut self, a: Arc<dyn BlockAnalyzer>) -> Self {
        self.analyzer = a;
        self
    }

    /// Switch to measured rate-distortion scoring with the given
    /// objective (see the module docs).
    pub fn with_measured(mut self, target: OptimizeTarget) -> Self {
        self.mode = SelectionMode::Measured;
        self.optimize = target;
        self
    }

    /// The candidates as canonical spec strings.
    pub fn candidates(&self) -> &[String] {
        &self.names
    }

    /// Measure predictor-error signals on a sample of `field`.
    pub fn signals(&self, field: &Field, conf: &CompressConf) -> Result<ChunkSignals> {
        let (lo, hi) = field.value_range();
        let range = hi - lo;
        // one O(n) scan serves both the range signal and the Rel bound
        let eb = conf.bound.to_abs_with_range(|| (lo, hi))?;
        // copy only the sampled rows out (not the whole chunk): selection
        // runs on the compression hot path, and a full f64 materialization
        // of a 2^21-element chunk would dwarf the max_blocks cap
        let push_range = |out: &mut Vec<f64>, start: usize, len: usize| match &field.values {
            FieldValues::F32(v) => {
                out.extend(v[start..start + len].iter().map(|&x| x as f64))
            }
            FieldValues::F64(v) => out.extend_from_slice(&v[start..start + len]),
            FieldValues::I32(v) => {
                out.extend(v[start..start + len].iter().map(|&x| x as f64))
            }
        };
        let dims = field.shape.dims();
        let nd = dims.len();
        let side = block_side(nd);
        let strides = field.shape.strides();

        let mut signals = ChunkSignals { range, eb, ..Default::default() };
        // Analysis blocks shrink to the chunk: coordinator shards are often
        // only a few rows deep along the slow axis, and demanding a full
        // `side`-cube there would push every such chunk onto a degenerate
        // path that never runs the BlockAnalyzer.
        let bdims: Vec<usize> = dims.iter().map(|&d| side.min(d)).collect();
        if field.len() < 4 {
            // too small for any fit: flat first/second differences double
            // as the Lorenzo and regression proxies
            let mut vals = Vec::with_capacity(field.len());
            push_range(&mut vals, 0, field.len());
            let (fd, cv) = diff_errors(&vals);
            signals.first_diff_err = fd;
            signals.curvature_err = cv;
            signals.lorenzo_err = fd;
            signals.regression_err = fd.max(cv);
            return Ok(signals);
        }

        // evenly subsample the block grid up to max_blocks
        let blocks_per_dim: Vec<usize> =
            dims.iter().zip(&bdims).map(|(&d, &b)| d / b).collect();
        let total_full: usize = blocks_per_dim.iter().product();
        let take = total_full.min(self.max_blocks.max(1));
        let step = total_full as f64 / take as f64;
        let block_len: usize = bdims.iter().product();
        let inner = bdims[nd - 1];
        let mut buf: Vec<f64> = Vec::with_capacity(take * block_len);
        for k in 0..take {
            let flat_block = (k as f64 * step) as usize;
            // decode the block grid index, then the element origin
            let mut rem = flat_block;
            let mut origin = vec![0usize; nd];
            for d in (0..nd).rev() {
                origin[d] = (rem % blocks_per_dim[d]) * bdims[d];
                rem /= blocks_per_dim[d];
            }
            // extract the block row-major; the innermost axis is contiguous
            let base: usize = origin.iter().zip(strides).map(|(&o, &s)| o * s).sum();
            let outer: usize = block_len / inner;
            let mut lidx = vec![0usize; nd.saturating_sub(1)];
            for _ in 0..outer {
                let off: usize = lidx
                    .iter()
                    .zip(strides.iter())
                    .map(|(&l, &s)| l * s)
                    .sum();
                push_range(&mut buf, base + off, inner);
                for d in (0..lidx.len()).rev() {
                    lidx[d] += 1;
                    if lidx[d] < bdims[d] {
                        break;
                    }
                    lidx[d] = 0;
                }
            }
        }
        // diff-based proxies over the sampled contiguous rows
        let mut fd_sum = 0.0;
        let mut fd_n = 0usize;
        let mut cv_sum = 0.0;
        let mut cv_n = 0usize;
        for row in buf.chunks_exact(inner.max(1)) {
            for w in row.windows(2) {
                fd_sum += (w[1] - w[0]).abs();
                fd_n += 1;
            }
            for w in row.windows(3) {
                cv_sum += (w[2] - 2.0 * w[1] + w[0]).abs();
                cv_n += 1;
            }
        }
        signals.first_diff_err = fd_sum / fd_n.max(1) as f64;
        signals.curvature_err = if cv_n > 0 {
            cv_sum / cv_n as f64
        } else {
            signals.first_diff_err
        };

        // size-1 axes carry no variance (the regression fit's denominator
        // would vanish); squeezing them out leaves the same row-major
        // buffer, so the analyzer sees an equivalent lower-rank block
        let analysis_dims: Vec<usize> =
            bdims.iter().copied().filter(|&b| b >= 2).collect();
        if analysis_dims.is_empty() {
            signals.lorenzo_err = signals.first_diff_err;
            signals.regression_err = signals.first_diff_err.max(signals.curvature_err);
            return Ok(signals);
        }
        let analyses = self.analyzer.analyze_batch(&buf, &analysis_dims)?;
        let n = analyses.len().max(1) as f64;
        signals.lorenzo_err = analyses.iter().map(|a| a.lorenzo_err).sum::<f64>() / n;
        signals.regression_err =
            analyses.iter().map(|a| a.regression_err).sum::<f64>() / n;
        Ok(signals)
    }

    /// Stable metric label for a spec's predictor family (the
    /// [`obs::SELECTOR_FAMILIES`] vocabulary).
    fn family_label(s: &PipelineSpec) -> &'static str {
        match s.pred {
            PredSpec::Block { .. } => "block",
            PredSpec::Interp(_) => "interp",
            PredSpec::Lorenzo(_) | PredSpec::Zero => "point",
            PredSpec::Truncation { .. } => "truncation",
            PredSpec::ConstBlock { .. } => "szx",
            PredSpec::Transform { .. } => "transform",
            PredSpec::Pastri { .. } => "pastri",
            PredSpec::Aps { .. } => "aps",
        }
    }

    /// Pick the best-fit candidate for `field` under `conf`.
    pub fn select(&self, field: &Field, conf: &CompressConf) -> Result<Selection> {
        let t_select = std::time::Instant::now();
        let _span = obs::trace::Span::enter("select", "selector");
        obs::SELECTOR_CANDIDATES.add(self.specs.len() as u64);
        let signals = self.signals(field, conf)?;
        if self.mode == SelectionMode::Measured {
            if let Some(sel) = self.select_measured(field, conf, signals) {
                obs::SELECTOR_US.observe_since(t_select);
                return Ok(sel);
            }
            // no candidate qualified on the sample (e.g. a degenerate
            // chunk): fall through to the proxy path
        }
        let nd = field.shape.ndim();
        let noise = LorenzoPredictor::noise_factor(nd) * signals.eb;
        let noise_1d = LorenzoPredictor::noise_factor(1) * signals.eb;
        // estimated mean |residual| if the chunk ran through each candidate,
        // keyed on the spec's predictor family — any composition over a
        // modeled predictor participates, however its later stages differ
        let proxy = |s: &PipelineSpec| -> Option<f64> {
            match s.pred {
                PredSpec::Block { .. } => {
                    Some((signals.lorenzo_err + noise).min(signals.regression_err))
                }
                // the first-difference model describes a *linearized* scan
                // (the lorenzo-1d shape); an N-d order-1 Lorenzo without
                // the linearize prefix predicts from multi-axis neighbors,
                // which this signal does not estimate
                PredSpec::Lorenzo(1) if s.pre == PreSpec::Linearize => {
                    Some(signals.first_diff_err + noise_1d)
                }
                PredSpec::Interp(_) => Some(0.5 * signals.curvature_err),
                // the transform's low-sequency coefficients capture what a
                // midpoint interpolant would; the lifting's non-orthogonal
                // basis leaves a slightly larger residual tail
                PredSpec::Transform { .. } => Some(0.6 * signals.curvature_err),
                // no residual model (non-linearized point lorenzo, zero,
                // pastri, aps, truncation)
                _ => None,
            }
        };
        let mut best: Option<(usize, f64)> = None;
        for (i, s) in self.specs.iter().enumerate() {
            if let Some(e) = proxy(s) {
                if best.map(|(_, b)| e < b).unwrap_or(true) {
                    best = Some((i, e));
                }
            }
        }
        let truncation = self
            .specs
            .iter()
            .position(|s| matches!(s.pred, PredSpec::Truncation { .. }));
        let constblock = self
            .specs
            .iter()
            .position(|s| matches!(s.pred, PredSpec::ConstBlock { .. }));
        // near-constant chunk: the whole value range fits inside one
        // representative ± eb, so every constblock scan block collapses to
        // a single stored mean — no predictor can beat that
        if signals.range <= 2.0 * signals.eb {
            if let Some(c) = constblock {
                obs::SELECTOR_OVERRIDES.inc();
                obs::selector_win(Self::family_label(&self.specs[c]));
                obs::SELECTOR_US.observe_since(t_select);
                return Ok(Selection { pipeline: self.names[c].clone(), signals });
            }
        }
        let winner = match (best, truncation) {
            // unpredictable data: every predictor leaves residuals near the
            // raw value range, so prediction buys almost nothing over plain
            // bit truncation — take the cheaper pipeline if it is a candidate
            (Some((_, e)), Some(t)) if e > UNPREDICTABLE_FRACTION * signals.range => {
                obs::SELECTOR_OVERRIDES.inc();
                t
            }
            (Some((i, _)), _) => i,
            // no candidate has a residual model: keep the user's first choice
            (None, _) => 0,
        };
        if let Some(s) = self.specs.get(winner) {
            obs::selector_win(Self::family_label(s));
        }
        obs::SELECTOR_US.observe_since(t_select);
        Ok(Selection { pipeline: self.names[winner].clone(), signals })
    }

    /// Measured rate-distortion selection: compress a stratified sample
    /// through every candidate, disqualify bound violators, and score the
    /// survivors by the configured [`OptimizeTarget`]. Returns `None`
    /// when no candidate qualifies (caller falls back to the proxy).
    fn select_measured(
        &self,
        field: &Field,
        conf: &CompressConf,
        signals: ChunkSignals,
    ) -> Option<Selection> {
        let sample = sample_field(field);
        let truth = sample.values.to_f64_vec();
        // the bound is resolved against the FULL chunk's range (a Rel
        // bound measured on the sample's narrower range would be unfairly
        // strict), then pinned as absolute for every candidate
        let abs_conf = CompressConf::with_radius(ErrorBound::Abs(signals.eb), conf.radius);
        let tol = signals.eb * (1.0 + 1e-9);
        let mut qualified: Vec<(usize, f64, f64)> = Vec::new(); // (idx, bytes, ns/byte)
        for (i, name) in self.names.iter().enumerate() {
            let Ok(c) = crate::pipeline::build(name) else { continue };
            let t = std::time::Instant::now();
            let Ok(stream) = c.compress(&sample, &abs_conf) else { continue };
            let elapsed_ns = t.elapsed().as_nanos() as f64;
            let Ok(out) = crate::pipeline::decompress_any(&stream) else { continue };
            let decoded = out.values.to_f64_vec();
            if decoded.len() != truth.len() {
                continue;
            }
            let max_err = truth
                .iter()
                .zip(&decoded)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            if !max_err.is_finite() || max_err > tol {
                continue; // sample reconstruction violates the bound
            }
            // blend the sample's measured throughput with the family's
            // one-shot microbenchmark: the sample timing reflects this
            // exact candidate (lossless level and all) but is noisy at
            // sample size, the table is stable but family-granular
            let elem_bytes = match &sample.values {
                FieldValues::F64(_) => 8usize,
                FieldValues::F32(_) | FieldValues::I32(_) => 4,
            };
            let spec_cost = elapsed_ns / ((truth.len() * elem_bytes).max(1) as f64);
            let family_cost = self
                .specs
                .get(i)
                .map(|s| family_cost_ns_per_byte(Self::family_label(s)))
                .unwrap_or(spec_cost);
            qualified.push((i, stream.len() as f64, 0.5 * (spec_cost + family_cost)));
        }
        let min_bytes =
            qualified.iter().map(|&(_, b, _)| b).fold(f64::INFINITY, f64::min);
        let min_cost =
            qualified.iter().map(|&(_, _, c)| c).fold(f64::INFINITY, f64::min);
        let score = |bytes: f64, cost: f64| -> f64 {
            match self.optimize {
                OptimizeTarget::Ratio => bytes,
                OptimizeTarget::Speed => cost,
                OptimizeTarget::Balanced => {
                    // normalized so neither axis dominates on units alone
                    (bytes / min_bytes.max(1.0))
                        * (cost / min_cost.max(1e-9)).sqrt()
                }
            }
        };
        let (winner, _) = qualified.iter().fold(None, |best, &(i, b, c)| {
            let s = score(b, c);
            match best {
                Some((_, bs)) if bs <= s => best,
                _ => Some((i, s)),
            }
        })?;
        if let Some(s) = self.specs.get(winner) {
            obs::selector_win(Self::family_label(s));
        }
        Some(Selection { pipeline: self.names.get(winner)?.clone(), signals })
    }
}

/// Stratified ~1/16 sample of a chunk: four contiguous slabs along the
/// slowest axis (one per quartile stratum), concatenated. Slabs keep full
/// N-d structure so block/interp/transform candidates behave as on real
/// data; chunks ≤ 4096 elements are measured whole.
fn sample_field(field: &Field) -> Field {
    let dims = field.shape.dims();
    let n = field.len();
    if n <= 4096 {
        return field.clone();
    }
    let plane: usize = dims.iter().skip(1).product::<usize>().max(1);
    let d0 = dims[0];
    let per = (d0 / 64).max(1);
    let strata = 4usize.min(d0);
    let mut ranges: Vec<(usize, usize)> = Vec::with_capacity(strata);
    for s in 0..strata {
        let start = (s * d0 / strata).min(d0 - per);
        ranges.push((start * plane, per * plane));
    }
    let total: usize = ranges.iter().map(|&(_, l)| l).sum();
    let values = match &field.values {
        FieldValues::F32(v) => FieldValues::F32(
            ranges.iter().flat_map(|&(s, l)| v[s..s + l].iter().copied()).collect(),
        ),
        FieldValues::F64(v) => FieldValues::F64(
            ranges.iter().flat_map(|&(s, l)| v[s..s + l].iter().copied()).collect(),
        ),
        FieldValues::I32(v) => FieldValues::I32(
            ranges.iter().flat_map(|&(s, l)| v[s..s + l].iter().copied()).collect(),
        ),
    };
    let mut sdims: Vec<usize> = dims.to_vec();
    sdims[0] = total / plane;
    Field::new(field.name.clone(), &sdims, values)
        .unwrap_or_else(|_| field.clone())
}

/// One-shot per-family compression-cost table (ns per input byte),
/// measured once per process on a synthetic smooth field. Families
/// missing from the probe set (or whose probe failed) report the table's
/// median so they are neither favored nor punished.
pub fn family_cost_ns_per_byte(label: &str) -> f64 {
    static TABLE: OnceLock<Vec<(&'static str, f64)>> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        const PROBES: &[(&str, &str)] = &[
            ("block", "sz3-lr"),
            ("interp", "sz3-interp"),
            ("point", "lorenzo-1d"),
            ("truncation", "sz3-truncation"),
            ("szx", "szx"),
            ("transform", "zfp-like"),
            ("pastri", "sz3-pastri"),
            ("aps", "sz3-aps"),
        ];
        let dims = [24usize, 24, 24];
        let vals: Vec<f32> = (0..dims.iter().product::<usize>())
            .map(|i| {
                let t = i as f32 * 0.013;
                t.sin() + 0.3 * (t * 2.7).cos()
            })
            .collect();
        let Ok(f) = Field::f32("cost-probe", &dims, vals) else {
            return Vec::new();
        };
        let conf = CompressConf::new(ErrorBound::Abs(1e-3));
        PROBES
            .iter()
            .filter_map(|&(label, alias)| {
                let c = crate::pipeline::build(alias).ok()?;
                let t = std::time::Instant::now();
                // two passes: the first warms per-process lazy state
                c.compress(&f, &conf).ok()?;
                c.compress(&f, &conf).ok()?;
                let ns = t.elapsed().as_nanos() as f64 / 2.0;
                Some((label, ns / (f.len() * 4) as f64))
            })
            .collect()
    });
    if let Some(&(_, c)) = table.iter().find(|&&(l, _)| l == label) {
        return c;
    }
    let mut costs: Vec<f64> = table.iter().map(|&(_, c)| c).collect();
    costs.sort_by(f64::total_cmp);
    costs.get(costs.len() / 2).copied().unwrap_or(1.0)
}

impl Default for AdaptiveChunkSelector {
    fn default() -> Self {
        Self::new()
    }
}

/// Mean |first difference| and |second difference| of a flat sequence.
fn diff_errors(vals: &[f64]) -> (f64, f64) {
    let mut fd = 0.0;
    for w in vals.windows(2) {
        fd += (w[1] - w[0]).abs();
    }
    let mut cv = 0.0;
    for w in vals.windows(3) {
        cv += (w[2] - 2.0 * w[1] + w[0]).abs();
    }
    let fd = fd / (vals.len().saturating_sub(1)).max(1) as f64;
    let cv = if vals.len() >= 3 {
        cv / (vals.len() - 2) as f64
    } else {
        fd
    };
    (fd, cv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::ErrorBound;
    use crate::util::rng::Pcg32;

    fn conf() -> CompressConf {
        CompressConf::new(ErrorBound::Abs(0.5))
    }

    /// Canonical spec of a registry alias, for selection assertions.
    fn canon(alias: &str) -> String {
        spec::canonical(alias).unwrap()
    }

    #[test]
    fn unknown_candidate_rejected() {
        assert!(AdaptiveChunkSelector::from_names(vec!["nope".to_string()]).is_err());
        assert!(AdaptiveChunkSelector::from_names(Vec::<String>::new()).is_err());
        // malformed raw specs are rejected with the same path
        assert!(AdaptiveChunkSelector::from_names(vec![
            "lorenzo/linear/huffman".to_string()
        ])
        .is_err());
    }

    #[test]
    fn raw_spec_candidates_enter_the_search_space() {
        // a non-registry composition participates in selection and its
        // canonical spec is what the selection reports
        let mut rng = Pcg32::seeded(25);
        let dims = [16usize, 24, 24];
        let vals = crate::util::prop::smooth_field(&mut rng, &dims);
        let f = Field::f32("smooth", &dims, vals).unwrap();
        let sel = AdaptiveChunkSelector::from_names(
            ["block(lorenzo+regression)/linear/huffman/lzhuf", "truncation/rle"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let s = sel.select(&f, &CompressConf::new(ErrorBound::Abs(1e-3))).unwrap();
        assert_eq!(s.pipeline, "block(lorenzo+regression)/linear/huffman/lzhuf");
        assert!(crate::pipeline::build(&s.pipeline).is_ok());
        // noise routes to the truncation-family candidate, whatever its
        // lossless stage
        let noisy: Vec<f32> =
            (0..16 * 24 * 24).map(|_| rng.uniform(-1000.0, 1000.0) as f32).collect();
        let f = Field::f32("noise", &dims, noisy).unwrap();
        let s = sel.select(&f, &conf()).unwrap();
        assert_eq!(s.pipeline, "truncation/rle");
    }

    #[test]
    fn white_noise_selects_truncation() {
        let mut rng = Pcg32::seeded(21);
        let dims = [16usize, 24, 24];
        let vals: Vec<f32> =
            (0..16 * 24 * 24).map(|_| rng.uniform(-1000.0, 1000.0) as f32).collect();
        let f = Field::f32("noise", &dims, vals).unwrap();
        let sel = AdaptiveChunkSelector::new();
        let s = sel.select(&f, &conf()).unwrap();
        assert_eq!(s.pipeline, canon("sz3-truncation"), "signals: {:?}", s.signals);
    }

    #[test]
    fn smooth_data_selects_a_predictor() {
        let mut rng = Pcg32::seeded(22);
        let dims = [16usize, 24, 24];
        let vals = crate::util::prop::smooth_field(&mut rng, &dims);
        let f = Field::f32("smooth", &dims, vals).unwrap();
        let sel = AdaptiveChunkSelector::new();
        let s = sel.select(&f, &CompressConf::new(ErrorBound::Abs(1e-3))).unwrap();
        assert_ne!(s.pipeline, canon("sz3-truncation"), "signals: {:?}", s.signals);
    }

    #[test]
    fn constant_chunk_selects_the_constblock_fast_path() {
        let f = Field::f32("flat", &[8, 12, 12], vec![3.5; 8 * 12 * 12]).unwrap();
        let sel = AdaptiveChunkSelector::new();
        let s = sel.select(&f, &CompressConf::new(ErrorBound::Rel(1e-3))).unwrap();
        assert_eq!(s.pipeline, canon("szx"), "signals: {:?}", s.signals);
        assert!(crate::pipeline::build(&s.pipeline).is_ok());
    }

    #[test]
    fn constant_chunk_stays_prediction_based_without_constblock() {
        // when the fast family is not a candidate, a flat chunk must not
        // fall through to truncation (prediction nails it exactly)
        let f = Field::f32("flat", &[8, 12, 12], vec![3.5; 8 * 12 * 12]).unwrap();
        let sel = AdaptiveChunkSelector::from_names(
            ["sz3-lr", "sz3-interp", "sz3-truncation"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let s = sel.select(&f, &CompressConf::new(ErrorBound::Rel(1e-3))).unwrap();
        assert_ne!(s.pipeline, canon("sz3-truncation"));
    }

    #[test]
    fn thin_chunks_still_use_block_analysis() {
        // coordinator shards are often only a few rows deep (< block side
        // along the slow axis); selection must not degrade to the flat-diff
        // fallback there — noise must still route to truncation and smooth
        // data to a predictor
        let mut rng = Pcg32::seeded(24);
        let dims = [2usize, 64, 64];
        let noisy: Vec<f32> =
            (0..2 * 64 * 64).map(|_| rng.uniform(-1000.0, 1000.0) as f32).collect();
        let sel = AdaptiveChunkSelector::new();
        let f = Field::f32("thin-noise", &dims, noisy).unwrap();
        let s = sel.select(&f, &conf()).unwrap();
        assert_eq!(s.pipeline, canon("sz3-truncation"), "signals: {:?}", s.signals);
        let smooth = crate::util::prop::smooth_field(&mut rng, &dims);
        let f = Field::f32("thin-smooth", &dims, smooth).unwrap();
        let s = sel.select(&f, &CompressConf::new(ErrorBound::Abs(1e-3))).unwrap();
        assert_ne!(s.pipeline, canon("sz3-truncation"), "signals: {:?}", s.signals);
    }

    #[test]
    fn tiny_chunk_does_not_panic() {
        let f = Field::f32("tiny", &[3], vec![1.0, 2.0, 3.0]).unwrap();
        let sel = AdaptiveChunkSelector::new();
        let s = sel.select(&f, &conf()).unwrap();
        assert!(crate::pipeline::build(&s.pipeline).is_ok());
    }

    #[test]
    fn optimize_target_parses_known_tokens_only() {
        assert_eq!(OptimizeTarget::from_name("ratio").unwrap(), OptimizeTarget::Ratio);
        assert_eq!(OptimizeTarget::from_name("speed").unwrap(), OptimizeTarget::Speed);
        assert_eq!(
            OptimizeTarget::from_name("balanced").unwrap(),
            OptimizeTarget::Balanced
        );
        assert!(OptimizeTarget::from_name("best").is_err());
    }

    #[test]
    fn sample_field_is_a_stratified_sixteenth() {
        let dims = [256usize, 16, 16];
        let n: usize = dims.iter().product();
        let vals: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let f = Field::f32("big", &dims, vals).unwrap();
        let s = sample_field(&f);
        // ~1/16 of the rows, full row planes, dtype preserved
        assert_eq!(s.shape.dims()[1..], dims[1..]);
        assert_eq!(s.len(), n / 16);
        assert!(matches!(s.values, FieldValues::F32(_)));
        // stratified: the sample spans all four quartiles of the slow axis
        let got = s.values.to_f64_vec();
        let quartile = (n / 4) as f64;
        for q in 0..4 {
            let lo = q as f64 * quartile;
            assert!(
                got.iter().any(|&v| v >= lo && v < lo + quartile),
                "stratum {q} unsampled"
            );
        }
        // small chunks are measured whole
        let tiny = Field::f32("tiny", &[40, 10], vec![1.0; 400]).unwrap();
        assert_eq!(sample_field(&tiny).len(), 400);
    }

    #[test]
    fn measured_mode_honors_bounds_and_picks_a_winner() {
        let mut rng = Pcg32::seeded(0x3ea5);
        let dims = [64usize, 24, 24];
        let vals = crate::util::prop::smooth_field(&mut rng, &dims);
        let f = Field::f32("smooth", &dims, vals).unwrap();
        let conf = CompressConf::new(ErrorBound::Abs(1e-3));
        for target in
            [OptimizeTarget::Ratio, OptimizeTarget::Speed, OptimizeTarget::Balanced]
        {
            let sel = AdaptiveChunkSelector::new().with_measured(target);
            let s = sel.select(&f, &conf).unwrap();
            // the winner compresses the FULL chunk within the bound
            let c = crate::pipeline::build(&s.pipeline).unwrap();
            let stream = c.compress(&f, &conf).unwrap();
            let out = crate::pipeline::decompress_any(&stream).unwrap();
            let worst = f
                .values
                .to_f64_vec()
                .iter()
                .zip(out.values.to_f64_vec())
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            assert!(worst <= 1e-3 * (1.0 + 1e-9), "{target:?}: err {worst}");
        }
    }

    #[test]
    fn measured_ratio_tracks_the_smallest_fixed_candidate() {
        // on a flat chunk the fast families produce tiny streams; measured
        // ratio selection must land within 25% of the best fixed pipeline
        let f = Field::f32("flat", &[128, 16, 16], vec![2.25; 128 * 16 * 16]).unwrap();
        let conf = CompressConf::new(ErrorBound::Abs(1e-3));
        let sel = AdaptiveChunkSelector::new().with_measured(OptimizeTarget::Ratio);
        let s = sel.select(&f, &conf).unwrap();
        let winner_bytes =
            crate::pipeline::build(&s.pipeline).unwrap().compress(&f, &conf).unwrap().len();
        let best_fixed = AdaptiveChunkSelector::DEFAULT_CANDIDATES
            .iter()
            .map(|a| {
                crate::pipeline::build(a).unwrap().compress(&f, &conf).unwrap().len()
            })
            .min()
            .unwrap();
        // multiplicative slack for payload noise, additive for the fixed
        // per-stream header difference between candidate spec strings
        assert!(
            winner_bytes as f64 <= best_fixed as f64 * 1.25 + 256.0,
            "winner {} bytes vs best fixed {}",
            winner_bytes,
            best_fixed
        );
    }

    #[test]
    fn cost_table_probes_every_default_family() {
        for fam in ["block", "interp", "point", "truncation", "szx", "transform"] {
            let c = family_cost_ns_per_byte(fam);
            assert!(c.is_finite() && c > 0.0, "{fam}: {c}");
        }
        // unknown families get the median, not a panic or a freebie
        let m = family_cost_ns_per_byte("no-such-family");
        assert!(m.is_finite() && m > 0.0);
    }

    #[test]
    fn transform_family_participates_in_default_selection() {
        assert!(AdaptiveChunkSelector::DEFAULT_CANDIDATES.contains(&"zfp-like"));
        let sel = AdaptiveChunkSelector::new();
        assert!(sel
            .candidates()
            .iter()
            .any(|c| c == &spec::canonical("zfp-like").unwrap()));
    }

    #[test]
    fn truncation_needs_to_be_a_candidate() {
        let mut rng = Pcg32::seeded(23);
        let dims = [16usize, 24, 24];
        let vals: Vec<f32> =
            (0..16 * 24 * 24).map(|_| rng.uniform(-1000.0, 1000.0) as f32).collect();
        let f = Field::f32("noise", &dims, vals).unwrap();
        let sel = AdaptiveChunkSelector::from_names(
            ["sz3-lr", "sz3-interp"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let s = sel.select(&f, &conf()).unwrap();
        assert!(
            s.pipeline == canon("sz3-lr") || s.pipeline == canon("sz3-interp"),
            "{}",
            s.pipeline
        );
    }
}
