//! Snapshot delta arithmetic: residual fields against a decoded baseline.
//!
//! A v3 series container may store snapshot *k*'s chunks as error-bounded
//! residuals against the **decoded** snapshot *k−1* baseline (never the
//! original — the decoder only ever has the decoded baseline, so deltaing
//! against anything else would let error accumulate across the chain).
//! [`residual`] builds the field a delta chunk compresses; [`apply`]
//! reconstructs the snapshot from baseline + decoded residual. Both sides
//! of the chain — the series packer computing next-snapshot baselines and
//! the reader resolving delta chunks — call the *same* two functions, so
//! their reconstructions agree bit for bit.
//!
//! Float residuals are computed in f64 and rounded once back to the
//! field's own dtype; the rounding is bounded by one ulp of the residual
//! magnitude, orders of magnitude below any practical error bound (the
//! residual compressor's bound dominates). Integer residuals use wrapping
//! arithmetic and are exactly invertible.

use crate::data::{Field, FieldValues};
use crate::error::{Result, SzError};
use crate::util::simd;

fn check_pair(a: &Field, b: &Field, what: &str) -> Result<()> {
    if a.shape.dims() != b.shape.dims() {
        return Err(SzError::Shape(format!(
            "{what}: dims {:?} vs baseline {:?}",
            a.shape.dims(),
            b.shape.dims()
        )));
    }
    if a.values.dtype() != b.values.dtype() {
        return Err(SzError::Shape(format!(
            "{what}: dtype {} vs baseline {}",
            a.values.dtype(),
            b.values.dtype()
        )));
    }
    Ok(())
}

/// Residual field `original − baseline`, same name/dims/dtype as
/// `original` — the input a delta chunk's compressor sees.
pub fn residual(original: &Field, baseline: &Field) -> Result<Field> {
    check_pair(original, baseline, "delta residual")?;
    // Element math lives in the runtime-dispatched SIMD kernels; each arm
    // preserves the original per-element semantics bit for bit (the kernel
    // tests pin this).
    let values = match (&original.values, &baseline.values) {
        (FieldValues::F32(a), FieldValues::F32(b)) => {
            let mut out = vec![0f32; a.len()];
            simd::delta_sub_f32(a, b, &mut out);
            FieldValues::F32(out)
        }
        (FieldValues::F64(a), FieldValues::F64(b)) => {
            let mut out = vec![0f64; a.len()];
            simd::delta_sub_f64(a, b, &mut out);
            FieldValues::F64(out)
        }
        (FieldValues::I32(a), FieldValues::I32(b)) => {
            let mut out = vec![0i32; a.len()];
            simd::delta_sub_i32(a, b, &mut out);
            FieldValues::I32(out)
        }
        _ => {
            return Err(SzError::Shape(
                "delta residual: mismatched dtypes survived check_pair".into(),
            ))
        }
    };
    Field::new(original.name.clone(), original.shape.dims(), values)
}

/// Reconstruct `baseline + residual` — the inverse of [`residual`] modulo
/// the residual compressor's error bound. Keeps the residual's name (the
/// source field name the packer recorded).
pub fn apply(baseline: &Field, residual: &Field) -> Result<Field> {
    check_pair(residual, baseline, "delta apply")?;
    let values = match (&baseline.values, &residual.values) {
        (FieldValues::F32(b), FieldValues::F32(r)) => {
            let mut out = vec![0f32; b.len()];
            simd::delta_add_f32(b, r, &mut out);
            FieldValues::F32(out)
        }
        (FieldValues::F64(b), FieldValues::F64(r)) => {
            let mut out = vec![0f64; b.len()];
            simd::delta_add_f64(b, r, &mut out);
            FieldValues::F64(out)
        }
        (FieldValues::I32(b), FieldValues::I32(r)) => {
            let mut out = vec![0i32; b.len()];
            simd::delta_add_i32(b, r, &mut out);
            FieldValues::I32(out)
        }
        _ => {
            return Err(SzError::Shape(
                "delta apply: mismatched dtypes survived check_pair".into(),
            ))
        }
    };
    Field::new(residual.name.clone(), residual.shape.dims(), values)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residual_then_apply_roundtrips_floats() {
        let a = Field::f32("x", &[2, 3], vec![1.0, 2.5, -3.0, 0.0, 7.25, -0.5]).unwrap();
        let b = Field::f32("x", &[2, 3], vec![1.5, 2.0, -2.0, 0.5, 7.0, -1.0]).unwrap();
        let r = residual(&a, &b).unwrap();
        let out = apply(&b, &r).unwrap();
        assert_eq!(out.values, a.values, "exact residual must reconstruct exactly");
    }

    #[test]
    fn integer_residuals_wrap_exactly() {
        let a = Field::new("i", &[3], FieldValues::I32(vec![i32::MAX, -7, 0])).unwrap();
        let b = Field::new("i", &[3], FieldValues::I32(vec![-1, 5, i32::MIN])).unwrap();
        let r = residual(&a, &b).unwrap();
        assert_eq!(apply(&b, &r).unwrap().values, a.values);
    }

    #[test]
    fn mismatched_pairs_rejected() {
        let a = Field::f32("x", &[4], vec![0.0; 4]).unwrap();
        let b = Field::f32("x", &[2, 2], vec![0.0; 4]).unwrap();
        assert!(residual(&a, &b).is_err(), "dims must match");
        let c = Field::f64("x", &[4], vec![0.0; 4]).unwrap();
        assert!(residual(&a, &c).is_err(), "dtypes must match");
        assert!(apply(&a, &c).is_err());
    }
}
