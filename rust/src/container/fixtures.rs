//! Deterministic golden-fixture corpus for format-compatibility testing.
//!
//! [`golden_set`] builds one tiny artifact per container version (v1, v2,
//! v3, and a v3 delta series) from fixed-seed data, together with the
//! expected decoded bytes of every `(snapshot, field)` — computed by
//! [`reference_decode`], a deliberately independent re-implementation of
//! the decode semantics that never touches [`crate::reader`]. The compat
//! suite (`rust/tests/compat.rs`) asserts the real reader agrees with the
//! reference bit for bit, and `examples/gen_fixtures.rs` materializes the
//! corpus under `rust/tests/fixtures/` so committed artifacts lock the
//! byte format against future bumps.
//!
//! Everything here is seeded and single-valued: two builds of the corpus
//! on any machine produce identical bytes (the repo already pins
//! compression determinism in `coordinator::tests`).

use super::delta;
use crate::config::JobConfig;
use crate::coordinator::{Coordinator, Snapshot};
use crate::data::Field;
use crate::error::{Result, SzError};
use crate::pipeline::{self, ErrorBound};
use crate::util::{prop, rng::Pcg32};
use std::collections::HashMap;

/// One corpus entry: a packed artifact plus its expected decode.
pub struct Fixture {
    /// File stem under `rust/tests/fixtures/` (e.g. `"v1"`).
    pub name: &'static str,
    /// The packed container bytes.
    pub artifact: Vec<u8>,
    /// Expected decoded output per `(snapshot, field)`, as the
    /// little-endian bytes `FieldValues::to_le_bytes` produces.
    pub expected: Vec<(usize, String, Vec<u8>)>,
}

impl Fixture {
    /// File name of the artifact (`<name>.sz3c`).
    pub fn artifact_file(&self) -> String {
        format!("{}.sz3c", self.name)
    }

    /// File name of one expected-decode blob (`<name>.s<snap>.<field>.bin`).
    pub fn expected_file(&self, snapshot: usize, field: &str) -> String {
        format!("{}.s{snapshot}.{field}.bin", self.name)
    }
}

/// Deterministic smoothly-drifting series: snapshot *t* holds
/// `base + drift_scale · t · drift` for two fixed-seed smooth fields,
/// tagged `t0..tN`. The shape every series test and bench exercises —
/// consecutive snapshots stay correlated, so delta mode has something to
/// win on — shared here so the construction exists exactly once.
pub fn smooth_series(
    seed: u64,
    dims: &[usize],
    steps: usize,
    drift_scale: f32,
    field: &str,
) -> Vec<Snapshot> {
    let mut rng = Pcg32::seeded(seed);
    let base = prop::smooth_field(&mut rng, dims);
    let drift = prop::smooth_field(&mut rng, dims);
    (0..steps)
        .map(|t| {
            let vals: Vec<f32> = base
                .iter()
                .zip(&drift)
                .map(|(&b, &d)| b + drift_scale * t as f32 * d)
                .collect();
            Snapshot::new(
                format!("t{t}"),
                vec![Field::f32(field, dims, vals).expect("valid fixture dims")],
            )
        })
        .collect()
}

fn corpus_coordinator() -> Coordinator {
    let cfg = JobConfig {
        pipeline: "sz3-lr".into(),
        bound: ErrorBound::Abs(1e-3),
        workers: 1,
        chunk_elems: 2 * 36, // dims [8,6,6]: 2 rows per chunk -> 4 chunks
        queue_depth: 2,
        ..Default::default()
    };
    Coordinator::from_config(&cfg).expect("corpus pipeline is registered")
}

/// Build the whole corpus. Infallible in practice; errors only surface if
/// the compression stack itself is broken.
pub fn golden_set() -> Result<Vec<Fixture>> {
    let dims = [8usize, 6, 6];
    // a 3-step smoothly-drifting series so the corpus exercises the
    // snapshot table and at least one delta chunk; its first snapshot
    // doubles as the single-snapshot v1/v2/v3 fixture field
    let series = smooth_series(20260730, &dims, 3, 0.01, "a");
    let field = series[0].fields[0].clone();

    let coord = corpus_coordinator();
    let mut chunks = Vec::new();
    coord.run(vec![field], |c| chunks.push(c))?;

    let mut out = Vec::new();
    for (name, artifact) in [
        ("v1", super::pack_v1(&chunks)?),
        ("v2", super::pack_v2(&chunks)?),
        ("v3", super::pack(&chunks)?),
    ] {
        let expected = reference_decode(&artifact)?;
        out.push(Fixture { name, artifact, expected });
    }

    // a v2 artifact whose chunk index and inner stream headers carry the
    // legacy *alias* name ("sz3-lr") — exactly what pre-spec releases
    // wrote — so the container-level alias-fallback decode path stays
    // locked by the committed corpus, not only by unit tests
    let legacy_field = series[0].fields[0].clone();
    let mut legacy = corpus_coordinator();
    legacy.make_compressor =
        std::sync::Arc::new(|| Box::new(crate::pipeline::BlockCompressor::sz3_lr()));
    let mut legacy_chunks = Vec::new();
    legacy.run(vec![legacy_field], |c| legacy_chunks.push(c))?;
    debug_assert!(legacy_chunks.iter().all(|c| c.pipeline == "sz3-lr"));
    let artifact = super::pack_v2(&legacy_chunks)?;
    let expected = reference_decode(&artifact)?;
    out.push(Fixture { name: "v2-alias", artifact, expected });

    let (artifact, _) = coord.run_series_to_container(series, true)?;
    let expected = reference_decode(&artifact)?;
    out.push(Fixture { name: "v3-series", artifact, expected });

    // a v3 artifact whose chunks were compressed by the ZFP-style
    // transform family, locking the `tblock(4)` stream layout (lifted
    // coefficients + embedded bitplanes) into the committed corpus: a
    // format bump that breaks transform decode fails compat, not just
    // unit tests
    // field named "a" like the rest of the corpus: the compat suite
    // region-checks field "a" on every fixture
    let transform_field = smooth_series(20260808, &dims, 1, 0.0, "a")[0].fields[0].clone();
    let cfg = JobConfig {
        pipeline: "zfp-like".into(),
        bound: ErrorBound::Abs(1e-3),
        workers: 1,
        chunk_elems: 2 * 36,
        queue_depth: 2,
        ..Default::default()
    };
    let tcoord = Coordinator::from_config(&cfg)?;
    let mut tchunks = Vec::new();
    tcoord.run(vec![transform_field], |c| tchunks.push(c))?;
    let artifact = super::pack(&tchunks)?;
    let expected = reference_decode(&artifact)?;
    out.push(Fixture { name: "v3-transform", artifact, expected });
    Ok(out)
}

/// Decode a fully-resident container **without** [`crate::reader`]: parse
/// the index, decompress every chunk stream straight off the payload in
/// snapshot order, resolve delta chunks against the previously decoded
/// `(snapshot − 1, field, chunk_index)` baseline, and concatenate per
/// field. This is the compat suite's oracle — two independent decode
/// implementations must agree bit for bit.
pub fn reference_decode(artifact: &[u8]) -> Result<Vec<(usize, String, Vec<u8>)>> {
    let (index, payload) = super::read_index(artifact)?;
    let mut ids: Vec<usize> = (0..index.entries.len()).collect();
    ids.sort_by_key(|&i| {
        let e = &index.entries[i];
        (e.snapshot, e.field.clone(), e.chunk_index)
    });
    let mut decoded: HashMap<(usize, &str, usize), Field> = HashMap::new();
    for &i in &ids {
        let e = &index.entries[i];
        let raw = pipeline::decompress_any(&payload[e.offset..e.offset + e.len])?;
        let field = if e.delta {
            let b = decoded
                .get(&(e.snapshot - 1, e.field.as_str(), e.chunk_index))
                .ok_or_else(|| {
                    SzError::corrupt(format!(
                        "fixture chunk {} of '{}' has no baseline",
                        e.chunk_index, e.field
                    ))
                })?;
            delta::apply(b, &raw)?
        } else {
            raw
        };
        decoded.insert((e.snapshot, e.field.as_str(), e.chunk_index), field);
    }
    // assemble (snapshot, field) outputs in snapshot-major first-appearance
    // order, matching the reader's read_all
    let mut groups: Vec<(usize, String)> = Vec::new();
    for &i in &ids {
        let e = &index.entries[i];
        let key = (e.snapshot, e.field.clone());
        if !groups.contains(&key) {
            groups.push(key);
        }
    }
    let mut out = Vec::new();
    for (snapshot, name) in groups {
        let mut parts: Vec<(usize, &Field)> = decoded
            .iter()
            .filter(|((s, f, _), _)| *s == snapshot && *f == name)
            .map(|((_, _, ci), field)| (*ci, field))
            .collect();
        parts.sort_by_key(|(ci, _)| *ci);
        let mut bytes = Vec::new();
        for (_, f) in parts {
            bytes.extend_from_slice(&f.values.to_le_bytes());
        }
        out.push((snapshot, name, bytes));
    }
    Ok(out)
}

/// Re-slice a reference decode into the rows a region read would return —
/// lets tests compare `read_region_at` against the oracle without going
/// through the reader twice.
pub fn reference_region(
    artifact: &[u8],
    snapshot: usize,
    field: &str,
    rows: std::ops::Range<usize>,
) -> Result<Vec<u8>> {
    let (index, _) = super::read_index(artifact)?;
    let dims = index
        .entries
        .iter()
        .find(|e| e.snapshot == snapshot && e.field == field)
        .map(|e| e.field_dims.clone())
        .ok_or_else(|| SzError::config(format!("no field '{field}'")))?;
    let full = reference_decode(artifact)?
        .into_iter()
        .find(|(s, f, _)| *s == snapshot && f == field)
        .map(|(_, _, bytes)| bytes)
        .expect("field located above");
    // reconstruct an f32/f64/i32-agnostic slice via byte arithmetic: the
    // per-row byte count divides the total evenly
    let row_bytes = full.len() / dims[0];
    Ok(full[rows.start * row_bytes..rows.end * row_bytes].to_vec())
}
