//! Chunked container format (`SZ3C`) — the coordinator's native artifact.
//!
//! The streaming coordinator shards fields into row-range chunks and
//! compresses each independently (possibly through a *different* pipeline
//! per chunk, see [`AdaptiveChunkSelector`]). This module packs those
//! chunks into one self-describing artifact and fans them back out across
//! a worker pool for parallel decompression.
//!
//! # Format (version 1)
//!
//! ```text
//! magic   4 bytes  "SZ3C"
//! version u8       1
//! chunks  varint   number of chunk-index entries
//! fields  varint   number of distinct fields (informational)
//! entry × chunks:
//!     field        str     source field name
//!     chunk_index  varint  position of this chunk within its field
//!     chunk_count  varint  chunks in the field
//!     row_start    varint  } [start, end) along the split (slowest) axis
//!     row_end      varint  }
//!     ndim         varint  ≤ data::shape::MAX_DIMS
//!     dims[ndim]   varint  full field dims
//!     pipeline     str     registry pipeline that compressed the chunk
//!     offset       varint  payload-relative byte offset of the stream
//!     len          varint  stream length in bytes
//! payload_len varint
//! payload     bytes   concatenated per-chunk `SZ3R` streams
//! ```
//!
//! Every chunk stream is itself a complete self-describing `SZ3R` stream,
//! so the index's `pipeline` name is a dispatch/statistics shortcut that is
//! cross-checked against the inner header during decompression. All index
//! integers are validated against the buffer (dim-count cap, row-range
//! sanity, offset bounds) before any allocation is sized from them.

pub mod adaptive;

pub use adaptive::{AdaptiveChunkSelector, ChunkSignals, Selection};

use crate::byteio::{ByteReader, ByteWriter};
use crate::coordinator::CompressedChunk;
use crate::data::{Field, FieldValues};
use crate::error::{Result, SzError};
use crate::pipeline;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Container magic (distinct from the per-stream `SZ3R`).
pub const CONTAINER_MAGIC: &[u8; 4] = b"SZ3C";
const VERSION: u8 = 1;

/// True if `stream` starts with the container magic.
pub fn is_container(stream: &[u8]) -> bool {
    stream.len() >= 4 && &stream[..4] == CONTAINER_MAGIC
}

/// One chunk-index entry.
#[derive(Clone, Debug, PartialEq)]
pub struct ChunkEntry {
    /// Source field name.
    pub field: String,
    /// Position of this chunk within its field.
    pub chunk_index: usize,
    /// Chunks in the field.
    pub chunk_count: usize,
    /// Row range [start, end) along the split axis.
    pub rows: (usize, usize),
    /// Full field dims.
    pub field_dims: Vec<usize>,
    /// Registry pipeline that compressed this chunk.
    pub pipeline: String,
    /// Payload-relative byte offset of the chunk stream.
    pub offset: usize,
    /// Chunk stream length in bytes.
    pub len: usize,
}

/// Parsed container index.
#[derive(Clone, Debug, Default)]
pub struct ContainerIndex {
    /// Chunk entries in delivery (seq) order.
    pub entries: Vec<ChunkEntry>,
}

impl ContainerIndex {
    /// Distinct field names in order of first appearance.
    pub fn field_names(&self) -> Vec<&str> {
        let mut out: Vec<&str> = Vec::new();
        for e in &self.entries {
            if !out.contains(&e.field.as_str()) {
                out.push(&e.field);
            }
        }
        out
    }

    /// Chunk counts per pipeline name (sorted by name).
    pub fn per_pipeline(&self) -> Vec<(String, usize)> {
        let mut map = std::collections::BTreeMap::new();
        for e in &self.entries {
            *map.entry(e.pipeline.clone()).or_insert(0usize) += 1;
        }
        map.into_iter().collect()
    }
}

/// Pack ordered coordinator chunks into a container artifact.
///
/// All chunks of a field must carry the same `field_dims`/`chunk_count`
/// (the coordinator guarantees this); ordering within the buffer is free
/// since decompression sorts by `chunk_index`.
pub fn pack(chunks: &[CompressedChunk]) -> Result<Vec<u8>> {
    // Reject chunk sets that could never decode — duplicate chunk indices
    // (two source fields sharing a name) or a count that disagrees with
    // the declared chunk_count — instead of emitting a poison artifact.
    let mut fields: Vec<&str> = Vec::new();
    let mut seen: std::collections::HashMap<&str, (usize, Vec<bool>)> =
        std::collections::HashMap::new();
    for c in chunks {
        if !fields.contains(&c.field.as_str()) {
            fields.push(&c.field);
        }
        let (count, got) = seen
            .entry(&c.field)
            .or_insert_with(|| (c.chunk_count, vec![false; c.chunk_count]));
        if c.chunk_count != *count || c.chunk_index >= *count {
            return Err(SzError::config(format!(
                "field '{}': chunk {}/{} disagrees with count {count}",
                c.field, c.chunk_index, c.chunk_count
            )));
        }
        if std::mem::replace(&mut got[c.chunk_index], true) {
            return Err(SzError::config(format!(
                "field '{}': duplicate chunk index {} (two source fields \
                 with the same name?)",
                c.field, c.chunk_index
            )));
        }
    }
    for (name, (count, got)) in &seen {
        if got.iter().filter(|&&g| g).count() != *count {
            return Err(SzError::config(format!(
                "field '{name}': packed {} of {count} chunks",
                got.iter().filter(|&&g| g).count()
            )));
        }
    }
    let mut w = ByteWriter::new();
    w.put_bytes(CONTAINER_MAGIC);
    w.put_u8(VERSION);
    w.put_varint(chunks.len() as u64);
    w.put_varint(fields.len() as u64);
    let mut offset = 0usize;
    for c in chunks {
        w.put_str(&c.field);
        w.put_varint(c.chunk_index as u64);
        w.put_varint(c.chunk_count as u64);
        w.put_varint(c.rows.0 as u64);
        w.put_varint(c.rows.1 as u64);
        w.put_varint(c.field_dims.len() as u64);
        for &d in &c.field_dims {
            w.put_varint(d as u64);
        }
        w.put_str(&c.pipeline);
        w.put_varint(offset as u64);
        w.put_varint(c.stream.len() as u64);
        offset += c.stream.len();
    }
    w.put_varint(offset as u64);
    for c in chunks {
        w.put_bytes(&c.stream);
    }
    Ok(w.finish())
}

/// Parse and validate the chunk index; returns the index and the payload.
pub fn read_index(stream: &[u8]) -> Result<(ContainerIndex, &[u8])> {
    let mut r = ByteReader::new(stream);
    let magic = r.get_bytes(4)?;
    if magic != CONTAINER_MAGIC {
        return Err(SzError::corrupt("bad container magic"));
    }
    let ver = r.get_u8()?;
    if ver != VERSION {
        return Err(SzError::corrupt(format!("unsupported container version {ver}")));
    }
    let n_chunks = r.get_varint()? as usize;
    // Every entry consumes ≥ 1 byte, so the remaining length bounds the
    // plausible entry count — reject before growing any allocation.
    if n_chunks > r.remaining() {
        return Err(SzError::corrupt(format!(
            "chunk count {n_chunks} exceeds container size"
        )));
    }
    let _n_fields = r.get_varint()?;
    let mut entries = Vec::new();
    for _ in 0..n_chunks {
        let field = r.get_str()?;
        let chunk_index = r.get_varint()? as usize;
        let chunk_count = r.get_varint()? as usize;
        let row_start = r.get_varint()? as usize;
        let row_end = r.get_varint()? as usize;
        let nd = r.get_varint()? as usize;
        if nd == 0 || nd > crate::data::shape::MAX_DIMS {
            return Err(SzError::corrupt(format!(
                "index dim count {nd} outside 1..={}",
                crate::data::shape::MAX_DIMS
            )));
        }
        let mut field_dims = Vec::with_capacity(nd);
        for _ in 0..nd {
            field_dims.push(r.get_varint()? as usize);
        }
        let pipeline = r.get_str()?;
        let offset = r.get_varint()? as usize;
        let len = r.get_varint()? as usize;
        if chunk_count == 0 || chunk_index >= chunk_count {
            return Err(SzError::corrupt(format!(
                "chunk index {chunk_index} outside count {chunk_count}"
            )));
        }
        if row_start >= row_end || row_end > field_dims[0] {
            return Err(SzError::corrupt(format!(
                "row range [{row_start}, {row_end}) invalid for {} rows",
                field_dims[0]
            )));
        }
        entries.push(ChunkEntry {
            field,
            chunk_index,
            chunk_count,
            rows: (row_start, row_end),
            field_dims,
            pipeline,
            offset,
            len,
        });
    }
    let payload_len = r.get_varint()? as usize;
    let payload = r.get_bytes(payload_len)?;
    for e in &entries {
        let end = e
            .offset
            .checked_add(e.len)
            .ok_or_else(|| SzError::corrupt("chunk extent overflows"))?;
        if end > payload.len() {
            return Err(SzError::corrupt(format!(
                "chunk [{}..{end}) outside payload of {} bytes",
                e.offset,
                payload.len()
            )));
        }
    }
    Ok((ContainerIndex { entries }, payload))
}

/// Decompress a container: fan chunks out across `workers` threads (each
/// chunk dispatched on its index pipeline, cross-checked against the inner
/// stream header), then reassemble fields with shape verification.
/// Fields are returned in order of first appearance in the index.
pub fn decompress_container(stream: &[u8], workers: usize) -> Result<Vec<Field>> {
    let (index, payload) = read_index(stream)?;
    decompress_indexed(&index, payload, workers)
}

/// Decompress a container whose exactly-one field is wanted (the
/// [`crate::pipeline::decompress_any`] path); parses the index once for
/// both the field-count check and the decode.
pub fn decompress_single_field(stream: &[u8], workers: usize) -> Result<Field> {
    let (index, payload) = read_index(stream)?;
    let n = index.field_names().len();
    if n != 1 {
        return Err(SzError::config(format!(
            "container holds {n} fields; use container::decompress_container"
        )));
    }
    decompress_indexed(&index, payload, workers)?
        .pop()
        .ok_or_else(|| SzError::corrupt("container decoded no fields"))
}

fn decompress_indexed(
    index: &ContainerIndex,
    payload: &[u8],
    workers: usize,
) -> Result<Vec<Field>> {
    let n = index.entries.len();
    if n == 0 {
        return Ok(Vec::new());
    }

    // parallel fan-out: workers pull entry indices from a shared counter
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<Result<Field>>>> = Mutex::new((0..n).map(|_| None).collect());
    let decode_one = |e: &ChunkEntry| -> Result<Field> {
        let chunk_stream = &payload[e.offset..e.offset + e.len];
        let compressor = pipeline::by_name(&e.pipeline).ok_or_else(|| {
            SzError::corrupt(format!("unknown pipeline '{}' in chunk index", e.pipeline))
        })?;
        let header = pipeline::peek_header(chunk_stream)?;
        if header.pipeline != e.pipeline {
            return Err(SzError::corrupt(format!(
                "index pipeline '{}' disagrees with stream header '{}'",
                e.pipeline, header.pipeline
            )));
        }
        let field = compressor.decompress(chunk_stream)?;
        let mut expect = e.field_dims.clone();
        expect[0] = e.rows.1 - e.rows.0;
        if field.shape.dims() != expect.as_slice() {
            return Err(SzError::corrupt(format!(
                "chunk {} of {}: decoded dims {:?}, index says {:?}",
                e.chunk_index,
                e.field,
                field.shape.dims(),
                expect
            )));
        }
        Ok(field)
    };
    let pool = workers.clamp(1, n);
    std::thread::scope(|s| {
        for _ in 0..pool {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = decode_one(&index.entries[i]);
                slots.lock().unwrap()[i] = Some(r);
            });
        }
    });
    let decoded: Vec<Field> = slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|slot| slot.expect("every slot filled by the pool"))
        .collect::<Result<_>>()?;

    // group (entry, field) pairs per field, in order of first appearance
    let names: Vec<String> =
        index.field_names().into_iter().map(str::to_string).collect();
    let mut out = Vec::with_capacity(names.len());
    for name in names {
        let mut parts: Vec<(&ChunkEntry, &Field)> = index
            .entries
            .iter()
            .zip(&decoded)
            .filter(|(e, _)| e.field == name)
            .collect();
        parts.sort_by_key(|(e, _)| e.chunk_index);
        out.push(stitch(&name, &parts)?);
    }
    Ok(out)
}

/// Reassemble one field from its decoded chunks, verifying the index is
/// internally consistent (count, dims agreement, contiguous row coverage).
fn stitch(name: &str, parts: &[(&ChunkEntry, &Field)]) -> Result<Field> {
    let (first, _) = parts[0];
    if parts.len() != first.chunk_count {
        return Err(SzError::corrupt(format!(
            "field {name}: have {} of {} chunks",
            parts.len(),
            first.chunk_count
        )));
    }
    let dims = first.field_dims.clone();
    let mut next_row = 0usize;
    for (i, (e, _)) in parts.iter().enumerate() {
        if e.chunk_index != i || e.field_dims != dims || e.chunk_count != first.chunk_count {
            return Err(SzError::corrupt(format!(
                "field {name}: inconsistent chunk metadata at {i}"
            )));
        }
        if e.rows.0 != next_row {
            return Err(SzError::corrupt(format!(
                "field {name}: row gap at chunk {i} (expected start {next_row}, got {})",
                e.rows.0
            )));
        }
        next_row = e.rows.1;
    }
    if next_row != dims[0] {
        return Err(SzError::corrupt(format!(
            "field {name}: chunks cover {next_row} of {} rows",
            dims[0]
        )));
    }
    let values = FieldValues::concat(parts.iter().map(|(_, f)| &f.values))?;
    // Field::new re-verifies dims-vs-values agreement (shape verification)
    Field::new(name, &dims, values)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::JobConfig;
    use crate::coordinator::Coordinator;
    use crate::pipeline::ErrorBound;
    use crate::util::{prop, rng::Pcg32};

    fn sample_chunks(n_fields: usize) -> Vec<CompressedChunk> {
        let cfg = JobConfig {
            pipeline: "sz3-lr".into(),
            bound: ErrorBound::Abs(1e-3),
            workers: 2,
            chunk_elems: 512, // 3 rows of 12x12 per chunk -> 4 chunks per field
            queue_depth: 2,
            ..Default::default()
        };
        let coord = Coordinator::from_config(&cfg).unwrap();
        let mut rng = Pcg32::seeded(91);
        let fields: Vec<Field> = (0..n_fields)
            .map(|i| {
                let dims = [10usize, 12, 12];
                Field::f32(format!("f{i}"), &dims, prop::smooth_field(&mut rng, &dims))
                    .unwrap()
            })
            .collect();
        let mut chunks = Vec::new();
        coord.run(fields, |c| chunks.push(c)).unwrap();
        chunks
    }

    #[test]
    fn index_roundtrips() {
        let chunks = sample_chunks(2);
        let packed = pack(&chunks).unwrap();
        assert!(is_container(&packed));
        let (index, payload) = read_index(&packed).unwrap();
        assert_eq!(index.entries.len(), chunks.len());
        assert_eq!(index.field_names(), vec!["f0", "f1"]);
        let total: usize = chunks.iter().map(|c| c.stream.len()).sum();
        assert_eq!(payload.len(), total);
        for (e, c) in index.entries.iter().zip(&chunks) {
            assert_eq!(e.field, c.field);
            assert_eq!(e.rows, c.rows);
            assert_eq!(e.pipeline, c.pipeline);
            assert_eq!(&payload[e.offset..e.offset + e.len], &c.stream[..]);
        }
    }

    #[test]
    fn container_decompress_matches_per_chunk_decode() {
        let chunks = sample_chunks(2);
        let packed = pack(&chunks).unwrap();
        let fields = decompress_container(&packed, 4).unwrap();
        assert_eq!(fields.len(), 2);
        for f in &fields {
            assert_eq!(f.shape.dims(), &[10, 12, 12]);
        }
    }

    #[test]
    fn empty_container_roundtrips() {
        let packed = pack(&[]).unwrap();
        assert!(decompress_container(&packed, 4).unwrap().is_empty());
    }

    #[test]
    fn corrupt_containers_error_not_panic() {
        let chunks = sample_chunks(1);
        let packed = pack(&chunks).unwrap();
        // truncations at many offsets
        for cut in [4usize, 6, packed.len() / 3, packed.len() - 2] {
            let r = std::panic::catch_unwind(|| decompress_container(&packed[..cut], 2));
            match r {
                Ok(Err(_)) => {}
                Ok(Ok(_)) => panic!("truncated container decoded (cut={cut})"),
                Err(_) => panic!("panic on truncated container (cut={cut})"),
            }
        }
        // adversarial chunk count
        let mut bad = packed.clone();
        bad[5] = 0xff; // first byte of the chunk-count varint
        bad[6] = 0xff;
        let r = std::panic::catch_unwind(|| decompress_container(&bad, 2));
        assert!(matches!(r, Ok(Err(_))), "huge chunk count must error cleanly");
    }

    #[test]
    fn incomplete_or_colliding_chunk_sets_rejected_at_pack() {
        let mut chunks = sample_chunks(1);
        assert!(chunks.len() > 1, "need multiple chunks");
        // missing chunk: the artifact could never decode, refuse to emit it
        let dropped = chunks.pop().unwrap();
        let err = pack(&chunks).unwrap_err();
        assert!(err.to_string().contains("chunks"), "{err}");
        // duplicate chunk index (two source fields sharing a name)
        chunks.push(dropped.clone());
        chunks.push(dropped);
        let err = pack(&chunks).unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    #[test]
    fn missing_chunk_detected_on_decode() {
        // hand-craft an index claiming 4 chunks but carrying only the
        // first, bypassing pack()'s validation: stitch() must refuse
        let c = sample_chunks(1).remove(0);
        assert_eq!((c.chunk_count, c.rows), (4, (0, 3)));
        let mut w = ByteWriter::new();
        w.put_bytes(CONTAINER_MAGIC);
        w.put_u8(1);
        w.put_varint(1); // one entry…
        w.put_varint(1);
        w.put_str(&c.field);
        w.put_varint(c.chunk_index as u64);
        w.put_varint(c.chunk_count as u64); // …of a declared four
        w.put_varint(c.rows.0 as u64);
        w.put_varint(c.rows.1 as u64);
        w.put_varint(c.field_dims.len() as u64);
        for &d in &c.field_dims {
            w.put_varint(d as u64);
        }
        w.put_str(&c.pipeline);
        w.put_varint(0);
        w.put_varint(c.stream.len() as u64);
        w.put_varint(c.stream.len() as u64);
        w.put_bytes(&c.stream);
        let err = decompress_container(&w.finish(), 2).unwrap_err();
        assert!(err.to_string().contains("chunks"), "{err}");
    }
}
