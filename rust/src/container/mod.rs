//! Chunked container format (`SZ3C`) — the coordinator's native artifact.
//!
//! The streaming coordinator shards fields into row-range chunks and
//! compresses each independently (possibly through a *different* pipeline
//! per chunk, see [`AdaptiveChunkSelector`]). This module packs those
//! chunks into one self-describing artifact; [`crate::reader`] fans them
//! back out — in parallel for whole-container decompression, or chunk by
//! chunk for indexed-seek region reads — and [`crate::server`] publishes
//! artifacts over HTTP range queries (`sz3 serve-http`, API contract in
//! `docs/SERVE.md`).
//!
//! # Format
//!
//! ```text
//! magic   4 bytes  "SZ3C"
//! version u8       1 or 2
//! chunks  varint   number of chunk-index entries
//! fields  varint   number of distinct fields (informational)
//! entry × chunks:
//!     field        str     source field name
//!     chunk_index  varint  position of this chunk within its field
//!     chunk_count  varint  chunks in the field
//!     row_start    varint  } [start, end) along the split (slowest) axis
//!     row_end      varint  }
//!     ndim         varint  ≤ data::shape::MAX_DIMS
//!     dims[ndim]   varint  full field dims
//!     pipeline     str     registry pipeline that compressed the chunk
//!     offset       varint  payload-relative byte offset of the stream
//!     len          varint  stream length in bytes
//!     crc32        u32 LE  (v2 only) CRC-32/IEEE of the chunk stream
//! payload_len varint
//! payload     bytes   concatenated per-chunk `SZ3R` streams
//! ```
//!
//! v2 (current) adds a per-chunk CRC-32 to every index entry, verified on
//! every payload fetch by the reader; v1 artifacts (no checksum) remain
//! fully readable. The full byte-level specification lives in
//! `docs/CONTAINER.md`.
//!
//! Every chunk stream is itself a complete self-describing `SZ3R` stream,
//! so the index's `pipeline` name is a dispatch/statistics shortcut that is
//! cross-checked against the inner header during decompression. All index
//! integers are validated against the declared payload extent (dim-count
//! cap, row-range sanity, offset bounds) before any allocation is sized
//! from them — [`read_index_meta`] needs only an index-covering *prefix*
//! of the artifact, which is what lets [`crate::reader::ContainerReader`]
//! open a multi-GB container without loading its payload.

pub mod adaptive;

pub use adaptive::{AdaptiveChunkSelector, ChunkSignals, Selection};

use crate::byteio::{ByteReader, ByteWriter};
use crate::coordinator::CompressedChunk;
use crate::data::Field;
use crate::error::{Result, SzError};
use crate::util::crc32::crc32;

/// Container magic (distinct from the per-stream `SZ3R`).
pub const CONTAINER_MAGIC: &[u8; 4] = b"SZ3C";
/// Original index layout (no per-chunk checksum).
pub const VERSION_V1: u8 = 1;
/// Adds a CRC-32 per chunk-index entry, verified on every fetch.
pub const VERSION_V2: u8 = 2;
/// The version [`pack`] writes.
pub const CURRENT_VERSION: u8 = VERSION_V2;

/// True if `stream` starts with the container magic.
pub fn is_container(stream: &[u8]) -> bool {
    stream.len() >= 4 && &stream[..4] == CONTAINER_MAGIC
}

/// One chunk-index entry.
#[derive(Clone, Debug, PartialEq)]
pub struct ChunkEntry {
    /// Source field name.
    pub field: String,
    /// Position of this chunk within its field.
    pub chunk_index: usize,
    /// Chunks in the field.
    pub chunk_count: usize,
    /// Row range [start, end) along the split axis.
    pub rows: (usize, usize),
    /// Full field dims.
    pub field_dims: Vec<usize>,
    /// Registry pipeline that compressed this chunk.
    pub pipeline: String,
    /// Payload-relative byte offset of the chunk stream.
    pub offset: usize,
    /// Chunk stream length in bytes.
    pub len: usize,
    /// CRC-32 of the chunk stream (`None` for v1 containers).
    pub crc32: Option<u32>,
}

/// Parsed container index.
#[derive(Clone, Debug, Default)]
pub struct ContainerIndex {
    /// Chunk entries in delivery (seq) order.
    pub entries: Vec<ChunkEntry>,
}

impl ContainerIndex {
    /// Distinct field names in order of first appearance.
    pub fn field_names(&self) -> Vec<&str> {
        let mut out: Vec<&str> = Vec::new();
        for e in &self.entries {
            if !out.contains(&e.field.as_str()) {
                out.push(&e.field);
            }
        }
        out
    }

    /// Chunk counts per pipeline name, deterministically ordered (sorted by
    /// pipeline name via `BTreeMap`) so `sz3 info` output and tests are
    /// stable across runs regardless of worker scheduling.
    pub fn per_pipeline(&self) -> Vec<(String, usize)> {
        let mut map = std::collections::BTreeMap::new();
        for e in &self.entries {
            *map.entry(e.pipeline.clone()).or_insert(0usize) += 1;
        }
        map.into_iter().collect()
    }
}

/// Index metadata parsed from an artifact *prefix*: everything before the
/// payload bytes. Unlike [`read_index`], producing this does not require
/// the payload to be present, so seekable sources can fetch chunks lazily.
#[derive(Clone, Debug)]
pub struct IndexMeta {
    /// The parsed chunk index.
    pub index: ContainerIndex,
    /// Container format version (1 or 2).
    pub version: u8,
    /// Absolute byte offset where the payload begins.
    pub payload_offset: usize,
    /// Declared payload length in bytes.
    pub payload_len: u64,
}

/// Pack ordered coordinator chunks into a container artifact (current
/// version, with per-chunk CRC-32).
///
/// All chunks of a field must carry the same `field_dims`/`chunk_count`
/// (the coordinator guarantees this); ordering within the buffer is free
/// since decompression sorts by `chunk_index`.
pub fn pack(chunks: &[CompressedChunk]) -> Result<Vec<u8>> {
    pack_version(chunks, CURRENT_VERSION)
}

/// Pack in the legacy v1 layout (no checksums). Kept for compatibility
/// testing and for producing artifacts older readers understand.
pub fn pack_v1(chunks: &[CompressedChunk]) -> Result<Vec<u8>> {
    pack_version(chunks, VERSION_V1)
}

fn pack_version(chunks: &[CompressedChunk], version: u8) -> Result<Vec<u8>> {
    if version != VERSION_V1 && version != VERSION_V2 {
        return Err(SzError::config(format!("cannot pack container version {version}")));
    }
    // Reject chunk sets that could never decode — duplicate chunk indices
    // (two source fields sharing a name) or a count that disagrees with
    // the declared chunk_count — instead of emitting a poison artifact.
    let mut fields: Vec<&str> = Vec::new();
    let mut seen: std::collections::HashMap<&str, (usize, Vec<bool>)> =
        std::collections::HashMap::new();
    for c in chunks {
        if !fields.contains(&c.field.as_str()) {
            fields.push(&c.field);
        }
        let (count, got) = seen
            .entry(&c.field)
            .or_insert_with(|| (c.chunk_count, vec![false; c.chunk_count]));
        if c.chunk_count != *count || c.chunk_index >= *count {
            return Err(SzError::config(format!(
                "field '{}': chunk {}/{} disagrees with count {count}",
                c.field, c.chunk_index, c.chunk_count
            )));
        }
        if std::mem::replace(&mut got[c.chunk_index], true) {
            return Err(SzError::config(format!(
                "field '{}': duplicate chunk index {} (two source fields \
                 with the same name?)",
                c.field, c.chunk_index
            )));
        }
    }
    for (name, (count, got)) in &seen {
        if got.iter().filter(|&&g| g).count() != *count {
            return Err(SzError::config(format!(
                "field '{name}': packed {} of {count} chunks",
                got.iter().filter(|&&g| g).count()
            )));
        }
    }
    let mut w = ByteWriter::new();
    w.put_bytes(CONTAINER_MAGIC);
    w.put_u8(version);
    w.put_varint(chunks.len() as u64);
    w.put_varint(fields.len() as u64);
    let mut offset = 0usize;
    for c in chunks {
        w.put_str(&c.field);
        w.put_varint(c.chunk_index as u64);
        w.put_varint(c.chunk_count as u64);
        w.put_varint(c.rows.0 as u64);
        w.put_varint(c.rows.1 as u64);
        w.put_varint(c.field_dims.len() as u64);
        for &d in &c.field_dims {
            w.put_varint(d as u64);
        }
        w.put_str(&c.pipeline);
        w.put_varint(offset as u64);
        w.put_varint(c.stream.len() as u64);
        if version >= VERSION_V2 {
            w.put_u32(crc32(&c.stream));
        }
        offset += c.stream.len();
    }
    w.put_varint(offset as u64);
    for c in chunks {
        w.put_bytes(&c.stream);
    }
    Ok(w.finish())
}

/// Parse and validate the chunk index from an artifact prefix; the payload
/// bytes need not be present. Chunk extents are validated against the
/// *declared* payload length, so a lazily-fetching reader can trust the
/// offsets before it has read a single payload byte.
pub fn read_index_meta(prefix: &[u8]) -> Result<IndexMeta> {
    let mut r = ByteReader::new(prefix);
    let magic = r.get_bytes(4)?;
    if magic != CONTAINER_MAGIC {
        return Err(SzError::corrupt("bad container magic"));
    }
    let version = r.get_u8()?;
    if version != VERSION_V1 && version != VERSION_V2 {
        return Err(SzError::corrupt(format!("unsupported container version {version}")));
    }
    let n_chunks = r.get_varint()? as usize;
    // Every entry consumes ≥ 1 byte, so the remaining length bounds the
    // plausible entry count — reject before growing any allocation. The
    // exhaustion-shaped message matters: on a short *prefix* of a valid
    // large index this is a retry-with-more-bytes condition
    // (`SzError::is_exhaustion`), not a verdict of corruption.
    if n_chunks > r.remaining() {
        return Err(SzError::corrupt(format!(
            "need {n_chunks} index entries, have {} bytes",
            r.remaining()
        )));
    }
    let _n_fields = r.get_varint()?;
    let mut entries = Vec::new();
    for _ in 0..n_chunks {
        let field = r.get_str()?;
        let chunk_index = r.get_varint()? as usize;
        let chunk_count = r.get_varint()? as usize;
        let row_start = r.get_varint()? as usize;
        let row_end = r.get_varint()? as usize;
        let nd = r.get_varint()? as usize;
        if nd == 0 || nd > crate::data::shape::MAX_DIMS {
            return Err(SzError::corrupt(format!(
                "index dim count {nd} outside 1..={}",
                crate::data::shape::MAX_DIMS
            )));
        }
        let mut field_dims = Vec::with_capacity(nd);
        for _ in 0..nd {
            field_dims.push(r.get_varint()? as usize);
        }
        let pipeline = r.get_str()?;
        let offset = r.get_varint()? as usize;
        let len = r.get_varint()? as usize;
        let crc = if version >= VERSION_V2 { Some(r.get_u32()?) } else { None };
        if chunk_count == 0 || chunk_index >= chunk_count {
            return Err(SzError::corrupt(format!(
                "chunk index {chunk_index} outside count {chunk_count}"
            )));
        }
        if row_start >= row_end || row_end > field_dims[0] {
            return Err(SzError::corrupt(format!(
                "row range [{row_start}, {row_end}) invalid for {} rows",
                field_dims[0]
            )));
        }
        entries.push(ChunkEntry {
            field,
            chunk_index,
            chunk_count,
            rows: (row_start, row_end),
            field_dims,
            pipeline,
            offset,
            len,
            crc32: crc,
        });
    }
    let payload_len = r.get_varint()?;
    let payload_offset = r.pos();
    for e in &entries {
        let end = e
            .offset
            .checked_add(e.len)
            .ok_or_else(|| SzError::corrupt("chunk extent overflows"))?;
        if end as u64 > payload_len {
            return Err(SzError::corrupt(format!(
                "chunk [{}..{end}) outside payload of {payload_len} bytes",
                e.offset
            )));
        }
    }
    Ok(IndexMeta { index: ContainerIndex { entries }, version, payload_offset, payload_len })
}

/// Parse and validate the chunk index of a fully-resident artifact;
/// returns the index and the payload slice. Reads both v1 and v2.
pub fn read_index(stream: &[u8]) -> Result<(ContainerIndex, &[u8])> {
    let meta = read_index_meta(stream)?;
    let avail = stream.len() - meta.payload_offset;
    if meta.payload_len > avail as u64 {
        return Err(SzError::corrupt(format!(
            "need {} payload bytes, have {avail}",
            meta.payload_len
        )));
    }
    let payload =
        &stream[meta.payload_offset..meta.payload_offset + meta.payload_len as usize];
    Ok((meta.index, payload))
}

/// Decompress a fully-resident container: routed through
/// [`crate::reader::ContainerReader`] (the single seek/verify/decode code
/// path — chunks fan out across `workers` threads, every v2 chunk is
/// CRC-checked, each stream's inner header is cross-checked against the
/// index, and fields reassemble with shape verification). Fields are
/// returned in order of first appearance in the index.
pub fn decompress_container(stream: &[u8], workers: usize) -> Result<Vec<Field>> {
    crate::reader::ContainerReader::from_slice(stream)?
        .with_workers(workers)
        .read_all()
}

/// Decompress a container whose exactly-one field is wanted (the
/// [`crate::pipeline::decompress_any`] path); parses the index once for
/// both the field-count check and the decode.
pub fn decompress_single_field(stream: &[u8], workers: usize) -> Result<Field> {
    let reader =
        crate::reader::ContainerReader::from_slice(stream)?.with_workers(workers);
    let n = reader.field_names().len();
    if n != 1 {
        return Err(SzError::config(format!(
            "container holds {n} fields; use container::decompress_container"
        )));
    }
    reader
        .read_all()?
        .pop()
        .ok_or_else(|| SzError::corrupt("container decoded no fields"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::JobConfig;
    use crate::coordinator::Coordinator;
    use crate::pipeline::ErrorBound;
    use crate::util::{prop, rng::Pcg32};

    fn sample_chunks(n_fields: usize) -> Vec<CompressedChunk> {
        let cfg = JobConfig {
            pipeline: "sz3-lr".into(),
            bound: ErrorBound::Abs(1e-3),
            workers: 2,
            chunk_elems: 512, // 3 rows of 12x12 per chunk -> 4 chunks per field
            queue_depth: 2,
            ..Default::default()
        };
        let coord = Coordinator::from_config(&cfg).unwrap();
        let mut rng = Pcg32::seeded(91);
        let fields: Vec<Field> = (0..n_fields)
            .map(|i| {
                let dims = [10usize, 12, 12];
                Field::f32(format!("f{i}"), &dims, prop::smooth_field(&mut rng, &dims))
                    .unwrap()
            })
            .collect();
        let mut chunks = Vec::new();
        coord.run(fields, |c| chunks.push(c)).unwrap();
        chunks
    }

    #[test]
    fn index_roundtrips() {
        let chunks = sample_chunks(2);
        let packed = pack(&chunks).unwrap();
        assert!(is_container(&packed));
        let (index, payload) = read_index(&packed).unwrap();
        assert_eq!(index.entries.len(), chunks.len());
        assert_eq!(index.field_names(), vec!["f0", "f1"]);
        let total: usize = chunks.iter().map(|c| c.stream.len()).sum();
        assert_eq!(payload.len(), total);
        for (e, c) in index.entries.iter().zip(&chunks) {
            assert_eq!(e.field, c.field);
            assert_eq!(e.rows, c.rows);
            assert_eq!(e.pipeline, c.pipeline);
            assert_eq!(e.crc32, Some(crc32(&c.stream)));
            assert_eq!(&payload[e.offset..e.offset + e.len], &c.stream[..]);
        }
    }

    #[test]
    fn v1_packs_without_checksums_and_still_reads() {
        let chunks = sample_chunks(1);
        let packed = pack_v1(&chunks).unwrap();
        let meta = read_index_meta(&packed).unwrap();
        assert_eq!(meta.version, VERSION_V1);
        assert!(meta.index.entries.iter().all(|e| e.crc32.is_none()));
        let fields = decompress_container(&packed, 2).unwrap();
        assert_eq!(fields.len(), 1);
        assert_eq!(fields[0].shape.dims(), &[10, 12, 12]);
    }

    #[test]
    fn index_meta_parses_from_prefix_only() {
        let chunks = sample_chunks(1);
        let packed = pack(&chunks).unwrap();
        let meta = read_index_meta(&packed).unwrap();
        assert_eq!(meta.version, VERSION_V2);
        // the payload is NOT needed: a prefix ending right at payload_offset
        // parses identically
        let prefix = &packed[..meta.payload_offset];
        let m2 = read_index_meta(prefix).unwrap();
        assert_eq!(m2.payload_offset, meta.payload_offset);
        assert_eq!(m2.payload_len, meta.payload_len);
        assert_eq!(m2.index.entries, meta.index.entries);
        assert_eq!(
            meta.payload_offset as u64 + meta.payload_len,
            packed.len() as u64
        );
    }

    #[test]
    fn container_decompress_matches_per_chunk_decode() {
        let chunks = sample_chunks(2);
        let packed = pack(&chunks).unwrap();
        let fields = decompress_container(&packed, 4).unwrap();
        assert_eq!(fields.len(), 2);
        for f in &fields {
            assert_eq!(f.shape.dims(), &[10, 12, 12]);
        }
    }

    #[test]
    fn empty_container_roundtrips() {
        let packed = pack(&[]).unwrap();
        assert!(decompress_container(&packed, 4).unwrap().is_empty());
    }

    #[test]
    fn per_pipeline_deterministically_sorted() {
        let index = ContainerIndex {
            entries: ["zzz", "aaa", "mmm", "aaa"]
                .iter()
                .enumerate()
                .map(|(i, p)| ChunkEntry {
                    field: "f".into(),
                    chunk_index: i,
                    chunk_count: 4,
                    rows: (i, i + 1),
                    field_dims: vec![4],
                    pipeline: p.to_string(),
                    offset: 0,
                    len: 0,
                    crc32: None,
                })
                .collect(),
        };
        let mix = index.per_pipeline();
        assert_eq!(
            mix,
            vec![("aaa".into(), 2), ("mmm".into(), 1), ("zzz".into(), 1)],
            "per_pipeline must be sorted by name, independent of entry order"
        );
    }

    #[test]
    fn corrupt_containers_error_not_panic() {
        let chunks = sample_chunks(1);
        let packed = pack(&chunks).unwrap();
        // truncations at many offsets
        for cut in [4usize, 6, packed.len() / 3, packed.len() - 2] {
            let r = std::panic::catch_unwind(|| decompress_container(&packed[..cut], 2));
            match r {
                Ok(Err(_)) => {}
                Ok(Ok(_)) => panic!("truncated container decoded (cut={cut})"),
                Err(_) => panic!("panic on truncated container (cut={cut})"),
            }
        }
        // adversarial chunk count
        let mut bad = packed.clone();
        bad[5] = 0xff; // first byte of the chunk-count varint
        bad[6] = 0xff;
        let r = std::panic::catch_unwind(|| decompress_container(&bad, 2));
        assert!(matches!(r, Ok(Err(_))), "huge chunk count must error cleanly");
    }

    #[test]
    fn incomplete_or_colliding_chunk_sets_rejected_at_pack() {
        let mut chunks = sample_chunks(1);
        assert!(chunks.len() > 1, "need multiple chunks");
        // missing chunk: the artifact could never decode, refuse to emit it
        let dropped = chunks.pop().unwrap();
        let err = pack(&chunks).unwrap_err();
        assert!(err.to_string().contains("chunks"), "{err}");
        // duplicate chunk index (two source fields sharing a name)
        chunks.push(dropped.clone());
        chunks.push(dropped);
        let err = pack(&chunks).unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    #[test]
    fn missing_chunk_detected_on_decode() {
        // hand-craft a v1 index claiming 4 chunks but carrying only the
        // first, bypassing pack()'s validation: coverage validation in the
        // reader must refuse
        let c = sample_chunks(1).remove(0);
        assert_eq!((c.chunk_count, c.rows), (4, (0, 3)));
        let mut w = ByteWriter::new();
        w.put_bytes(CONTAINER_MAGIC);
        w.put_u8(1);
        w.put_varint(1); // one entry…
        w.put_varint(1);
        w.put_str(&c.field);
        w.put_varint(c.chunk_index as u64);
        w.put_varint(c.chunk_count as u64); // …of a declared four
        w.put_varint(c.rows.0 as u64);
        w.put_varint(c.rows.1 as u64);
        w.put_varint(c.field_dims.len() as u64);
        for &d in &c.field_dims {
            w.put_varint(d as u64);
        }
        w.put_str(&c.pipeline);
        w.put_varint(0);
        w.put_varint(c.stream.len() as u64);
        w.put_varint(c.stream.len() as u64);
        w.put_bytes(&c.stream);
        let err = decompress_container(&w.finish(), 2).unwrap_err();
        assert!(err.to_string().contains("chunks"), "{err}");
    }
}
