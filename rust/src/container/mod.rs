//! Chunked container format (`SZ3C`) — the coordinator's native artifact.
//!
//! The streaming coordinator shards fields into row-range chunks and
//! compresses each independently (possibly through a *different* pipeline
//! per chunk, see [`AdaptiveChunkSelector`]). This module packs those
//! chunks into one self-describing artifact; [`crate::reader`] fans them
//! back out — in parallel for whole-container decompression, or chunk by
//! chunk for indexed-seek region reads — and [`crate::server`] publishes
//! artifacts over HTTP range queries (`sz3 serve-http`, API contract in
//! `docs/SERVE.md`).
//!
//! # Format
//!
//! ```text
//! magic   4 bytes  "SZ3C"
//! version u8       1, 2 or 3
//! chunks  varint   number of chunk-index entries
//! fields  varint   number of distinct fields (informational)
//! snaps   varint   (v3 only) snapshot-table length
//! tag × snaps str  (v3 only) per-snapshot timestamp tag (may be empty)
//! entry × chunks:
//!     field        str     source field name
//!     chunk_index  varint  position of this chunk within its field
//!     chunk_count  varint  chunks in the field
//!     row_start    varint  } [start, end) along the split (slowest) axis
//!     row_end      varint  }
//!     ndim         varint  ≤ data::shape::MAX_DIMS
//!     dims[ndim]   varint  full field dims
//!     pipeline     str     canonical pipeline spec that compressed the
//!                          chunk (legacy artifacts carry registry aliases)
//!     offset       varint  payload-relative byte offset of the stream
//!     len          varint  stream length in bytes
//!     crc32        u32 LE  (v2+) CRC-32/IEEE of the chunk stream
//!     snapshot     varint  (v3 only) snapshot-table index of this chunk
//!     flags        u8      (v3 only) bit 0: delta — the stream holds
//!                          residuals against the decoded (snapshot−1,
//!                          field, chunk_index) baseline
//! payload_len varint
//! index_crc32 u32 LE  (v3 only) CRC-32/IEEE of every byte above
//! payload     bytes   concatenated per-chunk `SZ3R` streams
//! ```
//!
//! v2 adds a per-chunk CRC-32 to every index entry, verified on every
//! payload fetch by the reader. v3 (current) adds the **snapshot axis**:
//! a tag table plus a per-entry snapshot id and delta flag, so one
//! artifact holds a whole time series and snapshot *k* chunks may be
//! stored as error-bounded residuals against the decoded snapshot *k−1*
//! baseline (see [`delta`] and
//! [`crate::coordinator::Coordinator::run_series_to_container`]) — plus
//! an **index checksum** verified at parse time, so a flipped index byte
//! (a delta flag, a snapshot id) errors instead of silently decoding
//! wrong data. v1 and v2 artifacts remain fully readable — they parse as
//! a single untagged snapshot 0 with no delta chunks. The full
//! byte-level specification lives in `docs/CONTAINER.md`.
//!
//! Every chunk stream is itself a complete self-describing `SZ3R` stream,
//! so the index's `pipeline` name is a dispatch/statistics shortcut that is
//! cross-checked against the inner header during decompression. All index
//! integers are validated against the declared payload extent (dim-count
//! cap, row-range sanity, offset bounds) before any allocation is sized
//! from them — [`read_index_meta`] needs only an index-covering *prefix*
//! of the artifact, which is what lets [`crate::reader::ContainerReader`]
//! open a multi-GB container without loading its payload.

pub mod adaptive;
pub mod delta;
pub mod fixtures;

pub use adaptive::{
    AdaptiveChunkSelector, ChunkSignals, OptimizeTarget, Selection, SelectionMode,
};

use crate::byteio::{ByteReader, ByteWriter};
use crate::coordinator::CompressedChunk;
use crate::data::Field;
use crate::error::{Result, SzError};
use crate::util::crc32::crc32;

/// Container magic (distinct from the per-stream `SZ3R`).
pub const CONTAINER_MAGIC: &[u8; 4] = b"SZ3C";
/// Original index layout (no per-chunk checksum).
pub const VERSION_V1: u8 = 1;
/// Adds a CRC-32 per chunk-index entry, verified on every fetch.
pub const VERSION_V2: u8 = 2;
/// Adds the snapshot axis: a tag table plus a per-entry snapshot id and
/// delta flag for multi-snapshot time-series artifacts.
pub const VERSION_V3: u8 = 3;
/// The version [`pack`] writes.
pub const CURRENT_VERSION: u8 = VERSION_V3;

/// Entry flag bit: the chunk stream holds residuals against the decoded
/// `(snapshot − 1, field, chunk_index)` baseline.
const FLAG_DELTA: u8 = 1;

/// True if `stream` starts with the container magic.
pub fn is_container(stream: &[u8]) -> bool {
    stream.len() >= 4 && &stream[..4] == CONTAINER_MAGIC
}

/// One chunk-index entry.
#[derive(Clone, Debug, PartialEq)]
pub struct ChunkEntry {
    /// Source field name.
    pub field: String,
    /// Position of this chunk within its field.
    pub chunk_index: usize,
    /// Chunks in the field.
    pub chunk_count: usize,
    /// Row range [start, end) along the split axis.
    pub rows: (usize, usize),
    /// Full field dims.
    pub field_dims: Vec<usize>,
    /// Pipeline that compressed this chunk — a canonical spec string
    /// (registry aliases in legacy artifacts); either form rebuilds
    /// through [`crate::pipeline::build`].
    pub pipeline: String,
    /// Payload-relative byte offset of the chunk stream.
    pub offset: usize,
    /// Chunk stream length in bytes.
    pub len: usize,
    /// CRC-32 of the chunk stream (`None` for v1 containers).
    pub crc32: Option<u32>,
    /// Snapshot this chunk belongs to (always 0 for v1/v2 artifacts).
    pub snapshot: usize,
    /// True if the stream holds residuals against the decoded
    /// `(snapshot − 1, field, chunk_index)` baseline (v3 only).
    pub delta: bool,
}

/// Parsed container index.
#[derive(Clone, Debug, Default)]
pub struct ContainerIndex {
    /// Chunk entries in delivery (seq) order.
    pub entries: Vec<ChunkEntry>,
    /// Per-snapshot timestamp tags, indexed by snapshot id. v1/v2
    /// artifacts parse as a single untagged snapshot.
    pub snapshots: Vec<String>,
}

impl ContainerIndex {
    /// Number of snapshots the artifact holds (1 for v1/v2).
    pub fn snapshot_count(&self) -> usize {
        self.snapshots.len()
    }

    /// Chunk counts per snapshot as `(total, delta)` pairs, indexed by
    /// snapshot id.
    pub fn per_snapshot(&self) -> Vec<(usize, usize)> {
        let mut out = vec![(0usize, 0usize); self.snapshots.len()];
        for e in &self.entries {
            if let Some(slot) = out.get_mut(e.snapshot) {
                slot.0 += 1;
                slot.1 += e.delta as usize;
            }
        }
        out
    }

    /// Distinct field names in order of first appearance.
    pub fn field_names(&self) -> Vec<&str> {
        let mut out: Vec<&str> = Vec::new();
        for e in &self.entries {
            if !out.contains(&e.field.as_str()) {
                out.push(&e.field);
            }
        }
        out
    }

    /// Chunk counts per pipeline name, deterministically ordered (sorted by
    /// pipeline name via `BTreeMap`) so `sz3 info` output and tests are
    /// stable across runs regardless of worker scheduling.
    pub fn per_pipeline(&self) -> Vec<(String, usize)> {
        let mut map = std::collections::BTreeMap::new();
        for e in &self.entries {
            *map.entry(e.pipeline.clone()).or_insert(0usize) += 1;
        }
        map.into_iter().collect()
    }
}

/// Index metadata parsed from an artifact *prefix*: everything before the
/// payload bytes. Unlike [`read_index`], producing this does not require
/// the payload to be present, so seekable sources can fetch chunks lazily.
#[derive(Clone, Debug)]
pub struct IndexMeta {
    /// The parsed chunk index.
    pub index: ContainerIndex,
    /// Container format version (1, 2 or 3).
    pub version: u8,
    /// Absolute byte offset where the payload begins.
    pub payload_offset: usize,
    /// Declared payload length in bytes.
    pub payload_len: u64,
}

/// Pack ordered coordinator chunks into a container artifact (current
/// version). Snapshot tags default to empty strings, one per snapshot id
/// the chunks reference; use [`pack_series`] to name them.
///
/// All chunks of a `(snapshot, field)` must carry the same
/// `field_dims`/`chunk_count` (the coordinator guarantees this); ordering
/// within the buffer is free since decompression sorts by `chunk_index`.
pub fn pack(chunks: &[CompressedChunk]) -> Result<Vec<u8>> {
    let snaps = chunks.iter().map(|c| c.snapshot + 1).max().unwrap_or(1);
    pack_with(chunks, CURRENT_VERSION, &vec![String::new(); snaps])
}

/// Pack a multi-snapshot series with explicit per-snapshot tags (v3).
pub fn pack_series(chunks: &[CompressedChunk], tags: &[String]) -> Result<Vec<u8>> {
    pack_with(chunks, VERSION_V3, tags)
}

/// Pack in the legacy v1 layout (no checksums). Kept for compatibility
/// testing and for producing artifacts older readers understand.
pub fn pack_v1(chunks: &[CompressedChunk]) -> Result<Vec<u8>> {
    pack_with(chunks, VERSION_V1, &[String::new()])
}

/// Pack in the legacy v2 layout (CRC-32 per chunk, no snapshot axis).
pub fn pack_v2(chunks: &[CompressedChunk]) -> Result<Vec<u8>> {
    pack_with(chunks, VERSION_V2, &[String::new()])
}

fn pack_with(chunks: &[CompressedChunk], version: u8, tags: &[String]) -> Result<Vec<u8>> {
    if version < VERSION_V1 || version > VERSION_V3 {
        return Err(SzError::config(format!("cannot pack container version {version}")));
    }
    if tags.is_empty() {
        return Err(SzError::config("container needs ≥ 1 snapshot tag"));
    }
    if version < VERSION_V3 {
        if tags.len() > 1 || chunks.iter().any(|c| c.snapshot != 0 || c.delta) {
            return Err(SzError::config(format!(
                "container v{version} cannot encode snapshots or delta chunks"
            )));
        }
    }
    // Reject chunk sets that could never decode — duplicate chunk indices
    // (two source fields sharing a name), a count that disagrees with the
    // declared chunk_count, or a delta chunk with no baseline — instead of
    // emitting a poison artifact.
    let mut fields: Vec<&str> = Vec::new();
    let mut seen: std::collections::HashMap<(usize, &str), (usize, Vec<bool>)> =
        std::collections::HashMap::new();
    for c in chunks {
        if !fields.contains(&c.field.as_str()) {
            fields.push(&c.field);
        }
        if c.snapshot >= tags.len() {
            return Err(SzError::config(format!(
                "field '{}': snapshot {} outside the {}-entry snapshot table",
                c.field,
                c.snapshot,
                tags.len()
            )));
        }
        let (count, got) = seen
            .entry((c.snapshot, &c.field))
            .or_insert_with(|| (c.chunk_count, vec![false; c.chunk_count]));
        if c.chunk_count != *count || c.chunk_index >= *count {
            return Err(SzError::config(format!(
                "field '{}': chunk {}/{} disagrees with count {count}",
                c.field, c.chunk_index, c.chunk_count
            )));
        }
        let duplicate = match got.get_mut(c.chunk_index) {
            Some(slot) => std::mem::replace(slot, true),
            None => true,
        };
        if duplicate {
            return Err(SzError::config(format!(
                "field '{}': duplicate chunk index {} (two source fields \
                 with the same name?)",
                c.field, c.chunk_index
            )));
        }
    }
    for ((snap, name), (count, got)) in &seen {
        if got.iter().filter(|&&g| g).count() != *count {
            return Err(SzError::config(format!(
                "snapshot {snap} field '{name}': packed {} of {count} chunks",
                got.iter().filter(|&&g| g).count()
            )));
        }
    }
    for c in chunks {
        if !c.delta {
            continue;
        }
        if c.snapshot == 0 {
            return Err(SzError::config(format!(
                "field '{}': snapshot 0 cannot be delta-encoded (no baseline)",
                c.field
            )));
        }
        let baseline = chunks.iter().find(|b| {
            b.snapshot == c.snapshot - 1
                && b.field == c.field
                && b.chunk_index == c.chunk_index
        });
        match baseline {
            Some(b) if b.rows == c.rows && b.field_dims == c.field_dims => {}
            _ => {
                return Err(SzError::config(format!(
                    "field '{}': delta chunk {} of snapshot {} has no matching \
                     baseline in snapshot {}",
                    c.field,
                    c.chunk_index,
                    c.snapshot,
                    c.snapshot - 1
                )))
            }
        }
    }
    let mut w = ByteWriter::new();
    w.put_bytes(CONTAINER_MAGIC);
    w.put_u8(version);
    w.put_varint(chunks.len() as u64);
    w.put_varint(fields.len() as u64);
    if version >= VERSION_V3 {
        w.put_varint(tags.len() as u64);
        for t in tags {
            w.put_str(t);
        }
    }
    let mut offset = 0usize;
    for c in chunks {
        w.put_str(&c.field);
        w.put_varint(c.chunk_index as u64);
        w.put_varint(c.chunk_count as u64);
        w.put_varint(c.rows.0 as u64);
        w.put_varint(c.rows.1 as u64);
        w.put_varint(c.field_dims.len() as u64);
        for &d in &c.field_dims {
            w.put_varint(d as u64);
        }
        w.put_str(&c.pipeline);
        w.put_varint(offset as u64);
        w.put_varint(c.stream.len() as u64);
        if version >= VERSION_V2 {
            w.put_u32(crc32(&c.stream));
        }
        if version >= VERSION_V3 {
            w.put_varint(c.snapshot as u64);
            w.put_u8(if c.delta { FLAG_DELTA } else { 0 });
        }
        offset += c.stream.len();
    }
    w.put_varint(offset as u64);
    let mut bytes = w.finish();
    if version >= VERSION_V3 {
        // v3: checksum the whole index (magic through payload_len) so a
        // flipped snapshot id, delta flag, or tag byte can never decode
        // silently-wrong data — the per-chunk CRCs only cover payloads
        let c = crc32(&bytes);
        bytes.extend_from_slice(&c.to_le_bytes());
    }
    for c in chunks {
        bytes.extend_from_slice(&c.stream);
    }
    Ok(bytes)
}

/// Parse and validate the chunk index from an artifact prefix; the payload
/// bytes need not be present. Chunk extents are validated against the
/// *declared* payload length, so a lazily-fetching reader can trust the
/// offsets before it has read a single payload byte.
fn varint_usize(r: &mut ByteReader<'_>, what: &str) -> Result<usize> {
    usize::try_from(r.get_varint()?)
        .map_err(|_| SzError::corrupt(format!("{what} exceeds this platform's usize")))
}

pub fn read_index_meta(prefix: &[u8]) -> Result<IndexMeta> {
    let mut r = ByteReader::new(prefix);
    let magic = r.get_bytes(4)?;
    if magic != CONTAINER_MAGIC {
        return Err(SzError::corrupt("bad container magic"));
    }
    let version = r.get_u8()?;
    if version < VERSION_V1 || version > VERSION_V3 {
        return Err(SzError::corrupt(format!("unsupported container version {version}")));
    }
    let n_chunks = varint_usize(&mut r, "chunk count")?;
    // Every entry consumes ≥ 1 byte, so the remaining length bounds the
    // plausible entry count — reject before growing any allocation. The
    // exhaustion-shaped message matters: on a short *prefix* of a valid
    // large index this is a retry-with-more-bytes condition
    // (`SzError::is_exhaustion`), not a verdict of corruption.
    if n_chunks > r.remaining() {
        return Err(SzError::corrupt(format!(
            "need {n_chunks} index entries, have {} bytes",
            r.remaining()
        )));
    }
    let _n_fields = r.get_varint()?;
    let snapshots = if version >= VERSION_V3 {
        let n_snaps = varint_usize(&mut r, "snapshot count")?;
        if n_snaps == 0 {
            return Err(SzError::corrupt("v3 container declares no snapshots"));
        }
        if n_snaps > r.remaining() {
            return Err(SzError::corrupt(format!(
                "need {n_snaps} snapshot tags, have {} bytes",
                r.remaining()
            )));
        }
        let mut tags = Vec::with_capacity(n_snaps);
        for _ in 0..n_snaps {
            tags.push(r.get_str()?);
        }
        tags
    } else {
        // v1/v2: a single implicit untagged snapshot, so every caller can
        // treat the snapshot axis uniformly
        vec![String::new()]
    };
    let mut entries = Vec::new();
    for _ in 0..n_chunks {
        let field = r.get_str()?;
        let chunk_index = varint_usize(&mut r, "chunk index")?;
        let chunk_count = varint_usize(&mut r, "chunk count")?;
        let row_start = varint_usize(&mut r, "row start")?;
        let row_end = varint_usize(&mut r, "row end")?;
        let nd = varint_usize(&mut r, "dim count")?;
        if nd == 0 || nd > crate::data::shape::MAX_DIMS {
            return Err(SzError::corrupt(format!(
                "index dim count {nd} outside 1..={}",
                crate::data::shape::MAX_DIMS
            )));
        }
        let mut field_dims = Vec::with_capacity(nd);
        for _ in 0..nd {
            field_dims.push(varint_usize(&mut r, "field dim")?);
        }
        let pipeline = r.get_str()?;
        let offset = varint_usize(&mut r, "chunk offset")?;
        let len = varint_usize(&mut r, "chunk length")?;
        let crc = if version >= VERSION_V2 { Some(r.get_u32()?) } else { None };
        let (snapshot, delta) = if version >= VERSION_V3 {
            let snapshot = varint_usize(&mut r, "chunk snapshot")?;
            let flags = r.get_u8()?;
            if flags & !FLAG_DELTA != 0 {
                return Err(SzError::corrupt(format!(
                    "unknown chunk flags {flags:#04x}"
                )));
            }
            if snapshot >= snapshots.len() {
                return Err(SzError::corrupt(format!(
                    "chunk snapshot {snapshot} outside the {}-entry table",
                    snapshots.len()
                )));
            }
            let delta = flags & FLAG_DELTA != 0;
            if delta && snapshot == 0 {
                return Err(SzError::corrupt(
                    "snapshot 0 chunk flagged delta (no baseline exists)",
                ));
            }
            (snapshot, delta)
        } else {
            (0, false)
        };
        if chunk_count == 0 || chunk_index >= chunk_count {
            return Err(SzError::corrupt(format!(
                "chunk index {chunk_index} outside count {chunk_count}"
            )));
        }
        if row_start >= row_end || row_end > field_dims[0] {
            return Err(SzError::corrupt(format!(
                "row range [{row_start}, {row_end}) invalid for {} rows",
                field_dims[0]
            )));
        }
        entries.push(ChunkEntry {
            field,
            chunk_index,
            chunk_count,
            rows: (row_start, row_end),
            field_dims,
            pipeline,
            offset,
            len,
            crc32: crc,
            snapshot,
            delta,
        });
    }
    let payload_len = r.get_varint()?;
    if version >= VERSION_V3 {
        let covered = r.pos();
        let got = r.get_u32()?;
        let covered_bytes = prefix
            .get(..covered)
            .ok_or_else(|| SzError::corrupt("index crc range outside prefix"))?;
        let expect = crc32(covered_bytes);
        if got != expect {
            return Err(SzError::corrupt(format!(
                "index crc32 mismatch (stored {got:#010x}, computed {expect:#010x})"
            )));
        }
    }
    let payload_offset = r.pos();
    for e in &entries {
        let end = e
            .offset
            .checked_add(e.len)
            .ok_or_else(|| SzError::corrupt("chunk extent overflows"))?;
        if end as u64 > payload_len {
            return Err(SzError::corrupt(format!(
                "chunk [{}..{end}) outside payload of {payload_len} bytes",
                e.offset
            )));
        }
    }
    Ok(IndexMeta {
        index: ContainerIndex { entries, snapshots },
        version,
        payload_offset,
        payload_len,
    })
}

/// Human-readable artifact summary — the exact lines `sz3 info` prints.
/// Living in the library (not `main.rs`) lets a test lock the v1/v2
/// output byte-for-byte across format bumps.
pub fn describe(meta: &IndexMeta) -> String {
    let index = &meta.index;
    let mut out = String::new();
    if meta.version >= VERSION_V3 {
        out.push_str(&format!(
            "container v{}: {} chunks, {} fields, {} snapshots, payload {} \
             bytes, per-chunk crc32\n",
            meta.version,
            index.entries.len(),
            index.field_names().len(),
            index.snapshot_count(),
            meta.payload_len,
        ));
        for (id, ((total, delta), tag)) in
            index.per_snapshot().iter().zip(&index.snapshots).enumerate()
        {
            let label =
                if tag.is_empty() { String::new() } else { format!(" '{tag}'") };
            out.push_str(&format!(
                "  snapshot {id}{label}: {total} chunks, {delta} delta\n"
            ));
        }
    } else {
        out.push_str(&format!(
            "container v{}: {} chunks, {} fields, payload {} bytes{}\n",
            meta.version,
            index.entries.len(),
            index.field_names().len(),
            meta.payload_len,
            if meta.version >= VERSION_V2 { ", per-chunk crc32" } else { ", no checksums" }
        ));
    }
    for (p, n) in index.per_pipeline() {
        out.push_str(&format!("  pipeline {p}: {n} chunks\n"));
    }
    for e in &index.entries {
        let prefix = if meta.version >= VERSION_V3 {
            format!("s{} ", e.snapshot)
        } else {
            String::new()
        };
        out.push_str(&format!(
            "  {prefix}{}[{}/{}] rows {}..{} dims {:?} via {} ({} bytes){}\n",
            e.field,
            e.chunk_index.saturating_add(1),
            e.chunk_count,
            e.rows.0,
            e.rows.1,
            e.field_dims,
            e.pipeline,
            e.len,
            if e.delta { ", delta" } else { "" }
        ));
    }
    out
}

/// Parse and validate the chunk index of a fully-resident artifact;
/// returns the index and the payload slice. Reads v1 through v3.
pub fn read_index(stream: &[u8]) -> Result<(ContainerIndex, &[u8])> {
    let meta = read_index_meta(stream)?;
    let avail = stream.len() - meta.payload_offset;
    if meta.payload_len > avail as u64 {
        return Err(SzError::corrupt(format!(
            "need {} payload bytes, have {avail}",
            meta.payload_len
        )));
    }
    let plen = usize::try_from(meta.payload_len)
        .map_err(|_| SzError::corrupt("payload length exceeds this platform's usize"))?;
    let payload = meta
        .payload_offset
        .checked_add(plen)
        .and_then(|end| stream.get(meta.payload_offset..end))
        .ok_or_else(|| SzError::corrupt("payload extent outside stream"))?;
    Ok((meta.index, payload))
}

/// Decompress a fully-resident container: routed through
/// [`crate::reader::ContainerReader`] (the single seek/verify/decode code
/// path — chunks fan out across `workers` threads, every v2 chunk is
/// CRC-checked, each stream's inner header is cross-checked against the
/// index, and fields reassemble with shape verification). Fields are
/// returned in order of first appearance in the index.
pub fn decompress_container(stream: &[u8], workers: usize) -> Result<Vec<Field>> {
    crate::reader::ContainerReader::from_slice(stream)?
        .with_workers(workers)
        .read_all()
}

/// Decompress a container whose exactly-one field is wanted (the
/// [`crate::pipeline::decompress_any`] path); parses the index once for
/// both the field-count check and the decode.
pub fn decompress_single_field(stream: &[u8], workers: usize) -> Result<Field> {
    let reader =
        crate::reader::ContainerReader::from_slice(stream)?.with_workers(workers);
    let snaps = reader.snapshot_count();
    if snaps != 1 {
        return Err(SzError::config(format!(
            "container holds {snaps} snapshots; use container::decompress_container"
        )));
    }
    let n = reader.field_names().len();
    if n != 1 {
        return Err(SzError::config(format!(
            "container holds {n} fields; use container::decompress_container"
        )));
    }
    reader
        .read_all()?
        .pop()
        .ok_or_else(|| SzError::corrupt("container decoded no fields"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::JobConfig;
    use crate::coordinator::Coordinator;
    use crate::pipeline::ErrorBound;
    use crate::util::{prop, rng::Pcg32};

    fn sample_chunks(n_fields: usize) -> Vec<CompressedChunk> {
        let cfg = JobConfig {
            pipeline: "sz3-lr".into(),
            bound: ErrorBound::Abs(1e-3),
            workers: 2,
            chunk_elems: 512, // 3 rows of 12x12 per chunk -> 4 chunks per field
            queue_depth: 2,
            ..Default::default()
        };
        let coord = Coordinator::from_config(&cfg).unwrap();
        let mut rng = Pcg32::seeded(91);
        let fields: Vec<Field> = (0..n_fields)
            .map(|i| {
                let dims = [10usize, 12, 12];
                Field::f32(format!("f{i}"), &dims, prop::smooth_field(&mut rng, &dims))
                    .unwrap()
            })
            .collect();
        let mut chunks = Vec::new();
        coord.run(fields, |c| chunks.push(c)).unwrap();
        chunks
    }

    #[test]
    fn index_roundtrips() {
        let chunks = sample_chunks(2);
        let packed = pack(&chunks).unwrap();
        assert!(is_container(&packed));
        let (index, payload) = read_index(&packed).unwrap();
        assert_eq!(index.entries.len(), chunks.len());
        assert_eq!(index.field_names(), vec!["f0", "f1"]);
        let total: usize = chunks.iter().map(|c| c.stream.len()).sum();
        assert_eq!(payload.len(), total);
        for (e, c) in index.entries.iter().zip(&chunks) {
            assert_eq!(e.field, c.field);
            assert_eq!(e.rows, c.rows);
            assert_eq!(e.pipeline, c.pipeline);
            assert_eq!(e.crc32, Some(crc32(&c.stream)));
            assert_eq!(&payload[e.offset..e.offset + e.len], &c.stream[..]);
        }
    }

    #[test]
    fn v1_packs_without_checksums_and_still_reads() {
        let chunks = sample_chunks(1);
        let packed = pack_v1(&chunks).unwrap();
        let meta = read_index_meta(&packed).unwrap();
        assert_eq!(meta.version, VERSION_V1);
        assert!(meta.index.entries.iter().all(|e| e.crc32.is_none()));
        let fields = decompress_container(&packed, 2).unwrap();
        assert_eq!(fields.len(), 1);
        assert_eq!(fields[0].shape.dims(), &[10, 12, 12]);
    }

    #[test]
    fn index_meta_parses_from_prefix_only() {
        let chunks = sample_chunks(1);
        let packed = pack(&chunks).unwrap();
        let meta = read_index_meta(&packed).unwrap();
        assert_eq!(meta.version, VERSION_V3);
        assert_eq!(meta.index.snapshots, vec![String::new()]);
        // the payload is NOT needed: a prefix ending right at payload_offset
        // parses identically
        let prefix = &packed[..meta.payload_offset];
        let m2 = read_index_meta(prefix).unwrap();
        assert_eq!(m2.payload_offset, meta.payload_offset);
        assert_eq!(m2.payload_len, meta.payload_len);
        assert_eq!(m2.index.entries, meta.index.entries);
        assert_eq!(
            meta.payload_offset as u64 + meta.payload_len,
            packed.len() as u64
        );
    }

    #[test]
    fn container_decompress_matches_per_chunk_decode() {
        let chunks = sample_chunks(2);
        let packed = pack(&chunks).unwrap();
        let fields = decompress_container(&packed, 4).unwrap();
        assert_eq!(fields.len(), 2);
        for f in &fields {
            assert_eq!(f.shape.dims(), &[10, 12, 12]);
        }
    }

    #[test]
    fn empty_container_roundtrips() {
        let packed = pack(&[]).unwrap();
        assert!(decompress_container(&packed, 4).unwrap().is_empty());
    }

    #[test]
    fn per_pipeline_deterministically_sorted() {
        let index = ContainerIndex {
            entries: ["zzz", "aaa", "mmm", "aaa"]
                .iter()
                .enumerate()
                .map(|(i, p)| ChunkEntry {
                    field: "f".into(),
                    chunk_index: i,
                    chunk_count: 4,
                    rows: (i, i + 1),
                    field_dims: vec![4],
                    pipeline: p.to_string(),
                    offset: 0,
                    len: 0,
                    crc32: None,
                    snapshot: 0,
                    delta: false,
                })
                .collect(),
            snapshots: vec![String::new()],
        };
        let mix = index.per_pipeline();
        assert_eq!(
            mix,
            vec![("aaa".into(), 2), ("mmm".into(), 1), ("zzz".into(), 1)],
            "per_pipeline must be sorted by name, independent of entry order"
        );
    }

    #[test]
    fn corrupt_containers_error_not_panic() {
        let chunks = sample_chunks(1);
        let packed = pack(&chunks).unwrap();
        // truncations at many offsets
        for cut in [4usize, 6, packed.len() / 3, packed.len() - 2] {
            let r = std::panic::catch_unwind(|| decompress_container(&packed[..cut], 2));
            match r {
                Ok(Err(_)) => {}
                Ok(Ok(_)) => panic!("truncated container decoded (cut={cut})"),
                Err(_) => panic!("panic on truncated container (cut={cut})"),
            }
        }
        // adversarial chunk count
        let mut bad = packed.clone();
        bad[5] = 0xff; // first byte of the chunk-count varint
        bad[6] = 0xff;
        let r = std::panic::catch_unwind(|| decompress_container(&bad, 2));
        assert!(matches!(r, Ok(Err(_))), "huge chunk count must error cleanly");
    }

    #[test]
    fn incomplete_or_colliding_chunk_sets_rejected_at_pack() {
        let mut chunks = sample_chunks(1);
        assert!(chunks.len() > 1, "need multiple chunks");
        // missing chunk: the artifact could never decode, refuse to emit it
        let dropped = chunks.pop().unwrap();
        let err = pack(&chunks).unwrap_err();
        assert!(err.to_string().contains("chunks"), "{err}");
        // duplicate chunk index (two source fields sharing a name)
        chunks.push(dropped.clone());
        chunks.push(dropped);
        let err = pack(&chunks).unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    #[test]
    fn v3_series_index_roundtrips_snapshot_table() {
        // two snapshots of the same field: snapshot 1 flagged delta
        let base = sample_chunks(1);
        let mut chunks = base.clone();
        for c in base {
            chunks.push(CompressedChunk { snapshot: 1, delta: true, ..c });
        }
        let tags = vec!["t0".to_string(), "t1".to_string()];
        let packed = pack_series(&chunks, &tags).unwrap();
        let meta = read_index_meta(&packed).unwrap();
        assert_eq!(meta.version, VERSION_V3);
        assert_eq!(meta.index.snapshots, tags);
        assert_eq!(meta.index.snapshot_count(), 2);
        let per = meta.index.per_snapshot();
        assert_eq!(per.len(), 2);
        assert_eq!(per[0], (4, 0), "snapshot 0: 4 direct chunks");
        assert_eq!(per[1], (4, 4), "snapshot 1: 4 delta chunks");
        for e in &meta.index.entries {
            assert_eq!(e.delta, e.snapshot == 1);
        }
    }

    #[test]
    fn pack_rejects_unencodable_snapshot_layouts() {
        let base = sample_chunks(1);
        // legacy versions cannot encode the snapshot axis
        let mut series = base.clone();
        series.push(CompressedChunk { snapshot: 1, ..base[0].clone() });
        assert!(pack_v1(&base).is_ok());
        assert!(pack_v2(&base).is_ok());
        let err = pack_with(&series, VERSION_V2, &[String::new()]).unwrap_err();
        assert!(err.to_string().contains("cannot encode"), "{err}");
        // snapshot id outside the tag table
        let err = pack_series(&series, &["only".to_string()]).unwrap_err();
        assert!(err.to_string().contains("snapshot table"), "{err}");
        // delta on snapshot 0
        let mut bad = base.clone();
        bad[0].delta = true;
        let err = pack(&bad).unwrap_err();
        assert!(err.to_string().contains("no baseline"), "{err}");
        // delta with a baseline whose rows disagree
        let mut chunks = base.clone();
        for c in &base {
            let mut d = c.clone();
            d.snapshot = 1;
            d.delta = true;
            chunks.push(d);
        }
        chunks.last_mut().unwrap().rows = (0, 1);
        let err =
            pack_series(&chunks, &[String::new(), String::new()]).unwrap_err();
        assert!(err.to_string().contains("matching baseline"), "{err}");
    }

    #[test]
    fn describe_output_is_byte_stable_for_legacy_versions() {
        // regression lock: the v3 format bump must not change what
        // `sz3 info` prints for v1/v2 artifacts (the pipeline column shows
        // whatever string the index carries — canonical specs for current
        // artifacts, registry aliases for truly old ones)
        let canon = crate::pipeline::canonical("sz3-lr").unwrap();
        let chunks: Vec<CompressedChunk> = sample_chunks(1)
            .into_iter()
            .map(|c| CompressedChunk { stream: vec![0u8; 10], ..c })
            .collect();
        assert!(chunks.iter().all(|c| c.pipeline == canon));
        let v1 = describe(&read_index_meta(&pack_v1(&chunks).unwrap()).unwrap());
        assert!(
            v1.starts_with(
                "container v1: 4 chunks, 1 fields, payload 40 bytes, no checksums\n"
            ),
            "{v1}"
        );
        let v2 = describe(&read_index_meta(&pack_v2(&chunks).unwrap()).unwrap());
        assert!(
            v2.starts_with(
                "container v2: 4 chunks, 1 fields, payload 40 bytes, per-chunk crc32\n"
            ),
            "{v2}"
        );
        for out in [&v1, &v2] {
            assert!(out.contains(&format!("  pipeline {canon}: 4 chunks\n")), "{out}");
            assert!(
                out.contains(&format!(
                    "  f0[1/4] rows 0..3 dims [10, 12, 12] via {canon} (10 bytes)\n"
                )),
                "{out}"
            );
            assert!(!out.contains("snapshot"), "legacy info must not mention snapshots");
            assert!(!out.contains("delta"), "{out}");
        }
        // v3 output is snapshot-aware
        let v3 = describe(&read_index_meta(&pack(&chunks).unwrap()).unwrap());
        assert!(v3.contains("1 snapshots"), "{v3}");
        assert!(v3.contains("  snapshot 0: 4 chunks, 0 delta\n"), "{v3}");
        assert!(v3.contains("  s0 f0[1/4]"), "{v3}");
    }

    #[test]
    fn missing_chunk_detected_on_decode() {
        // hand-craft a v1 index claiming 4 chunks but carrying only the
        // first, bypassing pack()'s validation: coverage validation in the
        // reader must refuse
        let c = sample_chunks(1).remove(0);
        assert_eq!((c.chunk_count, c.rows), (4, (0, 3)));
        let mut w = ByteWriter::new();
        w.put_bytes(CONTAINER_MAGIC);
        w.put_u8(1);
        w.put_varint(1); // one entry…
        w.put_varint(1);
        w.put_str(&c.field);
        w.put_varint(c.chunk_index as u64);
        w.put_varint(c.chunk_count as u64); // …of a declared four
        w.put_varint(c.rows.0 as u64);
        w.put_varint(c.rows.1 as u64);
        w.put_varint(c.field_dims.len() as u64);
        for &d in &c.field_dims {
            w.put_varint(d as u64);
        }
        w.put_str(&c.pipeline);
        w.put_varint(0);
        w.put_varint(c.stream.len() as u64);
        w.put_varint(c.stream.len() as u64);
        w.put_bytes(&c.stream);
        let err = decompress_container(&w.finish(), 2).unwrap_err();
        assert!(err.to_string().contains("chunks"), "{err}");
    }
}
