//! From-scratch gzip-class lossless backend: LZ77 (hash-chain match finder,
//! 64 KiB window) followed by canonical Huffman coding of the token and
//! distance streams. Built so the framework has a fully self-contained
//! lossless stage independent of external libraries.
//!
//! Stream layout:
//!   varint original_len
//!   varint n_tokens
//!   huffman(tokens)    — 0..=255 literal byte; 256+k match of length 4+k
//!   huffman(dist_hi)   — one per match: distance high byte
//!   huffman(dist_lo)   — one per match: distance low byte

use super::Lossless;
use crate::byteio::{ByteReader, ByteWriter};
use crate::encoder::{Encoder, HuffmanEncoder};
use crate::error::{Result, SzError};

const MIN_MATCH: usize = 4;
const MAX_MATCH: usize = 4 + 255; // length symbol fits in 256..=511
const WINDOW: usize = 1 << 16;
const HASH_BITS: u32 = 15;

/// LZ77 + Huffman backend.
#[derive(Clone)]
pub struct LzHuf {
    /// Max hash-chain probes per position (speed/ratio knob).
    pub max_chain: usize,
}

impl Default for LzHuf {
    fn default() -> Self {
        LzHuf { max_chain: 32 }
    }
}

/// Hash the 4-byte window at `i`; `None` when fewer than 4 bytes remain.
#[inline]
fn hash4(data: &[u8], i: usize) -> Option<usize> {
    let w = data.get(i..)?.get(..4)?;
    let v = u32::from_le_bytes([w[0], w[1], w[2], w[3]]);
    Some((v.wrapping_mul(2654435761) >> (32 - HASH_BITS)) as usize)
}

impl LzHuf {
    /// Tokenize `data` into (tokens, distances).
    fn tokenize(&self, data: &[u8]) -> (Vec<u32>, Vec<u32>) {
        let n = data.len();
        let mut tokens = Vec::with_capacity(n / 2);
        let mut dists = Vec::new();
        if n < MIN_MATCH {
            tokens.extend(data.iter().map(|&b| b as u32));
            return (tokens, dists);
        }
        let mut head = vec![usize::MAX; 1 << HASH_BITS];
        let mut prev = vec![usize::MAX; n];
        let mut i = 0usize;
        while let Some(&byte) = data.get(i) {
            let mut best_len = 0usize;
            let mut best_dist = 0usize;
            if let Some(h) = hash4(data, i) {
                let mut cand = head.get(h).copied().unwrap_or(usize::MAX);
                let mut chain = self.max_chain;
                while cand != usize::MAX && chain > 0 && i - cand <= WINDOW {
                    // candidate match length: compare the windows at `cand`
                    // and `i`; zip stops at the shorter tail on its own
                    let limit = (n - i).min(MAX_MATCH);
                    let back = data.get(cand..).unwrap_or(&[]);
                    let ahead = data.get(i..).unwrap_or(&[]);
                    let l = back
                        .iter()
                        .zip(ahead)
                        .take(limit)
                        .take_while(|&(a, b)| a == b)
                        .count();
                    if l > best_len {
                        best_len = l;
                        best_dist = i - cand;
                        if l >= limit {
                            break;
                        }
                    }
                    cand = prev.get(cand).copied().unwrap_or(usize::MAX);
                    chain -= 1;
                }
                if let Some(slot) = prev.get_mut(i) {
                    *slot = head.get(h).copied().unwrap_or(usize::MAX);
                }
                if let Some(slot) = head.get_mut(h) {
                    *slot = i;
                }
            }
            if best_len >= MIN_MATCH {
                tokens.push(256 + (best_len - MIN_MATCH) as u32);
                dists.push(best_dist as u32);
                // insert hash entries for covered positions (sparsely for speed)
                let end = i.saturating_add(best_len);
                let mut j = i + 1;
                while j < end {
                    let Some(h) = hash4(data, j) else { break };
                    if let Some(slot) = prev.get_mut(j) {
                        *slot = head.get(h).copied().unwrap_or(usize::MAX);
                    }
                    if let Some(slot) = head.get_mut(h) {
                        *slot = j;
                    }
                    j += 1;
                }
                i = end;
            } else {
                tokens.push(byte as u32);
                i += 1;
            }
        }
        (tokens, dists)
    }
}

impl Lossless for LzHuf {
    fn name(&self) -> &'static str {
        "lzhuf"
    }

    fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        let (tokens, dists) = self.tokenize(data);
        let huff = HuffmanEncoder::new();
        let mut w = ByteWriter::new();
        w.put_varint(data.len() as u64);
        w.put_varint(tokens.len() as u64);
        huff.encode(&tokens, &mut w)?;
        let hi: Vec<u32> = dists.iter().map(|&d| d >> 8).collect();
        let lo: Vec<u32> = dists.iter().map(|&d| d & 0xff).collect();
        huff.encode(&hi, &mut w)?;
        huff.encode(&lo, &mut w)?;
        Ok(w.finish())
    }

    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
        let mut r = ByteReader::new(data);
        let orig_len = usize::try_from(r.get_varint()?)
            .map_err(|_| SzError::corrupt("lzhuf: stored length exceeds this platform's usize"))?;
        let n_tokens = usize::try_from(r.get_varint()?)
            .map_err(|_| SzError::corrupt("lzhuf: token count exceeds this platform's usize"))?;
        let huff = HuffmanEncoder::new();
        let tokens = huff.decode(&mut r, n_tokens)?;
        let n_matches = tokens.iter().filter(|&&t| t >= 256).count();
        let hi = huff.decode(&mut r, n_matches)?;
        let lo = huff.decode(&mut r, n_matches)?;
        // every token emits at most MAX_MATCH bytes — reject a claimed
        // length the token stream cannot produce before allocating for it
        if orig_len > tokens.len().saturating_mul(MAX_MATCH) {
            return Err(SzError::corrupt("lzhuf: stored length exceeds token capacity"));
        }
        let mut out = Vec::with_capacity(orig_len);
        let mut m = 0usize;
        for &t in &tokens {
            if t < 256 {
                out.push(t as u8);
            } else {
                let len = MIN_MATCH + (t - 256) as usize;
                let (Some(&dh), Some(&dl)) = (hi.get(m), lo.get(m)) else {
                    return Err(SzError::corrupt("lzhuf: missing match distance"));
                };
                m += 1;
                // widen before the shift: a corrupt distance stream can
                // decode symbols ≥ 2^24, which `u32 << 8` would overflow
                let dist = ((dh as usize) << 8) | dl as usize;
                if dist == 0 || dist > out.len() {
                    return Err(SzError::corrupt("lzhuf: bad match distance"));
                }
                let start = out.len() - dist;
                for k in 0..len {
                    // start < out.len() and each push grows the buffer, so
                    // the overlapping-copy cursor never outruns it
                    let b = out.get(start + k).copied().unwrap_or(0);
                    out.push(b);
                }
            }
        }
        if out.len() != orig_len {
            return Err(SzError::corrupt(format!(
                "lzhuf: expected {orig_len} bytes, produced {}",
                out.len()
            )));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lossless::test_support::roundtrip;
    use crate::util::prop;

    #[test]
    fn overlapping_match_copies() {
        // "aaaaaaaa..." forces dist=1 overlapping copies (RLE-via-LZ).
        let l = LzHuf::default();
        let data = vec![b'a'; 5000];
        let size = roundtrip(&l, &data);
        assert!(size < 100, "run of a's should collapse, got {size}");
    }

    #[test]
    fn text_like_data_compresses() {
        let l = LzHuf::default();
        let data: Vec<u8> = "the quick brown fox jumps over the lazy dog. "
            .repeat(200)
            .into_bytes();
        let size = roundtrip(&l, &data);
        assert!(size < data.len() / 5, "got {size} of {}", data.len());
    }

    #[test]
    fn prop_roundtrip_structured_and_random() {
        prop::cases(25, 0x12f, |rng| {
            let l = LzHuf::default();
            let n = rng.below(40000);
            roundtrip(&l, &prop::vec_u8(rng, n % 5000));
            roundtrip(&l, &prop::compressible_u8(rng, n));
        });
    }

    #[test]
    fn max_match_boundary() {
        let l = LzHuf::default();
        for n in [MIN_MATCH - 1, MIN_MATCH, MAX_MATCH, MAX_MATCH + 1, 2 * MAX_MATCH + 3] {
            let data = vec![0x5au8; n];
            roundtrip(&l, &data);
        }
    }

    #[test]
    fn corrupt_stream_rejected() {
        let l = LzHuf::default();
        let c = l.compress(b"hello world hello world hello world").unwrap();
        assert!(l.decompress(&c[..c.len() / 2]).is_err());
    }
}
