//! Byte run-length backend — the fast path for streams dominated by runs
//! (e.g. the zero-heavy bitplane output of the unpred-aware quantizer).
//!
//! Format: records of `control` byte —
//!   `c < 128`  : copy the next `c + 1` literal bytes
//!   `c >= 128` : repeat the next byte `c - 128 + RUN_MIN` times

use super::Lossless;
use crate::error::{Result, SzError};

const RUN_MIN: usize = 4;
const RUN_MAX: usize = 127 + RUN_MIN; // 131
const LIT_MAX: usize = 128;

/// Byte RLE codec.
#[derive(Default, Clone)]
pub struct Rle;

impl Lossless for Rle {
    fn name(&self) -> &'static str {
        "rle"
    }

    fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(data.len() / 4 + 16);
        let n = data.len();
        let mut i = 0usize;
        let mut lit_start = 0usize;
        let flush_literals = |out: &mut Vec<u8>, from: usize, to: usize, data: &[u8]| {
            let mut s = from;
            while s < to {
                let take = (to - s).min(LIT_MAX);
                out.push((take - 1) as u8);
                out.extend_from_slice(data.get(s..s + take).unwrap_or(&[]));
                s += take;
            }
        };
        while let Some(&b) = data.get(i) {
            // measure run at i
            let mut run = 1usize;
            while data.get(i + run) == Some(&b) && run < RUN_MAX {
                run += 1;
            }
            if run >= RUN_MIN {
                flush_literals(&mut out, lit_start, i, data);
                out.push((128 + (run - RUN_MIN)) as u8);
                out.push(b);
                i += run;
                lit_start = i;
            } else {
                i += run;
            }
        }
        flush_literals(&mut out, lit_start, n, data);
        Ok(out)
    }

    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(data.len() * 2);
        let mut i = 0usize;
        while let Some(&cb) = data.get(i) {
            let c = cb as usize;
            i += 1;
            if c < 128 {
                let take = c + 1;
                let lits = data
                    .get(i..i + take)
                    .ok_or_else(|| SzError::corrupt("rle: truncated literal block"))?;
                out.extend_from_slice(lits);
                i += take;
            } else {
                let Some(&b) = data.get(i) else {
                    return Err(SzError::corrupt("rle: truncated run"));
                };
                let count = c - 128 + RUN_MIN;
                i += 1;
                out.extend(std::iter::repeat(b).take(count));
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lossless::test_support::roundtrip;
    use crate::util::prop;

    #[test]
    fn zero_heavy_stream_collapses() {
        let mut data = vec![0u8; 8192];
        data[100] = 9;
        data[5000] = 3;
        let size = roundtrip(&Rle, &data);
        assert!(size < 200, "rle size {size}");
    }

    #[test]
    fn run_length_boundaries() {
        for n in [1, RUN_MIN - 1, RUN_MIN, RUN_MAX, RUN_MAX + 1, 3 * RUN_MAX + 2] {
            roundtrip(&Rle, &vec![0xeeu8; n]);
        }
    }

    #[test]
    fn literal_block_boundaries() {
        // strictly alternating bytes => pure literals
        for n in [1, LIT_MAX - 1, LIT_MAX, LIT_MAX + 1, 3 * LIT_MAX] {
            let data: Vec<u8> = (0..n).map(|i| (i % 7) as u8).collect();
            roundtrip(&Rle, &data);
        }
    }

    #[test]
    fn prop_roundtrip() {
        prop::cases(100, 0x41e, |rng| {
            let n = rng.below(4000);
            // biased toward runs
            let mut data = Vec::with_capacity(n);
            while data.len() < n {
                let b = rng.next_u32() as u8 % 4;
                let run = rng.below(20) + 1;
                data.extend(std::iter::repeat(b).take(run.min(n - data.len())));
            }
            roundtrip(&Rle, &data);
        });
    }

    #[test]
    fn corrupt_stream_rejected() {
        assert!(Rle.decompress(&[5, 1, 2]).is_err()); // literal block needs 6 bytes
        assert!(Rle.decompress(&[200]).is_err()); // run missing byte
    }
}
