//! Lossless-compressor stage (paper §3.2, Appendix A.5): shrinks the byte
//! stream produced by the encoder stage.
//!
//! Per the paper this module "acts mainly as a proxy of state-of-the-art
//! lossless compression libraries": [`ZstdLossless`] and [`GzipLossless`]
//! proxy the vendored `zstd`/`flate2` backends. Additionally this repo
//! implements its own gzip-class backend from scratch ([`lzhuf::LzHuf`]),
//! a fast byte-RLE ([`rle::Rle`]) and a [`Bypass`] (the paper's "module
//! bypass" speed/ratio tradeoff).

pub mod lzhuf;
pub mod rle;

pub use lzhuf::LzHuf;
pub use rle::Rle;

use crate::error::{Result, SzError};
use crate::obs;
use std::time::Instant;

/// Lossless byte-stream compressor (paper Appendix A.5).
pub trait Lossless: Send + Sync {
    /// Instance name for configs and stream headers.
    fn name(&self) -> &'static str;
    /// Compress `data`.
    fn compress(&self, data: &[u8]) -> Result<Vec<u8>>;
    /// Decompress `data` (inverse of [`Self::compress`]).
    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>>;
}

/// Identity backend — the paper's module bypass.
#[derive(Default, Clone)]
pub struct Bypass;

impl Lossless for Bypass {
    fn name(&self) -> &'static str {
        "bypass"
    }
    fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        Ok(data.to_vec())
    }
    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
        Ok(data.to_vec())
    }
}

/// Proxy to the zstd library (the paper's default lossless stage).
#[derive(Clone)]
pub struct ZstdLossless {
    /// zstd compression level (paper uses the default, 3).
    pub level: i32,
}

impl Default for ZstdLossless {
    fn default() -> Self {
        ZstdLossless { level: 3 }
    }
}

impl Lossless for ZstdLossless {
    fn name(&self) -> &'static str {
        "zstd"
    }
    fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        zstd::bulk::compress(data, self.level)
            .map_err(|e| SzError::Lossless(format!("zstd compress: {e}")))
    }
    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
        zstd::stream::decode_all(data)
            .map_err(|e| SzError::Lossless(format!("zstd decompress: {e}")))
    }
}

/// Proxy to GZIP/DEFLATE via flate2.
#[derive(Clone)]
pub struct GzipLossless {
    /// Deflate level 0-9.
    pub level: u32,
}

impl Default for GzipLossless {
    fn default() -> Self {
        GzipLossless { level: 6 }
    }
}

impl Lossless for GzipLossless {
    fn name(&self) -> &'static str {
        "gzip"
    }
    fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        use std::io::Write;
        let mut enc = flate2::write::ZlibEncoder::new(
            Vec::new(),
            flate2::Compression::new(self.level),
        );
        enc.write_all(data)?;
        enc.finish().map_err(|e| SzError::Lossless(format!("gzip: {e}")))
    }
    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
        use std::io::Read;
        let mut dec = flate2::read::ZlibDecoder::new(data);
        let mut out = Vec::new();
        dec.read_to_end(&mut out)?;
        Ok(out)
    }
}

/// Timing shim recording lossless stage metrics around any backend.
/// Applied by [`by_name`], so every pipeline-built backend reports into
/// [`crate::obs`] — one clock pair per stream-level call.
struct TimedLossless {
    inner: Box<dyn Lossless>,
}

impl Lossless for TimedLossless {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        let start = Instant::now();
        let out = self.inner.compress(data);
        let bytes_out = match &out {
            Ok(v) => v.len() as u64,
            Err(_) => 0,
        };
        obs::stage(obs::ST_LOSSLESS).record(start, data.len() as u64, bytes_out);
        out
    }

    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
        let start = Instant::now();
        let out = self.inner.decompress(data);
        let bytes_out = match &out {
            Ok(v) => v.len() as u64,
            Err(_) => 0,
        };
        obs::stage(obs::ST_UNLOSSLESS).record(start, data.len() as u64, bytes_out);
        out
    }
}

/// Construct a boxed lossless backend by name (wrapped in the
/// stage-metrics timing shim). A `@lN` suffix selects the backend level
/// (`zstd@l19`: zstd accepts 1..=22, gzip 1..=9; the other backends take
/// no level) — the same token grammar the pipeline spec canonicalizes.
pub fn by_name(name: &str) -> Option<Box<dyn Lossless>> {
    let (base, level) = match name.split_once("@l") {
        Some((b, rest)) => (b, Some(rest.parse::<u32>().ok()?)),
        None => (name, None),
    };
    let inner: Box<dyn Lossless> = match (base, level) {
        ("bypass" | "none", None) => Box::new(Bypass),
        ("zstd", None) => Box::new(ZstdLossless::default()),
        ("zstd", Some(l)) if (1..=22).contains(&l) => {
            Box::new(ZstdLossless { level: l as i32 })
        }
        ("gzip", None) => Box::new(GzipLossless::default()),
        ("gzip", Some(l)) if (1..=9).contains(&l) => {
            Box::new(GzipLossless { level: l })
        }
        ("lzhuf", None) => Box::new(LzHuf::default()),
        ("rle", None) => Box::new(Rle),
        _ => return None,
    };
    Some(Box::new(TimedLossless { inner }))
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;

    pub fn roundtrip(l: &dyn Lossless, data: &[u8]) -> usize {
        let c = l.compress(data).expect("compress");
        let d = l.decompress(&c).expect("decompress");
        assert_eq!(d, data, "lossless {} failed roundtrip", l.name());
        c.len()
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::roundtrip;
    use super::*;
    use crate::util::prop;

    fn backends() -> Vec<Box<dyn Lossless>> {
        ["bypass", "zstd", "gzip", "lzhuf", "rle"]
            .iter()
            .map(|n| by_name(n).unwrap())
            .collect()
    }

    #[test]
    fn all_backends_roundtrip_edges() {
        for b in backends() {
            roundtrip(b.as_ref(), &[]);
            roundtrip(b.as_ref(), &[0]);
            roundtrip(b.as_ref(), &[1, 2, 3, 4, 5]);
            roundtrip(b.as_ref(), &vec![7u8; 10000]);
        }
    }

    #[test]
    fn prop_all_backends_roundtrip_random() {
        prop::cases(15, 0x10f, |rng| {
            let n = rng.below(20000);
            let data = prop::vec_u8(rng, n);
            for b in backends() {
                roundtrip(b.as_ref(), &data);
            }
        });
    }

    #[test]
    fn prop_all_backends_roundtrip_compressible() {
        prop::cases(15, 0x110, |rng| {
            let n = rng.below(30000) + 100;
            let data = prop::compressible_u8(rng, n);
            for b in backends() {
                let size = roundtrip(b.as_ref(), &data);
                if b.name() == "zstd" || b.name() == "gzip" || b.name() == "lzhuf" {
                    assert!(size < data.len(), "{} did not compress motif data", b.name());
                }
            }
        });
    }

    #[test]
    fn unknown_backend_is_none() {
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn leveled_backends_roundtrip_and_reject_bad_levels() {
        let mut rng = crate::util::rng::Pcg32::seeded(0x1eve1);
        let data = prop::compressible_u8(&mut rng, 40_000);
        let mut sizes = Vec::new();
        for n in ["zstd@l1", "zstd@l19", "zstd@l22", "gzip@l1", "gzip@l9"] {
            let b = by_name(n).unwrap_or_else(|| panic!("{n} should construct"));
            sizes.push(roundtrip(b.as_ref(), &data));
        }
        // a higher level must not be catastrophically worse on motif data
        assert!(sizes[1] <= sizes[0] * 2, "zstd@l19 vs @l1: {sizes:?}");
        assert!(sizes[4] <= sizes[3] * 2, "gzip@l9 vs @l1: {sizes:?}");
        for n in [
            "zstd@l0", "zstd@l23", "gzip@l0", "gzip@l10", "lzhuf@l3",
            "rle@l1", "bypass@l2", "zstd@lx", "zstd@l", "zstd@l-1",
        ] {
            assert!(by_name(n).is_none(), "{n} should be rejected");
        }
        // a leveled compressor's output decodes through the default one
        let c = by_name("zstd@l19").unwrap().compress(&data).unwrap();
        let d = by_name("zstd").unwrap().decompress(&c).unwrap();
        assert_eq!(d, data);
    }
}
