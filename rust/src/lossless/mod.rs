//! Lossless-compressor stage (paper §3.2, Appendix A.5): shrinks the byte
//! stream produced by the encoder stage.
//!
//! Per the paper this module "acts mainly as a proxy of state-of-the-art
//! lossless compression libraries": [`ZstdLossless`] and [`GzipLossless`]
//! proxy the vendored `zstd`/`flate2` backends. Additionally this repo
//! implements its own gzip-class backend from scratch ([`lzhuf::LzHuf`]),
//! a fast byte-RLE ([`rle::Rle`]) and a [`Bypass`] (the paper's "module
//! bypass" speed/ratio tradeoff).

pub mod lzhuf;
pub mod rle;

pub use lzhuf::LzHuf;
pub use rle::Rle;

use crate::error::{Result, SzError};
use crate::obs;
use std::time::Instant;

/// Lossless byte-stream compressor (paper Appendix A.5).
pub trait Lossless: Send + Sync {
    /// Instance name for configs and stream headers.
    fn name(&self) -> &'static str;
    /// Compress `data`.
    fn compress(&self, data: &[u8]) -> Result<Vec<u8>>;
    /// Decompress `data` (inverse of [`Self::compress`]).
    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>>;
}

/// Identity backend — the paper's module bypass.
#[derive(Default, Clone)]
pub struct Bypass;

impl Lossless for Bypass {
    fn name(&self) -> &'static str {
        "bypass"
    }
    fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        Ok(data.to_vec())
    }
    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
        Ok(data.to_vec())
    }
}

/// Proxy to the zstd library (the paper's default lossless stage).
#[derive(Clone)]
pub struct ZstdLossless {
    /// zstd compression level (paper uses the default, 3).
    pub level: i32,
}

impl Default for ZstdLossless {
    fn default() -> Self {
        ZstdLossless { level: 3 }
    }
}

impl Lossless for ZstdLossless {
    fn name(&self) -> &'static str {
        "zstd"
    }
    fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        zstd::bulk::compress(data, self.level)
            .map_err(|e| SzError::Lossless(format!("zstd compress: {e}")))
    }
    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
        zstd::stream::decode_all(data)
            .map_err(|e| SzError::Lossless(format!("zstd decompress: {e}")))
    }
}

/// Proxy to GZIP/DEFLATE via flate2.
#[derive(Clone)]
pub struct GzipLossless {
    /// Deflate level 0-9.
    pub level: u32,
}

impl Default for GzipLossless {
    fn default() -> Self {
        GzipLossless { level: 6 }
    }
}

impl Lossless for GzipLossless {
    fn name(&self) -> &'static str {
        "gzip"
    }
    fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        use std::io::Write;
        let mut enc = flate2::write::ZlibEncoder::new(
            Vec::new(),
            flate2::Compression::new(self.level),
        );
        enc.write_all(data)?;
        enc.finish().map_err(|e| SzError::Lossless(format!("gzip: {e}")))
    }
    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
        use std::io::Read;
        let mut dec = flate2::read::ZlibDecoder::new(data);
        let mut out = Vec::new();
        dec.read_to_end(&mut out)?;
        Ok(out)
    }
}

/// Timing shim recording lossless stage metrics around any backend.
/// Applied by [`by_name`], so every pipeline-built backend reports into
/// [`crate::obs`] — one clock pair per stream-level call.
struct TimedLossless {
    inner: Box<dyn Lossless>,
}

impl Lossless for TimedLossless {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        let start = Instant::now();
        let out = self.inner.compress(data);
        let bytes_out = match &out {
            Ok(v) => v.len() as u64,
            Err(_) => 0,
        };
        obs::stage(obs::ST_LOSSLESS).record(start, data.len() as u64, bytes_out);
        out
    }

    fn decompress(&self, data: &[u8]) -> Result<Vec<u8>> {
        let start = Instant::now();
        let out = self.inner.decompress(data);
        let bytes_out = match &out {
            Ok(v) => v.len() as u64,
            Err(_) => 0,
        };
        obs::stage(obs::ST_UNLOSSLESS).record(start, data.len() as u64, bytes_out);
        out
    }
}

/// Construct a boxed lossless backend by name (wrapped in the
/// stage-metrics timing shim).
pub fn by_name(name: &str) -> Option<Box<dyn Lossless>> {
    let inner: Box<dyn Lossless> = match name {
        "bypass" | "none" => Box::new(Bypass),
        "zstd" => Box::new(ZstdLossless::default()),
        "gzip" => Box::new(GzipLossless::default()),
        "lzhuf" => Box::new(LzHuf::default()),
        "rle" => Box::new(Rle),
        _ => return None,
    };
    Some(Box::new(TimedLossless { inner }))
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;

    pub fn roundtrip(l: &dyn Lossless, data: &[u8]) -> usize {
        let c = l.compress(data).expect("compress");
        let d = l.decompress(&c).expect("decompress");
        assert_eq!(d, data, "lossless {} failed roundtrip", l.name());
        c.len()
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::roundtrip;
    use super::*;
    use crate::util::prop;

    fn backends() -> Vec<Box<dyn Lossless>> {
        ["bypass", "zstd", "gzip", "lzhuf", "rle"]
            .iter()
            .map(|n| by_name(n).unwrap())
            .collect()
    }

    #[test]
    fn all_backends_roundtrip_edges() {
        for b in backends() {
            roundtrip(b.as_ref(), &[]);
            roundtrip(b.as_ref(), &[0]);
            roundtrip(b.as_ref(), &[1, 2, 3, 4, 5]);
            roundtrip(b.as_ref(), &vec![7u8; 10000]);
        }
    }

    #[test]
    fn prop_all_backends_roundtrip_random() {
        prop::cases(15, 0x10f, |rng| {
            let n = rng.below(20000);
            let data = prop::vec_u8(rng, n);
            for b in backends() {
                roundtrip(b.as_ref(), &data);
            }
        });
    }

    #[test]
    fn prop_all_backends_roundtrip_compressible() {
        prop::cases(15, 0x110, |rng| {
            let n = rng.below(30000) + 100;
            let data = prop::compressible_u8(rng, n);
            for b in backends() {
                let size = roundtrip(b.as_ref(), &data);
                if b.name() == "zstd" || b.name() == "gzip" || b.name() == "lzhuf" {
                    assert!(size < data.len(), "{} did not compress motif data", b.name());
                }
            }
        });
    }

    #[test]
    fn unknown_backend_is_none() {
        assert!(by_name("nope").is_none());
    }
}
