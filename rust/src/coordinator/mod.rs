//! L3 streaming coordinator: the data-pipeline face of the framework.
//!
//! Scientific simulations emit snapshots field-by-field; the coordinator
//! turns that stream into bounded-memory parallel compression:
//!
//! ```text
//!  source ──► chunker ──► bounded queue ──► worker pool ──► reorder ──► sink
//!             (shard       (backpressure)    (N compress     (ordered
//!              planner)                       workers)        delivery)
//! ```
//!
//! * **Sharding**: fields are split along the slowest axis into chunks of
//!   ~`chunk_elems` elements; each chunk is an independent compression unit.
//! * **Backpressure**: the work queue is a bounded `sync_channel`; when
//!   workers fall behind, the producer blocks instead of buffering the
//!   whole snapshot (blocked time is reported).
//! * **Rebalancing**: workers pull from the shared queue (work stealing),
//!   so a slow shard doesn't idle the pool; per-worker counters expose the
//!   achieved balance.
//! * **Adaptive selection**: with a [`AdaptiveChunkSelector`] installed,
//!   each worker picks the best-fit registry pipeline per chunk (paper §3
//!   contribution 2 at chunk granularity); the choice is recorded on the
//!   chunk and lands in the container index.
//! * [`Coordinator::run_to_container`] packs the ordered chunks into the
//!   self-describing `SZ3C` artifact; [`crate::container`] fans it back
//!   out for parallel decompression with shape verification.

pub mod series;

pub use series::{SeriesReport, Snapshot};

use crate::container::{self, AdaptiveChunkSelector};
use crate::data::{Field, FieldValues};
use crate::error::{Result, SzError};
use crate::obs::{self, trace::Span};
use crate::pipeline::{self, CompressConf, Compressor};
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One compressed shard of a field.
#[derive(Clone, Debug)]
pub struct CompressedChunk {
    /// Global sequence number (delivery order).
    pub seq: usize,
    /// Source field name.
    pub field: String,
    /// Index of this chunk within its field.
    pub chunk_index: usize,
    /// Number of chunks in the field.
    pub chunk_count: usize,
    /// Row range [start, end) along the split axis.
    pub rows: (usize, usize),
    /// Full field dims.
    pub field_dims: Vec<usize>,
    /// Pipeline that compressed this chunk (fixed or adaptively selected),
    /// as its canonical spec string — recorded in the container index for
    /// per-chunk dispatch through [`pipeline::build`]. Legacy artifacts
    /// carry registry aliases here instead, which `build` also resolves.
    pub pipeline: String,
    /// The compressed stream.
    pub stream: Vec<u8>,
    /// Uncompressed bytes of this chunk.
    pub raw_bytes: usize,
    /// Snapshot this chunk belongs to (0 outside series packing; see
    /// [`Coordinator::run_series_to_container`]).
    pub snapshot: usize,
    /// True if `stream` compresses residuals against the decoded
    /// `(snapshot − 1, field, chunk_index)` baseline instead of the data
    /// itself.
    pub delta: bool,
}

/// Aggregated run metrics.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    /// Fields consumed.
    pub fields: usize,
    /// Chunks compressed.
    pub chunks: usize,
    /// Total uncompressed bytes.
    pub bytes_in: u64,
    /// Total compressed bytes.
    pub bytes_out: u64,
    /// Wall-clock duration.
    pub elapsed: Duration,
    /// Time the producer spent blocked on the full queue (backpressure).
    pub producer_blocked: Duration,
    /// Chunks compressed per worker (work-stealing balance).
    pub per_worker: Vec<usize>,
    /// Chunks per pipeline name (interesting under adaptive selection).
    pub per_pipeline: BTreeMap<String, usize>,
}

impl RunReport {
    /// Overall compression ratio.
    pub fn ratio(&self) -> f64 {
        self.bytes_in as f64 / self.bytes_out.max(1) as f64
    }

    /// End-to-end throughput over uncompressed bytes (MB/s).
    pub fn throughput_mbs(&self) -> f64 {
        self.bytes_in as f64 / 1e6 / self.elapsed.as_secs_f64().max(1e-9)
    }
}

impl std::fmt::Display for RunReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} fields, {} chunks: {:.2} MB -> {:.2} MB (ratio {:.2}) in {:.2?} \
             ({:.1} MB/s, producer blocked {:.2?}, worker balance {:?})",
            self.fields,
            self.chunks,
            self.bytes_in as f64 / 1e6,
            self.bytes_out as f64 / 1e6,
            self.ratio(),
            self.elapsed,
            self.throughput_mbs(),
            self.producer_blocked,
            self.per_worker
        )?;
        if self.per_pipeline.len() > 1 {
            write!(f, " pipelines {:?}", self.per_pipeline)?;
        }
        Ok(())
    }
}

/// Shard planner: split a field into row ranges of ~`chunk_elems`.
/// Degenerate shapes (no axes, zero-length rows) are rejected instead of
/// panicking on the unchecked `dims[0]` access this used to do.
pub fn plan_chunks(field: &Field, chunk_elems: usize) -> Result<Vec<(usize, usize)>> {
    let t0 = Instant::now();
    let _span = Span::enter("plan_chunks", "coordinator");
    let dims = field.shape.dims();
    if dims.is_empty() {
        return Err(SzError::config("cannot chunk a 0-dimensional field"));
    }
    let rows = dims[0];
    let row_elems: usize = dims[1..].iter().product::<usize>().max(1);
    if rows == 0 || row_elems == 0 || field.len() == 0 {
        return Err(SzError::config(format!(
            "cannot chunk empty field '{}' with dims {dims:?}",
            field.name
        )));
    }
    let rows_per_chunk = (chunk_elems / row_elems).clamp(1, rows);
    let mut out = Vec::new();
    let mut r = 0;
    while r < rows {
        let e = (r + rows_per_chunk).min(rows);
        out.push((r, e));
        r = e;
    }
    obs::CHUNKS_PLANNED.add(out.len() as u64);
    obs::CHUNK_PLAN_NS.add(obs::elapsed_ns(t0));
    Ok(out)
}

/// Copy out rows `[start, end)` of a field along the split (slowest) axis —
/// the chunker's slicing primitive, shared with the reader's
/// region-assembly path.
pub fn slice_rows(field: &Field, rows: (usize, usize)) -> Result<Field> {
    let dims = field.shape.dims();
    let (start, end) = rows;
    if dims.is_empty() || start >= end || end > dims[0] {
        return Err(SzError::config(format!(
            "row slice [{start}, {end}) invalid for dims {dims:?}"
        )));
    }
    let row_elems: usize = dims[1..].iter().product::<usize>().max(1);
    let mut new_dims = dims.to_vec();
    new_dims[0] = end - start;
    let a = start * row_elems;
    let b = end * row_elems;
    let values = match &field.values {
        FieldValues::F32(v) => FieldValues::F32(v[a..b].to_vec()),
        FieldValues::F64(v) => FieldValues::F64(v[a..b].to_vec()),
        FieldValues::I32(v) => FieldValues::I32(v[a..b].to_vec()),
    };
    Field::new(field.name.clone(), &new_dims, values)
}

/// The streaming compression coordinator.
pub struct Coordinator {
    /// Configured pipeline — a registry alias or raw spec (the fixed
    /// pipeline when no selector is set).
    pub pipeline: String,
    /// Per-chunk compression configuration.
    pub conf: CompressConf,
    /// Worker threads.
    pub workers: usize,
    /// Elements per chunk (shard size).
    pub chunk_elems: usize,
    /// Bounded queue depth (backpressure window).
    pub queue_depth: usize,
    /// Factory for per-worker compressor instances (lets callers inject a
    /// PJRT-backed pipeline; defaults to the registry).
    pub make_compressor: Arc<dyn Fn() -> Box<dyn Compressor> + Send + Sync>,
    /// Per-chunk best-fit pipeline selection; when set, each worker picks a
    /// registry pipeline per chunk instead of using `make_compressor`.
    pub selector: Option<Arc<AdaptiveChunkSelector>>,
}

impl Coordinator {
    /// Coordinator from a job config. `cfg.pipeline` and `cfg.candidates`
    /// may be registry aliases or raw pipeline specs — anything
    /// [`pipeline::build`] accepts.
    pub fn from_config(cfg: &crate::config::JobConfig) -> Result<Self> {
        let name = cfg.pipeline.clone();
        pipeline::build(&name)
            .map_err(|e| SzError::config(format!("pipeline '{name}': {e}")))?;
        // `measured` implies adaptive: asking for measured selection without
        // a selector would silently run the fixed pipeline.
        let selector = if cfg.adaptive || cfg.measured {
            let mut sel = if cfg.candidates.is_empty() {
                AdaptiveChunkSelector::new()
            } else {
                AdaptiveChunkSelector::from_names(cfg.candidates.iter().cloned())?
            };
            if cfg.measured {
                sel = sel.with_measured(crate::container::OptimizeTarget::from_name(
                    &cfg.optimize,
                )?);
            }
            Some(Arc::new(sel))
        } else {
            None
        };
        let n2 = name.clone();
        Ok(Coordinator {
            pipeline: name,
            conf: cfg.compress_conf(),
            workers: cfg.workers,
            chunk_elems: cfg.chunk_elems,
            queue_depth: cfg.queue_depth,
            make_compressor: Arc::new(move || {
                pipeline::build(&n2).expect("validated at from_config")
            }),
            selector,
        })
    }

    /// Stream `source` through the worker pool; deliver ordered chunks to
    /// `sink`. Returns aggregate metrics.
    pub fn run<I, S>(&self, source: I, mut sink: S) -> Result<RunReport>
    where
        I: IntoIterator<Item = Field>,
        S: FnMut(CompressedChunk),
    {
        struct Job {
            seq: usize,
            field: Arc<Field>,
            chunk_index: usize,
            chunk_count: usize,
            rows: (usize, usize),
        }

        let started = Instant::now();
        let (work_tx, work_rx) = sync_channel::<Job>(self.queue_depth);
        let work_rx = Arc::new(Mutex::new(work_rx));
        let (done_tx, done_rx) = sync_channel::<Result<CompressedChunk>>(self.queue_depth * 2);
        let worker_counts: Arc<Vec<AtomicU64>> =
            Arc::new((0..self.workers).map(|_| AtomicU64::new(0)).collect());

        let mut handles = Vec::new();
        for wid in 0..self.workers {
            let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&work_rx);
            let tx = done_tx.clone();
            let conf = self.conf.clone();
            let make = Arc::clone(&self.make_compressor);
            let selector = self.selector.clone();
            let counts = Arc::clone(&worker_counts);
            handles.push(std::thread::spawn(move || {
                // fixed mode uses one compressor per worker; adaptive mode
                // bypasses it, instantiating pipelines on demand into a
                // per-worker cache so repeated selections reuse the instance
                let compressor = if selector.is_none() { Some(make()) } else { None };
                let mut cache: HashMap<String, Box<dyn Compressor>> = HashMap::new();
                loop {
                    let job = match rx.lock().unwrap().recv() {
                        Ok(j) => j,
                        Err(_) => break, // queue closed
                    };
                    let result = slice_rows(&job.field, job.rows).and_then(|chunk| {
                        let raw = chunk.nbytes();
                        let t_chunk = Instant::now();
                        let mut span = Span::enter("chunk", "coordinator")
                            .arg("seq", job.seq as u64);
                        let (stream, used) = match &selector {
                            Some(sel) => {
                                let name = sel.select(&chunk, &conf)?.pipeline;
                                if !cache.contains_key(&name) {
                                    let c = pipeline::build(&name).map_err(|e| {
                                        SzError::config(format!(
                                            "selector chose unbuildable pipeline \
                                             '{name}': {e}"
                                        ))
                                    })?;
                                    cache.insert(name.clone(), c);
                                }
                                (cache[&name].compress(&chunk, &conf)?, name)
                            }
                            None => {
                                let c =
                                    compressor.as_ref().expect("fixed-mode compressor");
                                (c.compress(&chunk, &conf)?, c.name().to_string())
                            }
                        };
                        span.set_arg("bytes_out", stream.len() as u64);
                        drop(span);
                        obs::CHUNK_COMPRESS_US.observe_since(t_chunk);
                        obs::CHUNK_BYTES_IN.add(raw as u64);
                        obs::CHUNK_BYTES_OUT.add(stream.len() as u64);
                        Ok(CompressedChunk {
                            seq: job.seq,
                            field: job.field.name.clone(),
                            chunk_index: job.chunk_index,
                            chunk_count: job.chunk_count,
                            rows: job.rows,
                            field_dims: job.field.shape.dims().to_vec(),
                            pipeline: used,
                            stream,
                            raw_bytes: raw,
                            snapshot: 0,
                            delta: false,
                        })
                    });
                    counts[wid].fetch_add(1, Ordering::Relaxed);
                    if tx.send(result).is_err() {
                        break;
                    }
                }
            }));
        }
        drop(done_tx);

        // producer + ordered sink on this thread: interleave submissions
        // with draining the done queue (reorder buffer keyed by seq).
        let mut report = RunReport { per_worker: vec![0; self.workers], ..Default::default() };
        let mut pending: BTreeMap<usize, CompressedChunk> = BTreeMap::new();
        let mut next_deliver = 0usize;
        let mut first_err: Option<SzError> = None;

        let deliver =
            |pending: &mut BTreeMap<usize, CompressedChunk>,
             next: &mut usize,
             report: &mut RunReport,
             sink: &mut S| {
                while let Some(chunk) = pending.remove(next) {
                    report.chunks += 1;
                    report.bytes_in += chunk.raw_bytes as u64;
                    report.bytes_out += chunk.stream.len() as u64;
                    *report.per_pipeline.entry(chunk.pipeline.clone()).or_insert(0) += 1;
                    sink(chunk);
                    *next += 1;
                }
            };

        let mut seq = 0usize;
        for field in source {
            report.fields += 1;
            let field = Arc::new(field);
            let chunks = match plan_chunks(&field, self.chunk_elems) {
                Ok(c) => c,
                Err(e) => {
                    first_err.get_or_insert(e);
                    break;
                }
            };
            let count = chunks.len();
            for (ci, rows) in chunks.into_iter().enumerate() {
                let job = Job {
                    seq,
                    field: Arc::clone(&field),
                    chunk_index: ci,
                    chunk_count: count,
                    rows,
                };
                seq += 1;
                // drain completions opportunistically to keep queues moving
                while let Ok(done) = done_rx.try_recv() {
                    match done {
                        Ok(c) => {
                            pending.insert(c.seq, c);
                        }
                        Err(e) => {
                            first_err.get_or_insert(e);
                        }
                    }
                    deliver(&mut pending, &mut next_deliver, &mut report, &mut sink);
                }
                let t0 = Instant::now();
                work_tx
                    .send(job)
                    .map_err(|_| SzError::Runtime("worker pool died".into()))?;
                report.producer_blocked += t0.elapsed();
            }
        }
        drop(work_tx); // close the queue; workers exit when drained

        for done in done_rx.iter() {
            match done {
                Ok(c) => {
                    pending.insert(c.seq, c);
                }
                Err(e) => {
                    first_err.get_or_insert(e);
                }
            }
            deliver(&mut pending, &mut next_deliver, &mut report, &mut sink);
        }
        for h in handles {
            // audit:allow(swallow, reason = "worker panics already surfaced as channel errors collected into first_err")
            let _ = h.join();
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        deliver(&mut pending, &mut next_deliver, &mut report, &mut sink);
        for (i, c) in worker_counts.iter().enumerate() {
            report.per_worker[i] = c.load(Ordering::Relaxed) as usize;
        }
        report.elapsed = started.elapsed();
        Ok(report)
    }

    /// Stream `source` through the pool and pack the ordered chunks into a
    /// self-describing `SZ3C` container (the coordinator's native artifact;
    /// see [`crate::container`] for the format and the parallel
    /// decompression path).
    pub fn run_to_container<I>(&self, source: I) -> Result<(Vec<u8>, RunReport)>
    where
        I: IntoIterator<Item = Field>,
    {
        let mut chunks: Vec<CompressedChunk> = Vec::new();
        let report = self.run(source, |c| chunks.push(c))?;
        let artifact = container::pack(&chunks)?;
        Ok((artifact, report))
    }
}

/// Reassemble a field from its ordered chunks (inverse of the chunker).
pub fn reassemble(chunks: &[CompressedChunk]) -> Result<Field> {
    if chunks.is_empty() {
        return Err(SzError::config("no chunks to reassemble"));
    }
    let mut sorted: Vec<&CompressedChunk> = chunks.iter().collect();
    sorted.sort_by_key(|c| c.chunk_index);
    if sorted.len() != sorted[0].chunk_count {
        return Err(SzError::corrupt(format!(
            "field {}: have {} of {} chunks",
            sorted[0].field,
            sorted.len(),
            sorted[0].chunk_count
        )));
    }
    let full_dims = sorted[0].field_dims.clone();
    let mut fields = Vec::with_capacity(sorted.len());
    for c in &sorted {
        fields.push(pipeline::decompress_any(&c.stream)?);
    }
    let values = FieldValues::concat(fields.iter().map(|f| &f.values))?;
    Field::new(sorted[0].field.clone(), &full_dims, values)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::ErrorBound;
    use crate::util::prop;
    use std::collections::HashMap;

    fn coordinator(pipeline: &str, workers: usize) -> Coordinator {
        let cfg = crate::config::JobConfig {
            pipeline: pipeline.into(),
            bound: ErrorBound::Abs(1e-3),
            workers,
            chunk_elems: 4096,
            queue_depth: 2,
            ..Default::default()
        };
        Coordinator::from_config(&cfg).unwrap()
    }

    fn fields(n: usize, seed: u64) -> Vec<Field> {
        let mut rng = crate::util::rng::Pcg32::seeded(seed);
        (0..n)
            .map(|i| {
                let dims = [24usize, 16, 16];
                Field::f32(format!("f{i}"), &dims, prop::smooth_field(&mut rng, &dims))
                    .unwrap()
            })
            .collect()
    }

    #[test]
    fn ordered_delivery_and_roundtrip() {
        let coord = coordinator("sz3-lr", 4);
        let input = fields(3, 11);
        let mut chunks: Vec<CompressedChunk> = Vec::new();
        let report = coord.run(input.clone(), |c| chunks.push(c)).unwrap();
        assert_eq!(report.fields, 3);
        assert_eq!(report.chunks, chunks.len());
        // chunks record the alias's canonical spec, not the alias itself
        let canon = pipeline::canonical("sz3-lr").unwrap();
        assert_eq!(report.per_pipeline.get(&canon), Some(&chunks.len()));
        // in-order delivery
        for (i, c) in chunks.iter().enumerate() {
            assert_eq!(c.seq, i);
            assert_eq!(c.pipeline, canon);
        }
        // reassemble and verify bound per field
        let mut by_field: HashMap<String, Vec<CompressedChunk>> = HashMap::new();
        for c in chunks {
            by_field.entry(c.field.clone()).or_default().push(c);
        }
        for f in &input {
            let rec = reassemble(&by_field[&f.name]).unwrap();
            assert_eq!(rec.shape.dims(), f.shape.dims());
            for (o, d) in f.values.to_f64_vec().iter().zip(rec.values.to_f64_vec().iter())
            {
                assert!((o - d).abs() <= 1e-3 * (1.0 + 1e-12));
            }
        }
    }

    #[test]
    fn work_is_distributed() {
        let coord = coordinator("sz3-lr", 3);
        let report = coord.run(fields(4, 12), |_| {}).unwrap();
        let busy = report.per_worker.iter().filter(|&&n| n > 0).count();
        assert!(busy >= 2, "work stealing should engage ≥2 workers: {:?}", report.per_worker);
        assert_eq!(report.per_worker.iter().sum::<usize>(), report.chunks);
    }

    #[test]
    fn single_worker_deterministic_output() {
        let coord = coordinator("sz3-interp", 1);
        let mut out1 = Vec::new();
        let mut out2 = Vec::new();
        coord.run(fields(2, 13), |c| out1.push(c.stream)).unwrap();
        coord.run(fields(2, 13), |c| out2.push(c.stream)).unwrap();
        assert_eq!(out1, out2);
    }

    #[test]
    fn plan_chunks_covers_rows() {
        let f = fields(1, 14).remove(0);
        let plan = plan_chunks(&f, 1000).unwrap();
        assert_eq!(plan.first().unwrap().0, 0);
        assert_eq!(plan.last().unwrap().1, f.shape.dims()[0]);
        for w in plan.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
    }

    #[test]
    fn unknown_pipeline_rejected() {
        let cfg = crate::config::JobConfig { pipeline: "nope".into(), ..Default::default() };
        assert!(Coordinator::from_config(&cfg).is_err());
    }

    #[test]
    fn raw_spec_pipeline_through_config() {
        // a composed spec that matches no registry alias flows through the
        // coordinator and lands verbatim in every chunk's pipeline field
        let spec = "block(lorenzo+regression)/linear/huffman/lzhuf";
        let coord = coordinator(spec, 2);
        let input = fields(1, 16);
        let mut chunks: Vec<CompressedChunk> = Vec::new();
        coord.run(input.clone(), |c| chunks.push(c)).unwrap();
        assert!(!chunks.is_empty());
        assert!(chunks.iter().all(|c| c.pipeline == spec), "{:?}", chunks[0].pipeline);
        let rec = reassemble(&chunks).unwrap();
        assert_eq!(rec.shape.dims(), input[0].shape.dims());
    }

    #[test]
    fn adaptive_config_validates_candidates() {
        let cfg = crate::config::JobConfig {
            adaptive: true,
            candidates: vec!["sz3-lr".into(), "bogus".into()],
            ..Default::default()
        };
        assert!(Coordinator::from_config(&cfg).is_err());
        let cfg = crate::config::JobConfig { adaptive: true, ..Default::default() };
        assert!(Coordinator::from_config(&cfg).unwrap().selector.is_some());
    }

    #[test]
    fn measured_config_builds_a_measured_selector() {
        use crate::container::{OptimizeTarget, SelectionMode};
        // measured implies adaptive even when the adaptive flag is off
        let cfg = crate::config::JobConfig {
            measured: true,
            optimize: "speed".into(),
            ..Default::default()
        };
        let coord = Coordinator::from_config(&cfg).unwrap();
        let sel = coord.selector.expect("measured implies a selector");
        assert_eq!(sel.mode, SelectionMode::Measured);
        assert_eq!(sel.optimize, OptimizeTarget::Speed);
        // adaptive without measured stays in proxy mode
        let cfg = crate::config::JobConfig { adaptive: true, ..Default::default() };
        let sel = Coordinator::from_config(&cfg).unwrap().selector.unwrap();
        assert_eq!(sel.mode, SelectionMode::Proxy);
        // a bad objective fails config-side, but from_config guards too
        let cfg = crate::config::JobConfig {
            measured: true,
            optimize: "best".into(),
            ..Default::default()
        };
        assert!(Coordinator::from_config(&cfg).is_err());
    }

    #[test]
    fn run_to_container_roundtrips() {
        let coord = coordinator("sz3-lr", 2);
        let input = fields(2, 15);
        let (artifact, report) = coord.run_to_container(input.clone()).unwrap();
        assert!(crate::container::is_container(&artifact));
        assert_eq!(report.fields, 2);
        let out = crate::container::decompress_container(&artifact, 4).unwrap();
        assert_eq!(out.len(), 2);
        for (f, o) in input.iter().zip(&out) {
            assert_eq!(f.shape.dims(), o.shape.dims());
            assert_eq!(f.name, o.name);
        }
    }
}
