//! Multi-snapshot series packing: N timesteps of the same fields into one
//! v3 `SZ3C` artifact, with an optional per-chunk **snapshot delta mode**.
//!
//! Scientific producers emit the same fields across many timesteps, and
//! consecutive snapshots are usually highly correlated — the residual
//! between timestep *k* and the *decoded* timestep *k−1* spans a far
//! smaller value range than the data itself, so compressing the residual
//! under the same error bound costs fewer bits (cf. the temporal
//! dimension exploited by arXiv:1706.03791). Divergent regions are the
//! exception: where the field changed shape between steps, the residual
//! is *noisier* than the data and delta would pay for a bad baseline.
//!
//! [`Coordinator::run_series_to_container`] therefore decides **per
//! chunk**: every snapshot is compressed directly through the normal
//! worker pool (adaptive selection included), snapshots after the first
//! are *also* compressed as residual fields, and each chunk keeps
//! whichever stream is smaller — so delta mode can only shrink the
//! payload, never grow it. The chosen representation is recorded in the
//! v3 chunk index (`delta` flag) and resolved transparently by
//! [`crate::reader::ContainerReader::read_region_at`].
//!
//! Residuals are always taken against the **decoded** previous snapshot
//! (the exact bytes a reader reconstructs, delta chunks included), so the
//! error bound never accumulates across the chain: reconstruction error
//! at snapshot *k* is the residual compressor's own error, not a sum over
//! *k* steps.

use super::{slice_rows, CompressedChunk, Coordinator, RunReport};
use crate::container::{self, delta};
use crate::data::{Field, FieldValues};
use crate::error::{Result, SzError};
use crate::pipeline::{CompressConf, ErrorBound};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// One timestep of a series: a tag (timestamp, step id, …) and the
/// snapshot's fields. Every snapshot of a series must carry the same
/// field names, dims, and dtypes.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// Timestamp tag recorded in the v3 snapshot table (may be empty).
    pub tag: String,
    /// The snapshot's fields.
    pub fields: Vec<Field>,
}

impl Snapshot {
    /// Snapshot from a tag and fields.
    pub fn new(tag: impl Into<String>, fields: Vec<Field>) -> Self {
        Snapshot { tag: tag.into(), fields }
    }
}

/// Aggregated metrics of a series packing run.
#[derive(Clone, Debug, Default)]
pub struct SeriesReport {
    /// Per-snapshot coordinator reports (the direct compression pass).
    pub snapshots: Vec<RunReport>,
    /// Chunks stored direct.
    pub direct_chunks: usize,
    /// Chunks stored as snapshot residuals.
    pub delta_chunks: usize,
    /// Payload bytes had every chunk been stored direct.
    pub direct_bytes: u64,
    /// Payload bytes actually stored (≤ `direct_bytes` by construction).
    pub stored_bytes: u64,
}

impl SeriesReport {
    /// Fraction of the direct payload saved by delta mode (0 when delta
    /// never won or was disabled).
    pub fn delta_savings(&self) -> f64 {
        if self.direct_bytes == 0 {
            return 0.0;
        }
        1.0 - self.stored_bytes as f64 / self.direct_bytes as f64
    }
}

impl std::fmt::Display for SeriesReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} snapshots, {} chunks ({} delta): {:.2} MB stored vs {:.2} MB \
             direct ({:.1}% saved)",
            self.snapshots.len(),
            self.direct_chunks + self.delta_chunks,
            self.delta_chunks,
            self.stored_bytes as f64 / 1e6,
            self.direct_bytes as f64 / 1e6,
            100.0 * self.delta_savings()
        )
    }
}

/// `(name, dims, dtype)` signature a series holds constant across steps.
fn signature(fields: &[Field]) -> Vec<(String, Vec<usize>, &'static str)> {
    fields
        .iter()
        .map(|f| (f.name.clone(), f.shape.dims().to_vec(), f.values.dtype()))
        .collect()
}

/// Decode one snapshot's *chosen* chunks back into full fields — the
/// baseline the next snapshot's residuals are taken against. Uses the
/// same [`delta::apply`] the reader uses, so packer and reader baselines
/// agree bit for bit.
fn decode_snapshot(
    chunks: &[CompressedChunk],
    prev: &HashMap<String, Field>,
    workers: usize,
) -> Result<HashMap<String, Field>> {
    let slots: Mutex<Vec<Option<Result<Field>>>> =
        Mutex::new((0..chunks.len()).map(|_| None).collect());
    crate::util::par_for_each(chunks.len(), workers, |i| {
        let c = &chunks[i];
        let r = (|| {
            let raw = crate::pipeline::decompress_any(&c.stream)?;
            if !c.delta {
                return Ok(raw);
            }
            let base_full = prev.get(&c.field).ok_or_else(|| {
                SzError::config(format!("delta chunk of '{}' has no baseline", c.field))
            })?;
            delta::apply(&slice_rows(base_full, c.rows)?, &raw)
        })();
        slots.lock().unwrap()[i] = Some(r);
    });
    let decoded: Vec<Field> = slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|s| s.expect("every slot filled by the pool"))
        .collect::<Result<_>>()?;
    let mut out = HashMap::new();
    let mut order: Vec<&str> = Vec::new();
    for c in chunks {
        if !order.contains(&c.field.as_str()) {
            order.push(&c.field);
        }
    }
    for name in order {
        let mut parts: Vec<(usize, &Field)> = chunks
            .iter()
            .zip(&decoded)
            .filter(|(c, _)| c.field == name)
            .map(|(c, d)| (c.chunk_index, d))
            .collect();
        parts.sort_by_key(|(i, _)| *i);
        let dims = chunks
            .iter()
            .find(|c| c.field == name)
            .expect("name from this chunk set")
            .field_dims
            .clone();
        let values = FieldValues::concat(parts.iter().map(|(_, d)| &d.values))?;
        out.insert(name.to_string(), Field::new(name, &dims, values)?);
    }
    Ok(out)
}

impl Coordinator {
    /// A coordinator sharing this one's pipeline/pool configuration but
    /// compressing under `conf` — how the series packer pins a resolved
    /// absolute bound for a delta snapshot's two passes.
    fn with_conf(&self, conf: CompressConf) -> Coordinator {
        Coordinator {
            pipeline: self.pipeline.clone(),
            conf,
            workers: self.workers,
            chunk_elems: self.chunk_elems,
            queue_depth: self.queue_depth,
            make_compressor: Arc::clone(&self.make_compressor),
            selector: self.selector.clone(),
        }
    }

    /// Stream a whole time series through the worker pool and pack it
    /// into one v3 `SZ3C` artifact with a snapshot table. With `delta`
    /// enabled, every snapshot after the first is additionally compressed
    /// as residuals against the decoded previous snapshot, and each chunk
    /// keeps whichever stream is smaller (recorded per chunk in the
    /// index) — see the module docs for the error-bound argument.
    ///
    /// Bound semantics under delta: a relative (`Rel`) bound is resolved
    /// to an **absolute** bound against each snapshot's *original* fields
    /// (the tightest across the snapshot's fields) before either pass
    /// runs — resolving it against a residual field would scale the
    /// tolerance by the residual's range, not the data's, and silently
    /// loosen the promise. Pointwise-relative (`PwRel`) bounds are
    /// incompatible with additive residuals and are rejected.
    pub fn run_series_to_container(
        &self,
        series: Vec<Snapshot>,
        delta: bool,
    ) -> Result<(Vec<u8>, SeriesReport)> {
        if series.is_empty() {
            return Err(SzError::config("series needs ≥ 1 snapshot"));
        }
        if delta && matches!(self.conf.bound, ErrorBound::PwRel(_)) {
            return Err(SzError::config(
                "snapshot delta mode cannot honor a pointwise-relative bound \
                 (residuals are additive); use --abs/--rel or --no-delta",
            ));
        }
        let sig = signature(&series[0].fields);
        let n_snaps = series.len();
        let mut all: Vec<CompressedChunk> = Vec::new();
        let mut tags: Vec<String> = Vec::new();
        let mut prev: HashMap<String, Field> = HashMap::new();
        let mut report = SeriesReport::default();
        for (s, snap) in series.into_iter().enumerate() {
            if signature(&snap.fields) != sig {
                return Err(SzError::config(format!(
                    "snapshot {s} ('{}') does not match the series field \
                     signature (same names, dims, dtypes, in order)",
                    snap.tag
                )));
            }
            // in delta mode a Rel bound is pinned to an absolute one
            // resolved against the snapshot's original fields, so the
            // residual pass cannot re-resolve it against residual ranges
            let pinned: Option<Coordinator> = match (delta, self.conf.bound) {
                (true, ErrorBound::Rel(_)) => {
                    let mut abs = f64::INFINITY;
                    for f in &snap.fields {
                        abs = abs.min(self.conf.bound.to_abs(f)?);
                    }
                    let mut conf = self.conf.clone();
                    conf.bound = ErrorBound::Abs(abs);
                    Some(self.with_conf(conf))
                }
                _ => None,
            };
            let coord: &Coordinator = pinned.as_ref().unwrap_or(self);
            // residual inputs are built before `run` consumes the originals
            let resid_input: Option<Vec<Field>> = if delta && s > 0 {
                Some(
                    snap.fields
                        .iter()
                        .map(|f| delta::residual(f, &prev[&f.name]))
                        .collect::<Result<_>>()?,
                )
            } else {
                None
            };
            let mut direct: Vec<CompressedChunk> = Vec::new();
            let run_report = coord.run(snap.fields, |c| direct.push(c))?;
            report.snapshots.push(run_report);
            let chosen: Vec<CompressedChunk> = match resid_input {
                Some(ri) => {
                    let mut resid: Vec<CompressedChunk> = Vec::new();
                    coord.run(ri, |c| resid.push(c))?;
                    if resid.len() != direct.len() {
                        return Err(SzError::Runtime(
                            "residual pass produced a different chunking than \
                             the direct pass"
                                .into(),
                        ));
                    }
                    direct
                        .into_iter()
                        .zip(resid)
                        .map(|(d, r)| {
                            if r.field != d.field
                                || r.chunk_index != d.chunk_index
                                || r.rows != d.rows
                            {
                                return Err(SzError::Runtime(
                                    "residual chunking diverged from direct".into(),
                                ));
                            }
                            report.direct_bytes += d.stream.len() as u64;
                            let c = if r.stream.len() < d.stream.len() {
                                report.delta_chunks += 1;
                                crate::obs::SERIES_DELTA_CHUNKS.inc();
                                crate::obs::SERIES_BYTES_SAVED.add(
                                    (d.stream.len() as u64)
                                        .saturating_sub(r.stream.len() as u64),
                                );
                                CompressedChunk { snapshot: s, delta: true, ..r }
                            } else {
                                report.direct_chunks += 1;
                                crate::obs::SERIES_DIRECT_CHUNKS.inc();
                                CompressedChunk { snapshot: s, ..d }
                            };
                            report.stored_bytes += c.stream.len() as u64;
                            Ok(c)
                        })
                        .collect::<Result<_>>()?
                }
                None => direct
                    .into_iter()
                    .map(|c| {
                        report.direct_bytes += c.stream.len() as u64;
                        report.stored_bytes += c.stream.len() as u64;
                        report.direct_chunks += 1;
                        crate::obs::SERIES_DIRECT_CHUNKS.inc();
                        CompressedChunk { snapshot: s, ..c }
                    })
                    .collect(),
            };
            if delta && s + 1 < n_snaps {
                // the next snapshot deltas against what a reader would
                // reconstruct, never against the lossy-compressed original
                // (skipped for the last snapshot — nothing deltas against it)
                prev = decode_snapshot(&chosen, &prev, self.workers)?;
            }
            all.extend(chosen);
            tags.push(snap.tag);
        }
        let artifact = container::pack_series(&all, &tags)?;
        Ok((artifact, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::JobConfig;

    const EB: f64 = 1e-3;

    fn coordinator() -> Coordinator {
        let cfg = JobConfig {
            pipeline: "sz3-lr".into(),
            bound: ErrorBound::Abs(EB),
            workers: 2,
            chunk_elems: 4 * 144, // 4 rows of 12x12 per chunk
            queue_depth: 2,
            ..Default::default()
        };
        Coordinator::from_config(&cfg).unwrap()
    }

    /// A smoothly-evolving series: base field plus a slow per-step drift.
    fn smooth_series(steps: usize) -> Vec<Snapshot> {
        crate::container::fixtures::smooth_series(404, &[16, 12, 12], steps, 0.01, "rho")
    }

    #[test]
    fn delta_mode_never_beats_direct_on_bytes_and_stays_bounded() {
        let coord = coordinator();
        let series = smooth_series(4);
        let originals: Vec<Field> =
            series.iter().map(|s| s.fields[0].clone()).collect();
        let (with_delta, rep) =
            coord.run_series_to_container(series.clone(), true).unwrap();
        let (without, _) = coord.run_series_to_container(series, false).unwrap();
        assert!(rep.delta_chunks > 0, "smooth drift must pick delta: {rep}");
        assert!(rep.stored_bytes <= rep.direct_bytes);
        assert!(
            with_delta.len() < without.len(),
            "delta {} bytes must beat direct {} bytes",
            with_delta.len(),
            without.len()
        );
        // every snapshot reconstructs within the bound (delta chains do
        // not accumulate error); the 1% slack absorbs the one extra f32
        // rounding a baseline+residual reconstruction performs (~½ulp of
        // the value, orders below eb) — real accumulation would be ~2× eb
        let reader = crate::reader::ContainerReader::from_slice(&with_delta)
            .unwrap()
            .with_workers(2);
        assert_eq!(reader.snapshot_count(), 4);
        for (t, orig) in originals.iter().enumerate() {
            let out = reader.read_field_at(t, "rho").unwrap();
            for (o, d) in
                orig.values.to_f64_vec().iter().zip(out.values.to_f64_vec())
            {
                assert!((o - d).abs() <= EB * 1.01, "snapshot {t}");
            }
        }
    }

    #[test]
    fn delta_mode_pins_relative_bounds_and_rejects_pwrel() {
        // a Rel bound must resolve against the ORIGINAL data, not the
        // residual's (much smaller) range — otherwise delta chunks would
        // quietly get a looser tolerance than the user asked for
        let rel = 1e-3;
        let cfg = JobConfig {
            pipeline: "sz3-lr".into(),
            bound: ErrorBound::Rel(rel),
            workers: 2,
            chunk_elems: 4 * 144,
            queue_depth: 2,
            ..Default::default()
        };
        let coord = Coordinator::from_config(&cfg).unwrap();
        let series = smooth_series(3);
        let originals: Vec<Field> =
            series.iter().map(|s| s.fields[0].clone()).collect();
        let (artifact, _) = coord.run_series_to_container(series, true).unwrap();
        let reader =
            crate::reader::ContainerReader::from_slice(&artifact).unwrap();
        for (t, orig) in originals.iter().enumerate() {
            let (lo, hi) = orig.value_range();
            let abs = rel * (hi - lo);
            let out = reader.read_field_at(t, "rho").unwrap();
            for (o, d) in
                orig.values.to_f64_vec().iter().zip(out.values.to_f64_vec())
            {
                assert!(
                    (o - d).abs() <= abs * 1.01,
                    "snapshot {t}: rel bound must hold against the original range"
                );
            }
        }
        // pointwise-relative bounds are incompatible with additive
        // residuals and must be rejected up front in delta mode
        let cfg = JobConfig { bound: ErrorBound::PwRel(1e-2), ..cfg };
        let coord = Coordinator::from_config(&cfg).unwrap();
        let err = coord
            .run_series_to_container(smooth_series(2), true)
            .unwrap_err();
        assert!(err.to_string().contains("pointwise"), "{err}");
    }

    #[test]
    fn direct_series_snapshots_match_standalone_compression_bitwise() {
        // without delta, every snapshot's chunks are exactly what
        // run_to_container would produce for that snapshot alone
        let coord = coordinator();
        let series = smooth_series(3);
        let originals: Vec<Field> =
            series.iter().map(|s| s.fields[0].clone()).collect();
        let (artifact, rep) = coord.run_series_to_container(series, false).unwrap();
        assert_eq!(rep.delta_chunks, 0);
        let reader =
            crate::reader::ContainerReader::from_slice(&artifact).unwrap();
        for (t, orig) in originals.iter().enumerate() {
            let (standalone, _) =
                coord.run_to_container(vec![orig.clone()]).unwrap();
            let lone = crate::container::decompress_container(&standalone, 2)
                .unwrap()
                .remove(0);
            let from_series = reader.read_field_at(t, "rho").unwrap();
            assert_eq!(
                from_series.values, lone.values,
                "snapshot {t} must be bit-identical to standalone"
            );
        }
    }

    #[test]
    fn mismatched_snapshots_and_empty_series_rejected() {
        let coord = coordinator();
        assert!(coord.run_series_to_container(vec![], true).is_err());
        let mut series = smooth_series(2);
        series[1].fields[0].name = "other".into();
        let err = coord.run_series_to_container(series, true).unwrap_err();
        assert!(err.to_string().contains("signature"), "{err}");
    }

    #[test]
    fn single_snapshot_series_is_a_plain_v3_container() {
        let coord = coordinator();
        let series = smooth_series(1);
        let orig = series[0].fields[0].clone();
        let (artifact, rep) = coord.run_series_to_container(series, true).unwrap();
        assert_eq!(rep.delta_chunks, 0, "nothing to delta against");
        let out = crate::pipeline::decompress_any(&artifact).unwrap();
        assert_eq!(out.shape.dims(), orig.shape.dims());
    }
}
