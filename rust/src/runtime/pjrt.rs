//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and serves them to the L3 hot path.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Executables are shape-specialized: analysis runs in batches of
//! `manifest.batch` blocks, padded with zero blocks whose results are
//! dropped. Python never runs here — artifacts are plain HLO text.

use crate::config::Json;
use crate::error::{Result, SzError};
use crate::pipeline::analysis::{BlockAnalyzer, NativeAnalyzer, RawAnalysis};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;

fn rt_err<E: std::fmt::Display>(ctx: &str) -> impl FnOnce(E) -> SzError + '_ {
    move |e| SzError::Runtime(format!("{ctx}: {e}"))
}

/// Loaded artifact set (client + per-dimensionality executables).
pub struct PjrtEngine {
    client: xla::PjRtClient,
    /// Block batch per invocation.
    pub batch: usize,
    /// Elements per stats invocation.
    pub stats_n: usize,
    block_shapes: HashMap<usize, Vec<usize>>,
    analysis: HashMap<usize, xla::PjRtLoadedExecutable>,
    stats: Option<xla::PjRtLoadedExecutable>,
}

impl PjrtEngine {
    /// Default artifact directory (`$SZ3_ARTIFACTS` or `./artifacts`).
    pub fn default_dir() -> PathBuf {
        std::env::var_os("SZ3_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// True if an artifact manifest exists under `dir`.
    pub fn available(dir: &Path) -> bool {
        dir.join("manifest.json").is_file()
    }

    /// Load and compile every artifact listed in `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let manifest = Json::parse(&manifest_text)?;
        let batch = manifest
            .get("batch")
            .and_then(Json::as_usize)
            .ok_or_else(|| SzError::Runtime("manifest: missing batch".into()))?;
        let stats_n = manifest
            .get("stats_n")
            .and_then(Json::as_usize)
            .unwrap_or(1 << 16);
        let mut block_shapes = HashMap::new();
        if let Some(shapes) = manifest.get("block_shapes").and_then(Json::as_obj) {
            for (nd, arr) in shapes {
                let dims: Vec<usize> = arr
                    .as_arr()
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default();
                if let Ok(nd) = nd.parse::<usize>() {
                    block_shapes.insert(nd, dims);
                }
            }
        }
        let client = xla::PjRtClient::cpu().map_err(rt_err("pjrt client"))?;
        let arts = manifest
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| SzError::Runtime("manifest: missing artifacts".into()))?;
        let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(dir.join(file))
                .map_err(rt_err("hlo parse"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).map_err(rt_err("compile"))
        };
        let mut analysis = HashMap::new();
        for nd in 1..=4usize {
            if let Some(file) = arts.get(&format!("analysis_{nd}d")).and_then(Json::as_str) {
                analysis.insert(nd, compile(file)?);
            }
        }
        let stats = match arts.get("stats").and_then(Json::as_str) {
            Some(file) => Some(compile(file)?),
            None => None,
        };
        Ok(PjrtEngine { client, batch, stats_n, block_shapes, analysis, stats })
    }

    /// PJRT platform name (for logs).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Dimensionalities with a compiled analysis executable.
    pub fn analysis_dims(&self) -> Vec<usize> {
        let mut dims: Vec<usize> = self.analysis.keys().copied().collect();
        dims.sort_unstable();
        dims
    }

    /// True if `dims` matches the artifact block shape for its ndim.
    pub fn supports_block(&self, dims: &[usize]) -> bool {
        self.block_shapes.get(&dims.len()).map(|s| s.as_slice() == dims).unwrap_or(false)
    }

    /// Run batched block analysis on the PJRT executable.
    ///
    /// `blocks`: concatenated row-major blocks of shape `dims` (f64; converted
    /// to the artifact's f32). Returns one [`RawAnalysis`] per block.
    pub fn analyze(&self, blocks: &[f64], dims: &[usize]) -> Result<Vec<RawAnalysis>> {
        let nd = dims.len();
        if !self.supports_block(dims) {
            return Err(SzError::Runtime(format!(
                "no artifact for block dims {dims:?}"
            )));
        }
        let block_len: usize = dims.iter().product();
        debug_assert_eq!(blocks.len() % block_len, 0);
        let n_blocks = blocks.len() / block_len;
        let mut out = Vec::with_capacity(n_blocks);
        let mut lit_dims: Vec<i64> = Vec::with_capacity(nd + 1);
        lit_dims.push(self.batch as i64);
        lit_dims.extend(dims.iter().map(|&d| d as i64));
        let exe = self.analysis.get(&nd).ok_or_else(|| {
            SzError::Runtime(format!("no analysis executable for {nd}d"))
        })?;
        let mut start = 0usize;
        let mut buf = vec![0f32; self.batch * block_len];
        while start < n_blocks {
            let take = (n_blocks - start).min(self.batch);
            for (i, v) in blocks[start * block_len..(start + take) * block_len]
                .iter()
                .enumerate()
            {
                buf[i] = *v as f32;
            }
            buf[take * block_len..].fill(0.0); // zero-pad the tail batch
            let lit = xla::Literal::vec1(&buf)
                .reshape(&lit_dims)
                .map_err(rt_err("reshape"))?;
            let result = exe.execute::<xla::Literal>(&[lit]).map_err(rt_err("execute"))?;
            let tuple = result[0][0]
                .to_literal_sync()
                .map_err(rt_err("to_literal"))?;
            let (coeffs_l, lor_l, reg_l) = tuple.to_tuple3().map_err(rt_err("tuple"))?;
            let coeffs: Vec<f32> = coeffs_l.to_vec().map_err(rt_err("coeffs"))?;
            let lor: Vec<f32> = lor_l.to_vec().map_err(rt_err("lorenzo"))?;
            let reg: Vec<f32> = reg_l.to_vec().map_err(rt_err("regression"))?;
            for b in 0..take {
                out.push(RawAnalysis {
                    lorenzo_err: lor[b] as f64,
                    regression_err: reg[b] as f64,
                    coeffs: coeffs[b * (nd + 1)..(b + 1) * (nd + 1)]
                        .iter()
                        .map(|&c| c as f64)
                        .collect(),
                });
            }
            start += take;
        }
        Ok(out)
    }

    /// Run the stats artifact over `x` (padded/chunked to `stats_n`).
    /// Returns (min, max, sum, sumsq).
    pub fn stats(&self, x: &[f64]) -> Result<(f64, f64, f64, f64)> {
        let exe = self
            .stats
            .as_ref()
            .ok_or_else(|| SzError::Runtime("no stats artifact".into()))?;
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        let mut buf = vec![0f32; self.stats_n];
        for chunk in x.chunks(self.stats_n) {
            for (b, v) in buf.iter_mut().zip(chunk.iter()) {
                *b = *v as f32;
            }
            // pad with the first element so min/max are unaffected
            let fill = chunk.first().copied().unwrap_or(0.0) as f32;
            buf[chunk.len()..].fill(fill);
            let lit = xla::Literal::vec1(&buf);
            let result = exe.execute::<xla::Literal>(&[lit]).map_err(rt_err("execute"))?;
            let tuple = result[0][0]
                .to_literal_sync()
                .map_err(rt_err("to_literal"))?;
            let s = tuple.to_tuple1().map_err(rt_err("tuple"))?;
            let v: Vec<f32> = s.to_vec().map_err(rt_err("stats vec"))?;
            lo = lo.min(v[0] as f64);
            hi = hi.max(v[1] as f64);
            // correct the padded contribution to sum/sumsq
            let pad = (self.stats_n - chunk.len()) as f64;
            sum += v[2] as f64 - pad * fill as f64;
            sumsq += v[3] as f64 - pad * (fill as f64) * (fill as f64);
        }
        Ok((lo, hi, sum, sumsq))
    }
}

enum ServiceRequest {
    Analyze {
        blocks: Vec<f64>,
        dims: Vec<usize>,
        reply: mpsc::Sender<Result<Vec<RawAnalysis>>>,
    },
    Stats {
        x: Vec<f64>,
        reply: mpsc::Sender<Result<(f64, f64, f64, f64)>>,
    },
}

/// Thread-hosted PJRT engine. The `xla` crate's client is `Rc`-based (not
/// Send), so the coordinator's leader owns it on a dedicated service thread
/// and workers talk to it over channels — the vLLM-style "single engine,
/// many request threads" topology.
#[derive(Clone)]
pub struct PjrtService {
    tx: mpsc::Sender<ServiceRequest>,
    /// PJRT platform name.
    pub platform: String,
    /// Dimensionalities with compiled analysis artifacts.
    pub dims: Vec<usize>,
    block_shapes: HashMap<usize, Vec<usize>>,
}

// The Sender endpoint is Send but not Sync; wrap sends in a Mutex-free
// clone-per-caller pattern: each caller clones the service (cheap).
impl PjrtService {
    /// Spawn the service thread, loading artifacts from `dir`.
    pub fn start(dir: &Path) -> Result<PjrtService> {
        let dir = dir.to_path_buf();
        let (tx, rx) = mpsc::channel::<ServiceRequest>();
        let (ready_tx, ready_rx) = mpsc::channel();
        std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || {
                let engine = match PjrtEngine::load(&dir) {
                    Ok(e) => {
                        let meta = (
                            e.platform(),
                            e.analysis_dims(),
                            e.block_shapes.clone(),
                        );
                        // audit:allow(swallow, reason = "a dropped ready receiver means the caller gave up on startup; nothing to tell it")
                        let _ = ready_tx.send(Ok(meta));
                        e
                    }
                    Err(err) => {
                        // audit:allow(swallow, reason = "a dropped ready receiver means the caller gave up on startup; nothing to tell it")
                        let _ = ready_tx.send(Err(err));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        ServiceRequest::Analyze { blocks, dims, reply } => {
                            // audit:allow(swallow, reason = "send fails only when the requester hung up; the result has no other consumer")
                            let _ = reply.send(engine.analyze(&blocks, &dims));
                        }
                        ServiceRequest::Stats { x, reply } => {
                            // audit:allow(swallow, reason = "send fails only when the requester hung up; the result has no other consumer")
                            let _ = reply.send(engine.stats(&x));
                        }
                    }
                }
            })
            .map_err(|e| SzError::Runtime(format!("spawn pjrt service: {e}")))?;
        let (platform, dims, block_shapes) = ready_rx
            .recv()
            .map_err(|_| SzError::Runtime("pjrt service died during load".into()))??;
        Ok(PjrtService { tx, platform, dims, block_shapes })
    }

    /// True if `dims` matches an artifact block shape.
    pub fn supports_block(&self, dims: &[usize]) -> bool {
        self.block_shapes.get(&dims.len()).map(|s| s.as_slice() == dims).unwrap_or(false)
    }

    /// Remote batched analysis.
    pub fn analyze(&self, blocks: &[f64], dims: &[usize]) -> Result<Vec<RawAnalysis>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(ServiceRequest::Analyze {
                blocks: blocks.to_vec(),
                dims: dims.to_vec(),
                reply,
            })
            .map_err(|_| SzError::Runtime("pjrt service gone".into()))?;
        rx.recv().map_err(|_| SzError::Runtime("pjrt service dropped reply".into()))?
    }

    /// Remote stats: (min, max, sum, sumsq).
    pub fn stats(&self, x: &[f64]) -> Result<(f64, f64, f64, f64)> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(ServiceRequest::Stats { x: x.to_vec(), reply })
            .map_err(|_| SzError::Runtime("pjrt service gone".into()))?;
        rx.recv().map_err(|_| SzError::Runtime("pjrt service dropped reply".into()))?
    }
}

/// [`BlockAnalyzer`] backed by the PJRT service, falling back to the native
/// analyzer for block shapes without a compiled artifact.
pub struct PjrtAnalyzer {
    service: std::sync::Mutex<PjrtService>,
    fallback: NativeAnalyzer,
}

impl PjrtAnalyzer {
    /// Wrap a service handle.
    pub fn new(service: PjrtService) -> Self {
        PjrtAnalyzer { service: std::sync::Mutex::new(service), fallback: NativeAnalyzer }
    }
}

impl BlockAnalyzer for PjrtAnalyzer {
    fn analyze_batch(&self, blocks: &[f64], dims: &[usize]) -> Result<Vec<RawAnalysis>> {
        let service = self.service.lock().unwrap();
        if service.supports_block(dims) {
            service.analyze(blocks, dims)
        } else {
            self.fallback.analyze_batch(blocks, dims)
        }
    }

    fn backend(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Pcg32};

    fn engine() -> Option<PjrtEngine> {
        let dir = PjrtEngine::default_dir();
        if !PjrtEngine::available(&dir) {
            eprintln!("skipping: no artifacts at {dir:?} (run `make artifacts`)");
            return None;
        }
        Some(PjrtEngine::load(&dir).expect("engine load"))
    }

    #[test]
    fn pjrt_analysis_matches_native() {
        let Some(engine) = engine() else { return };
        let mut rng = Pcg32::seeded(71);
        for dims in [vec![128usize], vec![12usize, 12], vec![6usize, 6, 6]] {
            let block_len: usize = dims.iter().product();
            let nb = 37; // deliberately not a multiple of the batch
            let blocks: Vec<f64> = (0..nb * block_len)
                .map(|_| rng.uniform(-50.0, 50.0))
                .collect();
            let pjrt = engine.analyze(&blocks, &dims).unwrap();
            let native = NativeAnalyzer.analyze_batch(&blocks, &dims).unwrap();
            assert_eq!(pjrt.len(), native.len());
            for (p, n) in pjrt.iter().zip(&native) {
                // artifact computes in f32; native in f64
                assert!(
                    (p.lorenzo_err - n.lorenzo_err).abs() <= 1e-3 * n.lorenzo_err.abs() + 1e-4,
                    "lorenzo {} vs {}",
                    p.lorenzo_err,
                    n.lorenzo_err
                );
                assert!(
                    (p.regression_err - n.regression_err).abs()
                        <= 1e-3 * n.regression_err.abs() + 1e-4
                );
                for (a, b) in p.coeffs.iter().zip(&n.coeffs) {
                    assert!((a - b).abs() <= 1e-3 * b.abs() + 1e-3, "{a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn pjrt_stats_match() {
        let Some(engine) = engine() else { return };
        let mut rng = Pcg32::seeded(72);
        let n = engine.stats_n + 123; // force a padded second chunk
        let x: Vec<f64> = (0..n).map(|_| rng.uniform(-10.0, 10.0)).collect();
        let (lo, hi, sum, sumsq) = engine.stats(&x).unwrap();
        let elo = x.iter().cloned().fold(f64::INFINITY, f64::min);
        let ehi = x.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let esum: f64 = x.iter().sum();
        let esumsq: f64 = x.iter().map(|v| v * v).sum();
        assert!((lo - elo).abs() < 1e-4);
        assert!((hi - ehi).abs() < 1e-4);
        assert!((sum - esum).abs() < esum.abs().max(1.0) * 1e-3 + 0.5);
        assert!((sumsq - esumsq).abs() < esumsq * 1e-3);
    }

    #[test]
    fn block_compressor_with_pjrt_analyzer_roundtrips() {
        let dir = PjrtEngine::default_dir();
        if !PjrtEngine::available(&dir) {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        use crate::data::Field;
        use crate::pipeline::{BlockCompressor, CompressConf, Compressor, ErrorBound};
        let service = PjrtService::start(&dir).expect("service");
        let mut rng = Pcg32::seeded(73);
        let dims = [18usize, 18, 18];
        let data = prop::smooth_field(&mut rng, &dims);
        let f = Field::f32("pjrt", &dims, data).unwrap();
        let conf = CompressConf::new(ErrorBound::Abs(1e-3));
        let c = BlockCompressor::sz3_lr()
            .with_analyzer(std::sync::Arc::new(PjrtAnalyzer::new(service)));
        let stream = c.compress(&f, &conf).unwrap();
        let out = c.decompress(&stream).unwrap();
        for (o, d) in f.values.to_f64_vec().iter().zip(out.values.to_f64_vec().iter()) {
            assert!((o - d).abs() <= 1e-3 * (1.0 + 1e-12));
        }
    }
}
