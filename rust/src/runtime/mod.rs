//! PJRT runtime facade: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and serves them to the L3 hot path.
//!
//! The real implementation ([`pjrt`]) needs the `xla` crate, which the
//! offline build image does not carry, so it is gated behind the `pjrt`
//! cargo feature. The default build exposes the same public surface via
//! [`stub`]: `PjrtEngine::available` reports `false`, `start`/`load`
//! return [`crate::error::SzError::Runtime`], and [`PjrtAnalyzer`] falls
//! back to the native analyzer — every caller that probes availability
//! before starting the service works unchanged.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{PjrtAnalyzer, PjrtEngine, PjrtService};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{PjrtAnalyzer, PjrtEngine, PjrtService};
