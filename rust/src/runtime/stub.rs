//! Dependency-free stand-in for the PJRT runtime (default build).
//!
//! Mirrors the public surface of the feature-gated [`super::pjrt`] module
//! so binaries, examples and benches compile without the `xla` crate.
//! `available()` is always `false`, the constructors return
//! [`SzError::Runtime`], and [`PjrtAnalyzer`] delegates to the native
//! analyzer — callers that probe availability first never hit an error.

use crate::error::{Result, SzError};
use crate::pipeline::analysis::{BlockAnalyzer, NativeAnalyzer, RawAnalysis};
use std::path::{Path, PathBuf};

fn unavailable(ctx: &str) -> SzError {
    SzError::Runtime(format!(
        "{ctx}: built without the 'pjrt' feature (xla crate unavailable offline)"
    ))
}

/// Stub artifact engine: reports artifacts as unavailable.
pub struct PjrtEngine {
    /// Block batch per invocation (mirrors the real engine's field).
    pub batch: usize,
    /// Elements per stats invocation.
    pub stats_n: usize,
}

impl PjrtEngine {
    /// Default artifact directory (`$SZ3_ARTIFACTS` or `./artifacts`).
    pub fn default_dir() -> PathBuf {
        std::env::var_os("SZ3_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Always `false` in the stub: the PJRT backend cannot run.
    pub fn available(_dir: &Path) -> bool {
        false
    }

    /// Always an error in the stub.
    pub fn load(_dir: &Path) -> Result<Self> {
        Err(unavailable("PjrtEngine::load"))
    }

    /// PJRT platform name (for logs).
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Dimensionalities with a compiled analysis executable (none).
    pub fn analysis_dims(&self) -> Vec<usize> {
        Vec::new()
    }

    /// True if `dims` matches an artifact block shape (never, in the stub).
    pub fn supports_block(&self, _dims: &[usize]) -> bool {
        false
    }

    /// Batched analysis — unreachable in practice (`load` always fails).
    pub fn analyze(&self, _blocks: &[f64], _dims: &[usize]) -> Result<Vec<RawAnalysis>> {
        Err(unavailable("PjrtEngine::analyze"))
    }

    /// Stats artifact — unreachable in practice (`load` always fails).
    pub fn stats(&self, _x: &[f64]) -> Result<(f64, f64, f64, f64)> {
        Err(unavailable("PjrtEngine::stats"))
    }
}

/// Stub service handle. `start` always fails; the fields exist so callers
/// that log `service.platform` / `service.dims` after a successful start
/// compile unchanged.
#[derive(Clone)]
pub struct PjrtService {
    /// PJRT platform name.
    pub platform: String,
    /// Dimensionalities with compiled analysis artifacts.
    pub dims: Vec<usize>,
}

impl PjrtService {
    /// Always an error in the stub.
    pub fn start(_dir: &Path) -> Result<PjrtService> {
        Err(unavailable("PjrtService::start"))
    }

    /// True if `dims` matches an artifact block shape (never, in the stub).
    pub fn supports_block(&self, _dims: &[usize]) -> bool {
        false
    }

    /// Remote batched analysis — falls back to the native analyzer so any
    /// handle that somehow exists still produces correct results.
    pub fn analyze(&self, blocks: &[f64], dims: &[usize]) -> Result<Vec<RawAnalysis>> {
        NativeAnalyzer.analyze_batch(blocks, dims)
    }

    /// Remote stats — computed natively.
    pub fn stats(&self, x: &[f64]) -> Result<(f64, f64, f64, f64)> {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for &v in x {
            lo = lo.min(v);
            hi = hi.max(v);
            sum += v;
            sumsq += v * v;
        }
        Ok((lo, hi, sum, sumsq))
    }
}

/// [`BlockAnalyzer`] with the PJRT surface; delegates to the native
/// analyzer in the stub build.
pub struct PjrtAnalyzer {
    fallback: NativeAnalyzer,
}

impl PjrtAnalyzer {
    /// Wrap a service handle (ignored in the stub).
    pub fn new(_service: PjrtService) -> Self {
        PjrtAnalyzer { fallback: NativeAnalyzer }
    }
}

impl BlockAnalyzer for PjrtAnalyzer {
    fn analyze_batch(&self, blocks: &[f64], dims: &[usize]) -> Result<Vec<RawAnalysis>> {
        self.fallback.analyze_batch(blocks, dims)
    }

    fn backend(&self) -> &'static str {
        "pjrt-stub"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(!PjrtEngine::available(&PjrtEngine::default_dir()));
        assert!(PjrtEngine::load(Path::new("artifacts")).is_err());
        assert!(PjrtService::start(Path::new("artifacts")).is_err());
    }

    #[test]
    fn stub_analyzer_matches_native() {
        let blocks: Vec<f64> = (0..128).map(|i| (i as f64 * 0.1).sin()).collect();
        let svc = PjrtService { platform: "x".into(), dims: vec![] };
        let a = PjrtAnalyzer::new(svc);
        let got = a.analyze_batch(&blocks, &[128]).unwrap();
        let want = NativeAnalyzer.analyze_batch(&blocks, &[128]).unwrap();
        assert_eq!(got.len(), want.len());
        assert_eq!(got[0].coeffs, want[0].coeffs);
    }
}
